"""Jamba-v0.1-52B — hybrid Mamba/attention 7:1 interleave with MoE (16e top-2)
on alternate layers. [arXiv:2403.19887]

Super-block (8 layers): positions 0–6 Mamba, position 7 attention; MoE FFN on
odd positions (1,3,5,7), dense FFN elsewhere — the paper's 1:7 attn ratio and
every-other-layer MoE.
"""
from repro.models.config import ArchConfig, AttnConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    vocab_size=65536,
    d_ff=14336,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                    rope_theta=10000.0, sliding_window=8192, use_rope=False),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336,
                  norm_topk_prob=False),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=64),
    superblock=("mamba", "mamba", "mamba", "mamba",
                "mamba", "mamba", "mamba", "attn"),
    moe_positions=(1, 3, 5, 7),
    norm_eps=1e-6,
    max_seq_len=524288,  # SSM+SWA ⇒ long-context decode is native
    source="arXiv:2403.19887 (Jamba). Note: Jamba uses no positional "
           "encoding on its attention layers (use_rope=False); we add an "
           "8192 sliding window for the long_500k shape.",
)
