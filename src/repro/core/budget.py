"""HBM budget model + admission control (paper §3.3).

``BudgetModel`` performs the one-shot budget initialization: given the device
envelope and the fixed allocations (non-expert params, KV cache, activation
headroom), it derives the per-layer hi-precision capacity ``n_hi,l``.
``BudgetTracker`` is the runtime admission gate: every promotion must
``try_reserve`` its bytes before it may enter the transition pipeline, so the
hi pool can never overflow — budget feasibility by construction.
"""
from __future__ import annotations

import dataclasses
import threading


class BudgetExceeded(Exception):
    pass


class BudgetTracker:
    """Thread-safe byte reservation ledger for the hi pool."""

    def __init__(self, cap_bytes: int):
        if cap_bytes < 0:
            raise ValueError("cap must be >= 0")
        self.cap = int(cap_bytes)
        self._used = 0
        self._lock = threading.Lock()

    @property
    def used(self) -> int:
        return self._used

    @property
    def free(self) -> int:
        return self.cap - self._used

    def try_reserve(self, nbytes: int) -> bool:
        with self._lock:
            if self._used + nbytes > self.cap:
                return False
            self._used += nbytes
            return True

    def release(self, nbytes: int) -> None:
        with self._lock:
            self._used -= nbytes
            if self._used < 0:
                raise BudgetExceeded("released more than reserved")


@dataclasses.dataclass(frozen=True)
class BudgetPlan:
    m_total: int          # usable device bytes
    m_fixed: int          # non-expert params + KV cache + activations
    m_lo: int             # always-resident lo-pool bytes
    m_hi_cap: int         # hi-pool envelope
    n_hi_per_layer: int   # derived per-layer hi capacity (experts)

    def check(self):
        if self.m_fixed + self.m_lo + self.m_hi_cap > self.m_total:
            raise BudgetExceeded(
                f"infeasible: fixed {self.m_fixed} + lo {self.m_lo} + hi "
                f"{self.m_hi_cap} > total {self.m_total}")


def plan_budget(m_total: int, m_fixed: int, lo_bytes_total: int,
                hi_bytes_per_expert_layer: int, n_layers: int,
                num_experts: int, align: int = 1) -> BudgetPlan:
    """Budget initialization: everything left after fixed + lo goes to the hi
    pool, expressed as a per-layer expert count (the paper's n_hi,l).

    ``align``: round n_hi down to a multiple (e.g. the model-parallel degree,
    so each shard owns an integer number of hi slots).
    """
    if m_fixed + lo_bytes_total > m_total:
        raise BudgetExceeded(
            f"lo tier alone does not fit: fixed {m_fixed} + lo "
            f"{lo_bytes_total} > total {m_total}")
    remaining = m_total - m_fixed - lo_bytes_total
    n_hi = remaining // (hi_bytes_per_expert_layer * n_layers)
    n_hi = min(int(n_hi), num_experts)
    if align > 1:
        n_hi = n_hi // align * align
    plan = BudgetPlan(
        m_total=m_total, m_fixed=m_fixed, m_lo=lo_bytes_total,
        m_hi_cap=n_hi * hi_bytes_per_expert_layer * n_layers,
        n_hi_per_layer=int(n_hi))
    plan.check()
    return plan
