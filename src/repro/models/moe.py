"""Mixture-of-Experts layer with capacity-based dispatch and a pluggable
expert bank (dense bf16 for training, DynaExq mixed-precision for serving).

Two dispatch layouts, selected per call (``dispatch=``, default from
``kernels.ops.moe_dispatch_default``):

* **padded** (reference): sort the token→expert assignments, scatter into a
  fixed-capacity (E, C, d) buffer, run the batched expert GEMM over ALL E
  experts, combine with the router gates. Simple, shardable, and the
  bit-parity oracle — but at decode batch sizes most of (E, C) is padding,
  so every step pays the weight-read bytes of every expert.
* **ragged** (serving decode hot path): sort + compact into a (Tt·bm, d)
  buffer whose per-expert segments are aligned to the row tile ``bm``, and
  hand per-tile expert/slot maps to ONE fused mixed-precision kernel
  (``kernels.ops.ragged_quant_ffn_op``). Only experts that received tokens
  this step stream their weights, and each streams its *resident tier only*
  (hi bf16 slot or packed lo codes dequantized in VMEM) — the bytes/token
  the lo tier was built to save are actually saved.

Execution regimes:

* Single device (tests, CPU serving, benchmarks): both layouts available.
* Distributed (via ``repro.launch.dist``): inside ``shard_map``, two
  regimes. The padded body — each data shard routes its own tokens, each
  model shard computes only its local E/n experts
  (``e_offset``/``e_local``), partial token outputs reduce with a single
  psum over the model axis. And the first-class expert-parallel serving
  path (``DistContext.tokens_ep_sharded`` + ragged dispatch): tokens shard
  over data AND model axes, each shard compacts its kept assignments per
  destination expert-shard and exchanges a statically-bounded bm-aligned
  payload with one ``lax.all_to_all`` each way, so per-MoE-layer
  interconnect bytes scale with the payload budget ``ep_payload_rows``
  instead of the full activation psum. Both are formulations GSPMD cannot
  derive on its own (data-dependent sort/scatter) and the reason dispatch
  is explicit here.

Per-(layer, expert) selection counts — the hotness signal the DynaExq
scheduler consumes (paper §3.5) — fall out of dispatch for free, as do the
dispatch-efficiency gauges (``MoEAux.active_experts`` /
``dispatch_pad_ratio``) the serving stats surface.
"""
from __future__ import annotations

import os
from typing import Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.ver import ExpertBankQ
from repro.kernels import ops as kops
from repro.models.config import MoEConfig
from repro.models.layers import _init
from repro.models.mlp import init_swiglu, swiglu
from repro.quant.qtensor import dequantize

#: Row-tile height of the ragged layout: each active expert's token segment
#: is padded up to a multiple of this (the ONLY padding the ragged path
#: pays). 8 matches the f32 sublane on TPU and keeps CPU tests cheap.
RAGGED_BM = int(os.environ.get("REPRO_MOE_RAGGED_BM", "8"))


class MoEAux(NamedTuple):
    counts: jax.Array     # (E,) int32 — router selections this call
    aux_loss: jax.Array   # scalar f32 — load-balance loss
    dropped: jax.Array    # scalar f32 — fraction of assignments dropped
    # (R, E) int32 — selections segment-summed per row (request/slot), only
    # when ``moe_apply(..., n_rows=R)`` asks for it. Rows whose tokens are
    # all masked by ``token_valid`` contribute zeros, which is what lets the
    # serving engine keep vacant continuous-batching slots and prompt
    # padding out of the hotness signal.
    row_counts: Optional[jax.Array] = None
    # Dispatch-efficiency telemetry (None on the sharded path): number of
    # experts that received ≥1 assignment this call, and the fraction of
    # GEMM rows that were padding — (E·C − kept)/(E·C) for the padded
    # layout, (Tt·bm − routed)/(Tt·bm) for the ragged layout.
    active_experts: Optional[jax.Array] = None
    dispatch_pad_ratio: Optional[jax.Array] = None


def init_moe(key, d_model: int, cfg: MoEConfig) -> Dict:
    ks = jax.random.split(key, 5)
    E, f = cfg.num_experts, cfg.d_ff_expert
    p = {
        "router": _init(ks[0], (d_model, E), scale=d_model ** -0.5,
                        dtype=jnp.float32),
        "experts": {
            "w_gate": _init(ks[1], (E, d_model, f)),
            "w_up": _init(ks[2], (E, d_model, f)),
            "w_down": _init(ks[3], (E, f, d_model)),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = init_swiglu(ks[4], d_model,
                                  cfg.d_ff_shared * cfg.n_shared_experts)
    return p


def effective_expert_weights(bank: Union[Dict, ExpertBankQ],
                             e_offset: int = 0,
                             e_local: Optional[int] = None,
                             slot_lo: int = 0,
                             n_slot_local: Optional[int] = None
                             ) -> Dict[str, jax.Array]:
    """Materialize per-expert weights (E_local, K, N) in bf16.

    Dense bank: identity. DynaExq bank: dequantize the lo tier then scatter
    the published hi versions over their owners — experts whose stable handle
    points at a hi slot compute with hi weights, the rest with lo. Under
    expert parallelism the bank leaves arrive pre-sliced to the local expert
    (and hi-slot) ranges; ``slot_owner`` stays global, so owners are shifted
    by ``e_offset`` and out-of-range owners drop out of the scatter.
    (The Pallas serving kernel performs the same selection in-kernel without
    materializing; this jnp path is the oracle + dry-run path.)
    """
    if isinstance(bank, ExpertBankQ):
        owner = bank.slot_owner            # (n_hi,) global, after scan slicing
        E = bank.slot_map.shape[-1]
        e_local = e_local if e_local is not None else E
        if n_slot_local is not None:
            owner = jax.lax.dynamic_slice_in_dim(owner, slot_lo, n_slot_local)
        owner = owner - e_offset
        safe_owner = jnp.where((owner >= 0) & (owner < e_local),
                               owner, e_local)          # OOB ⇒ dropped
        out = {}
        for name, qt in bank.lo.items():
            w = dequantize(qt)             # (E_local, K, N)
            out[name] = w.at[safe_owner].set(bank.hi[name], mode="drop")
        return out
    return bank


def route(router_w: jax.Array, x: jax.Array, cfg: MoEConfig):
    """x: (T, d) → gates (T, k), idx (T, k), probs (T, E)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk_prob:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, idx, probs


def _sort_routing(idx: jax.Array, e_local: int):
    """Shared dispatch prologue — the ONE place the assignment order, the
    per-expert counts and positions, and therefore the padded↔ragged
    bit-identity contract are defined. idx: (T, k) local expert ids with
    ``e_local`` as the out-of-range sentinel. Returns ``(order, sorted_eid,
    counts (e_local,), pos_in_e, tok)`` over the stable sort-by-expert of
    the flattened assignments."""
    k = idx.shape[1]
    fidx = idx.reshape(-1)                                   # (T*k,)
    order = jnp.argsort(fidx, stable=True)
    sorted_eid = fidx[order]
    counts_all = jnp.bincount(fidx, length=e_local + 1)
    counts = counts_all[:e_local]
    starts = jnp.cumsum(counts_all) - counts_all
    pos_in_e = jnp.arange(fidx.shape[0], dtype=jnp.int32) - \
        starts[sorted_eid]
    tok = order // k                                         # source token
    return order, sorted_eid, counts, pos_in_e, tok


def _keep_mask(sorted_eid: jax.Array, pos_in_e: jax.Array, tok: jax.Array,
               e_local: int, capacity: int, row_capacity: Optional[int],
               n_rows: Optional[int], n_tokens: int) -> jax.Array:
    """The ONE drop rule both layouts share: global per-expert capacity, or
    the per-row normalization when ``row_capacity`` is set."""
    if row_capacity is None:
        return (pos_in_e < capacity) & (sorted_eid < e_local)
    return _row_capacity_keep(sorted_eid, tok, e_local, n_rows, n_tokens,
                              row_capacity) & (sorted_eid < e_local)


def _row_capacity_keep(sorted_eid: jax.Array, tok: jax.Array, e_local: int,
                       n_rows: int, n_tokens: int,
                       row_capacity: int) -> jax.Array:
    """Per-row drop rule: an assignment survives iff its rank among ITS OWN
    row's assignments to the same expert is < ``row_capacity``. Whether a
    token's assignment drops then depends only on that row's routing —
    never on which other rows share the compute batch (the batch-shape
    independence prefix sharing and spec-verify token-identity need in drop
    regimes). Assumes ``sorted_eid``/``tok`` come from the stable
    sort-by-expert (same-(expert, row) entries are contiguous and in token
    order)."""
    tpr = n_tokens // n_rows
    rid = tok // tpr
    key = jnp.where(sorted_eid < e_local, sorted_eid * n_rows + rid,
                    e_local * n_rows)
    cnt = jnp.zeros((e_local * n_rows + 1,), jnp.int32).at[key].add(1)
    kstart = jnp.cumsum(cnt) - cnt
    pos_re = jnp.arange(key.shape[0], dtype=jnp.int32) - kstart[key]
    return pos_re < row_capacity


def dispatch_compute(bank, x: jax.Array, idx: jax.Array, gates: jax.Array,
                     e_local: int, capacity: int, e_offset: int = 0,
                     n_slot_local: Optional[int] = None, slot_lo: int = 0,
                     ff_axis=None, row_capacity: Optional[int] = None,
                     n_rows: Optional[int] = None, gemm: Optional[str] = None):
    """Padded sort-scatter dispatch + batched expert GEMM + gated combine.

    x: (T, d); idx: (T, k) LOCAL expert ids with ``e_local`` as the
    out-of-range sentinel; gates: (T, k) with zeros on sentinel entries.
    ``row_capacity`` (with ``n_rows``) switches the drop rule from the
    global per-expert capacity to the per-row rule (see
    ``_row_capacity_keep``); ``capacity`` must then be the physical bound
    the caller derived (``n_rows · row_capacity`` makes overflow
    impossible). Returns (y (T, d), counts (e_local,), dropped scalar).
    """
    T, d = x.shape
    order, sorted_eid, counts, pos_in_e, tok = _sort_routing(idx, e_local)
    valid = _keep_mask(sorted_eid, pos_in_e, tok, e_local, capacity,
                       row_capacity, n_rows, T)
    if row_capacity is None:
        scat_pos = pos_in_e
    else:
        # Scatter by rank among KEPT assignments of the expert so the
        # physical buffer only ever holds survivors.
        kept_i = valid.astype(jnp.int32)
        inc = jnp.cumsum(kept_i)
        kept_e = jnp.zeros((e_local + 1,), jnp.int32) \
            .at[sorted_eid].add(kept_i)
        kstart = jnp.cumsum(kept_e) - kept_e
        scat_pos = jnp.where(valid, inc - 1 - kstart[sorted_eid], capacity)

    xg = jnp.zeros((e_local, capacity, d), x.dtype)
    xg = xg.at[sorted_eid, scat_pos].set(x[tok], mode="drop")

    if isinstance(bank, ExpertBankQ):
        yg = _quant_expert_ffn(bank, xg, e_offset=e_offset, e_local=e_local,
                               slot_lo=slot_lo, n_slot_local=n_slot_local,
                               ff_axis=ff_axis, gemm=gemm)
    else:
        w = bank
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, w["w_gate"])
                        .astype(jnp.float32)).astype(x.dtype)
        h = h * jnp.einsum("ecd,edf->ecf", xg, w["w_up"])
        yg = jnp.einsum("ecf,efd->ecd", h, w["w_down"])

    pos_safe = jnp.minimum(scat_pos, capacity - 1)
    eid_safe = jnp.minimum(sorted_eid, e_local - 1)
    y_sorted = yg[eid_safe, pos_safe]
    gate_sorted = gates.reshape(-1)[order].astype(x.dtype)
    contrib = jnp.where(valid[:, None], y_sorted * gate_sorted[:, None], 0)
    # yg's output-feature dim may be data-sliced under 2-D expert sharding.
    y = jnp.zeros((T, yg.shape[-1]), x.dtype).at[tok].add(contrib)

    routed = jnp.sum(jnp.where(sorted_eid < e_local, 1.0, 0.0))
    kept = jnp.sum(jnp.where(valid, 1.0, 0.0))
    dropped = 1.0 - kept / jnp.maximum(routed, 1.0)
    return y, counts.astype(jnp.int32), dropped


def _quant_expert_ffn(bank: ExpertBankQ, xg: jax.Array, e_offset=0,
                      e_local: Optional[int] = None, slot_lo: int = 0,
                      n_slot_local: Optional[int] = None,
                      ff_axis=None, gemm: Optional[str] = None) -> jax.Array:
    """SwiGLU expert FFN on the lo tier (group-blocked quantized GEMMs via
    the ``kernels.ops.grouped_lo_matmul`` dispatcher — jnp expression or
    Pallas kernel, one math) with the published hi-precision experts
    overlaid: hi slots compute in bf16 and their outputs replace the lo
    outputs of the experts they own — numerically identical to swapping the
    weights, without materializing per-expert dense weights."""
    E_, C, d = xg.shape
    lo = bank.lo
    g1 = kops.grouped_lo_matmul(xg, lo["w_gate"].packed, lo["w_gate"].scales,
                                lo["w_gate"].bits, lo["w_gate"].group_size,
                                backend=gemm)
    up = kops.grouped_lo_matmul(xg, lo["w_up"].packed, lo["w_up"].scales,
                                lo["w_up"].bits, lo["w_up"].group_size,
                                backend=gemm)
    h = (jax.nn.silu(g1.astype(jnp.float32)).astype(xg.dtype) * up)
    if ff_axis is not None:
        # 2-D expert sharding for token-replicated decode (batch-1 long
        # context): gate/up are FF-sliced over the otherwise-idle data axis,
        # so each rank dequantized/read only F/|data| of every expert. The
        # activations are tiny at decode — gathering h costs ~100 KB.
        h = jax.lax.all_gather(h, ff_axis, axis=2, tiled=True)
    y = kops.grouped_lo_matmul(h, lo["w_down"].packed, lo["w_down"].scales,
                               lo["w_down"].bits, lo["w_down"].group_size,
                               backend=gemm)

    owner = bank.slot_owner
    if n_slot_local is not None:
        owner = jax.lax.dynamic_slice_in_dim(owner, slot_lo, n_slot_local)
    hi = bank.hi
    n_slots = owner.shape[0]
    if n_slots == 0:
        return y
    owner_l = owner - e_offset
    valid = (owner_l >= 0) & (owner_l < E_)
    safe = jnp.where(valid, owner_l, 0)
    xh = xg[safe]                                     # (n_hi, C, d)
    hh = jax.nn.silu(jnp.einsum("scd,sdf->scf", xh, hi["w_gate"])
                     .astype(jnp.float32)).astype(xg.dtype)
    hh = hh * jnp.einsum("scd,sdf->scf", xh, hi["w_up"])
    if ff_axis is not None:
        hh = jax.lax.all_gather(hh, ff_axis, axis=2, tiled=True)
    yh = jnp.einsum("scf,sfd->scd", hh, hi["w_down"])
    sentinel = jnp.where(valid, owner_l, E_)          # OOB ⇒ dropped
    return y.at[sentinel].set(yh, mode="drop")


def ragged_tile_map(counts: jax.Array, bm: int, n_assign: int):
    """bm-aligned ragged layout over per-expert assignment ``counts``
    ((E,) int32; ``n_assign`` = static total assignment budget T·k).

    Returns ``(astart (E,), tile_eid (Tt,), n_tiles scalar)``: expert e's
    segment starts at compact row ``astart[e]``; row tile t computes with
    expert ``tile_eid[t]``. Experts with zero tokens never appear in the
    live prefix ``tile_eid[:n_tiles]`` — their weights are never streamed.
    Σ ceil(c_e/bm) tiles ≤ n_assign//bm + #active, so the static tile
    budget Tt covers every routing; tail tiles (t ≥ n_tiles) repeat the
    last active expert — no fresh weight DMA, and their garbage rows are
    never gathered back."""
    e_local = counts.shape[0]
    aligned = ((counts + bm - 1) // bm) * bm
    astart = jnp.cumsum(aligned) - aligned
    ntile = aligned // bm
    cum_t = jnp.cumsum(ntile)
    n_tiles = cum_t[-1]
    Tt = n_assign // bm + min(e_local, n_assign) + 1
    t_range = jnp.arange(Tt, dtype=jnp.int32)
    tile_eid = jnp.searchsorted(cum_t, t_range, side="right") \
        .astype(jnp.int32)
    e_last = jnp.maximum(
        jnp.max(jnp.where(counts > 0, jnp.arange(e_local), -1)), 0)
    tile_eid = jnp.clip(jnp.where(t_range < n_tiles, tile_eid, e_last),
                        0, e_local - 1)
    return astart, tile_eid, n_tiles


def _dispatch_ragged(bank: Union[Dict, ExpertBankQ], x: jax.Array,
                     idx: jax.Array, gates: jax.Array, e_local: int,
                     capacity: int, row_capacity: Optional[int] = None,
                     n_rows: Optional[int] = None,
                     gemm: Optional[str] = None):
    """Padding-free ragged dispatch + ONE fused mixed-precision kernel.

    Same routing contract as ``dispatch_compute`` (idx sorted stably by
    expert, identical drop rule, identical gate-weighted combine — the two
    layouts are bit-identical per token on a given backend), but tokens
    compact into a (Tt·bm, d) buffer whose per-expert segments are aligned
    to the row tile ``RAGGED_BM`` instead of scattering into (E, C, d).
    The tile→expert map visits only experts that received tokens this
    step; per tile the kernel streams the expert's resident tier only (hi
    slot derived from ``slot_owner`` — the same stable handles the padded
    overlay scatters through, so an all-lo draft bank stays all-lo here
    too). A dense dict bank (fp16/offload serving, which has no quantized
    tier) takes the same layout through ``ragged_dense_ffn_op``: inactive
    experts still skip their weight reads. Dropped-by-capacity assignments
    still occupy compact rows (the layout depends only on routing) but are
    zeroed at combine, exactly like the padded path never computing them.

    Returns (y (T, D), counts (E,), dropped, pad_ratio)."""
    T, d = x.shape
    Tk = T * idx.shape[1]
    bm = RAGGED_BM
    order, sorted_eid, counts, pos_in_e, tok = _sort_routing(idx, e_local)
    kept = _keep_mask(sorted_eid, pos_in_e, tok, e_local, capacity,
                      row_capacity, n_rows, T)
    astart, tile_eid, n_tiles = ragged_tile_map(counts, bm, Tk)
    R = tile_eid.shape[0] * bm
    safe_e = jnp.minimum(sorted_eid, e_local - 1)
    rowpos = jnp.where(sorted_eid < e_local,
                       astart[safe_e] + pos_in_e, R)        # sentinel → drop
    xs = jnp.zeros((R, d), x.dtype).at[rowpos].set(x[tok], mode="drop")

    if isinstance(bank, ExpertBankQ):
        # Stable handles: expert → hi slot derived from slot_owner (NOT
        # slot_map), matching the padded overlay's semantics — a draft bank
        # that disowns every slot is all-lo under both layouts.
        owner = bank.slot_owner                              # (n_hi,)
        n_hi = owner.shape[0]
        if n_hi > 0:
            eff_map = jnp.full((e_local + 1,), -1, jnp.int32).at[
                jnp.where(owner >= 0, owner, e_local)].set(
                jnp.arange(n_hi, dtype=jnp.int32), mode="drop")[:e_local]
            tile_slot = eff_map[tile_eid]
        else:
            tile_slot = jnp.full_like(tile_eid, -1)

        y_rows = kops.ragged_quant_ffn_op(
            xs, tile_eid, tile_slot, bank.lo, bank.hi if n_hi else None,
            bits=bank.lo["w_gate"].bits, group=bank.lo["w_gate"].group_size,
            bm=bm, backend=gemm)
    else:
        y_rows = kops.ragged_dense_ffn_op(xs, tile_eid, bank, bm=bm,
                                          backend=gemm)

    y_asn = y_rows[jnp.minimum(rowpos, R - 1)]
    gate_sorted = gates.reshape(-1)[order].astype(x.dtype)
    contrib = jnp.where(kept[:, None], y_asn * gate_sorted[:, None], 0)
    y = jnp.zeros((T, y_rows.shape[-1]), x.dtype).at[tok].add(contrib)

    routed = jnp.sum(jnp.where(sorted_eid < e_local, 1.0, 0.0))
    kept_f = jnp.sum(jnp.where(kept, 1.0, 0.0))
    dropped = 1.0 - kept_f / jnp.maximum(routed, 1.0)
    pad_ratio = 1.0 - routed / jnp.maximum(n_tiles * bm, 1).astype(jnp.float32)
    return y, counts.astype(jnp.int32), dropped, pad_ratio


def _moe_local(params: Dict, bank, x: jax.Array, cfg: MoEConfig,
               capacity: int, e_offset, e_local: int,
               slot_lo=0, n_slot_local: Optional[int] = None, ff_axis=None,
               token_valid: Optional[jax.Array] = None,
               n_rows: Optional[int] = None,
               row_capacity: Optional[int] = None,
               dispatch: Optional[str] = None, gemm: Optional[str] = None):
    """Route + dispatch for one shard (e_offset may be traced).

    ``token_valid`` ((T,) bool) drops masked tokens from dispatch entirely:
    they route to the sentinel expert (zero output, no capacity consumed)
    and vanish from every count — the per-row validity signal prefill
    padding and vacant decode slots ride in on. ``n_rows`` additionally
    returns (n_rows, E) counts segment-summed over T/n_rows-token rows.
    ``row_capacity`` switches the drop rule to the per-row normalization
    (see ``_row_capacity_keep``); ``dispatch``/``gemm`` select the token
    layout and GEMM backend (see ``kernels.ops``).
    """
    E, k = cfg.num_experts, cfg.top_k
    T = x.shape[0]
    gates, idx, probs = route(params["router"], x, cfg)
    sel = (idx >= e_offset) & (idx < e_offset + e_local)
    if token_valid is not None:
        sel = sel & token_valid[:, None]
    idx_l = jnp.where(sel, idx - e_offset, e_local)          # sentinel
    gates_l = jnp.where(sel, gates, 0.0)
    if row_capacity is not None:
        if n_rows is None:
            raise ValueError("row_capacity needs n_rows")
        # Physical capacity covering the per-row rule's worst case (all
        # surviving assignments on one expert) — overflow-free, so drops
        # come from the row rule alone.
        capacity = n_rows * row_capacity
    # Ragged layout: full-expert-range bodies only — shifted expert windows
    # (traced e_offset), sliced slot pools, and FF-split experts keep the
    # padded reference body. Quantized AND dense dict banks both qualify
    # (the dense variant routes through ``ragged_dense_ffn_op``).
    use_ragged = (dispatch == "ragged"
                  and isinstance(e_offset, int) and e_offset == 0
                  and n_slot_local is None and ff_axis is None)
    if use_ragged:
        y, counts_l, dropped, pad_ratio = _dispatch_ragged(
            bank, x, idx_l, gates_l, e_local, capacity,
            row_capacity=row_capacity, n_rows=n_rows, gemm=gemm)
    else:
        y, counts_l, dropped = dispatch_compute(
            bank, x, idx_l, gates_l, e_local, capacity,
            e_offset=e_offset, slot_lo=slot_lo, n_slot_local=n_slot_local,
            ff_axis=ff_axis, row_capacity=row_capacity, n_rows=n_rows,
            gemm=gemm)
        kept_rows = jnp.sum(jnp.clip(counts_l, 0, capacity))
        pad_ratio = 1.0 - kept_rows.astype(jnp.float32) / \
            jnp.float32(max(e_local * capacity, 1))
    active_experts = jnp.sum((counts_l > 0).astype(jnp.int32))

    # Load-balance aux on the full (replicated) router distribution,
    # restricted to valid tokens so padding cannot skew the balance target.
    if token_valid is None:
        full_idx = jnp.clip(idx.reshape(-1), 0, E)
        n_assign = x.shape[0] * k
        mean_prob = jnp.mean(probs, axis=0)
    else:
        full_idx = jnp.where(token_valid[:, None], jnp.clip(idx, 0, E),
                             E).reshape(-1)
        n_assign = jnp.maximum(jnp.sum(token_valid), 1) * k
        tv = token_valid[:, None].astype(jnp.float32)
        mean_prob = jnp.sum(probs * tv, axis=0) / \
            jnp.maximum(jnp.sum(tv), 1.0)
    full_counts = jnp.zeros((E + 1,), jnp.int32).at[full_idx].add(1)[:E]
    frac_routed = full_counts.astype(jnp.float32) / jnp.maximum(n_assign, 1)
    aux_loss = cfg.router_aux_coef * E * jnp.sum(frac_routed * mean_prob)

    row_counts = None
    if n_rows is not None:
        # Segment-sum the valid assignments per row: row r covers tokens
        # [r·T/R, (r+1)·T/R). Uses GLOBAL expert ids (telemetry is shard-
        # agnostic); masked/out-of-shard assignments fall into the E bucket.
        tpr = T // n_rows
        rid = jnp.arange(T, dtype=jnp.int32) // tpr
        eid = jnp.where(sel, idx, E)
        row_counts = jnp.zeros((n_rows, E + 1), jnp.int32).at[
            jnp.broadcast_to(rid[:, None], (T, k)), eid].add(1)[:, :E]
    return y, counts_l, full_counts.astype(jnp.int32), aux_loss, dropped, \
        row_counts, active_experts, pad_ratio


def moe_apply(params: Dict, bank: Union[Dict, ExpertBankQ], x: jax.Array,
              cfg: MoEConfig, capacity: int,
              token_valid: Optional[jax.Array] = None,
              n_rows: Optional[int] = None,
              row_capacity: Optional[int] = None,
              dispatch: Optional[str] = None,
              gemm: Optional[str] = None) -> tuple[jax.Array, MoEAux]:
    """Single-device path. params: {'router', ['shared']}; x: (T, d).

    ``token_valid``/``n_rows``: see ``_moe_local`` — masked tokens are
    excluded from dispatch, capacity and every count; ``n_rows`` requests
    per-row (R, E) counts in ``MoEAux.row_counts``. ``row_capacity``
    normalizes the drop rule per row (batch-shape-independent drops;
    requires ``n_rows``). ``dispatch`` ∈ {padded, ragged} picks the token
    layout (None → ``kernels.ops.moe_dispatch_default()``); ``gemm`` ∈
    {jnp, pallas} the quantized-GEMM backend.
    """
    dist = _get_dist()
    if dist is not None:
        return _moe_apply_sharded(params, bank, x, cfg, capacity, dist,
                                  token_valid=token_valid, n_rows=n_rows,
                                  row_capacity=row_capacity,
                                  dispatch=dispatch, gemm=gemm)
    if dispatch is None:
        dispatch = kops.moe_dispatch_default()
    y, counts, _full, aux_loss, dropped, row_counts, active, padr = \
        _moe_local(params, bank, x, cfg, capacity, 0, cfg.num_experts,
                   token_valid=token_valid, n_rows=n_rows,
                   row_capacity=row_capacity, dispatch=dispatch, gemm=gemm)
    if "shared" in params:
        y = y + swiglu(params["shared"], x)
    return y, MoEAux(counts=counts, aux_loss=aux_loss, dropped=dropped,
                     row_counts=row_counts, active_experts=active,
                     dispatch_pad_ratio=padr)


def _get_dist():
    try:
        from repro.launch.dist import get_dist
        return get_dist()
    except ImportError:  # pragma: no cover
        return None


def _moe_apply_sharded(params, bank, x, cfg: MoEConfig, capacity, dist,
                       token_valid=None, n_rows=None, row_capacity=None,
                       dispatch=None, gemm=None):
    """shard_map expert parallelism (see module docstring).

    Two sharded regimes, chosen statically at trace time:

    * **EP ragged** (``dist.tokens_ep_sharded`` + ragged dispatch): tokens
      shard over the data AND model axes (every device owns a token slice
      plus its E/n experts). Each shard routes its local tokens, compacts
      the kept assignments per destination expert-shard (the stable
      sort-by-expert already groups destinations contiguously), exchanges a
      statically-bounded bm-aligned payload with ONE ``all_to_all`` each
      way (per-(dest, expert) counts ride a second tiny one), runs the
      grouped ragged kernel on its local experts at their resident tier
      (local hi-slot slice), and combines with the router gates back on the
      sender — the same per-token scatter-add order and dtype as the
      single-device ragged path, so drop-free regimes (decode, and any
      ``row_capacity`` run) are bit-identical per token. When the global
      per-expert ``capacity`` binds, drops apply per (expert, sender) at
      ``ep_cap_shard`` — the same 1/n slicing the padded dp body already
      does — so heavy prefill overflow degrades the same way it always has.
    * **padded** (everything else): each data shard routes its own tokens,
      each model shard computes its local experts into the fixed (E, C, d)
      buffer, partial outputs psum over the model axis. The reference — and
      the fallback whenever the EP layout can't hold statically (tokens not
      divisible over the token shards, unsharded hi pool, padded dispatch).

    ``token_valid`` shards alongside ``x`` and masks dispatch exactly like
    the single-device path. ``n_rows`` produces ``MoEAux.row_counts`` with
    the row dim sharded like the tokens (EP: data×model; padded: data when
    the rows divide, else replicated) — the engine's hotness/telemetry
    signal no longer goes dark under a mesh. ``row_capacity`` keeps its
    per-row drop rule exactly: rows never straddle a token shard.

    The bank is decomposed into plain dicts around the shard_map boundary
    (PartitionSpec trees must structurally match the args; custom pytree
    metadata like QuantizedTensor's logical shape changes under slicing)."""
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

    import inspect
    # jax ≥ 0.6 renamed check_rep → check_vma; support both.
    check_kw = "check_vma" if "check_vma" in \
        inspect.signature(shard_map).parameters else "check_rep"

    mesh = dist.mesh
    mn = dist.model_size
    E = cfg.num_experts
    k = cfg.top_k
    T = x.shape[0]
    if dispatch is None:
        dispatch = kops.moe_dispatch_default()
    if E % mn:
        # Cannot expert-shard — run replicated (noted by the planner).
        y, counts, _f, aux, dropped, rc, act, padr = _moe_local(
            params, bank, x, cfg, capacity, 0, E, token_valid=token_valid,
            n_rows=n_rows, row_capacity=row_capacity, dispatch=dispatch,
            gemm=gemm)
        if "shared" in params:
            y = y + swiglu(params["shared"], x)
        return y, MoEAux(counts, aux, dropped, row_counts=rc,
                         active_experts=act, dispatch_pad_ratio=padr)
    e_local = E // mn
    is_q = isinstance(bank, ExpertBankQ)
    n_hi = bank.n_hi if is_q else 0
    hi_shard = n_hi > 0 and n_hi % mn == 0
    nh_local = n_hi // mn if hi_shard else None

    dp_n = 1
    for a in dist.dp_axes:
        dp_n *= mesh.shape[a]
    n_tok = dp_n * mn if dist.tokens_ep_sharded else dp_n

    # ---- EP ragged eligibility (static) ---------------------------------
    use_ep = (dist.tokens_ep_sharded and dispatch == "ragged"
              and T % n_tok == 0 and (T // n_tok) > 0
              and (n_hi == 0 or hi_shard))
    if row_capacity is not None and n_rows is None:
        raise ValueError("row_capacity needs n_rows")
    if use_ep and n_rows is not None:
        # Rows must tile exactly over the token shards for the per-row
        # drop rule / row_counts to stay local.
        use_ep = (T % n_rows == 0 and n_rows % n_tok == 0
                  and (T // n_tok) % (T // n_rows) == 0)

    # capacity was computed for global T and global E; the local shard keeps
    # the same per-expert expectation: T_loc·k·cf / E = capacity / dp_n.
    cap_local = max(8, (capacity // dp_n + 7) // 8 * 8) \
        if dist.tokens_dp_sharded else capacity

    # FF-slice over the idle data axis when tokens are replicated (batch-1
    # long-context decode) and every sliced dim divides: 2-D expert sharding.
    dp1 = None if not dist.dp_axes else \
        (dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0])
    ff_axis = None
    if is_q and not dist.tokens_dp_sharded and dp_n > 1 and not use_ep:
        f_dim = bank.lo["w_gate"].packed.shape[-1]
        d_dim = bank.lo["w_down"].packed.shape[-1]
        if f_dim % dp_n == 0 and d_dim % dp_n == 0:
            ff_axis = dp1

    # ---- flatten bank to plain dicts + spec trees -----------------------
    eshard = P("model")          # prefix spec: shard dim 0 (E / n_hi)
    repl = P()
    if is_q:
        flat = {f"lo_packed.{n}": qt.packed for n, qt in bank.lo.items()}
        flat.update({f"lo_scales.{n}": qt.scales for n, qt in bank.lo.items()})
        flat.update({f"hi.{n}": a for n, a in bank.hi.items()})
        flat["slot_owner"] = bank.slot_owner
        flat["slot_map"] = bank.slot_map
        meta = {n: (qt.bits, qt.group_size) for n, qt in bank.lo.items()}

        def spec_of(kk):
            he = eshard if hi_shard else repl
            if kk.startswith("slot"):
                return repl
            base = eshard if kk.startswith("lo_") else he
            if ff_axis is not None:   # slice the last (F or D-out) dim
                return P(*(tuple(base) + (None,) * (2 - len(tuple(base))) + (dp1,)))
            return base
        bank_spec = {kk: spec_of(kk) for kk in flat}
    else:
        flat = dict(bank)
        meta = None
        bank_spec = {kk: eshard for kk in flat}

    def rebuild(flat_l):
        if not is_q:
            return flat_l
        lo = {n: QuantizedTensorLike(flat_l[f"lo_packed.{n}"],
                                     flat_l[f"lo_scales.{n}"], *meta[n])
              for n in bank.lo}
        return ExpertBankQ(lo=lo, hi={n: flat_l[f"hi.{n}"] for n in bank.hi},
                           slot_owner=flat_l["slot_owner"],
                           slot_map=flat_l["slot_map"])

    params_spec = jax.tree_util.tree_map(lambda _: repl, params)

    if use_ep:
        return _moe_local_ep(params, flat, rebuild, x, cfg, capacity, dist,
                             token_valid, n_rows, row_capacity, gemm, mesh,
                             mn, n_tok, e_local, nh_local, is_q, params_spec,
                             bank_spec, shard_map, check_kw)

    x_spec = P(dist.dp_axes) if dist.tokens_dp_sharded else repl
    tv_spec = None if token_valid is None else x_spec

    # Row split for row_counts / row_capacity: rows follow the tokens, so
    # they only shard when they tile exactly over the dp shards.
    rows_split = dist.tokens_dp_sharded and dp_n > 1
    n_rows_loc = None
    if n_rows is not None:
        if not rows_split:
            n_rows_loc = n_rows
        elif (T % n_rows == 0 and n_rows % dp_n == 0
                and (T // dp_n) % (T // n_rows) == 0):
            n_rows_loc = n_rows // dp_n
        elif row_capacity is not None:
            raise ValueError(
                f"row_capacity requires rows to tile over the {dp_n} data "
                f"shards (T={T}, n_rows={n_rows})")
    want_rc = n_rows_loc is not None
    rc_spec = P(dist.dp_axes, None) if (want_rc and rows_split) else repl

    def body(params_l, flat_l, x_l, tv_l):
        j = jax.lax.axis_index(dist.model_axis)
        e_off = j * e_local
        slot_lo = (j * nh_local) if hi_shard else 0
        y, counts_l, _full, aux, dropped, rc, _a, padr = _moe_local(
            params_l, rebuild(flat_l), x_l, cfg, cap_local, e_off, e_local,
            slot_lo=slot_lo, n_slot_local=nh_local, ff_axis=ff_axis,
            token_valid=tv_l, n_rows=n_rows_loc, row_capacity=row_capacity,
            dispatch=dispatch, gemm=gemm)
        y = jax.lax.psum(y, dist.model_axis)
        if ff_axis is not None:   # y is D-sliced over data: gather (tiny)
            y = jax.lax.all_gather(y, ff_axis, axis=1, tiled=True)
        if "shared" in params_l:
            y = y + swiglu(params_l["shared"], x_l)
        # Global hotness counts: place the local expert slice, reduce over
        # model (expert partition) and data (token partition).
        counts = jnp.zeros((cfg.num_experts,), jnp.int32)
        counts = jax.lax.dynamic_update_slice(counts, counts_l, (e_off,))
        counts = jax.lax.psum(counts, dist.model_axis)
        if dist.tokens_dp_sharded and dist.dp_axes:
            counts = jax.lax.psum(counts, dist.dp_axes)
            aux = jax.lax.pmean(aux, dist.dp_axes)
            dropped = jax.lax.pmean(dropped, dist.dp_axes)
            padr = jax.lax.pmean(padr, dist.dp_axes)
        dropped = jax.lax.pmean(dropped, dist.model_axis)
        padr = jax.lax.pmean(padr, dist.model_axis)
        if not want_rc:
            return y, counts, aux, dropped, padr
        # Each model shard only sees its own experts' assignments — the
        # psum fills in the rest; rows stay local to their dp shard.
        rc = jax.lax.psum(rc, dist.model_axis)
        if not rows_split and dist.dp_axes and dist.tokens_dp_sharded:
            rc = jax.lax.psum(rc, dist.dp_axes)
        return y, counts, aux, dropped, padr, rc

    out_specs = (x_spec, repl, repl, repl, repl) + \
        ((rc_spec,) if want_rc else ())
    res = shard_map(
        body, mesh=mesh,
        in_specs=(params_spec, bank_spec, x_spec, tv_spec),
        out_specs=out_specs,
        **{check_kw: False},
    )(params, flat, x, token_valid)
    y, counts, aux, dropped, padr = res[:5]
    rc = res[5] if want_rc else None
    active = jnp.sum((counts > 0).astype(jnp.int32))
    return y, MoEAux(counts=counts, aux_loss=aux, dropped=dropped,
                     row_counts=rc, active_experts=active,
                     dispatch_pad_ratio=padr)


def _moe_local_ep(params, flat, rebuild, x, cfg: MoEConfig, capacity, dist,
                  token_valid, n_rows, row_capacity, gemm, mesh, mn, n_tok,
                  e_local, nh_local, is_q, params_spec, bank_spec, shard_map,
                  check_kw):
    """The EP ragged all-to-all pipeline (see ``_moe_apply_sharded``).

    Wire protocol per shard pair: a (mn·S, d) row payload — block ``s`` of
    the send buffer holds the rows destined for shard ``s``, bm-aligned
    budget ``S`` rows each (``ep_payload_rows``) — and an (mn, e_local)
    count matrix whose row ``s`` says how many of those rows belong to each
    of shard ``s``'s experts, in expert order. After the exchange the
    receiver rebuilds per-expert segments with the SAME ``_sort_routing``
    contract the single-device path compiles (stable by (expert, source,
    send order)), feeds the grouped kernel, and the result rows ride the
    inverse route home."""
    from jax.sharding import PartitionSpec as P

    E, kk = cfg.num_experts, cfg.top_k
    T, _d = x.shape
    bm = RAGGED_BM
    T_l = T // n_tok
    n_rows_l = None if n_rows is None else n_rows // n_tok
    cap_shard = None if row_capacity is not None else ep_cap_shard(capacity,
                                                                   n_tok)
    S = ep_payload_rows(T, kk, e_local, capacity, n_tok, bm=bm,
                        n_rows=n_rows, row_capacity=row_capacity)
    tok_axes = tuple(dist.dp_axes) + (dist.model_axis,)
    x_spec = P(tok_axes)
    tv_spec = None if token_valid is None else x_spec
    want_rc = n_rows is not None
    repl = P()

    def body(params_l, flat_l, x_l, tv_l):
        bank_l = rebuild(flat_l)
        j = jax.lax.axis_index(dist.model_axis)
        d = x_l.shape[1]
        gates, idx, probs = route(params_l["router"], x_l, cfg)
        if tv_l is not None:
            idx_v = jnp.where(tv_l[:, None], idx, E)
            gates_v = jnp.where(tv_l[:, None], gates, 0.0)
        else:
            idx_v, gates_v = idx, gates

        # -- sender: sort by GLOBAL expert id (= grouped by destination
        # shard, experts ascending within each destination) and compact the
        # kept assignments into the per-destination payload blocks.
        order, sorted_eid, counts_l, pos_in_e, tok = _sort_routing(idx_v, E)
        kept = _keep_mask(sorted_eid, pos_in_e, tok, E,
                          cap_shard if cap_shard is not None else 0,
                          row_capacity, n_rows_l, T_l)
        dest = jnp.where(sorted_eid < E, sorted_eid // e_local, mn)
        kept_i = kept.astype(jnp.int32)
        inc = jnp.cumsum(kept_i)
        kept_d = jnp.zeros((mn + 1,), jnp.int32).at[dest].add(kept_i)
        dstart = jnp.cumsum(kept_d) - kept_d
        offs = inc - 1 - dstart[dest]          # rank among kept, within dest
        send_row = jnp.where(kept, dest * S + offs, mn * S)  # OOB ⇒ dropped
        send = jnp.zeros((mn * S, d), x_l.dtype).at[send_row].set(
            x_l[tok], mode="drop")
        cnt_send = jnp.zeros((E + 1,), jnp.int32).at[
            jnp.where(kept, sorted_eid, E)].add(1)[:E].reshape(mn, e_local)

        def a2a(v):
            return jax.lax.all_to_all(v, dist.model_axis, 0, 0, tiled=True)

        recv = a2a(send)          # (mn·S, d): block s ← source shard s
        cnt_recv = a2a(cnt_send)  # (mn, e_local): row s ← source shard s

        # -- receiver: per-row local expert id from the count boundaries
        # (payload rows past a block's total → e_local sentinel), then the
        # standard ragged compaction over the local experts.
        r = jnp.arange(mn * S, dtype=jnp.int32)
        src = r // S
        cum = jnp.cumsum(cnt_recv, axis=1)
        eid_r = jnp.sum(((r % S)[:, None] >= cum[src]).astype(jnp.int32),
                        axis=1)
        order_r, sorted_re, cnt_e, pos_re, rrow = _sort_routing(
            eid_r[:, None], e_local)
        astart, tile_eid, n_tiles = ragged_tile_map(cnt_e, bm, mn * S)
        R = tile_eid.shape[0] * bm
        safe_e = jnp.minimum(sorted_re, e_local - 1)
        rowpos = jnp.where(sorted_re < e_local, astart[safe_e] + pos_re, R)
        xs = jnp.zeros((R, d), x_l.dtype).at[rowpos].set(recv[rrow],
                                                         mode="drop")
        if is_q:
            if nh_local:
                # Local hi-slot slice: slot g = j·nh_local + s lives here;
                # owners are global expert positions.
                owner = jax.lax.dynamic_slice_in_dim(
                    bank_l.slot_owner, j * nh_local, nh_local)
                owner_l = owner - j * e_local
                eff = jnp.full((e_local + 1,), -1, jnp.int32).at[
                    jnp.where((owner_l >= 0) & (owner_l < e_local),
                              owner_l, e_local)].set(
                    jnp.arange(nh_local, dtype=jnp.int32),
                    mode="drop")[:e_local]
                tile_slot = eff[tile_eid]
                hi_l = bank_l.hi
            else:
                tile_slot = jnp.full_like(tile_eid, -1)
                hi_l = None
            y_rows = kops.ragged_quant_ffn_op(
                xs, tile_eid, tile_slot, bank_l.lo, hi_l,
                bits=bank_l.lo["w_gate"].bits,
                group=bank_l.lo["w_gate"].group_size, bm=bm, backend=gemm)
        else:
            y_rows = kops.ragged_dense_ffn_op(xs, tile_eid, bank_l, bm=bm,
                                              backend=gemm)
        D = y_rows.shape[-1]
        back = jnp.where((sorted_re < e_local)[:, None],
                         y_rows[jnp.minimum(rowpos, R - 1)], 0)
        y_recv = jnp.zeros((mn * S, D), x_l.dtype).at[rrow].set(back)

        # -- home: block d of the return exchange is MY rows' results from
        # shard d, at the offsets I sent them at.
        y_ret = a2a(y_recv)
        y_asn = y_ret[jnp.minimum(send_row, mn * S - 1)]
        gate_sorted = gates_v.reshape(-1)[order].astype(x_l.dtype)
        contrib = jnp.where(kept[:, None], y_asn * gate_sorted[:, None], 0)
        y = jnp.zeros((T_l, D), x_l.dtype).at[tok].add(contrib)
        if "shared" in params_l:
            y = y + swiglu(params_l["shared"], x_l)

        # -- exact global telemetry (counts keyed by global expert already)
        counts = jax.lax.psum(counts_l.astype(jnp.int32), tok_axes)
        routed = jax.lax.psum(
            jnp.sum((sorted_eid < E).astype(jnp.float32)), tok_axes)
        kept_g = jax.lax.psum(jnp.sum(kept.astype(jnp.float32)), tok_axes)
        dropped = 1.0 - kept_g / jnp.maximum(routed, 1.0)
        padr = jax.lax.pmean(
            1.0 - jnp.sum(cnt_e).astype(jnp.float32)
            / jnp.maximum(n_tiles * bm, 1).astype(jnp.float32), tok_axes)
        # Load-balance aux from globally psum'd routing stats — same value
        # the single-device formula produces.
        if tv_l is None:
            full_idx = jnp.clip(idx.reshape(-1), 0, E)
            n_val = jnp.float32(T_l)
            sum_prob = jnp.sum(probs, axis=0)
        else:
            full_idx = jnp.where(tv_l[:, None], jnp.clip(idx, 0, E),
                                 E).reshape(-1)
            n_val = jnp.sum(tv_l).astype(jnp.float32)
            sum_prob = jnp.sum(probs * tv_l[:, None].astype(jnp.float32),
                               axis=0)
        full_counts = jax.lax.psum(
            jnp.zeros((E + 1,), jnp.int32).at[full_idx].add(1)[:E], tok_axes)
        n_val = jax.lax.psum(n_val, tok_axes)
        sum_prob = jax.lax.psum(sum_prob, tok_axes)
        mean_prob = sum_prob / jnp.maximum(n_val, 1.0)
        frac = full_counts.astype(jnp.float32) / jnp.maximum(n_val * kk, 1.0)
        aux = cfg.router_aux_coef * E * jnp.sum(frac * mean_prob)
        if not want_rc:
            return y, counts, aux, dropped, padr
        tpr = T // n_rows
        rid = jnp.arange(T_l, dtype=jnp.int32) // tpr
        rc = jnp.zeros((n_rows_l, E + 1), jnp.int32).at[
            jnp.broadcast_to(rid[:, None], (T_l, kk)), idx_v].add(1)[:, :E]
        return y, counts, aux, dropped, padr, rc

    out_specs = (x_spec, repl, repl, repl, repl) + \
        ((P(tok_axes, None),) if want_rc else ())
    res = shard_map(
        body, mesh=mesh,
        in_specs=(params_spec, bank_spec, x_spec, tv_spec),
        out_specs=out_specs,
        **{check_kw: False},
    )(params, flat, x, token_valid)
    y, counts, aux, dropped, padr = res[:5]
    rc = res[5] if want_rc else None
    active = jnp.sum((counts > 0).astype(jnp.int32))
    return y, MoEAux(counts=counts, aux_loss=aux, dropped=dropped,
                     row_counts=rc, active_experts=active,
                     dispatch_pad_ratio=padr)


class QuantizedTensorLike(NamedTuple):
    """Local-shard view of a QuantizedTensor inside shard_map (plain tuple:
    no global-shape metadata to go stale)."""
    packed: jax.Array
    scales: jax.Array
    bits: int
    group_size: int


def moe_capacity(n_tokens: int, cfg: MoEConfig, factor: float | None = None) -> int:
    f = factor if factor is not None else cfg.capacity_factor
    cap = int(n_tokens * cfg.top_k * f / cfg.num_experts) + 1
    # Round up to a multiple of 8 for friendlier tiling/sharding.
    return max(8, (cap + 7) // 8 * 8)


def ep_cap_shard(capacity: int, n_token_shards: int) -> int:
    """Per-(expert, sender) capacity slice under EP token sharding: the
    global per-expert ``capacity`` split evenly over the senders, floored
    at 8 so small-batch decode (where a sender holds ≤ a handful of tokens)
    is always drop-free — the same 1/n scaling (and floor) the padded dp
    body applies to its local capacity."""
    return max(8, (-(-capacity // n_token_shards) + 7) // 8 * 8)


def ep_payload_rows(n_tokens: int, top_k: int, e_local: int, capacity: int,
                    n_token_shards: int, bm: int = RAGGED_BM,
                    n_rows: Optional[int] = None,
                    row_capacity: Optional[int] = None) -> int:
    """Static per-destination row budget ``S`` of the EP all-to-all payload.

    A sender can forward at most min(its local assignments, what one
    destination can keep) rows to any one shard: ``T_l·k`` assignments
    total, and per destination ``e_local`` experts × the per-sender keep
    bound (``ep_cap_shard``, or ``rows_l·row_capacity`` under the per-row
    rule). The bm round-up keeps the exchanged buffer tile-aligned for the
    grouped kernel on the receiver. This is also the bytes-moved model the
    ``ep_scaling`` benchmark reports: each shard moves ``2·(mn−1)·S·d``
    payload elements per MoE layer (out and back), independent of the
    global batch — vs. the replicated baseline's ``2·(mn−1)/mn·T·d`` psum."""
    t_l = n_tokens // n_token_shards
    if row_capacity is not None:
        per_dest = e_local * (n_rows // n_token_shards) * row_capacity
    else:
        per_dest = e_local * ep_cap_shard(capacity, n_token_shards)
    s = min(t_l * top_k, per_dest)
    return max(bm, -(-s // bm) * bm)
