"""Jitted public wrappers for the Pallas kernels.

On TPU the kernels compile natively; everywhere else (this CPU container)
they execute in ``interpret=True`` mode, which runs the kernel body in
Python for correctness validation against ``ref.py``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul import quant_matmul, grouped_quant_matmul
from repro.kernels.flash_decode import flash_decode, flash_decode_paged
from repro.quant.qtensor import QuantizedTensor


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul_op(x: jax.Array, qt: QuantizedTensor, bm: int = 128,
                    bn: int = 128, bk: int = 256,
                    interpret: bool | None = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    return quant_matmul(x, qt.packed, qt.scales, bits=qt.bits,
                        group=qt.group_size, bm=bm, bn=bn, bk=bk,
                        interpret=interpret)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def grouped_quant_matmul_op(xg: jax.Array, qt: QuantizedTensor, bm: int = 128,
                            bn: int = 128, bk: int = 256,
                            interpret: bool | None = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    return grouped_quant_matmul(xg, qt.packed, qt.scales, bits=qt.bits,
                                group=qt.group_size, bm=bm, bn=bn, bk=bk,
                                interpret=interpret)


@partial(jax.jit, static_argnames=("bs", "interpret"))
def flash_decode_op(q: jax.Array, k: jax.Array, v: jax.Array,
                    valid: jax.Array, bs: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    return flash_decode(q, k, v, valid, bs=bs, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged_op(q: jax.Array, k: jax.Array, v: jax.Array,
                          table: jax.Array, valid: jax.Array,
                          interpret: bool | None = None) -> jax.Array:
    """Block-table flash decode over the paged KV pool (see
    ``flash_decode_paged``); k/v are (N, Hkv, bt, hd) physical blocks —
    the ``PagedKVCache`` layout, one superblock slice."""
    interpret = _interpret_default() if interpret is None else interpret
    return flash_decode_paged(q, k, v, table, valid, interpret=interpret)
