"""Synthetic request workloads with controllable routing skew & shift.

The paper's Fig. 2 shows the hot expert set is disjoint across text / math /
code workloads. We reproduce the *mechanism* without real datasets: each
workload draws tokens Zipf-distributed over a workload-specific slice of the
vocabulary. Different input statistics → different embedding clusters →
different router hot sets (measured, not assumed — see
benchmarks/workload_shift.py).

Two granularities:

* ``make_prompts`` / ``mixed_stream`` — fixed-shape token batches (training
  eval, hotness measurement);
* ``Request`` / ``RequestStream`` — the serving-engine unit of work:
  variable-length prompts with arrival times and per-request workload tags,
  feeding ``InferenceEngine.submit`` (the same shifting mix as
  ``mixed_stream``, request- rather than batch-shaped).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.serving.sampler import SamplingParams

WORKLOADS = ("text", "math", "code")


@dataclasses.dataclass
class Request:
    """One serving request: a prompt plus generation and accounting tags.

    ``sampling``: per-request ``SamplingParams`` (temperature / top-k /
    top-p / seed). ``None`` means greedy — bit-identical to pre-sampler
    engines. Validated at ``InferenceEngine.submit``.

    ``qos``: SLO tier (``repro.serving.scheduler.QOS_CLASSES``); ``None``
    resolves to the engine's ``SchedulerConfig.qos_default`` at submit.
    ``deadline_ms``: optional per-request latency target (submit →
    finish) — drives SLO-attainment reporting, and expired *batch*-tier
    requests are dropped from the admission queue instead of served late.
    Both validated loudly at ``InferenceEngine.submit``."""
    tokens: np.ndarray                   # (prompt_len,) int32
    max_new_tokens: int = 16
    workload: str = "text"               # which traffic phase produced it
    arrival_s: float = 0.0               # offset from stream start
    eos_token_id: Optional[int] = None
    sampling: Optional[SamplingParams] = None
    qos: Optional[str] = None            # batch | standard | premium
    deadline_ms: Optional[float] = None  # submit→finish SLO target


class RequestStream:
    """Request-level arrival process over shifting workload phases.

    ``phases``: sequence of ``(workload, n_requests)`` — the same shifting
    serving mix ``mixed_stream`` yields batch-wise, one ``Request`` at a
    time. Arrivals are Poisson at ``arrival_rate_rps`` (or back-to-back when
    ``None``), with optional extra per-arrival jitter uniform in
    ``[0, arrival_jitter_s]``; prompt lengths jitter uniformly within
    ``prompt_len ± prompt_len_jitter`` so continuous batching sees genuinely
    variable-length work.

    ``qos``: ``None`` (requests carry no class — the engine default
    applies), a fixed class name, or the string ``"workload"`` to map each
    request's workload tag through ``scheduler.WORKLOAD_QOS`` (code →
    premium, text → standard, math → batch). ``deadline_ms`` attaches the
    same submit→finish SLO target to every request.
    """

    def __init__(self, vocab_size: int,
                 phases: Sequence[Tuple[str, int]],
                 prompt_len: int = 32,
                 prompt_len_jitter: int = 0,
                 max_new_tokens: int = 8,
                 arrival_rate_rps: Optional[float] = None,
                 arrival_jitter_s: float = 0.0,
                 seed: int = 0,
                 sampling: Optional[SamplingParams] = None,
                 qos: Optional[str] = None,
                 deadline_ms: Optional[float] = None):
        self.vocab_size = vocab_size
        self.phases = list(phases)
        self.prompt_len = prompt_len
        self.prompt_len_jitter = prompt_len_jitter
        self.max_new_tokens = max_new_tokens
        self.arrival_rate_rps = arrival_rate_rps
        self.arrival_jitter_s = float(arrival_jitter_s)
        self.seed = seed
        # Per-request sampling params: every request in the stream carries
        # its own seed (base seed + request ordinal) so replaying the
        # stream is reproducible while rows stay decorrelated.
        self.sampling = sampling
        if qos is not None and qos != "workload":
            from repro.serving.scheduler import resolve_qos
            resolve_qos(qos, qos)        # loud validation at construction
        self.qos = qos
        self.deadline_ms = deadline_ms

    def __len__(self) -> int:
        return sum(n for _, n in self.phases)

    def __iter__(self) -> Iterator[Request]:
        rng = np.random.default_rng(self.seed ^ 0x5EED)
        now = 0.0
        ordinal = 0
        for pi, (workload, n_requests) in enumerate(self.phases):
            for j in range(n_requests):
                lo = max(1, self.prompt_len - self.prompt_len_jitter)
                hi = self.prompt_len + self.prompt_len_jitter
                length = int(rng.integers(lo, hi + 1))
                toks = make_prompts(workload, self.vocab_size, 1, length,
                                    seed=self.seed + 1009 * pi + j)[0]
                if self.arrival_rate_rps:
                    now += float(rng.exponential(1.0 / self.arrival_rate_rps))
                if self.arrival_jitter_s:
                    # Monotone jitter: arrivals stay in submit order so the
                    # replay loop never head-of-line blocks on timestamps.
                    now += float(rng.uniform(0.0, self.arrival_jitter_s))
                sampling = None
                if self.sampling is not None:
                    sampling = dataclasses.replace(
                        self.sampling, seed=self.sampling.seed + ordinal)
                if self.qos == "workload":
                    from repro.serving.scheduler import WORKLOAD_QOS
                    qos = WORKLOAD_QOS[workload]
                else:
                    qos = self.qos
                yield Request(tokens=toks, max_new_tokens=self.max_new_tokens,
                              workload=workload, arrival_s=now,
                              sampling=sampling, qos=qos,
                              deadline_ms=self.deadline_ms)
                ordinal += 1


def _zipf_probs(n: int, s: float = 1.2) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1) ** s
    return p / p.sum()


def make_prompts(workload: str, vocab_size: int, batch: int, length: int,
                 seed: int = 0) -> np.ndarray:
    """(batch, length) int32 token ids for one workload."""
    if workload not in WORKLOADS:
        raise KeyError(f"unknown workload {workload!r}")
    wi = WORKLOADS.index(workload)
    rng = np.random.default_rng(seed + 1000 * wi)
    # Each workload occupies a third of the vocab, shuffled so slices are not
    # trivially ordered; heavy-tailed within the slice.
    perm = np.random.default_rng(42).permutation(vocab_size)
    lo = wi * vocab_size // 3
    hi = (wi + 1) * vocab_size // 3
    slice_ids = perm[lo:hi]
    probs = _zipf_probs(len(slice_ids))
    draws = rng.choice(len(slice_ids), size=(batch, length), p=probs)
    return slice_ids[draws].astype(np.int32)


def mixed_stream(vocab_size: int, batch: int, length: int, phases,
                 seed: int = 0):
    """Yield (workload_name, prompts) per phase — the shifting serving mix."""
    for i, (workload, n_batches) in enumerate(phases):
        for j in range(n_batches):
            yield workload, make_prompts(workload, vocab_size, batch, length,
                                         seed=seed + 17 * i + j)
