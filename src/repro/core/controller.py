"""DynaExq control loop (paper Fig. 4): glue between the hotness estimator,
the budget-feasible policy, and the transition pipeline.

The worker (serving engine) calls ``observe(counts)`` after every step with
the router-trace counts the MoE layers emit; ``maybe_update(now)`` runs the
policy at the ``T_u`` cadence. All of this is host-side and O(L·E) — far off
the token critical path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.core.budget import BudgetTracker, plan_budget
from repro.core.hotness import HotnessEstimator
from repro.core.policy import PolicyConfig, select_hi_set
from repro.core.transitions import TransitionManager
from repro.core.ver import (ExpertBankQ, Residency, build_bank,
                            expert_hi_nbytes, swap_expert_rows,
                            swap_router_cols)
from repro.fault.inject import TransferFault


@dataclasses.dataclass
class ControllerConfig:
    update_interval_s: float = 1.0      # T_u
    alpha: float = 0.8                  # EMA
    margin: float = 0.0                 # hysteresis
    migration_bytes_per_window: int = 0
    max_transitions_per_layer: int = 0


@dataclasses.dataclass
class RebalanceConfig:
    """Cadence/thresholds for the EP expert-ownership rebalancer."""
    interval_s: float = 2.0             # coordinator window
    skew_threshold: float = 1.5         # max/min shard hotness ratio trigger
    max_migrations_per_window: int = 2  # per MoE position


class DynaExqController:
    def __init__(self, bank: ExpertBankQ, host_hi: Dict[str, np.ndarray],
                 n_hi_per_layer: int, hi_bytes_per_expert: int,
                 cfg: Optional[ControllerConfig] = None, tracker=None,
                 ep_shards: int = 1, shard_trackers=None):
        """``tracker``: optional byte-reservation ledger (e.g. an
        account-scoped ``BudgetView`` of a serving engine's shared HBM
        envelope, so promotions contend with KV-cache admission); defaults
        to a private tracker capped at the hi pool's own size.
        ``ep_shards``/``shard_trackers``: expert-parallel serving — the hi
        pool's slots are owned per shard and each shard's promotions bill
        its own local-HBM tracker (see ``TransitionManager``)."""
        # A dataclass default instance would be shared (and mutated) across
        # every controller; each controller gets its own config.
        cfg = cfg if cfg is not None else ControllerConfig()
        L, E = bank.slot_map.shape
        self.cfg = cfg
        self.hotness = HotnessEstimator(L, E, alpha=cfg.alpha)
        self.policy = PolicyConfig(
            n_hi=n_hi_per_layer, margin=cfg.margin,
            max_transitions_per_layer=cfg.max_transitions_per_layer)
        self.tracker = tracker if tracker is not None else \
            BudgetTracker(n_hi_per_layer * L * hi_bytes_per_expert)
        self.tm = TransitionManager(
            bank, host_hi, self.tracker, hi_bytes_per_expert,
            migration_bytes_per_window=cfg.migration_bytes_per_window,
            n_shards=ep_shards, shard_trackers=shard_trackers)
        self._last_update = time.monotonic()
        # Failure-decay penalty (fault tolerance): a (L, E) multiplier on
        # folded hotness, halved each time an expert's promotion copy fails
        # and recovering toward 1 every window — a flapping expert keeps
        # getting re-candidated but can't livelock the promotion budget.
        self._fail_penalty = np.ones((L, E))
        self.fail_decay = 0.5
        self.fail_recover = 0.5
        self.tm.fail_cb = self.note_promotion_failure

    def note_promotion_failure(self, layer: int, expert: int) -> None:
        self._fail_penalty[layer, expert] *= self.fail_decay

    def folded_scores(self) -> np.ndarray:
        """Fold the hotness EMA and apply (then partially recover) the
        failure-decay penalty. All policy paths — per-layer ``update()``
        and the global allocator — must rank on THIS, not the raw fold."""
        scores = self.hotness.fold() * self._fail_penalty
        self._fail_penalty += (1.0 - self._fail_penalty) * self.fail_recover
        return scores

    @property
    def bank(self) -> ExpertBankQ:
        return self.tm.bank

    def observe(self, counts) -> None:
        self.hotness.observe(counts)

    def maybe_update(self, now: Optional[float] = None, force: bool = False) -> bool:
        now = now if now is not None else time.monotonic()
        if not force and now - self._last_update < self.cfg.update_interval_s:
            # Still publish any copies that completed since last step.
            self.tm.publish_ready()
            return False
        self._last_update = now
        self.update()
        return True

    def update(self) -> None:
        """One policy window: fold EMA → per-layer top-n w/ hysteresis →
        enqueue transitions → drain → publish completed."""
        scores = self.folded_scores()
        L = scores.shape[0]
        for l in range(L):
            current = self.tm.hi_set(l) | self.tm.pending_experts(l)
            _, promos, demos = select_hi_set(scores[l], current, self.policy)
            for e in demos:
                self.tm.request_demotion(l, int(e))
            for e in promos:
                self.tm.request_promotion(l, int(e))
        self.tm.drain()
        self.tm.publish_ready()

    def apply_plan(self, promotions, demotions) -> None:
        """Enqueue an externally computed transition plan (the global
        cross-layer allocator's) and run one drain/publish window. The
        lists are (layer, expert) pairs — promotions hottest-first,
        demotions coldest-first, exactly the admission order ``update()``
        would derive per layer; the transition pipeline (budget gates,
        rate limit, publish-then-switch) is identical."""
        for l, e in demotions:
            self.tm.request_demotion(int(l), int(e))
        for l, e in promotions:
            self.tm.request_promotion(int(l), int(e))
        self.tm.drain()
        self.tm.publish_ready()

    def flush(self) -> None:
        """Block on all in-flight transitions and publish (tests/shutdown)."""
        self.tm.drain()
        self.tm.publish_ready(wait=True)
        # Anything still deferred (budget) is retried once after publish.
        self.tm.drain()
        self.tm.publish_ready(wait=True)


class EPCoordinator:
    """Hotness-aware expert-ownership rebalancer for expert-parallel serving.

    Shard ``j`` of the model axis owns expert positions
    ``[j·E/n, (j+1)·E/n)`` — the bank's lo/hi leaves are sharded along the
    expert/slot dims, so position IS placement. When traffic skews hot onto
    one shard, that shard's local hi-slot budget saturates while others idle.
    The coordinator periodically reads the folded per-shard hotness (the
    per-expert counts are psum'd across every token shard inside the MoE
    body — that psum is the "all-gather" of per-shard counters; the
    host-side fold here sees the global view each shard would) and migrates
    expert *ownership* by relabeling: swap the hottest expert on the
    most-loaded shard with the coldest on the least-loaded one. A relabel
    swaps the pair's router columns, lo rows, host-hi rows and hotness
    history; the forward function is invariant under it (the router swap
    compensates the weight swap), so it applies between engine steps through
    the existing stable handles with no forward-pass glitch. Both experts
    must be RESIDENT_LO — hi residents are demoted (and their slots drained)
    first, since their hi slots live in shard-local HBM and cannot move.
    """

    def __init__(self, n_shards: int, cfg: Optional[RebalanceConfig] = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.n_shards = n_shards
        self.cfg = cfg if cfg is not None else RebalanceConfig()
        self._entries = []   # (controller, moe_params dict, placement (L,E))
        self.stats = {"migrations": 0, "windows": 0, "bytes_moved": 0,
                      "deferred_migrations": 0, "aborted_migrations": 0}
        self._last = time.monotonic()
        self.tracer = None   # FlightRecorder, attached by the serving layer
        self.injector = None  # FaultInjector, attached by the serving layer

    def register(self, ctl: DynaExqController, moe_params: Dict) -> None:
        """Track one MoE position: its controller and the live params dict
        holding the ``router`` leaf (mutated in place on migration)."""
        L, E = ctl.tm.state.shape
        if E % self.n_shards:
            raise ValueError(f"E={E} not divisible by n_shards={self.n_shards}")
        placement = np.tile(np.arange(E), (L, 1))   # position → original expert
        self._entries.append((ctl, moe_params, placement))

    # -- policy ----------------------------------------------------------
    def shard_loads(self, scores_row: np.ndarray) -> np.ndarray:
        """(E,) per-expert hotness → (n_shards,) per-shard load."""
        return scores_row.reshape(self.n_shards, -1).sum(axis=1)

    def maybe_rebalance(self, now: Optional[float] = None,
                        force: bool = False) -> int:
        now = now if now is not None else time.monotonic()
        if not force and now - self._last < self.cfg.interval_s:
            return 0
        self._last = now
        return self.rebalance()

    def rebalance(self) -> int:
        """One coordinator window: per layer, swap hottest-on-max-shard with
        coldest-on-min-shard while the skew ratio exceeds the threshold."""
        self.stats["windows"] += 1
        if self.n_shards < 2:
            return 0
        total = 0
        for ctl, moe_params, placement in self._entries:
            # Unfolded EMA + counts accumulated since the last fold: the
            # freshest global view without perturbing the fold cadence.
            hot = ctl.hotness.scores + ctl.hotness.counts
            L, E = hot.shape
            e_per = E // self.n_shards
            moved = 0
            for l in range(L):
                while moved < self.cfg.max_migrations_per_window:
                    loads = self.shard_loads(hot[l])
                    donor = int(loads.argmax())
                    recv = int(loads.argmin())
                    if donor == recv or loads[donor] <= \
                            self.cfg.skew_threshold * max(loads[recv], 1e-9):
                        break
                    d0, r0 = donor * e_per, recv * e_per
                    e = d0 + int(hot[l, d0:d0 + e_per].argmax())
                    f = r0 + int(hot[l, r0:r0 + e_per].argmin())
                    if hot[l, e] <= hot[l, f]:
                        break
                    # Admit the swap only if it strictly shrinks the max
                    # shard load: monotone descent terminates, and a single
                    # red-hot expert can never ping-pong between shards
                    # within one window (donor→recv then straight back).
                    delta = hot[l, e] - hot[l, f]
                    if max(loads[donor] - delta, loads[recv] + delta) >= \
                            loads[donor]:
                        break
                    if not self._migrate(ctl, moe_params, placement, l, e, f):
                        break
                    hot[l, [e, f]] = hot[l, [f, e]]
                    moved += 1
                    total += 1
        self.stats["migrations"] += total
        return total

    # -- mechanism -------------------------------------------------------
    def _migrate(self, ctl: DynaExqController, moe_params: Dict,
                 placement: np.ndarray, l: int, e: int, f: int) -> bool:
        """Relabel experts ``e`` and ``f`` at layer ``l``. Returns False if
        either side could not be brought to RESIDENT_LO (in-flight
        promotion) — the pair is retried at the next window."""
        tm = ctl.tm
        bank = ctl.bank
        # Relabeling ships both experts' lo rows across the interconnect —
        # price those bytes into the SAME per-window transfer budget
        # promotions draw from (``migration_bytes_per_window``), so a
        # window saturated by promotions defers rebalancing (and vice
        # versa) instead of silently exceeding the transfer envelope.
        relabel_bytes = 2 * sum(
            (qt.packed.nbytes + qt.scales.nbytes)
            // (qt.packed.shape[0] * qt.packed.shape[1])
            for qt in bank.lo.values())
        if not tm.try_consume_window(relabel_bytes):
            self.stats["deferred_migrations"] += 1
            return False
        lo_val = Residency.RESIDENT_LO.value
        if tm.state[l, e] != lo_val or tm.state[l, f] != lo_val:
            tm.request_demotion(l, e)
            tm.request_demotion(l, f)
            tm.drain()
            tm.publish_ready(wait=True)
        if tm.state[l, e] != lo_val or tm.state[l, f] != lo_val:
            return False
        fault = None
        if self.injector is not None:
            fault = self.injector.fire("ep_mig", layer=l, expert=e, peer=f)
        if fault is not None and fault.kind == "fail":
            # Abort before any mutation: refund the window bytes so the
            # budget only prices transfers that landed; retried next window.
            tm.refund_window(relabel_bytes)
            self.stats["aborted_migrations"] += 1
            return False
        li, ei, fi = np.int32(l), np.int32(e), np.int32(f)
        moved = 0
        applied = []
        try:
            for i_leaf, (name, qt) in enumerate(list(bank.lo.items())):
                packed = swap_expert_rows(qt.packed, li, ei, fi)
                scales = swap_expert_rows(qt.scales, li, ei, fi)
                bank.lo[name] = dataclasses.replace(qt, packed=packed,
                                                    scales=scales)
                applied.append(name)
                moved += (packed.nbytes + scales.nbytes) // (
                    packed.shape[0] * packed.shape[1])
                if fault is not None and i_leaf == 0:
                    # Injected mid-swap failure: some leaves relabeled,
                    # the rest (and the compensating router swap) not yet —
                    # exactly the partial-swap state that must roll back.
                    raise TransferFault("ep_mig", kind=fault.kind,
                                        seq=fault.seq)
        except TransferFault:
            # Partial-swap abort: a second swap of the same pair restores
            # the applied leaves bit-exactly. The router column swap only
            # happens after ALL leaves land, so the forward function stayed
            # invariant throughout (swap+swap = identity per leaf).
            for name in applied:
                qt = bank.lo[name]
                packed = swap_expert_rows(qt.packed, li, ei, fi)
                scales = swap_expert_rows(qt.scales, li, ei, fi)
                bank.lo[name] = dataclasses.replace(qt, packed=packed,
                                                    scales=scales)
            tm.refund_window(relabel_bytes)
            self.stats["aborted_migrations"] += 1
            if self.tracer is not None:
                self.tracer.instant("fault_cancel", cat="fault", site="ep_mig",
                                    layer=l, expert=e, peer=f)
            return False
        moe_params["router"] = swap_router_cols(moe_params["router"],
                                                li, ei, fi)
        for name, arr in tm.host_hi.items():
            if not arr.flags.writeable:
                # np.asarray over a device array yields a read-only view;
                # the first migration takes the one-time writable copy.
                arr = arr.copy()
                tm.host_hi[name] = arr
            arr[l, [e, f]] = arr[l, [f, e]]
        swap_masks = getattr(tm.host_hi, "swap_experts", None)
        if swap_masks is not None:      # HostExpertStore: relabel its
            swap_masks(l, e, f)         # presence/residency masks too
        ctl.hotness.swap(l, e, f)
        placement[l, [e, f]] = placement[l, [f, e]]
        # Both directions of the pairwise exchange cross the interconnect.
        self.stats["bytes_moved"] += 2 * moved
        if self.tracer is not None:
            self.tracer.instant("ep_migration", cat="ep", layer=l, expert=e,
                                peer=f, bytes=2 * moved)
        return True
