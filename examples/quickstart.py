"""Quickstart: build a reduced MoE, train it briefly, quantize it, and serve
it with DynaExq online precision allocation.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ControllerConfig
from repro.models import init_params
from repro.serving import MoEServer, ServeConfig, make_prompts
from repro.training import SyntheticLMTask, TrainConfig, train_loop
from repro.training.adamw import AdamWConfig


def main():
    # 1. A reduced Qwen3-MoE-family config (any of the ten assigned archs
    #    works: get_config("<arch-id>") for the full production config).
    cfg = get_config("granite-moe-1b-a400m", reduced=True)
    print(f"arch={cfg.name}  layers={cfg.n_layers}  experts/layer="
          f"{cfg.moe.num_experts} top-{cfg.moe.top_k}")

    # 2. Train a few steps on the synthetic LM task (real learned weights
    #    make the quality comparison meaningful).
    params = init_params(jax.random.PRNGKey(0), cfg)
    task = SyntheticLMTask(cfg.vocab_size, seed=0)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=2e-3, total_steps=60))
    params, _, hist = train_loop(cfg, params, task.batches(16, 65, 60), tcfg,
                                 log_every=20)

    # 3. Serve with DynaExq: int4 lo tier always resident, a budget-limited
    #    bf16 hi pool, residency driven online by router traces.
    srv = MoEServer(
        cfg, params,
        ServeConfig(mode="dynaexq", lo_bits=4, n_hi_per_layer=1, max_len=96,
                    controller=ControllerConfig(update_interval_s=0.0)),
        batch=4)
    prompts = jnp.asarray(make_prompts("text", cfg.vocab_size, 4, 32))
    out, ttft, times = srv.generate({"tokens": prompts}, 8)
    srv.flush()
    print(f"generated {out.shape}  TTFT={ttft*1e3:.1f}ms  "
          f"TPOP={1e3*sum(times)/len(times):.1f}ms")
    print("hi-precision residency per layer:", srv.hi_sets()["0"])
    print("transition stats:", srv.controllers["0"].tm.stats)


if __name__ == "__main__":
    main()
