"""Hotness estimator (paper §3.5): EMA fold semantics."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hotness import HotnessEstimator


def test_fold_ema_math():
    h = HotnessEstimator(1, 3, alpha=0.5)
    h.observe([[10, 0, 2]])
    s1 = h.fold().copy()
    np.testing.assert_allclose(s1, [[5.0, 0.0, 1.0]])
    h.observe([[0, 4, 2]])
    s2 = h.fold()
    np.testing.assert_allclose(s2, [[2.5, 2.0, 1.5]])
    assert h.counts.sum() == 0   # counters reset each interval


def test_observe_accumulates_within_interval():
    h = HotnessEstimator(2, 2, alpha=0.0)
    h.observe([[1, 2], [3, 4]])
    h.observe([[1, 0], [0, 1]])
    s = h.fold()
    np.testing.assert_allclose(s, [[2, 2], [3, 5]])


@settings(max_examples=30, deadline=None)
@given(alpha=st.floats(0.0, 0.99), n=st.integers(1, 20),
       seed=st.integers(0, 999))
def test_scores_bounded_by_max_interval_count(alpha, n, seed):
    """EMA of nonneg counts is bounded by the max per-interval count."""
    rng = np.random.default_rng(seed)
    h = HotnessEstimator(1, 4, alpha=alpha)
    mx = 0
    for _ in range(n):
        c = rng.integers(0, 100, size=(1, 4))
        mx = max(mx, c.max())
        h.observe(c)
        h.fold()
    assert (h.scores <= mx + 1e-9).all()
    assert (h.scores >= 0).all()


def test_shape_validation():
    h = HotnessEstimator(2, 4)
    with pytest.raises(ValueError):
        h.observe(np.zeros((3, 4)))
    with pytest.raises(ValueError):
        HotnessEstimator(1, 1, alpha=1.0)
