from repro.models.config import ArchConfig, AttnConfig, MoEConfig, SSMConfig
from repro.models.model import (
    init_params, init_caches, init_paged_caches, attn_logical_capacity,
    forward_train, prefill, prefill_paged, decode_step, decode_step_paged,
    spec_draft, spec_verify, DecodeCaches,
)

__all__ = [
    "ArchConfig", "AttnConfig", "MoEConfig", "SSMConfig",
    "init_params", "init_caches", "init_paged_caches",
    "attn_logical_capacity", "forward_train", "prefill", "prefill_paged",
    "decode_step", "decode_step_paged", "spec_draft", "spec_verify",
    "DecodeCaches",
]
