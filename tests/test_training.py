"""Training substrate: learnability, optimizer math, checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.training import (SyntheticLMTask, TrainConfig, load_checkpoint,
                            save_checkpoint, train_loop)
from repro.training.adamw import AdamWConfig, adamw_init, adamw_update


def test_loss_decreases_tiny_moe():
    cfg = get_config("granite-moe-1b-a400m", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    task = SyntheticLMTask(cfg.vocab_size, seed=0)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=2e-3, warmup_steps=5,
                                             total_steps=80))
    params, _, hist = train_loop(cfg, params, task.batches(16, 33, 80), tcfg,
                                 log_every=79, log=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


def test_adamw_decoupled_decay_and_clip():
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 100.0)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=1.0,
                      warmup_steps=1, total_steps=10)
    st = adamw_init(params)
    p2, st2, m = adamw_update(cfg, params, grads, st)
    assert float(m["gnorm"]) > 1.0
    assert np.all(np.isfinite(np.asarray(p2["w"])))
    # decay-only behaviour: zero grad, nonzero decay shrinks weights
    cfg2 = AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=1)
    p3, _, _ = adamw_update(cfg2, params, {"w": jnp.zeros((4,))}, adamw_init(params))
    assert float(p3["w"][0]) < 1.0


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("granite-moe-1b-a400m", reduced=True)
    params = init_params(jax.random.PRNGKey(3), cfg)
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params, step=7)
    like = init_params(jax.random.PRNGKey(4), cfg)
    restored, step = load_checkpoint(path, like)
    assert step == 7
    a = jax.tree_util.tree_leaves(params)
    b = jax.tree_util.tree_leaves(restored)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_synthetic_task_deterministic_and_learnable_structure():
    t = SyntheticLMTask(128, seed=1)
    a = t.sample(4, 32, seed=5)
    b = t.sample(4, 32, seed=5)
    np.testing.assert_array_equal(a, b)
    # successors come from the table ≥ (1 - noise) of the time
    toks = t.sample(64, 64, seed=9, noise=0.1)
    hits = 0
    total = 0
    for row in toks:
        for i in range(len(row) - 1):
            total += 1
            hits += row[i + 1] in t.table[row[i]]
    assert hits / total > 0.8
