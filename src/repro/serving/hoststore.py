"""Pinned host-DRAM expert store — the third tier of the residency ladder.

The ladder is hi-bf16 ↔ lo-int4/int2 ↔ host-DRAM, all governed by the global
allocator (``core.allocator``). This module owns everything host-side:

* the **hi source** rows ``TransitionManager`` copies from on promotion —
  either materialized upfront (``np.asarray`` of the dense experts, the
  classic path) or lazily from checkpoint shards via ``hi_loader``
  (streaming cold start: the host tier itself backfills in hotness order,
  so a large model never needs to fully materialize);
* the **lo staging pipeline**: host→lo promotion and cold-start backfill
  issue real async H2D writes of the packed lo rows
  (``ver.write_lo_expert``) and publish by flipping the residency masks
  only once the copy's own result arrays are ready — the same
  publish-then-switch discipline ``TransitionManager`` uses for hi slots,
  so a forward pass never observes a partially materialized expert;
* the residency masks: ``lo_valid`` (device lo rows hold real weights —
  monotone under serving, the cold-start gate) and ``lo_resident``
  (the allocator's accounting: a valid-but-nonresident cell has been
  demoted to the host tier and pays a modeled demand-fetch stall when
  routed);
* the ``FetchModel`` transfer-cost model shared with ``OffloadBackend``
  (absorbed into the ladder rather than sitting beside it).

The store duck-types the ``host_hi`` mapping interface (``items`` /
``__getitem__`` / ``__setitem__``) that ``TransitionManager`` and
``EPCoordinator`` already speak, plus ``ensure_hi`` for lazy shard loads.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.ver import ExpertBankQ, write_lo_expert, write_lo_rows
from repro.fault.inject import TransferFault
from repro.fault.retry import RetryExhausted, RetryPolicy, retry_call


@dataclasses.dataclass
class FetchModel:
    """Deterministic host↔device transfer-cost model (PCIe gen4 x16 by
    default — the paper's A6000). Layered on measured compute so backend
    comparisons reflect transfer volume, not CPU noise."""

    gbps: float = 16.0

    @property
    def bytes_per_s(self) -> float:
        return self.gbps * 1e9

    def stall_s(self, demand_bytes: int, overlap_bytes: int = 0,
                compute_s: float = 0.0) -> float:
        """Critical-path seconds: demand fetches always stall; overlapped
        (prefetch) bytes hide under ``compute_s`` and only their spill
        stalls."""
        spill = max(0.0, overlap_bytes - compute_s * self.bytes_per_s)
        return (demand_bytes + spill) / self.bytes_per_s


@dataclasses.dataclass
class _PendingLo:
    layer: int
    expert: int
    resident: bool            # reserve-accounted (vs transient cold-stage)
    nbytes: int
    arrays: tuple             # THIS copy's result arrays (probe these —
                              # the bank's leaves track only the newest
                              # staged copy, same hazard as hi promotions)


class HostExpertStore:
    def __init__(self, shapes: Dict[str, tuple],
                 hi: Optional[Dict[str, np.ndarray]] = None,
                 hi_loader: Optional[Callable[[int, int],
                                              Dict[str, np.ndarray]]] = None,
                 lo_loader: Optional[Callable[[int],
                                              Dict[str, np.ndarray]]] = None,
                 lo_valid_init: bool = True):
        """``shapes``: name → (L, E, K, N) dense shapes. ``hi``: fully
        materialized host rows (classic path). ``hi_loader(l, e)``: lazy
        per-expert source (streaming). ``lo_loader(l)``: per-layer packed
        lo rows, keys ``f"{name}.packed"``/``f"{name}.scales"`` with
        leading dim E (streaming cold start + host→lo staging)."""
        first = next(iter(shapes.values()))
        self.L, self.E = int(first[0]), int(first[1])
        self.shapes = dict(shapes)
        if hi is None and hi_loader is None:
            raise ValueError("need materialized hi rows or a hi_loader")
        self.hi: Dict[str, np.ndarray] = hi if hi is not None else {
            n: np.zeros(tuple(s), np.float32)
            for n, s in sorted(shapes.items())}
        self.hi_present = np.full((self.L, self.E), hi is not None, bool)
        self._hi_loader = hi_loader
        self._lo_loader = lo_loader
        self._lo_cache: Tuple[Optional[int], Optional[Dict]] = (None, None)
        self.lo_valid = np.full((self.L, self.E), lo_valid_init, bool)
        self.lo_resident = self.lo_valid.copy()
        self._staging: List[_PendingLo] = []
        self.stats = {"hi_loads": 0, "hi_bytes_loaded": 0,
                      "lo_staged": 0, "lo_bytes_staged": 0,
                      "retries": 0, "retry_stall_s": 0.0, "quarantines": 0}
        self.tracer = None   # FlightRecorder, attached by the serving layer
        # Fault tolerance: host loads and staging retry under ``retry``;
        # a cell whose staging source exhausts its retries is quarantined —
        # served from host (demand-fetch stall, zero-weight device rows
        # never referenced as valid) instead of blocking ``lo_complete``
        # forever. Healing: a later successful re-stage clears the flag.
        self.injector = None  # repro.fault.inject.FaultInjector
        self.retry = RetryPolicy()
        self.quarantined = np.zeros((self.L, self.E), bool)

    # -- host_hi mapping interface (TransitionManager / EPCoordinator) ----
    def items(self):
        return self.hi.items()

    def __getitem__(self, name: str) -> np.ndarray:
        return self.hi[name]

    def __setitem__(self, name: str, arr: np.ndarray) -> None:
        self.hi[name] = arr

    def swap_experts(self, layer: int, e: int, f: int) -> None:
        """EP relabeling: the residency/presence masks follow their expert
        (the hi row swap itself runs through the mapping interface)."""
        for m in (self.hi_present, self.lo_valid, self.lo_resident,
                  self.quarantined):
            m[layer, [e, f]] = m[layer, [f, e]]

    # -- fault plumbing ---------------------------------------------------
    def _seed(self) -> int:
        return self.injector.seed if self.injector is not None else 0

    def _fire(self, site: str, **ctx) -> None:
        """Evaluate the fault plan at a host-transfer site. ``stall`` is
        absorbed as modeled stall seconds; ``fail`` raises a retryable
        `TransferFault`; ``corrupt`` is a failed checksum — also retried."""
        if self.injector is None:
            return
        f = self.injector.fire(site, **ctx)
        if f is None:
            return
        if f.kind == "stall":
            self.stats["retry_stall_s"] += f.stall_s
            return
        raise TransferFault(site, kind=f.kind, seq=f.seq)

    def _retry(self, fn, site: str, key: int):
        """Run one host transfer under the shared retry policy, accounting
        retries + modeled backoff. `RetryExhausted` propagates to the
        caller's graceful-degradation path."""
        try:
            out, retries, waited = retry_call(
                fn, self.retry, seed=self._seed(), key=key, site=site,
                tracer=self.tracer)
        except RetryExhausted as e:
            # The attempts were still made (and their backoff modeled) —
            # account them before the degradation path takes over.
            self.stats["retries"] += e.attempts - 1
            self.stats["retry_stall_s"] += e.waited_s
            raise
        if retries:
            self.stats["retries"] += retries
            self.stats["retry_stall_s"] += waited
        return out

    # -- hi tier (host side) ----------------------------------------------
    def ensure_hi(self, layer: int, expert: int) -> None:
        """Materialize one expert's host hi rows (lazy shard read). Called
        by ``TransitionManager._issue_copy`` right before the H2D copy —
        hi backfill therefore follows promotion order, i.e. hotness."""
        if self.hi_present[layer, expert]:
            return
        if self._hi_loader is None:
            raise RuntimeError(
                f"expert ({layer}, {expert}) absent from the host store "
                f"and no hi_loader configured")

        def attempt():
            self._fire("host_hi", layer=layer, expert=expert)
            return self._hi_loader(layer, expert)

        rows = self._retry(attempt, "host_hi", (layer << 16) | expert)
        nbytes = 0
        for name, arr in self.hi.items():
            r = np.asarray(rows[name])
            arr[layer, expert] = r.astype(arr.dtype)
            nbytes += r.nbytes
        self.hi_present[layer, expert] = True
        self.stats["hi_loads"] += 1
        self.stats["hi_bytes_loaded"] += nbytes

    # -- lo tier (device staging) -----------------------------------------
    def _lo_rows(self, layer: int) -> Dict[str, np.ndarray]:
        if self._lo_loader is None:
            raise RuntimeError("no lo_loader configured for lo staging")
        cl, rows = self._lo_cache
        if cl != layer:
            def attempt():
                self._fire("host_lo", layer=layer)
                return self._lo_loader(layer)
            rows = self._retry(attempt, "host_lo", layer)
            self._lo_cache = (layer, rows)
        return rows

    def stage_lo(self, bank: ExpertBankQ, layer: int, expert: int,
                 resident: bool = True) -> int:
        """Issue the async H2D write of one expert's packed lo rows into
        the bank; returns the bytes in flight. The rows stay unreferenced
        (``lo_valid`` unflipped) until ``publish_lo`` sees the copy's own
        result arrays ready."""
        def fetch():
            self._fire("stage_lo", layer=layer, experts=1)
            return self._lo_rows(layer)
        rows = self._retry(fetch, "stage_lo", (layer << 16) | expert)
        arrays = []
        nbytes = 0
        li, ei = np.int32(layer), np.int32(expert)
        for name, qt in bank.lo.items():
            packed = write_lo_expert(qt.packed, li, ei,
                                     rows[f"{name}.packed"][expert])
            scales = write_lo_expert(qt.scales, li, ei,
                                     rows[f"{name}.scales"][expert])
            bank.lo[name] = dataclasses.replace(qt, packed=packed,
                                                scales=scales)
            arrays += [packed, scales]
            nbytes += (packed.nbytes + scales.nbytes) // (self.L * self.E)
        self._staging.append(_PendingLo(layer, expert, resident, nbytes,
                                        tuple(arrays)))
        self.stats["lo_staged"] += 1
        self.stats["lo_bytes_staged"] += nbytes
        if self.tracer is not None:
            self.tracer.instant("host_stage", cat="host", layer=layer,
                                experts=1, bytes=nbytes)
        return nbytes

    def stage_lo_batch(self, bank: ExpertBankQ, layer: int, experts,
                       resident) -> int:
        """Bulk-stage several experts of one layer: ONE device write per
        bank leaf instead of one per expert cell — the cold-start pump's
        fast path (dispatch overhead, not bytes, dominates tiny rows).
        ``resident`` is a per-expert bool sequence; publish semantics are
        identical to issuing ``stage_lo`` per cell."""
        idx = np.asarray(list(experts), np.int32)
        res = np.asarray(list(resident), bool)

        def fetch():
            self._fire("stage_lo", layer=layer, experts=int(idx.size))
            return self._lo_rows(layer)

        rows = self._retry(fetch, "stage_lo", layer)
        arrays = []
        nbytes = 0
        li = np.int32(layer)
        for name, qt in bank.lo.items():
            packed = write_lo_rows(qt.packed, li, idx,
                                   rows[f"{name}.packed"][idx])
            scales = write_lo_rows(qt.scales, li, idx,
                                   rows[f"{name}.scales"][idx])
            bank.lo[name] = dataclasses.replace(qt, packed=packed,
                                                scales=scales)
            arrays += [packed, scales]
            nbytes += (packed.nbytes + scales.nbytes) * idx.size \
                // (self.L * self.E)
        self._staging.append(_PendingLo(layer, idx, res, nbytes,
                                        tuple(arrays)))
        self.stats["lo_staged"] += int(idx.size)
        self.stats["lo_bytes_staged"] += nbytes
        if self.tracer is not None:
            self.tracer.instant("host_stage", cat="host", layer=layer,
                                experts=int(idx.size), bytes=nbytes)
        return nbytes

    def publish_lo(self, wait: bool = False) -> int:
        """Flip residency masks for completed staging copies (window
        boundary). Each pending entry is probed on ITS OWN result arrays."""
        if not self._staging:
            return 0
        still: List[_PendingLo] = []
        published = 0
        for p in self._staging:
            ready = wait or all(_is_ready(a) for a in p.arrays)
            if ready and wait:
                for a in p.arrays:
                    jax.block_until_ready(a)
            if not ready:
                still.append(p)
                continue
            ex = np.atleast_1d(np.asarray(p.expert))
            res = np.broadcast_to(np.atleast_1d(np.asarray(p.resident)),
                                  ex.shape)
            self.lo_valid[p.layer, ex] = True
            self.lo_resident[p.layer, ex[res]] = True
            # Healing: real rows just landed for these cells — any
            # quarantine from an earlier failed staging is lifted.
            self.quarantined[p.layer, ex] = False
            published += int(ex.size)
        self._staging = still
        if published and self.tracer is not None:
            self.tracer.instant("lo_publish", cat="host", experts=published)
        return published

    @property
    def staging_inflight(self) -> int:
        return len(self._staging)

    @property
    def lo_complete(self) -> bool:
        """Every expert's device lo rows hold real weights (or the cell is
        quarantined and served from host) — the serving gate on a streaming
        cold start. Quarantine keeps one unreadable shard from blocking
        ``serving_ready()`` forever."""
        return bool((self.lo_valid | self.quarantined).all()) \
            and not self._staging

    def quarantine(self, layer: int, experts) -> int:
        """Mark cells whose staging source exhausted its retries: they are
        served from the host tier (demand-fetch pricing, requests routed to
        them flagged degraded) and re-staged opportunistically until a copy
        lands and heals them."""
        ex = np.atleast_1d(np.asarray(experts, np.int64))
        ex = ex[~self.lo_valid[layer, ex]]      # valid cells need no rescue
        fresh = ex[~self.quarantined[layer, ex]]
        self.quarantined[layer, fresh] = True
        n = int(fresh.size)
        self.stats["quarantines"] += n
        if n and self.tracer is not None:
            self.tracer.instant("quarantine", cat="fault", layer=layer,
                                experts=n)
        return n

    def check_invariants(self) -> None:
        """Residency-ladder invariants: a lo-resident cell must be valid
        (accounting never outruns materialization), and a staged-but-
        unpublished cell is never already marked valid by that staging."""
        assert (self.lo_valid | ~self.lo_resident).all(), \
            "lo_resident cell with invalid device rows"
        # A quarantined cell is by definition not materialized on device:
        # never valid (healing clears the flag at publish) and never
        # counted resident by the allocator.
        assert not (self.quarantined & self.lo_valid).any(), \
            "quarantined cell marked lo_valid"
        assert not (self.quarantined & self.lo_resident).any(), \
            "quarantined cell counted lo_resident"
        if self._hi_loader is None:
            assert self.hi_present.all()


def _is_ready(arr) -> bool:
    try:
        return arr.is_ready()
    except AttributeError:
        jax.block_until_ready(arr)
        return True
