"""BudgetTracker / SlotPool / budget planning (paper §3.3)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.budget import BudgetExceeded, BudgetTracker, plan_budget
from repro.core.pools import SlotPool


@settings(max_examples=60, deadline=None)
@given(cap=st.integers(0, 1000),
       ops=st.lists(st.integers(1, 200), max_size=40))
def test_tracker_never_exceeds_cap(cap, ops):
    t = BudgetTracker(cap)
    reserved = []
    for n in ops:
        if t.try_reserve(n):
            reserved.append(n)
        assert 0 <= t.used <= cap
        # OOM-safety invariant: used equals the sum of granted reservations
        assert t.used == sum(reserved)
    for n in reserved:
        t.release(n)
    assert t.used == 0


def test_tracker_release_underflow():
    t = BudgetTracker(10)
    assert t.try_reserve(5)
    with pytest.raises(BudgetExceeded):
        t.release(6)


def test_slot_pool_constant_time_semantics():
    p = SlotPool(3)
    s = [p.alloc(e) for e in (7, 8, 9)]
    assert sorted(s) == [0, 1, 2] and p.n_free == 0
    with pytest.raises(RuntimeError):
        p.alloc(1)
    p.free(s[1])
    assert p.n_free == 1
    s2 = p.alloc(42)
    assert s2 == s[1] and p.owner(s2) == 42


def test_slot_pool_allocates_lowest_index_first():
    """Occupied hi slots stay packed toward the low end of the pool (the
    contiguous prefix the ragged kernel's BlockSpec indexing wants)."""
    p = SlotPool(4)
    s = [p.alloc(e) for e in (10, 11, 12, 13)]
    assert s == [0, 1, 2, 3]
    p.free(2)
    p.free(0)
    assert p.alloc(20) == 0            # lowest free slot, not LIFO
    assert p.alloc(21) == 2
    assert p.slots_of() == {0: 20, 1: 11, 2: 21, 3: 13}


def test_plan_budget_derives_n_hi():
    # 10 GB device, 2 GB fixed, 1 GB lo tier, hi expert = 50 MB, 16 layers.
    plan = plan_budget(m_total=10 << 30, m_fixed=2 << 30,
                       lo_bytes_total=1 << 30,
                       hi_bytes_per_expert_layer=50 << 20,
                       n_layers=16, num_experts=64)
    assert plan.n_hi_per_layer == ((7 << 30) // ((50 << 20) * 16))
    plan.check()


def test_plan_budget_infeasible_lo():
    with pytest.raises(BudgetExceeded):
        plan_budget(m_total=1 << 30, m_fixed=512 << 20,
                    lo_bytes_total=1 << 30, hi_bytes_per_expert_layer=1 << 20,
                    n_layers=4, num_experts=8)


def test_plan_budget_alignment():
    plan = plan_budget(m_total=100 << 30, m_fixed=0, lo_bytes_total=0,
                       hi_bytes_per_expert_layer=1 << 30, n_layers=10,
                       num_experts=64, align=4)
    assert plan.n_hi_per_layer % 4 == 0
