"""Sharding planner: param-tree paths → PartitionSpecs.

Baseline rules (§6 of DESIGN.md). Every rule degrades to replication rather
than failing, and the planner records *why* (the roofline §Perf loop reads
this to find sharding-limited architectures):

* embeddings (V, d)            → (model, None); lm_head (d, V) → (None, model)
* attention, heads divisible   → shard the head (q_dim) axis over model
* attention, heads NOT divisible → shard the d_model (contraction) axis —
  params still split 16-way, at the cost of an all-reduce after the matmul
* dense FFN                    → (None, model) / (model, None) classic TP
* MoE expert banks             → expert axis over model (expert parallelism);
  DynaExq hi pool + packed lo pool shard the same way; slot maps replicate
* Mamba in/out projections     → contraction-axis sharding (the concatenated
  zxBCdt output axis cannot be split without segment-aware reshards)
* batch dims of activations/caches → ('pod','data'); KV cache sequence axis
  → model (flash-decode style) so 32k-context decode fits HBM
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


def _div(n: int, d: int) -> bool:
    return n % d == 0


def _flat(spec_entry):
    """Axis names in one PartitionSpec entry (str | tuple | None)."""
    if spec_entry is None:
        return ()
    return (spec_entry,) if isinstance(spec_entry, str) else tuple(spec_entry)


class ShardingPlanner:
    def __init__(self, cfg: ArchConfig, mesh: Mesh,
                 notes: list | None = None, seq_shard_cache: bool = True,
                 pad_heads: bool = False, fsdp: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.model_n = mesh.shape["model"]
        self.dp = tuple(a for a in mesh.axis_names if a != "model")
        self.dp_n = int(np.prod([mesh.shape[a] for a in self.dp]))
        self.notes = notes if notes is not None else []
        self.seq_shard_cache = seq_shard_cache
        self.pad_heads = pad_heads  # §Perf variant: uneven head sharding
        # FSDP (train): additionally shard params/optimizer over the data
        # axes on one divisible dim — 30B×(2+8)B of params+AdamW moments
        # cannot live 16-way-sharded on 16 GB chips.
        self.fsdp = fsdp

    # ---- leaves ---------------------------------------------------------
    def spec_for_param(self, path: str, shape: tuple) -> P:
        spec = self._base_param_spec(path, shape)
        if self.fsdp and shape:
            spec = self._add_fsdp(spec, shape)
        return spec

    def _add_fsdp(self, spec: P, shape: tuple) -> P:
        parts = list(spec) + [None] * (len(shape) - len(spec))
        best, best_dim = None, -1
        for i, (axis, dim) in enumerate(zip(parts, shape)):
            if axis is None and dim % self.dp_n == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best is None:
            return spec
        parts[best] = self.dp if len(self.dp) > 1 else self.dp[0]
        return P(*parts)

    def _base_param_spec(self, path: str, shape: tuple) -> P:
        cfg, mn = self.cfg, self.model_n
        p = path.lower()
        nd = len(shape)

        def lead(spec_tail: tuple) -> P:
            """Prepend Nones for stacked (layer) leading dims."""
            return P(*((None,) * (nd - len(spec_tail)) + spec_tail))

        if "embed" in p:
            return P("model", None) if _div(shape[0], mn) else P()
        if "lm_head" in p:
            return P(None, "model") if _div(shape[1], mn) else P()
        if ("wq" in p or "wk" in p or "wv" in p or "wo" in p) and cfg.attn:
            a = cfg.attn
            heads = a.n_heads if ("wq" in p or "wo" in p) else a.n_kv_heads
            if _div(heads, mn) or (self.pad_heads and "cross" not in p):
                if "wo" in p:
                    return lead(("model", None))
                return lead((None, "model"))
            # non-divisible heads: replicate the projections (FSDP still
            # shards their storage over data) and let the model apply
            # sequence-parallel attention (layers._seq_parallel_constraint).
            self._note(f"{path}: {heads} heads % {mn} != 0 → replicated "
                       f"params + sequence-parallel attention")
            return lead(())
        if "experts" in p or (".lo" in p or ".hi" in p):
            # stacked expert banks: (L, E, K, N) / packed / scales / hi pool
            if nd >= 3 and _div(shape[1], mn):
                return P(None, "model", *(None,) * (nd - 2))
            self._note(f"{path}: expert dim {shape} not divisible → replicated")
            return lead(())
        if "slot" in p:
            return lead(())
        if "router" in p:
            return lead(())
        if "mlp" in p or "shared" in p:
            if "w_down" in p:
                return lead(("model", None)) if _div(shape[-2], mn) else lead(())
            return lead((None, "model")) if _div(shape[-1], mn) else lead(())
        if "in_proj" in p or "out_proj" in p:
            # contraction sharding (see module docstring)
            return lead(("model", None)) if _div(shape[-2], mn) else lead(())
        return lead(())  # norms, conv, A_log, biases, scalars

    def _batch_spec(self, batch: int):
        """Batch dims shard over data×model when divisible (serving: keeps
        attention fully batch-local — no seq/head resharding collectives),
        else data-only, else replicated."""
        full = self.dp + ("model",)
        if _div(batch, self.dp_n * self.model_n):
            return full
        if _div(batch, self.dp_n):
            return self.dp
        if _div(batch, self.mesh.shape[self.dp[-1]]):
            return self.dp[-1]
        return None

    def spec_for_cache(self, path: str, shape: tuple) -> P:
        """Caches are stacked (nsb, B, ...)."""
        p = path.lower()
        nd = len(shape)
        batch = shape[1] if nd > 1 else 1
        bspec = self._batch_spec(batch)
        if bspec is None and batch > 1:
            self._note(f"{path}: cache batch {batch} → replicated")
        if "cross" in p and nd == 5:
            # (nsb, B, Senc, Hkv, hd) — encoder cross-attn KV, seq-major.
            return P(None, bspec, None, None, None)
        if (".k" in p or ".v" in p) and nd == 5:
            # (nsb, B, Hkv, C, hd) — head-major decode cache; shard the
            # sequence axis (3) over model when the batch does not use it.
            seq = "model" if (self.seq_shard_cache and bspec is not None
                              and "model" not in _flat(bspec)
                              and _div(shape[3], self.model_n)) else None
            return P(None, bspec, None, seq, None)
        if "state" in p and nd == 5:   # (nsb, B, H, P, N)
            return P(None, bspec, None, None, None)
        if "conv" in p and nd == 4:    # (nsb, B, K, c)
            return P(None, bspec, None, None)
        return P(*((None,) * nd))

    def spec_for_input(self, name: str, shape: tuple) -> P:
        nd = len(shape)
        if nd == 0:
            return P()
        batch = shape[0]
        # Decode token vectors follow the cache's full batch split; 2-D token
        # grids (train/prefill) stay data-sharded for the MoE dispatch/loss.
        if nd == 1 and _div(batch, self.dp_n * self.model_n):
            return P(self.dp + ("model",))
        if _div(batch, self.dp_n):
            return P(self.dp, *(None,) * (nd - 1))
        # batch-1 long-context: replicate (baseline; §Perf shards seq)
        self._note(f"input {name}: batch {batch} % {self.dp_n} → replicated")
        return P(*(None,) * nd)

    def _note(self, msg: str) -> None:
        if msg not in self.notes:
            self.notes.append(msg)

    # ---- trees ----------------------------------------------------------
    def tree_shardings(self, tree: Any, kind: str):
        """kind: 'param' | 'cache' | 'input' → NamedSharding tree."""
        fn = {"param": self.spec_for_param, "cache": self.spec_for_cache,
              "input": self.spec_for_input}[kind]

        def one(kp, leaf):
            path = jax.tree_util.keystr(kp)
            shape = tuple(leaf.shape) if hasattr(leaf, "shape") else ()
            return NamedSharding(self.mesh, fn(path, shape))

        return jax.tree_util.tree_map_with_path(one, tree)
