"""Paper Fig. 2: hot-set identity shifts across workloads (text/math/code).
Measures top-k hot sets per workload on the trained model and reports their
pairwise overlap (paper observes full disjointness of top-10). Counts come
from the backend's uniform router-trace accumulator — the same observation
channel the DynaExq controller consumes."""
from __future__ import annotations

import itertools
import time

import numpy as np

from benchmarks.common import clone, trained_model
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           make_backend, make_prompts)
from repro.serving.requests import WORKLOADS


def hot_set(counts, k):
    order = np.argsort(-counts)
    return set(order[:k].tolist())


def run(report):
    cfg, params, task = trained_model()
    E = cfg.moe.num_experts
    k = max(2, E // 4)
    tops = {}
    t0 = time.perf_counter()
    for w in WORKLOADS:
        eng = InferenceEngine(cfg, clone(params), make_backend("fp16"),
                              EngineConfig(max_slots=8, max_len=96))
        for i in range(4):
            toks = make_prompts(w, cfg.vocab_size, 8, 48, seed=100 + i)
            for b in range(8):
                eng.submit(Request(tokens=toks[b], max_new_tokens=1,
                                   workload=w))
            eng.drain()
        agg = np.asarray(eng.backend.router_counts()["0"])   # (L, E)
        tops[w] = [hot_set(agg[layer], k) for layer in range(cfg.n_layers)]
    dt = time.perf_counter() - t0
    overlaps = []
    for a, b in itertools.combinations(WORKLOADS, 2):
        per_layer = [len(tops[a][layer] & tops[b][layer]) / k
                     for layer in range(cfg.n_layers)]
        ov = float(np.mean(per_layer))
        overlaps.append(ov)
        report(f"workload_shift/top{k}_overlap/{a}-{b}", 0.0, round(ov, 3))
    report("workload_shift/mean_overlap", dt * 1e6 / 3,
           round(float(np.mean(overlaps)), 3))
