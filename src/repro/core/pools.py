"""Fixed-granularity slot pools (paper §3.3, TPU adaptation).

On CUDA the paper fights allocator fragmentation with fixed-size block pools
and constant-time free lists. In JAX the device arrays are preallocated once,
so fragmentation cannot occur; what remains is the *slot accounting*: which
hi-pool slot is free, which expert owns which slot. ``SlotPool`` is that
constant-time free list, host-side, one per layer.
"""
from __future__ import annotations


class SlotPool:
    """Constant-time free list over ``n_slots`` fixed-granularity slots."""

    def __init__(self, n_slots: int):
        self._free = list(range(n_slots - 1, -1, -1))
        self._owner: dict[int, int] = {}      # slot → expert
        self.n_slots = n_slots

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, expert: int) -> int:
        """Pop a free slot for ``expert``; raises if full (the admission
        check must prevent that)."""
        if not self._free:
            raise RuntimeError("pool exhausted — admission control bug")
        slot = self._free.pop()
        self._owner[slot] = expert
        return slot

    def free(self, slot: int) -> None:
        if slot in self._owner:
            del self._owner[slot]
            self._free.append(slot)

    def owner(self, slot: int) -> int | None:
        return self._owner.get(slot)

    def slots_of(self) -> dict[int, int]:
        return dict(self._owner)
