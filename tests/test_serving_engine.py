"""Serving engine: three modes, budget accounting, online adaptation, and
the offload baseline's transfer model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ControllerConfig
from repro.models import init_params
from repro.serving import (MoEServer, OffloadConfig, OffloadServer,
                           ServeConfig, make_prompts)
from repro.serving.requests import WORKLOADS


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-moe-1b-a400m", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(make_prompts("text", cfg.vocab_size, 4, 24))
    return cfg, params, toks


def _clone(params):
    return jax.tree_util.tree_map(lambda x: x, params)


@pytest.mark.parametrize("mode", ["fp16", "static", "dynaexq"])
def test_modes_generate(setup, mode):
    cfg, params, toks = setup
    srv = MoEServer(cfg, _clone(params),
                    ServeConfig(mode=mode, lo_bits=4, n_hi_per_layer=2,
                                max_len=64,
                                controller=ControllerConfig(
                                    update_interval_s=0.0)), batch=4)
    out, ttft, times = srv.generate({"tokens": toks}, 5)
    srv.flush()
    assert out.shape == (4, 5)
    assert ttft > 0 and len(times) == 4
    assert not np.isnan(np.asarray(out, np.float32)).any()


def test_footprint_ordering(setup):
    """static < dynaexq < fp16 expert bytes — the budget story of Table 4."""
    cfg, params, toks = setup
    sizes = {}
    for mode in ["fp16", "static", "dynaexq"]:
        srv = MoEServer(cfg, _clone(params),
                        ServeConfig(mode=mode, lo_bits=4, n_hi_per_layer=2,
                                    max_len=64,
                                    controller=ControllerConfig(
                                        update_interval_s=0.0)), batch=4)
        if mode == "dynaexq":
            srv.generate({"tokens": toks}, 4)
            srv.flush()
        sizes[mode] = srv.expert_device_bytes()
    assert sizes["static"] < sizes["dynaexq"] < sizes["fp16"]


def test_dynaexq_promotes_under_skew(setup):
    cfg, params, toks = setup
    srv = MoEServer(cfg, _clone(params),
                    ServeConfig(mode="dynaexq", lo_bits=4, n_hi_per_layer=2,
                                max_len=64,
                                controller=ControllerConfig(
                                    update_interval_s=0.0)), batch=4)
    srv.generate({"tokens": toks}, 6)
    srv.flush()
    hi = srv.hi_sets()["0"]
    assert all(len(s) == 2 for s in hi)    # budget-full residency
    ctl = srv.controllers["0"]
    ctl.tm.check_invariants()
    assert ctl.tm.stats["promoted"] >= 2 * len(hi)  # n_hi × layers at least


def test_budget_derived_n_hi(setup):
    """hbm_gb envelope → plan_budget path derives n_hi (paper's budget init)."""
    cfg, params, toks = setup
    srv = MoEServer(cfg, _clone(params),
                    ServeConfig(mode="dynaexq", lo_bits=4, hbm_gb=0.05,
                                max_len=64, activation_slack_bytes=1 << 20,
                                controller=ControllerConfig(
                                    update_interval_s=0.0)), batch=4)
    ctl = srv.controllers.get("0")
    if ctl is not None:
        assert 0 < ctl.policy.n_hi <= cfg.moe.num_experts


def test_offload_baseline_accounts_transfers(setup):
    cfg, params, toks = setup
    srv = OffloadServer(cfg, _clone(params),
                        OffloadConfig(cache_experts_per_layer=2,
                                      pcie_gbps=16.0),
                        batch=4, max_len=64)
    out, ttft, times = srv.generate({"tokens": toks}, 5)
    st = srv.stats
    assert st["misses"] > 0 and st["bytes_fetched"] > 0
    assert st["stall_s"] > 0
    # stall must equal modeled bytes/bw within the prefetch-overlap slack
    assert st["stall_s"] <= st["bytes_fetched"] / (16e9) + 1e-6


def test_offload_cache_larger_means_fewer_misses(setup):
    cfg, params, toks = setup
    misses = {}
    for c in (1, 4):
        srv = OffloadServer(cfg, _clone(params),
                            OffloadConfig(cache_experts_per_layer=c,
                                          prefetch=False),
                            batch=4, max_len=64)
        srv.generate({"tokens": toks}, 5)
        misses[c] = srv.stats["misses"]
    assert misses[4] <= misses[1]


def test_workload_token_distributions_disjoint():
    """Different workloads draw from (mostly) disjoint vocab slices —
    the mechanism behind Fig. 2's hot-set shift."""
    sets = []
    for w in WORKLOADS:
        toks = make_prompts(w, 3000, 8, 128, seed=1)
        sets.append(set(np.asarray(toks).reshape(-1).tolist()))
    assert not (sets[0] & sets[1])
    assert not (sets[1] & sets[2])
