"""Shared retry-with-exponential-backoff policy for transfer sites.

All four retrying sites (promotion copies, host hi/lo loads, lo staging,
streaming shard reads) share one `RetryPolicy`.  Backoff is *modeled* time —
`retry_call` never sleeps, it accumulates the backoff it *would* have waited
and returns it so callers can account it as stall seconds on the virtual
clock.  Jitter comes from the same counter-based Philox generator the
sampler uses, keyed by ``(seed, site, key, attempt)``, so a replayed run
retries with bit-identical delays.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

from repro.fault.inject import TransferFault, _counter_uniform, _site_stream

_JITTER_OFFSET = 101  # separate the jitter stream from the decision stream


class RetryExhausted(RuntimeError):
    """A transfer failed on every allowed attempt (or blew its deadline).

    Callers degrade gracefully instead of crashing: promotions cancel and
    refund, staging quarantines, demand fetches fall back to host."""

    def __init__(self, site: str, attempts: int, waited_s: float):
        self.site = site
        self.attempts = attempts
        self.waited_s = waited_s
        super().__init__(f"transfer at {site} failed after {attempts} "
                         f"attempt(s), {waited_s:.4f}s modeled backoff")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with a cap and an optional total deadline."""
    max_attempts: int = 3
    base_s: float = 0.002
    cap_s: float = 0.1
    timeout_s: Optional[float] = None

    def delay_s(self, attempt: int, seed: int = 0, site: str = "",
                key: int = 0) -> float:
        """Modeled backoff before retry ``attempt`` (1-based), jittered to
        [0.5, 1.5)× the exponential schedule."""
        d = min(self.cap_s, self.base_s * (2.0 ** (attempt - 1)))
        j = _counter_uniform(seed, _site_stream(site) + _JITTER_OFFSET,
                             key, attempt)
        return d * (0.5 + j)


def retry_call(fn: Callable, policy: RetryPolicy, *, seed: int = 0,
               key: int = 0, site: str = "",
               tracer=None) -> Tuple[object, int, float]:
    """Run ``fn`` until it stops raising `TransferFault`.

    Returns ``(result, retries, backoff_s)`` where ``backoff_s`` is the total
    modeled backoff accumulated across retries.  Raises `RetryExhausted`
    (chained to the last fault) once ``max_attempts`` attempts failed or the
    modeled deadline is exceeded.  Non-`TransferFault` exceptions — including
    a nested `RetryExhausted` from an inner retried transfer — propagate
    unretried.
    """
    waited = 0.0
    attempt = 0
    while True:
        try:
            return fn(), attempt, waited
        except TransferFault as e:
            attempt += 1
            if attempt >= policy.max_attempts:
                raise RetryExhausted(site, attempt, waited) from e
            d = policy.delay_s(attempt, seed=seed, site=site, key=key)
            waited += d
            if policy.timeout_s is not None and waited > policy.timeout_s:
                raise RetryExhausted(site, attempt, waited) from e
            if tracer is not None:
                tracer.instant("retry", cat="fault", site=site,
                               attempt=attempt, backoff_s=round(d, 6))
