"""Paper Tables 1 & 2: expert-activation ratio vs batch size, decode and
prefill. Reproduces the densification observation — the regime where
offloading/prefetching loses to resident mixed precision."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import clone, trained_model
from repro.serving import MoEServer, ServeConfig


def run(report):
    cfg, params, task = trained_model()
    E = cfg.moe.num_experts
    rows = {}
    for stage in ("decode", "prefill"):
        for bs in (1, 2, 4, 8, 16, 32):
            srv = MoEServer(cfg, clone(params),
                            ServeConfig(mode="fp16", max_len=96), batch=bs)
            toks = jnp.asarray(task.sample(bs, 32, seed=bs))
            t0 = time.perf_counter()
            srv.start({"tokens": toks})
            if stage == "decode":
                tok = jnp.zeros((bs,), jnp.int32)
                srv.step(tok)
            dt = time.perf_counter() - t0
            counts = np.asarray(srv._counts_last["0"])  # (L, E)
            ratio = float((counts > 0).mean())
            rows[(stage, bs)] = ratio
            report(f"activation_ratio/{stage}/bs{bs}", dt * 1e6,
                   round(ratio * 100, 1))
    # densification factor (paper: ratio grows sharply with batch)
    for stage in ("decode", "prefill"):
        report(f"activation_ratio/{stage}/densification_x",
               0.0, round(rows[(stage, 32)] / max(rows[(stage, 1)], 1e-9), 2))
