"""Paper Figs 6–9: TTFT, TPOT, end-to-end latency, throughput vs batch size
for fp16 / static PTQ / DynaExq / ExpertFlow-style offloading — all four as
``ResidencyBackend``s behind literally the same ``InferenceEngine`` loop, so
the comparison is structural, not an artifact of per-baseline serving code.

Compute is measured on CPU; the host↔device transfer costs (the quantity the
paper's comparison is actually about) use the deterministic PCIe model
inside the backends, so the ordering reflects transfer volume on/off the
critical path. DynaExq's background promotions are charged to the migration
stream (off critical path) and reported as ``bytes_moved``; offloading's
demand misses stall the step (``stall_s``, on critical path) — the paper's
structural distinction, now visible in one uniform stats table."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_backend, clone, trained_model
from repro.core import ControllerConfig
from repro.serving import (EngineConfig, InferenceEngine, Request, STAT_KEYS)

N_NEW = 8
PROMPT = 48
KINDS = ("fp16", "static", "dynaexq", "offload")


def _backend(kind):
    return bench_backend(kind, controller=ControllerConfig(
        update_interval_s=0.05, migration_bytes_per_window=1 << 20))


def _run_engine(kind, cfg, params, bs, toks):
    import time
    eng = InferenceEngine(cfg, clone(params), _backend(kind),
                          EngineConfig(max_slots=bs, max_len=96))
    t0 = time.perf_counter()
    for i in range(bs):
        eng.submit(Request(tokens=toks[i], max_new_tokens=N_NEW))
    eng.drain()
    wall = time.perf_counter() - t0
    eng.flush()
    st = eng.stats()
    # One consistent clock for the whole row: measured wall time plus every
    # MODELED stall (never slept, so wall alone would let offload's demand
    # misses ride for free). ttft_s/tpot_s in stats() are charged the same
    # way, so the table's columns agree with the derived e2e/throughput.
    st["e2e_s"] = wall + st["stall_s"]
    st["p99_s"] = float(np.percentile(eng.decode_times, 99)) \
        if eng.decode_times else 0.0
    return st


def run(report):
    cfg, params, task = trained_model()
    for bs in (1, 4, 8):
        toks = np.asarray(task.sample(bs, PROMPT, seed=bs))
        rows = {}
        for kind in KINDS:
            _run_engine(kind, cfg, params, bs, toks)   # warm-up compile
            st = _run_engine(kind, cfg, params, bs, toks)
            st["throughput_tps"] = bs * N_NEW / st["e2e_s"]
            rows[kind] = st
            report(f"serving/ttft/{kind}/bs{bs}", st["ttft_s"] * 1e6,
                   round(st["ttft_s"], 4))
            # derived column carries the tail (p99 per-step latency)
            report(f"serving/tpot/{kind}/bs{bs}", st["tpot_s"] * 1e6,
                   round(st["p99_s"], 4))
            report(f"serving/stall_s/{kind}/bs{bs}", 0.0,
                   round(st["stall_s"], 5))
            report(f"serving/throughput_tps/{kind}/bs{bs}", 0.0,
                   round(st["throughput_tps"], 2))
        # One comparable table straight from the uniform stats() schema.
        cols = list(STAT_KEYS) + ["p99_s", "throughput_tps"]
        print(f"\n== serving_perf bs={bs} (uniform backend stats) ==")
        print(f"{'backend':>9} " + " ".join(f"{c:>14}" for c in cols))
        for kind in KINDS:
            print(f"{kind:>9} " + " ".join(
                f"{rows[kind].get(c, 0.0):>14.6g}" for c in cols))
        report(f"serving/dynaexq_vs_offload_tput_x/bs{bs}", 0.0,
               round(rows["dynaexq"]["throughput_tps"] /
                     max(rows["offload"]["throughput_tps"], 1e-9), 2))
