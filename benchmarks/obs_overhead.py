"""Observability tax: serve the same decode workload with the flight
recorder + metrics registry attached and detached, and assert the attached
run keeps ≥95% of the detached throughput (the obs layer must stay off the
jit path — everything it records is host-side Python on already-fetched
counters).

The obs-on run's trace is saved to ``experiments/obs.trace.json`` (Chrome
trace-event JSON, viewable in Perfetto) and replayed through
``repro.obs.costmodel`` so the artifact also carries the measured-vs-roofline
bytes/token residuals and the promotion publish-latency percentiles — the
validation half of the PR, regenerated on every benchmark run.

``BENCH_SMOKE=1`` shrinks reps/tokens for CI.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import (BENCH_SMOKE, bench_backend, clone,
                               trained_model)
from repro.core import ControllerConfig
from repro.serving import EngineConfig, InferenceEngine, Request

# Even the smoke run needs a measurable wall: at ~80 tok/s a 4-token decode
# finishes in ~0.2 s and scheduler jitter alone reads as >5% "overhead".
N_NEW = 8 if BENCH_SMOKE else 12
BATCH = 4
PROMPT = 32
REPS = 3 if BENCH_SMOKE else 4
MAX_OVERHEAD = 0.05
JSON_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "BENCH_obs.json")
TRACE_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "obs.trace.json")


def _engine(cfg, params, obs):
    return InferenceEngine(
        cfg, clone(params),
        bench_backend("dynaexq", controller=ControllerConfig(
            update_interval_s=0.0)),
        EngineConfig(max_slots=BATCH, max_len=64), obs=obs)


def _serve_once(cfg, params, toks, obs):
    eng = _engine(cfg, params, obs)
    t0 = time.perf_counter()
    handles = [eng.submit(Request(tokens=t, max_new_tokens=N_NEW))
               for t in toks]
    eng.drain()
    wall = time.perf_counter() - t0
    eng.flush()
    return sum(len(h.tokens) for h in handles) / wall


def run(report):
    from repro.obs import Observability, ObsConfig, costmodel
    cfg, params, task = trained_model()
    toks = list(task.sample(BATCH, PROMPT, seed=3))
    _serve_once(cfg, params, toks, None)               # warm-up compile
    tps = {"off": 0.0, "on": 0.0}
    last_obs = None
    for _ in range(REPS):                              # interleaved reps so
        tps["off"] = max(tps["off"],                   # drift hits both arms
                         _serve_once(cfg, params, toks, None))
        obs = Observability(ObsConfig())
        tps["on"] = max(tps["on"], _serve_once(cfg, params, toks, obs))
        last_obs = obs
    overhead = 1.0 - tps["on"] / tps["off"]

    last_obs.tracer.save(TRACE_OUT)
    model = costmodel.report(last_obs.tracer)
    roof, prom = model["roofline"], model["promotions"]
    max_resid = max((abs(b["rel_residual"]) for b in roof["buckets"]),
                    default=0.0)

    report("obs/tokens_per_s/off", 0.0, round(tps["off"], 2))
    report("obs/tokens_per_s/on", 0.0, round(tps["on"], 2))
    report("obs/overhead_frac", 0.0, round(overhead, 4))
    report("obs/roofline_max_abs_residual", 0.0, round(max_resid, 4))
    report("obs/promotion_publish_p95_ms", 0.0,
           round(prom["publish_latency_p95_s"] * 1e3, 2))
    print(f"obs overhead: {overhead*100:+.1f}% "
          f"({tps['off']:.1f} -> {tps['on']:.1f} tok/s, best of {REPS}); "
          f"roofline residual max {max_resid:.3f} over {roof['n_steps']} "
          f"decode steps; {prom['n_published']} promotions published "
          f"(p95 {prom['publish_latency_p95_s']*1e3:.1f} ms)")

    results = {"obs": {
        "tokens_per_s_off": tps["off"], "tokens_per_s_on": tps["on"],
        "overhead_frac": overhead, "max_overhead_frac": MAX_OVERHEAD,
        "reps": REPS, "smoke": BENCH_SMOKE,
        "trace_events": len(last_obs.tracer),
        "roofline": roof, "promotions": prom,
    }}
    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    merged = {}
    if os.path.exists(JSON_OUT):
        try:
            with open(JSON_OUT) as f:
                merged = json.load(f)
        except Exception:
            merged = {}
    merged.update(results)
    with open(JSON_OUT, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.normpath(JSON_OUT)} and "
          f"{os.path.normpath(TRACE_OUT)}")

    if overhead > MAX_OVERHEAD:
        raise AssertionError(
            f"observability overhead {overhead*100:.1f}% exceeds the "
            f"{MAX_OVERHEAD*100:.0f}% budget — something crept onto the "
            f"hot path (check _step_obs / observe instrumentation)")
