"""HBM budget model + admission control (paper §3.3).

``BudgetModel`` performs the one-shot budget initialization: given the device
envelope and the fixed allocations (non-expert params, KV cache, activation
headroom), it derives the per-layer hi-precision capacity ``n_hi,l``.
``BudgetTracker`` is the runtime admission gate: every promotion must
``try_reserve`` its bytes before it may enter the transition pipeline, so the
hi pool can never overflow — budget feasibility by construction.

A tracker can be split into named **accounts** (``tracker.view("kv")``):
every view reserves against the one shared envelope — so KV-cache block
admission and expert hi-tier promotions genuinely contend for the same
bytes — while each view's ``used``/``cap`` report only its own account
(per-subsystem invariants stay checkable). ``UNBOUNDED`` is the sentinel
cap for "no global envelope configured".
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional

#: Sentinel cap for a tracker that never binds (no device envelope given).
UNBOUNDED = 1 << 62


class BudgetExceeded(Exception):
    pass


class BudgetTracker:
    """Thread-safe byte reservation ledger over one shared envelope.

    Reservations are tagged with an ``account`` name (default ``"default"``)
    so several subsystems can draw from the same cap while keeping their own
    books; ``view(account)`` wraps one account behind the classic
    try_reserve/release/used/free interface.
    """

    def __init__(self, cap_bytes: int):
        if cap_bytes < 0:
            raise ValueError("cap must be >= 0")
        self.cap = int(cap_bytes)
        self._used = 0
        self._accounts: Dict[str, int] = {}
        self._lock = threading.Lock()

    @property
    def used(self) -> int:
        return self._used

    @property
    def free(self) -> int:
        return self.cap - self._used

    def headroom_frac(self) -> float:
        """Fraction of the envelope still free, as a load signal (the QoS
        scheduler's shed policy keys on it). An unbounded tracker always
        reports full headroom — no envelope, no byte pressure."""
        if self.cap >= UNBOUNDED:
            return 1.0
        return self.free / max(1, self.cap)

    def used_by(self, account: str) -> int:
        return self._accounts.get(account, 0)

    def try_reserve(self, nbytes: int, account: str = "default",
                    account_cap: Optional[int] = None) -> bool:
        with self._lock:
            if self._used + nbytes > self.cap:
                return False
            held = self._accounts.get(account, 0)
            if account_cap is not None and held + nbytes > account_cap:
                return False
            self._used += nbytes
            self._accounts[account] = held + nbytes
            return True

    def release(self, nbytes: int, account: str = "default") -> None:
        with self._lock:
            held = self._accounts.get(account, 0) - nbytes
            if held < 0:
                raise BudgetExceeded(
                    f"account {account!r} released more than reserved")
            self._accounts[account] = held
            self._used -= nbytes
            if self._used < 0:
                raise BudgetExceeded("released more than reserved")

    def view(self, account: str, cap: Optional[int] = None) -> "BudgetView":
        """An account-scoped handle with the classic tracker interface."""
        return BudgetView(self, account, cap)


class BudgetView:
    """One account of a shared ``BudgetTracker``.

    Duck-types the tracker interface (``try_reserve``/``release``/``used``/
    ``free``/``cap``): ``used`` reports only this account's bytes (so e.g.
    ``TransitionManager.check_invariants`` stays exact), while every
    reservation is gated by the PARENT envelope too — pressure from sibling
    accounts (KV blocks vs hi-tier experts) defers admission here.
    """

    def __init__(self, parent: BudgetTracker, account: str,
                 cap: Optional[int] = None):
        self.parent = parent
        self.account = account
        self._cap = cap

    @property
    def cap(self) -> int:
        return self._cap if self._cap is not None else self.parent.cap

    @property
    def used(self) -> int:
        return self.parent.used_by(self.account)

    @property
    def free(self) -> int:
        """Bytes this account could still reserve — the tighter of its own
        cap and the shared envelope's headroom."""
        return min(self.cap - self.used, self.parent.free)

    def try_reserve(self, nbytes: int) -> bool:
        return self.parent.try_reserve(nbytes, account=self.account,
                                       account_cap=self._cap)

    def release(self, nbytes: int) -> None:
        self.parent.release(nbytes, account=self.account)


@dataclasses.dataclass(frozen=True)
class BudgetPlan:
    m_total: int          # usable device bytes
    m_fixed: int          # non-expert params + KV cache + activations
    m_lo: int             # always-resident lo-pool bytes
    m_hi_cap: int         # hi-pool envelope
    n_hi_per_layer: int   # derived per-layer hi capacity (experts)

    def check(self):
        if self.m_fixed + self.m_lo + self.m_hi_cap > self.m_total:
            raise BudgetExceeded(
                f"infeasible: fixed {self.m_fixed} + lo {self.m_lo} + hi "
                f"{self.m_hi_cap} > total {self.m_total}")


@dataclasses.dataclass(frozen=True)
class HierarchyPlan:
    """Three-tier budget split: device bytes → (lo-resident cells, global
    hi slots); everything else lives in the host-DRAM tier."""
    m_total: int
    m_fixed: int
    m_lo_cap: int            # bytes reserved for lo-resident cells
    m_hi_cap: int            # bytes reserved for hi slots
    lo_resident_total: int   # lo-resident (layer, expert) cells, global
    total_hi: int            # hi slots, global (across all layers)

    def check(self):
        if self.m_fixed + self.m_lo_cap + self.m_hi_cap > self.m_total:
            raise BudgetExceeded(
                f"infeasible: fixed {self.m_fixed} + lo {self.m_lo_cap} + "
                f"hi {self.m_hi_cap} > total {self.m_total}")


def plan_hierarchy(m_total: int, m_fixed: int,
                   lo_bytes_per_expert_layer: int,
                   hi_bytes_per_expert_layer: int,
                   n_layers: int, num_experts: int) -> HierarchyPlan:
    """Three-tier budget initialization. Unlike :func:`plan_budget` (which
    REQUIRES the full lo tier to fit), the always-available fallback here is
    the host tier: fill lo residency first (it is the serving floor — a
    routed host expert pays a demand-fetch stall), then spend what remains
    on hi slots. An envelope too small for every lo cell yields a partial
    lo tier and zero hi slots — the model still serves, never having fully
    materialized."""
    avail = m_total - m_fixed
    cells = n_layers * num_experts
    lo_resident = min(cells, max(0, avail) // lo_bytes_per_expert_layer)
    if lo_resident == 0:
        raise BudgetExceeded(
            f"envelope fits no lo-resident expert at all: avail {avail} < "
            f"lo bytes {lo_bytes_per_expert_layer}")
    rem = avail - lo_resident * lo_bytes_per_expert_layer
    total_hi = min(cells, rem // hi_bytes_per_expert_layer)
    plan = HierarchyPlan(
        m_total=m_total, m_fixed=m_fixed,
        m_lo_cap=lo_resident * lo_bytes_per_expert_layer,
        m_hi_cap=total_hi * hi_bytes_per_expert_layer,
        lo_resident_total=int(lo_resident), total_hi=int(total_hi))
    plan.check()
    return plan


def plan_budget(m_total: int, m_fixed: int, lo_bytes_total: int,
                hi_bytes_per_expert_layer: int, n_layers: int,
                num_experts: int, align: int = 1) -> BudgetPlan:
    """Budget initialization: everything left after fixed + lo goes to the hi
    pool, expressed as a per-layer expert count (the paper's n_hi,l).

    ``align``: round n_hi down to a multiple (e.g. the model-parallel degree,
    so each shard owns an integer number of hi slots).
    """
    if m_fixed + lo_bytes_total > m_total:
        raise BudgetExceeded(
            f"lo tier alone does not fit: fixed {m_fixed} + lo "
            f"{lo_bytes_total} > total {m_total}")
    remaining = m_total - m_fixed - lo_bytes_total
    n_hi = remaining // (hi_bytes_per_expert_layer * n_layers)
    n_hi = min(int(n_hi), num_experts)
    if align > 1:
        n_hi = n_hi // align * align
    plan = BudgetPlan(
        m_total=m_total, m_fixed=m_fixed, m_lo=lo_bytes_total,
        m_hi_cap=n_hi * hi_bytes_per_expert_layer * n_layers,
        n_hi_per_layer=int(n_hi))
    plan.check()
    return plan
