"""Preemption correctness: a preempted-then-resumed request is
token-identical (temp=0) to an uninterrupted run — across full-attention,
sliding-window, and jamba (mamba+attention) stacks, paged and dense — and
evict-and-resume leaves the KV pool's block/refcount accounting invariant."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.serving import (EngineConfig, InferenceEngine, Request,
                           make_backend, make_prompts)


def _run(cfg, params, *, paged, sharing, preempt_at=None, plen=12,
         max_new=12, max_len=64, qos=None):
    clone = jax.tree_util.tree_map(lambda x: x, params)
    eng = InferenceEngine(
        cfg, clone, make_backend("fp16"),
        EngineConfig(max_slots=2, max_len=max_len, paged=paged,
                     prefix_sharing=sharing))
    h = eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, plen, seed=3)[0],
        max_new_tokens=max_new, qos=qos))
    steps = 0
    while h.state.value != "finished":
        eng.step()
        steps += 1
        if steps == preempt_at and h.state.value == "running":
            eng.preempt(h)
        assert steps < 500
    if eng.pool is not None:
        eng.pool.check_invariants()
    return h, eng


@pytest.fixture(scope="module")
def sw_setup():
    """Sliding-window variant of the reduced granite MoE."""
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("granite-moe-1b-a400m", reduced=True)
    cfg = dataclasses.replace(
        cfg, name="granite-sw32",
        attn=dataclasses.replace(cfg.attn, sliding_window=32))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def jamba_setup():
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("jamba-v0_1-52b", reduced=True)
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.mark.parametrize("paged,sharing", [(True, True), (True, False),
                                           (False, False)])
def test_full_attn_preempt_parity(serving_setup, paged, sharing):
    cfg, params = serving_setup
    base, _ = _run(cfg, params, paged=paged, sharing=sharing)
    for at in (2, 5, 9):
        pre, eng = _run(cfg, params, paged=paged, sharing=sharing,
                        preempt_at=at)
        assert pre.tokens == base.tokens, f"preempt@{at}"
        assert eng.counters["preemptions"] == 1
        assert eng.counters["resumes"] == 1
        assert pre.preempts == 1


@pytest.mark.parametrize("paged", [True, False])
def test_sliding_window_preempt_parity(sw_setup, paged):
    cfg, params = sw_setup
    # max_new rides the position past the 32-token window, so late
    # preemptions snapshot a WRAPPED ring (span = last window only).
    base, _ = _run(cfg, params, paged=paged, sharing=False, max_new=40)
    for at in (4, 30):
        pre, _ = _run(cfg, params, paged=paged, sharing=False, max_new=40,
                      preempt_at=at)
        assert pre.tokens == base.tokens, f"preempt@{at}"


@pytest.mark.parametrize("paged", [True, False])
def test_jamba_preempt_parity(jamba_setup, paged):
    cfg, params = jamba_setup
    base, _ = _run(cfg, params, paged=paged, sharing=False)
    for at in (3, 7):
        pre, _ = _run(cfg, params, paged=paged, sharing=False, preempt_at=at)
        assert pre.tokens == base.tokens, f"preempt@{at}"


def test_preempt_frees_and_restores_pool_state(serving_setup):
    """Trie off (registration would intentionally retain generated chunks):
    preemption must genuinely free every block + its quota, and the drained
    engine must return the pool to its pristine state."""
    cfg, params = serving_setup
    clone = jax.tree_util.tree_map(lambda x: x, params)
    eng = InferenceEngine(cfg, clone, make_backend("fp16"),
                          EngineConfig(max_slots=2, max_len=64,
                                       prefix_sharing=False))
    pool = eng.pool
    free0, used0 = pool.n_free, eng.budget.used
    h = eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, 12, seed=1)[0],
        max_new_tokens=10))
    for _ in range(3):
        eng.step()
    assert h.state.value == "running"
    assert pool.n_free < free0                     # blocks genuinely held
    eng.preempt(h)
    pool.check_invariants()
    # Eviction returns EVERY block and every reserved quota byte.
    assert pool.n_free == free0
    assert eng.budget.used == used0
    assert h.lease is None and h.slot is None
    eng.drain()
    pool.check_invariants()
    assert h.state.value == "finished" and len(h.tokens) == 10
    assert pool.n_free == free0
    assert eng.budget.used == used0


def test_automatic_preemption_for_blocked_premium(serving_setup):
    """A premium arrival behind a slot-hogging batch request evicts it;
    both finish, and the batch request's tokens still match an
    uninterrupted run (fp16 banks: lo tier == mixed tier)."""
    cfg, params = serving_setup
    base, _ = _run(cfg, params, paged=True, sharing=True, max_new=16,
                   qos="batch")

    clone = jax.tree_util.tree_map(lambda x: x, params)
    eng = InferenceEngine(cfg, clone, make_backend("fp16"),
                          EngineConfig(max_slots=1, max_len=64))
    batch = eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, 12, seed=3)[0],
        max_new_tokens=16, qos="batch"))
    for _ in range(3):
        eng.step()
    assert batch.state.value == "running"
    prem = eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, 8, seed=4)[0],
        max_new_tokens=4, qos="premium"))
    done = eng.drain()
    assert eng.counters["preemptions"] >= 1
    assert eng.counters["resumes"] >= 1
    # Premium jumped the line: it finished before the preempted batch row.
    assert done.index(prem) < done.index(batch)
    assert prem.tokens and len(prem.tokens) == 4
    assert batch.tokens == base.tokens
    eng.pool.check_invariants()


def test_preempt_non_running_rejected(serving_setup):
    cfg, params = serving_setup
    clone = jax.tree_util.tree_map(lambda x: x, params)
    eng = InferenceEngine(cfg, clone, make_backend("fp16"),
                          EngineConfig(max_slots=1, max_len=64))
    h = eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, 8, seed=0)[0],
        max_new_tokens=2))
    with pytest.raises(ValueError, match="preempt"):
        eng.preempt(h)                    # still QUEUED
    eng.drain()
    with pytest.raises(ValueError, match="preempt"):
        eng.preempt(h)                    # FINISHED
