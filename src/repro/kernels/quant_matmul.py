"""Pallas TPU kernel: fused dequant + matmul for int4/int2/int8 weights.

The DynaExq lo-tier GEMM. The packed codes stream HBM→VMEM at ``bits``/8
bytes per element — the entire memory-footprint benefit of the lo tier —
and are expanded to f32 *in VMEM* right before feeding the MXU, so no
dequantized copy ever exists in HBM.

Tiling: grid (M/bm, N/bn, K/bk); K is the innermost (sequential) axis with an
f32 VMEM accumulator. bm/bn default to 128 (MXU-aligned); bk is a multiple of
the quantization group so each K-tile sees whole scale groups.

``grouped_quant_matmul`` is the batched-over-experts variant used by the MoE
serving path: grid (E, C/bm, N/bn, K/bk) over the dispatched activations
(E, C, K) — the expert dim maps to the outermost grid axis, so on a
model-sharded mesh each core sweeps only its local experts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dequant_tile(wp: jax.Array, s: jax.Array, bits: int, group: int) -> jax.Array:
    """wp: (bk//epb, bn) uint8; s: (bk//g, bn) → (bk, bn) f32 (in VMEM)."""
    if bits == 8:
        q = wp.astype(jnp.int32) - 128
        bk = wp.shape[0]
    else:
        epb = 8 // bits
        bkp, bn = wp.shape
        bk = bkp * epb
        shifts = (jnp.arange(epb, dtype=jnp.uint32) * bits)[None, :, None]
        u = (wp.astype(jnp.uint32)[:, None, :] >> shifts) & ((1 << bits) - 1)
        q = u.reshape(bk, bn).astype(jnp.int32) - (1 << (bits - 1))
    scale = jnp.repeat(s.astype(jnp.float32), group, axis=0)  # (bk, bn)
    return q.astype(jnp.float32) * scale


def _qmm_kernel(x_ref, wp_ref, s_ref, o_ref, acc_ref, *, bits, group, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _dequant_tile(wp_ref[...], s_ref[...], bits, group)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quant_matmul(x: jax.Array, packed: jax.Array, scales: jax.Array, *,
                 bits: int, group: int, bm: int = 128, bn: int = 128,
                 bk: int = 256, interpret: bool = False) -> jax.Array:
    """x: (M, K) bf16 × packed (K//epb, N) uint8 / scales (K//g, N) → (M, N)."""
    M, K = x.shape
    epb = 8 // bits
    N = packed.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    bk = max(group, bk // group * group)
    if M % bm or N % bn or K % bk:
        raise ValueError(f"shape ({M},{K})x({K},{N}) not tileable by "
                         f"({bm},{bn},{bk})")
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, bits=bits, group=group, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // epb, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pl.ArrayRef((bm, bn), jnp.float32)]
        if hasattr(pl, "ArrayRef") else
        [_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, packed, scales)


def _vmem_scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _gqmm_kernel(x_ref, wp_ref, s_ref, o_ref, acc_ref, *, bits, group, nk):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _dequant_tile(wp_ref[0], s_ref[0], bits, group)
    acc_ref[...] += jnp.dot(x_ref[0].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_quant_matmul(xg: jax.Array, packed: jax.Array, scales: jax.Array,
                         *, bits: int, group: int, bm: int = 128,
                         bn: int = 128, bk: int = 256,
                         interpret: bool = False) -> jax.Array:
    """xg: (E, C, K) × packed (E, K//epb, N) → (E, C, N)."""
    E, C, K = xg.shape
    epb = 8 // bits
    N = packed.shape[2]
    bm, bn, bk = min(bm, C), min(bn, N), min(bk, K)
    bk = max(group, bk // group * group)
    if C % bm or N % bn or K % bk:
        raise ValueError(f"({E},{C},{K})x({K},{N}) not tileable by "
                         f"({bm},{bn},{bk})")
    nk = K // bk
    grid = (E, C // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_gqmm_kernel, bits=bits, group=group, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk // epb, bn), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, bk // group, bn), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, N), xg.dtype),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xg, packed, scales)
