"""End-to-end serving driver (the paper's deployment story):

  1. train a ~small MoE for a few hundred steps on the synthetic LM task,
  2. prepare DynaExq weight tiers (int2 lo / bf16 hi) under a device budget,
  3. serve a SHIFTING workload mix (text → math → code),
  4. watch the controller re-allocate the hi-precision budget online and
     compare quality/latency against static PTQ at the same footprint.

    PYTHONPATH=src python examples/serve_dynaexq.py [--steps 200]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ControllerConfig
from repro.models import init_params
from repro.serving import MoEServer, ServeConfig
from repro.serving.requests import WORKLOADS, make_prompts
from repro.training import SyntheticLMTask, TrainConfig, train_loop
from repro.training.adamw import AdamWConfig


def build_server(cfg, params, mode):
    return MoEServer(
        cfg, jax.tree_util.tree_map(lambda x: x, params),
        ServeConfig(mode=mode, lo_bits=2, n_hi_per_layer=2, max_len=128,
                    controller=ControllerConfig(update_interval_s=0.0,
                                                alpha=0.6, margin=0.5)),
        batch=4)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = get_config("granite-moe-1b-a400m", reduced=True)
    import dataclasses
    cfg = dataclasses.replace(
        cfg, n_layers=4,
        moe=dataclasses.replace(cfg.moe, num_experts=8, top_k=2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    task = SyntheticLMTask(cfg.vocab_size, seed=0)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=2e-3, total_steps=args.steps))
    print(f"=== training {args.steps} steps ===")
    params, _, _ = train_loop(cfg, params, task.batches(16, 65, args.steps),
                              tcfg, log_every=50)

    print("=== serving a shifting workload mix ===")
    dyn = build_server(cfg, params, "dynaexq")
    stat = build_server(cfg, params, "static")
    for phase, workload in enumerate(WORKLOADS):
        for i in range(3):
            toks = jnp.asarray(make_prompts(workload, cfg.vocab_size, 4, 48,
                                            seed=phase * 10 + i))
            dyn.generate({"tokens": toks}, 6)
            stat.generate({"tokens": toks}, 6)
        dyn.flush()
        print(f"phase {phase} ({workload:5s}): hi-sets layer0..3 = "
              f"{dyn.hi_sets()['0']}")
    ctl = dyn.controllers["0"]
    print("controller stats:", ctl.tm.stats)
    print(f"expert bytes: dynaexq={dyn.expert_device_bytes():,}  "
          f"static={stat.expert_device_bytes():,}")
    print("(hi sets follow the workload: promotions+demotions above zero,\n"
          " budget invariant held by construction — see tests/)")


if __name__ == "__main__":
    main()
