"""Quantization substrate: symmetric group-wise PTQ with int8/int4/int2 packing.

This is the numeric foundation DynaExq's precision tiers are built on.
Weights are quantized per output-channel group (``group_size`` input elements
share one scale), packed little-endian into uint8 words, and dequantized
either in pure jnp (reference / CPU path) or fused inside the Pallas
quant-matmul kernels (TPU path).
"""
from repro.quant.qtensor import (
    QuantizedTensor,
    quantize,
    dequantize,
    pack_bits,
    unpack_bits,
    bits_per_element,
    quantized_nbytes,
)
from repro.quant.ptq import quantize_expert_bank, quantize_tree
from repro.quant.sensitivity import (expert_sensitivity, load_sensitivity,
                                     model_sensitivity, save_sensitivity)

__all__ = [
    "QuantizedTensor",
    "quantize",
    "dequantize",
    "pack_bits",
    "unpack_bits",
    "bits_per_element",
    "quantized_nbytes",
    "quantize_expert_bank",
    "quantize_tree",
    "expert_sensitivity",
    "model_sensitivity",
    "save_sensitivity",
    "load_sensitivity",
]
