"""Training launcher: reduced configs locally, full configs on a real slice
(the production-mesh lowering path is proven by the dry-run).

    python -m repro.launch.train --arch qwen3-moe-30b-a3b --steps 100 \
        [--full --microbatches 4]
"""
import argparse

import jax

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params
from repro.training import SyntheticLMTask, TrainConfig, save_checkpoint, train_loop
from repro.training.adamw import AdamWConfig
from repro.training.train import eval_perplexity


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=65)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    if cfg.family in ("audio", "vlm"):
        raise SystemExit(f"{args.arch}: LM-only trainer; frontends are "
                         f"stubbed (see DESIGN.md)")
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params on "
          f"{jax.device_count()} device(s)")
    task = SyntheticLMTask(cfg.vocab_size, seed=0)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, total_steps=args.steps),
        microbatches=args.microbatches)
    params, _, hist = train_loop(
        cfg, params, task.batches(args.batch, args.seq, args.steps), tcfg,
        log_every=max(args.steps // 10, 1))
    ppl = eval_perplexity(cfg, params,
                          task.batches(args.batch, args.seq, 3, seed=9999))
    print(f"[train] final loss {hist[-1]['loss']:.3f}  held-out ppl {ppl:.2f}")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps)
        print(f"[train] checkpoint → {args.ckpt}")


if __name__ == "__main__":
    main()
