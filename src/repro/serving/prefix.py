"""Token-prefix trie over KV pool blocks: cross-request prefix sharing.

Requests that share a prompt prefix (system prompts, few-shot headers) map
the same physical KV blocks instead of recomputing them. The trie is keyed
by whole ``block_tokens``-sized token chunks: a node holds the physical
block carrying the KV of one chunk GIVEN its ancestors (KV at position p
depends on all tokens ≤ p, so a chunk's cache content is only reusable
under the exact same prefix — which is precisely what a trie path encodes).

Each registered node holds one pool reference of its own, so cached
prefixes survive the request that computed them; blocks whose only
remaining reference is the trie are reclaimable — ``evict`` walks leaves
in LRU order and hands blocks back to the pool when it runs dry. Writers
never mutate a registered block in place: the pool's refcount (> 1 while
the trie or any other lease holds it) forces copy-on-write in the engine,
so trie contents stay pristine even for ring (sliding-window) caches whose
decode wraps back over prefix slots.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.kvpool import KVBlockPool


class _Node:
    __slots__ = ("children", "parent", "chunk", "block", "last_used")

    def __init__(self, parent: Optional["_Node"], chunk, block: int):
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.parent = parent
        self.chunk = chunk          # key in parent.children (None for root)
        self.block = block          # physical pool block (-1 for root)
        self.last_used = 0


class PrefixTrie:
    """Chunk-granular prefix index over physical KV blocks."""

    def __init__(self, pool: KVBlockPool):
        self.pool = pool
        self.bt = pool.block_tokens
        self.root = _Node(None, None, -1)
        self._clock = itertools.count(1)
        self.n_nodes = 0
        self.stats = {"hit_blocks": 0, "miss_blocks": 0, "registered": 0,
                      "evicted": 0}

    # -- lookup ----------------------------------------------------------
    def _chunks(self, tokens: np.ndarray) -> List[Tuple[int, ...]]:
        toks = np.asarray(tokens).reshape(-1)
        n = toks.shape[0] // self.bt
        return [tuple(int(t) for t in toks[i * self.bt:(i + 1) * self.bt])
                for i in range(n)]

    def match(self, tokens: np.ndarray,
              max_blocks: Optional[int] = None) -> List[int]:
        """Longest chain of whole-chunk matches for ``tokens``; returns the
        physical block ids (NOT retained — the caller adopts them into a
        lease while still on the hook for this host thread). Touches the
        path's LRU clocks."""
        chunks = self._chunks(tokens)
        if max_blocks is not None:
            chunks = chunks[:max_blocks]
        node, out = self.root, []
        now = next(self._clock)
        for chunk in chunks:
            child = node.children.get(chunk)
            if child is None:
                break
            child.last_used = now
            out.append(child.block)
            node = child
        self.stats["hit_blocks"] += len(out)
        self.stats["miss_blocks"] += len(chunks) - len(out)
        return out

    # -- registration ----------------------------------------------------
    def insert(self, tokens: np.ndarray, blocks: Sequence[int]) -> int:
        """Register ``blocks[j]`` as the cache of chunk j of ``tokens``.
        Existing nodes keep their block (first writer wins — a concurrent
        duplicate computation stays private to its lease); new nodes retain
        one pool reference each. Returns the number of nodes created."""
        chunks = self._chunks(tokens)[:len(blocks)]
        node, created = self.root, 0
        now = next(self._clock)
        for j, chunk in enumerate(chunks):
            child = node.children.get(chunk)
            if child is None:
                blk = int(blocks[j])
                if blk < 0:
                    break                       # unallocated tail — stop
                self.pool.retain(blk)
                child = _Node(node, chunk, blk)
                node.children[chunk] = child
                self.n_nodes += 1
                created += 1
                self.stats["registered"] += 1
            child.last_used = now
            node = child
        return created

    # -- eviction ----------------------------------------------------------
    def _leaves(self) -> List[_Node]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            kids = list(n.children.values())
            if not kids and n is not self.root:
                out.append(n)
            stack.extend(kids)
        return out

    def _nodes(self) -> List[_Node]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root:
                out.append(n)
            stack.extend(n.children.values())
        return out

    def _drop(self, node: _Node) -> bool:
        """Unlink ``node`` and release its pool reference; True if the
        block actually returned to the free list."""
        del node.parent.children[node.chunk]
        self.n_nodes -= 1
        self.stats["evicted"] += 1
        return self.pool.release(node.block)

    def evict(self, need: int) -> int:
        """Reclaim ≥ ``need`` blocks into the pool's free list if possible,
        LRU-leaf-first; only trie-exclusive references (refcount == 1) free
        a block, so blocks still mapped by live leases are never yanked.
        When no leaf is directly freeable but a trie-exclusive block hides
        BEHIND lease-shared descendants (a COWed ancestor of a still-leased
        chunk), the LRU leaf is unlinked anyway — dropping only the trie's
        reference — to unwind the chain toward the reclaimable interior.
        Returns the number of blocks actually freed."""
        freed = 0
        while freed < need:
            leaves = self._leaves()
            if not leaves:
                break
            cands = [n for n in leaves if self.pool.refcount[n.block] == 1]
            if cands:
                if self._drop(min(cands, key=lambda n: n.last_used)):
                    freed += 1
                continue
            if not any(self.pool.refcount[n.block] == 1
                       for n in self._nodes()):
                break                # nothing trie-exclusive anywhere
            self._drop(min(leaves, key=lambda n: n.last_used))
        self.pool.stats["reclaimed"] += freed
        return freed

    def clear(self) -> int:
        """Drop every node (shutdown / tests); returns blocks freed."""
        freed = 0
        while True:
            leaves = self._leaves()
            if not leaves:
                break
            for n in leaves:
                freed += bool(self._drop(n))
        return freed
