"""Quality-benchmark helpers: perplexity under a given expert bank."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ver import build_bank
from repro.training.train import eval_perplexity


def stack_experts(params):
    """granite-style single-position MoE stack → {'name': (L, E, K, N)}."""
    return params["blocks"]["0"]["moe"]["experts"]


def bank_with_hotset(params, lo_bits: int, hi_sets, hi_bits: int = 16):
    """Build a DynaExq bank and publish ``hi_sets[l]`` (lists of expert ids)
    into the hi pool — the state the controller converges to."""
    experts = stack_experts(params)
    n_hi = max((len(s) for s in hi_sets), default=0)
    bank = build_bank(experts, n_hi=max(n_hi, 1), lo_bits=lo_bits,
                      hi_bits=hi_bits)
    sm = np.asarray(bank.slot_map).copy()
    so = np.asarray(bank.slot_owner).copy()
    hi = {n: np.asarray(a).copy() for n, a in bank.hi.items()}
    if hi_bits >= 16:
        host = {n: np.asarray(a) for n, a in experts.items()}
    else:  # int-hi tier: slots hold the hi-bit RTN values (paper's Int4-hi)
        from repro.quant import dequantize, quantize
        host = {n: np.asarray(dequantize(quantize(a, bits=hi_bits,
                                                  group_size=64)))
                for n, a in experts.items()}
    for l, hs in enumerate(hi_sets):
        for slot, e in enumerate(hs):
            sm[l, e] = slot
            so[l, slot] = e
            for n in hi:
                hi[n][l, slot] = host[n][l, e]
    bank.slot_map = jnp.asarray(sm)
    bank.slot_owner = jnp.asarray(so)
    bank.hi = {n: jnp.asarray(a) for n, a in hi.items()}
    return bank


def ppl(cfg, params, batches, bank=None) -> float:
    return eval_perplexity(cfg, params, batches, capacity_factor=8.0,
                           bank={"0": bank} if bank is not None else None)


def hotness_from_counts(cfg, params, batches) -> np.ndarray:
    """Router-trace hotness on an eval workload: (L, E) counts."""
    from repro.models import forward_train
    agg = None
    for b in batches:
        _, aux = forward_train(params, cfg,
                               {"tokens": jnp.asarray(b["tokens"])},
                               capacity_factor=8.0, remat=False)
        c = np.asarray(aux["counts"]["0"])
        agg = c if agg is None else agg + c
    return agg
