"""IBM Granite-3.0-1B-A400M — small MoE, 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.models.config import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    vocab_size=49155,
    d_ff=0,
    attn=AttnConfig(n_heads=16, n_kv_heads=8, head_dim=64,
                    rope_theta=10000.0),
    moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512,
                  norm_topk_prob=True),
    norm_eps=1e-6,
    tie_embeddings=True,
    max_seq_len=4096,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
