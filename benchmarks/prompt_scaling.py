"""Paper Fig. 10 (and Fig. 1's motivation): TTFT vs prompt length. Longer
prompts densify expert activation; offloading pays transfer stalls that grow
with the activated set, DynaExq and static PTQ do not."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import clone, trained_model
from benchmarks.hw import PCIE_GBPS
from repro.serving import (MoEServer, OffloadConfig, OffloadServer,
                           ServeConfig)


def run(report):
    cfg, params, task = trained_model()
    bs = 4
    for plen in (16, 64, 192):
        toks = jnp.asarray(task.sample(bs, plen, seed=plen))
        row = {}
        for kind in ("static", "dynaexq", "offload"):
            if kind == "offload":
                srv = OffloadServer(cfg, clone(params),
                                    OffloadConfig(cache_experts_per_layer=2,
                                                  pcie_gbps=PCIE_GBPS),
                                    batch=bs, max_len=256)
                srv.start({"tokens": toks})     # warm-up compile
                srv2 = OffloadServer(cfg, clone(params),
                                     OffloadConfig(cache_experts_per_layer=2,
                                                   pcie_gbps=PCIE_GBPS),
                                     batch=bs, max_len=256)
                _, ttft = srv2.start({"tokens": toks})
            else:
                scfg = ServeConfig(mode=kind if kind != "dynaexq" else "dynaexq",
                                   lo_bits=4, n_hi_per_layer=2, max_len=256)
                MoEServer(cfg, clone(params), scfg, batch=bs).start(
                    {"tokens": toks})
                srv = MoEServer(cfg, clone(params), scfg, batch=bs)
                _, ttft = srv.start({"tokens": toks})
            row[kind] = ttft
            report(f"prompt_scaling/ttft/{kind}/len{plen}", ttft * 1e6,
                   round(ttft, 4))
        report(f"prompt_scaling/offload_overhead_x/len{plen}", 0.0,
               round(row["offload"] / row["static"], 2))
