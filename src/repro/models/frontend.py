"""Modality frontends — STUBS per the assignment carve-out.

The audio (mel-spectrogram + conv feature extractor) and vision (ViT/SigLIP +
projector) frontends are not implemented; ``input_specs`` supplies
precomputed frame/patch embeddings of the right shape, and these helpers
generate random-but-deterministic stand-ins for runnable smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


def audio_frame_embeddings(key, cfg: ArchConfig, batch: int) -> jax.Array:
    """Stand-in for (log-mel → conv1d×2 → GELU) Whisper frontend output:
    (B, n_frames, d_model)."""
    return jax.random.normal(key, (batch, cfg.encoder_seq, cfg.d_model),
                             jnp.bfloat16) * 0.02


def image_patch_embeddings(key, cfg: ArchConfig, batch: int) -> jax.Array:
    """Stand-in for (anyres tiling → ViT → projector) LLaVA frontend output:
    (B, n_image_tokens, d_model)."""
    return jax.random.normal(key, (batch, cfg.num_image_tokens, cfg.d_model),
                             jnp.bfloat16) * 0.02
