"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the JSONL
records (last record wins per (arch, shape, mesh))."""
import json
import sys


def load(path):
    recs = {}
    try:
        for line in open(path):
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    except FileNotFoundError:
        pass
    return recs


def fmt_roofline(recs):
    head = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bottleneck "
            "| HBM/dev (GB) | fits 16GB | useful FLOPs | note |\n"
            "|---|---|---|---|---|---|---|---|---|---|")
    rows = [head]
    order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    archs = sorted({a for a, _, _ in recs})
    for a in archs:
        for s in order:
            r = recs.get((a, s, "16x16"))
            if r is None:
                continue
            if "skipped" in r:
                rows.append(f"| {a} | {s} | — | — | — | — | — | — | — | "
                            f"SKIP: {r['skipped']} |")
                continue
            if "error" in r:
                rows.append(f"| {a} | {s} | — | — | — | — | — | — | — | "
                            f"ERROR: {r['error'][:60]} |")
                continue
            rl = r["roofline"]
            note = _move_note(rl)
            rows.append(
                f"| {a} | {s} | {rl['t_compute_s']:.4g} | "
                f"{rl['t_memory_s']:.4g} | {rl['t_collective_s']:.4g} | "
                f"**{rl['bottleneck']}** | {r.get('per_device_hbm_gb', '?')} | "
                f"{'yes' if r.get('fits_16gb_hbm') else 'NO'} | "
                f"{rl['useful_flops_ratio']:.3f} | {note} |")
    return "\n".join(rows)


def _move_note(rl):
    b = rl["bottleneck"]
    if b == "memory":
        return "reduce bytes/step (see per-pair analysis, §Perf)"
    if b == "collective":
        return "reduce collective volume (see per-pair analysis, §Perf)"
    return "increase per-chip work (larger per-device batch)"


def fmt_dryrun(recs, mesh):
    rows = [f"| arch | shape | kind | compile (s) | HBM/dev (GB) | collectives (MB/chip) |",
            "|---|---|---|---|---|---|"]
    order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for a in sorted({a for a, _, _ in recs}):
        for s in order:
            r = recs.get((a, s, mesh))
            if r is None:
                continue
            if "skipped" in r:
                rows.append(f"| {a} | {s} | — | — | — | SKIP ({r['skipped']}) |")
                continue
            if "error" in r:
                rows.append(f"| {a} | {s} | — | — | — | ERROR |")
                continue
            coll = sum(r.get("collectives", {}).values()) / 1e6
            rows.append(f"| {a} | {s} | {r['kind']} | {r['compile_s']} | "
                        f"{r.get('per_device_hbm_gb', '?')} | {coll:.1f} |")
    return "\n".join(rows)


def fmt_obs(path="experiments/BENCH_obs.json"):
    """§Observability tables from the obs_overhead benchmark artifact:
    promotion publish-latency percentiles and the measured-vs-roofline
    bytes/token residuals per (tokens, hi-mix) bucket."""
    try:
        with open(path) as f:
            obs = json.load(f)["obs"]
    except (FileNotFoundError, KeyError):
        return None
    prom, roof = obs["promotions"], obs["roofline"]
    rows = [
        "### Observability tax + promotion latency",
        "",
        f"| tok/s (obs off) | tok/s (obs on) | overhead | trace events |",
        "|---|---|---|---|",
        f"| {obs['tokens_per_s_off']:.1f} | {obs['tokens_per_s_on']:.1f} | "
        f"{obs['overhead_frac']*100:+.1f}% (budget "
        f"{obs['max_overhead_frac']*100:.0f}%) | {obs['trace_events']} |",
        "",
        f"Promotions: {prom['n_published']} published, "
        f"{prom['n_cancelled']} cancelled; publish latency p50 "
        f"{prom['publish_latency_p50_s']*1e3:.1f} ms, p95 "
        f"{prom['publish_latency_p95_s']*1e3:.1f} ms, max "
        f"{prom['publish_latency_max_s']*1e3:.1f} ms.",
        "",
        "### Measured vs roofline MoE bytes/token "
        f"({roof['n_steps']} decode steps)",
        "",
        "| tokens/step | published hi/layer | steps | measured B/tok | "
        "predicted B/tok | residual |",
        "|---|---|---|---|---|---|",
    ]
    for b in roof["buckets"]:
        rows.append(
            f"| {b['tokens']:g} | {b['hi_per_layer']:g} | {b['n_steps']} | "
            f"{b['measured_bpt']:,.0f} | {b['predicted_bpt']:,.0f} | "
            f"{b['rel_residual']*100:+.2f}% |")
    return "\n".join(rows)


if __name__ == "__main__":
    single = load("experiments/dryrun_single.jsonl")
    multi = load("experiments/dryrun_multi.jsonl")
    opt = load("experiments/dryrun_single_opt.jsonl")
    if opt:
        with open("experiments/roofline_table_optimized.md", "w") as f:
            f.write(fmt_roofline(opt))
    n_ok_s = sum(1 for r in single.values() if "roofline" in r)
    n_ok_m = sum(1 for r in multi.values() if "roofline" in r)
    print(f"single-pod OK: {n_ok_s}, multi-pod OK: {n_ok_m}")
    with open("experiments/roofline_table.md", "w") as f:
        f.write(fmt_roofline(single))
    with open("experiments/dryrun_single_table.md", "w") as f:
        f.write(fmt_dryrun(single, "16x16"))
    with open("experiments/dryrun_multi_table.md", "w") as f:
        f.write(fmt_dryrun(multi, "2x16x16"))
    obs_md = fmt_obs()
    if obs_md is not None:
        with open("experiments/obs_table.md", "w") as f:
            f.write(obs_md + "\n")
    print("tables written to experiments/*.md")
