"""Paper Fig. 3: perplexity vs number of low-precision experts per layer.
Cold-first demotion (activation-aware) must yield a smooth, monotone-ish
curve; we also report the hot-first curve to show the contrast the paper's
policy exploits."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import eval_batches, trained_model
from benchmarks.quality_common import bank_with_hotset, hotness_from_counts, ppl


def run(report):
    cfg, params, task = trained_model()
    E = cfg.moe.num_experts
    L = cfg.n_layers
    hot = hotness_from_counts(cfg, params, eval_batches(task, cfg, n=3))
    order = np.argsort(-hot, axis=1)        # hottest first, per layer

    t0 = time.perf_counter()
    curves = {}
    for policy in ("cold_first", "hot_first"):
        curve = []
        for n_lo in (0, E // 4, E // 2, 3 * E // 4, E):
            n_hi = E - n_lo
            hi_sets = []
            for l in range(L):
                ids = order[l, :n_hi] if policy == "cold_first" \
                    else order[l, E - n_hi:]
                hi_sets.append([int(e) for e in ids])
            bank = bank_with_hotset(params, lo_bits=2, hi_sets=hi_sets)
            p = ppl(cfg, params, eval_batches(task, cfg, n=3), bank)
            curve.append(p)
            report(f"demotion_curve/{policy}/lo{n_lo}of{E}", 0.0, round(p, 3))
        curves[policy] = curve
    dt = time.perf_counter() - t0
    # smoothness: cold-first increments are bounded relative to the total rise
    c = curves["cold_first"]
    steps = np.diff(c)
    report("demotion_curve/cold_first_monotone_frac", dt * 1e6,
           round(float((steps >= -0.05 * c[-1]).mean()), 2))
    # protecting hot experts matters: at 50% demotion cold-first ≤ hot-first
    report("demotion_curve/hot_protection_gain_at_50pct", 0.0,
           round(curves["hot_first"][2] - curves["cold_first"][2], 3))
