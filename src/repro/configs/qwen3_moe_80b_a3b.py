"""Qwen3-Next-80B-A3B (paper Table 3) — 512 experts top-10 + 1 shared expert.

EXTRA config beyond the assigned ten: this is the paper's flagship evaluation
model (Int4-hi / Int2-lo tiers). We model its MoE/attention stack; the
gated-deltanet hybrid layers of the real Qwen3-Next are approximated with
standard attention (noted deviation).
"""
from repro.models.config import ArchConfig, AttnConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-80b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    vocab_size=151936,
    d_ff=0,
    attn=AttnConfig(n_heads=16, n_kv_heads=2, head_dim=256,
                    rope_theta=10_000_000.0, qk_norm=True),
    moe=MoEConfig(num_experts=512, top_k=10, d_ff_expert=512,
                  n_shared_experts=1, d_ff_shared=512, norm_topk_prob=True),
    norm_eps=1e-6,
    max_seq_len=262144,
    source="paper Table 3; hf:Qwen/Qwen3-Next-80B-A3B",
)
