"""SLO-tiered QoS serving under overload (ours; paper §4 serving claims).

Offered-load sweep over a shifting text/code/math request mix where each
workload carries its QoS class (code → premium, text → standard, math →
batch). Two engines serve the SAME arrival-timed stream:

* **baseline** — the single-queue engine: QoS tags stripped, FIFO
  admission, no shedding, no preemption across classes (there is only one
  class);
* **tiered** — the QoS scheduler: weighted-aging tiered queue, premium
  preempts batch for slots, batch decodes on the all-lo banks, and the
  ``reject`` shed policy drops/downgrades low tiers once queue depth or
  estimated wait crosses the overload thresholds.

Runs use the engine's **virtual replay clock** (``replay(realtime=False)``)
so every queue-wait, deadline and preemption decision is deterministic on
any machine: the sweep measures the *scheduling policy* — queue-wait-
dominated end-to-end TPOT and SLO attainment — not CPU kernel speed.
Per-class deadlines are calibrated from the measured underload latency, so
the numbers adapt to the model size instead of hard-coding milliseconds.

Acceptance (asserted, not just reported): at every ≥2× overload point the
tiered engine's premium p95 end-to-end TPOT is strictly below the
baseline's, premium SLO attainment is no worse, and degradation is ordered
— batch breaks (worse p95 TPOT, lower attainment) before premium does.

Results land under the ``"slo"`` key of ``experiments/BENCH_serving.json``
(read-modify-write — the file is shared with serving_perf).
``BENCH_SMOKE=1`` shrinks the stream and sweep for CI.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import BENCH_SMOKE, bench_backend, clone, trained_model
from repro.serving import (EngineConfig, InferenceEngine, RequestStream,
                           SchedulerConfig)
from repro.serving.scheduler import QOS_CLASSES, WORKLOAD_QOS

JSON_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "BENCH_serving.json")

N_NEW = 6
PROMPT = 24
MAX_SLOTS = 4
VSTEP = 2e-3                       # virtual seconds charged per engine step
# Shifting mix, interleaved so every class arrives throughout the run.
PHASE_UNIT = [("text", 2), ("code", 1), ("math", 2)]
REPS = 3 if BENCH_SMOKE else 7
LOAD_FACTORS = (0.8, 2.0) if BENCH_SMOKE else (0.8, 2.0, 3.0)
# Deadline = multiplier × calibrated underload p95 latency.
DEADLINE_X = {"premium": 2.0, "standard": 4.0, "batch": 8.0}


def _requests(cfg, rate_rps, deadlines_ms=None):
    """Materialize the mixed stream; per-class deadlines attached after."""
    reqs = list(RequestStream(
        cfg.vocab_size, phases=PHASE_UNIT * REPS, prompt_len=PROMPT,
        max_new_tokens=N_NEW, arrival_rate_rps=rate_rps,
        arrival_jitter_s=0.0, seed=7, qos="workload"))
    if deadlines_ms is not None:
        for r in reqs:
            r.deadline_ms = deadlines_ms[r.qos]
    return reqs


def _engine(cfg, params, tiered):
    sched = SchedulerConfig(shed_policy="reject") if tiered \
        else SchedulerConfig()
    return InferenceEngine(
        cfg, clone(params), bench_backend("dynaexq"),
        EngineConfig(max_slots=MAX_SLOTS, max_len=PROMPT + N_NEW + 8,
                     scheduler=sched))


def _serve(cfg, params, reqs, tiered):
    eng = _engine(cfg, params, tiered)
    if not tiered:                      # single queue: strip the QoS tags
        for r in reqs:
            r.qos = None
    handles = eng.replay(reqs, realtime=False, virtual_step_s=VSTEP)
    eng.flush()
    return eng, handles


def _per_class(reqs, handles):
    """Per-class latency/SLO table from arrival-ordered handles. The class
    is taken from the REQUEST's workload (baseline handles carry the
    stripped default), shed requests count against attainment."""
    out = {}
    for cls in QOS_CLASSES:
        idx = [i for i, r in enumerate(reqs)
               if WORKLOAD_QOS[r.workload] == cls]
        fin = [handles[i] for i in idx
               if handles[i].state.value == "finished" and handles[i].tokens]
        lat = np.array([h.finish_s - h.submit_s for h in fin])
        tpot = np.array([(h.finish_s - h.submit_s) / len(h.tokens)
                         for h in fin])
        met = sum(1 for i in idx
                  if handles[i].state.value == "finished"
                  and reqs[i].deadline_ms is not None
                  and (handles[i].finish_s - handles[i].submit_s) * 1e3
                  <= reqs[i].deadline_ms)
        out[cls] = {
            "n": len(idx), "served": len(fin),
            "shed": sum(1 for i in idx
                        if handles[i].state.value == "shed"),
            "p95_latency_s": float(np.percentile(lat, 95)) if len(lat)
            else float("nan"),
            "p95_tpot_s": float(np.percentile(tpot, 95)) if len(tpot)
            else float("nan"),
            "slo_attainment": met / max(1, len(idx)),
        }
    return out


def _throughput(handles):
    fin = [h for h in handles if h.state.value == "finished" and h.tokens]
    if not fin:
        return 0.0
    dur = max(h.finish_s for h in fin)
    return sum(len(h.tokens) for h in fin) / max(dur, 1e-9)


def run(report):
    cfg, params, _task = trained_model()

    # ---- calibration: back-to-back drain fixes the service capacity ----
    reqs = _requests(cfg, rate_rps=None)
    _, handles = _serve(cfg, params, reqs, tiered=False)
    dur = max(h.finish_s for h in handles)
    capacity_rps = len(handles) / dur
    report("slo/capacity_rps", 0.0, round(capacity_rps, 2))

    # Deadlines from the measured underload p95 latency: comfortably met
    # when the system keeps up, broken by queue wait once it does not.
    under = _requests(cfg, rate_rps=0.8 * capacity_rps)
    _, uh = _serve(cfg, params, under, tiered=False)
    lat95 = float(np.percentile(
        [h.finish_s - h.submit_s for h in uh
         if h.state.value == "finished"], 95))
    deadlines_ms = {c: x * lat95 * 1e3 for c, x in DEADLINE_X.items()}
    report("slo/deadline_premium_ms", 0.0,
           round(deadlines_ms["premium"], 2))

    results = {"smoke": BENCH_SMOKE, "capacity_rps": capacity_rps,
               "deadlines_ms": deadlines_ms, "by_load": {}}
    failures = []
    for factor in LOAD_FACTORS:
        rate = factor * capacity_rps
        row = {"offered_rps": rate, "load_factor": factor}
        for mode, tiered in (("baseline", False), ("tiered", True)):
            reqs = _requests(cfg, rate, deadlines_ms)
            eng, handles = _serve(cfg, params, reqs, tiered)
            st = eng.stats()
            row[mode] = {
                "classes": _per_class(reqs, handles),
                "throughput_tps": _throughput(handles),
                "preemptions": st["preemptions"],
                "shed_requests": st["shed_requests"],
                "downgraded": st["downgraded"],
            }
        base, tier = row["baseline"]["classes"], row["tiered"]["classes"]
        for cls in QOS_CLASSES:
            report(f"slo/p95_tpot/{cls}/base/x{factor}",
                   base[cls]["p95_tpot_s"] * 1e6,
                   round(base[cls]["slo_attainment"], 3))
            report(f"slo/p95_tpot/{cls}/tiered/x{factor}",
                   tier[cls]["p95_tpot_s"] * 1e6,
                   round(tier[cls]["slo_attainment"], 3))
        report(f"slo/throughput_tps/base/x{factor}", 0.0,
               round(row["baseline"]["throughput_tps"], 2))
        report(f"slo/throughput_tps/tiered/x{factor}", 0.0,
               round(row["tiered"]["throughput_tps"], 2))
        results["by_load"][f"x{factor}"] = row

        if factor >= 2.0:            # ---- acceptance gates ----
            if not (tier["premium"]["p95_tpot_s"]
                    < base["premium"]["p95_tpot_s"]):
                failures.append(
                    f"x{factor}: tiered premium p95 TPOT "
                    f"{tier['premium']['p95_tpot_s']:.4f}s not better than "
                    f"baseline {base['premium']['p95_tpot_s']:.4f}s")
            if tier["premium"]["slo_attainment"] \
                    < base["premium"]["slo_attainment"]:
                failures.append(
                    f"x{factor}: tiered premium attainment regressed")
            if not (tier["batch"]["p95_tpot_s"]
                    >= tier["premium"]["p95_tpot_s"]
                    or tier["batch"]["shed"] > 0):
                failures.append(
                    f"x{factor}: batch did not degrade before premium")
            if tier["batch"]["slo_attainment"] \
                    > tier["premium"]["slo_attainment"]:
                failures.append(
                    f"x{factor}: batch attainment above premium under "
                    f"overload — degradation order inverted")

    print("\n== slo_serving (virtual clock; per-class p95 e2e TPOT ms / "
          "SLO attainment) ==")
    hdr = " ".join(f"{c:>22}" for c in QOS_CLASSES)
    print(f"{'load':>6} {'mode':>9} {hdr} {'tput':>8} {'shed':>5}")
    for key, row in results["by_load"].items():
        for mode in ("baseline", "tiered"):
            cells = " ".join(
                "{:>13.1f}ms/{:>5.2f}".format(
                    row[mode]["classes"][c]["p95_tpot_s"] * 1e3,
                    row[mode]["classes"][c]["slo_attainment"])
                for c in QOS_CLASSES)
            print(f"{key:>6} {mode:>9} {cells} "
                  f"{row[mode]['throughput_tps']:>8.1f} "
                  f"{int(row[mode]['shed_requests']):>5}")

    # Shared artifact: merge under "slo" without clobbering serving_perf.
    existing = {}
    if os.path.exists(JSON_OUT):
        try:
            with open(JSON_OUT) as f:
                existing = json.load(f)
        except Exception:
            existing = {}
    existing["slo"] = results
    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    with open(JSON_OUT, "w") as f:
        json.dump(existing, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.normpath(JSON_OUT)} (slo key)")

    if failures:
        raise AssertionError("SLO acceptance failed:\n  " +
                             "\n  ".join(failures))
