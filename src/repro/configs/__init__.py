"""Architecture registry. ``get_config(name)`` returns the full assigned
config; ``get_config(name, reduced=True)`` the ≤2-layer smoke variant."""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig

ARCH_IDS = (
    "qwen3-moe-30b-a3b",
    "h2o-danube-3-4b",
    "granite-moe-1b-a400m",
    "llama3_2-3b",
    "whisper-tiny",
    "deepseek-7b",
    "jamba-v0_1-52b",
    "phi4-mini-3.8b",
    "mamba2-130m",
    "llava-next-34b",
    # the paper's own larger model family (extra, beyond the assigned ten)
    "qwen3-moe-80b-a3b",
)

_ALIASES = {
    "llama3.2-3b": "llama3_2-3b",
    "jamba-v0.1-52b": "jamba-v0_1-52b",
    "phi4-mini-3_8b": "phi4-mini-3.8b",
}


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(name: str, reduced: bool = False) -> ArchConfig:
    name = _ALIASES.get(name, name)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_module_name(name)}")
    cfg = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def all_configs(reduced: bool = False):
    return {a: get_config(a, reduced) for a in ARCH_IDS}
