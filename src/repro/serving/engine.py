"""Request-level MoE serving engine with pluggable expert residency and a
paged, prefix-shared KV cache.

The unit of work is a **request**, not a batch: ``submit(request)`` returns a
handle, ``step()`` advances every in-flight request — by one sampled token,
or by a whole accepted draft burst when self-speculative decoding is on
(``EngineConfig(spec_k > 0)``, see ``repro.serving.spec``) — and ``drain()``
runs until the queue empties. Tokens are drawn host-side by each request's
own ``SamplingParams`` (``repro.serving.sampler``; greedy default is exact
argmax). The engine implements continuous batching over a fixed pool of
``max_slots`` batch rows:

* **admission** — queued requests are batched into a padded, masked prefill:
  prompt lengths round up a small geometric bucket ladder
  (``bucket_base``·2^i, capped at ``max_len``), up to ``prefill_rows``
  same-bucket requests prefill in ONE forward (per-row true lengths mask
  padding out of attention-cache writes, MoE dispatch and router counts).
  XLA therefore compiles at most one prefill executable per bucket
  — O(#buckets), not O(#distinct prompt lengths) — and admission cost
  amortizes over the batch at high arrival rates;
* **decode** — one jitted step advances *all* occupied slots together, with
  a per-slot position vector (each request decodes at its own offset) and a
  per-slot validity mask: vacant slots still ride along for shape stability
  but are masked out of MoE dispatch and every router count;
* **eviction/refill** — a finished request frees its slot at the end of the
  step; the next ``step()`` admits queued work into it mid-stream.

KV residency (``paged=True``, the default) is a **block pool**
(``repro.serving.kvpool``): attention caches live as fixed-size physical
blocks leased to requests through per-slot block tables, with a token-prefix
trie (``repro.serving.prefix``) mapping shared prompt prefixes (system
prompts, few-shot headers) onto the SAME physical blocks — admission adopts
trie hits and prefills only the suffix, skipping recompute entirely; decode
appends lazily and copy-on-writes shared blocks on divergence. KV block
bytes are reserved from the same ``BudgetTracker`` the expert hi-tier
promotes against, so KV admission and DynaExq promotions genuinely contend
for one HBM envelope (``hbm_budget_bytes``): KV pressure defers promotions,
demotions free headroom for admission. ``paged=False`` keeps the dense
per-slot rows — the parity reference. (Parity caveat: with a TIGHT MoE
``capacity_factor`` the router may drop overflow tokens, and the drop set
is a function of the compute batch — prefix skipping changes that batch,
exactly like batching itself does. Token-identity between the shared and
dense paths is therefore guaranteed for drop-free capacity settings.)

Where expert weights live — dense fp16, static PTQ, DynaExq mixed precision,
or host-offloaded with an LRU device cache — is entirely the
``ResidencyBackend``'s business (see ``repro.serving.backends``). The engine
calls exactly the backend protocol: ``materialize_banks`` at build time
(receiving the POOL's byte accounting and the shared budget),
``observe(counts, compute_s, prefill, row_valid)`` after every forward with
per-row (slot-resolved) router counts plus the row-validity mask — so no
backend ever accounts phantom traffic from padding or vacant slots — and
``tick()`` at step boundaries. There is no mode switch and no per-backend
branch anywhere in this loop.

Per-request routing telemetry falls out of the same signal: every
``RequestHandle`` accumulates its own row's expert counts
(``handle.expert_counts``: MoE position → (nsb, E)), attributing router
traffic to the request that caused it (prefix-skipped tokens are attributed
to the request that originally computed them).

``generate(batch, n_tokens)`` survives as a thin compat shim over
submit + drain for the whole-batch callers (benchmarks, launchers).
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import itertools
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.budget import UNBOUNDED, BudgetTracker
from repro.kernels import ops as kops
from repro.launch.dist import dist_ctx
from repro.models import (attn_logical_capacity, decode_step,
                          decode_step_paged, init_caches, init_paged_caches,
                          prefill, prefill_paged)
from repro.models.config import ArchConfig
from repro.models.moe import RAGGED_BM, moe_capacity
from repro.models.model import DecodeCaches
from repro.serving.backends import ResidencyBackend
from repro.serving.kvpool import KVBlockPool, KVLease
from repro.serving.prefix import PrefixTrie
from repro.serving.requests import Request
from repro.serving.sampler import RequestSampler
from repro.serving.spec import (_gather_paged_lanes, _restore_paged_lanes,
                                all_lo_banks)
from repro.serving.scheduler import (Scheduler, SchedulerConfig,
                                     SlotSnapshot, TieredQueue)

#: Engine-level keys ``InferenceEngine.stats()`` adds on top of the
#: backend's ``STAT_KEYS`` + per-class ``STAT_EXTRAS`` (plus the
#: speculative decoder's live overwrites of schema keys). Pinned here so
#: the stats schema is a checked contract (tests/test_obs.py), not an
#: accretion: a new engine gauge must be added to this tuple or the
#: contract test fails.
ENGINE_STAT_KEYS = (
    "steps", "prefills", "admitted", "finished", "prefill_tokens",
    "prefix_hit_tokens", "kv_cow_copies", "preemptions", "resumes",
    "shed_requests", "downgraded", "chunk_prefills",
    "prefill_compiles", "kv_blocks_in_use", "kv_bytes_in_use",
    "prefix_trie_nodes", "spec_row_rounds", "watchdog_cancels")

#: Keys ``load_snapshot()`` returns — the shed policy's input schema,
#: pinned for the same reason.
LOAD_SNAPSHOT_KEYS = ("queue_depth", "tpot_ema_s", "est_wait_s",
                      "budget_headroom_frac", "residency_ready_frac")


# Module-level jitted entry points with the (frozen, hashable) ArchConfig as
# a static argument: the XLA compile cache is keyed on the function identity,
# so every engine built for the same config shares compilations — a warm-up
# engine genuinely warms the measured one (benchmarks rely on this).
#
# ``ep`` is an unused static cache key: the ambient DistContext is read at
# TRACE time (``moe_apply`` → ``get_dist()``), so an engine serving under an
# expert-parallel mesh must not share a cache entry with a single-device
# engine of identical shapes — the engine passes its token-shard count (0
# when no mesh) to force distinct compilations per distribution regime.

@functools.partial(jax.jit, static_argnames=("cfg", "capacity_factor",
                                             "moe_dispatch", "row_capacity",
                                             "ep"))
def _prefill_jit(params, batch, caches, banks, lengths, *, cfg,
                 capacity_factor, moe_dispatch=None, row_capacity=None,
                 ep=0):
    return prefill(params, cfg, batch, caches, bank=banks,
                   capacity_factor=capacity_factor, lengths=lengths,
                   per_row_counts=True, moe_dispatch=moe_dispatch,
                   row_capacity=row_capacity)


@functools.partial(jax.jit, static_argnames=("cfg", "capacity_factor",
                                             "moe_dispatch", "row_capacity",
                                             "ep"))
def _decode_jit(params, token, pos, caches, banks, row_valid, *, cfg,
                capacity_factor, moe_dispatch=None, row_capacity=None,
                ep=0):
    return decode_step(params, cfg, token, pos, caches, bank=banks,
                       capacity_factor=capacity_factor, row_valid=row_valid,
                       per_row_counts=True, moe_dispatch=moe_dispatch,
                       row_capacity=row_capacity)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "capacity_factor", "has_prefix",
                                    "moe_dispatch", "row_capacity", "ep"),
                   donate_argnums=(2,))
def _prefill_paged_jit(params, batch, caches, banks, table, start, lengths,
                       *, cfg, capacity_factor, has_prefix,
                       moe_dispatch=None, row_capacity=None, ep=0):
    return prefill_paged(params, cfg, batch, caches, table, start, lengths,
                         bank=banks, capacity_factor=capacity_factor,
                         per_row_counts=True, has_prefix=has_prefix,
                         moe_dispatch=moe_dispatch, row_capacity=row_capacity)


@functools.partial(jax.jit, static_argnames=("cfg", "capacity_factor",
                                             "moe_dispatch", "row_capacity",
                                             "ep"),
                   donate_argnums=(3,))
def _decode_paged_jit(params, token, pos, caches, banks, row_valid, table,
                      write_blk, write_off, *, cfg, capacity_factor,
                      moe_dispatch=None, row_capacity=None, ep=0):
    return decode_step_paged(params, cfg, token, pos, caches, table,
                             write_blk, write_off, bank=banks,
                             capacity_factor=capacity_factor,
                             row_valid=row_valid, per_row_counts=True,
                             moe_dispatch=moe_dispatch,
                             row_capacity=row_capacity)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(pool, rows, slots):
    """Write the first ``len(slots)`` prefilled rows of a bucket cache into
    the batch rows named by ``slots``. The pool is donated so XLA updates
    the (large) cache buffers in place."""
    n = slots.shape[0]
    return jax.tree_util.tree_map(
        lambda m, o: m.at[:, slots].set(o[:, :n]), pool, rows)


@jax.jit
def _merge_rows(new_sub, old_sub, mask):
    """Row-masked cache merge for tier-split dispatch: keep the freshly
    computed state only for rows in ``mask`` ((B,) bool); every other row
    keeps its pre-dispatch state. Needed for RECURRENT (mamba) leaves —
    a decode forward advances SSM state for masked rows too, so when one
    engine step dispatches several QoS groups, each group's forward must
    not clobber the live state of rows belonging to the others. (Attention
    caches need no merge: a masked row's garbage write lands at that row's
    next-write position, which its own group overwrites before any read.)
    Leaves are (nsb, B, ...)."""
    def one(nv, ov):
        m = mask.reshape((1, -1) + (1,) * (nv.ndim - 2))
        return jnp.where(m, nv, ov)
    return jax.tree_util.tree_map(one, new_sub, old_sub)


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_blocks(pools, src, dst):
    """Batched physical block copies (COW resolution): block ``src[i]`` →
    ``dst[i]`` in every attention pool leaf ((nsb, N, ...)). Sources are
    all gathered before any scatter, so same-step chains (A→B while A is
    reallocated as another copy's destination) read pre-step contents.
    Padding lanes are trash→trash self-copies."""
    return jax.tree_util.tree_map(
        lambda a: a.at[:, dst].set(a[:, src]), pools)


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 4               # concurrent requests (batch rows)
    max_len: int = 512               # per-slot sequence budget
    capacity_factor: float = 2.0
    pad_token_id: int = 0            # fed to never-yet-occupied decode rows
    bucket_base: int = 32            # smallest prefill length bucket
    # Rows per batched prefill (compile-time constant so the prefill compile
    # count stays O(#buckets)); None → min(4, max_slots).
    prefill_rows: Optional[int] = None
    # ---- paged KV pool ------------------------------------------------
    paged: bool = True               # block-pool KV (False = dense rows)
    block_tokens: int = 16           # cache positions per physical block
    # Physical blocks in the pool; None → exactly enough for max_slots full
    # sequences plus the trash block (sharing then only ADDS headroom).
    kv_blocks: Optional[int] = None
    prefix_sharing: bool = True      # trie-based cross-request prefix reuse
    # Unified HBM envelope shared by KV block reservations and the expert
    # hi tier (None = unbounded: per-subsystem caps still apply).
    hbm_budget_bytes: Optional[int] = None
    # ---- self-speculative decoding -----------------------------------
    # Max draft depth per round (0 = off). Drafting runs decode with the
    # backend's all-lo expert banks (no extra weights); every verify round
    # emits 1..spec_k+1 tokens. Token-identical to spec-off at
    # temperature=0 under drop-free MoE capacity (see serving.spec).
    spec_k: int = 0
    # Adapt the per-round draft depth from an acceptance-rate EMA over a
    # power-of-two ladder (False = always draft spec_k).
    spec_adaptive: bool = True
    # ---- MoE dispatch ------------------------------------------------
    # Token layout for every MoE layer of the serving forwards: "padded"
    # (fixed-capacity (E, C, d) scatter, reference), "ragged" (compacted
    # activations + fused mixed-precision kernel — only active experts'
    # weights stream), or None → kernels.ops.moe_dispatch_default()
    # (ragged on TPU, padded on CPU; REPRO_MOE_DISPATCH overrides).
    # Resolved ONCE at engine construction.
    moe_dispatch: Optional[str] = None
    # Per-row MoE capacity normalization: the drop rule under tight
    # capacity_factor becomes per-request-row (see moe._row_capacity_keep),
    # so whether a token's assignment drops no longer depends on which
    # other requests share the compute batch — prefix sharing and
    # spec-verify token identity then hold even in drop regimes.
    row_capacity_norm: bool = False
    # ---- SLO-tiered QoS scheduling -----------------------------------
    # Policy knobs for the tiered scheduler (queue aging, shed policy,
    # preemption, chunked prefill). None → SchedulerConfig() defaults,
    # which reproduce the untiered engine exactly for default-class
    # traffic. See repro.serving.scheduler.
    scheduler: Optional[SchedulerConfig] = None
    # ---- fault tolerance (repro.fault) -------------------------------
    # Cancel hi promotions stuck in flight longer than this (engine-clock
    # seconds since copy issue): slot freed, reservation refunded, expert
    # keeps serving lo. None = no promotion watchdog.
    promo_deadline_s: Optional[float] = None
    # Preempt-and-requeue RUNNING requests that appended no token for this
    # long (bit-exact snapshot resume). None = no request watchdog.
    watchdog_no_progress_s: Optional[float] = None


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"     # chunked prefill in flight (owns a slot)
    RUNNING = "running"
    FINISHED = "finished"
    SHED = "shed"                 # refused by the load-shedding policy


class EngineStallError(RuntimeError):
    """The engine went fully idle with queued work it could not admit and
    no in-flight transfers left to free bytes — no future step can change
    anything. Carries a structured ``snapshot`` (queue depths per QoS
    tier, pending promotions with ages, budget headroom, residency
    readiness) so operators see *why* admission wedged instead of a bare
    "stalled" string."""

    def __init__(self, snapshot: Dict[str, object]):
        self.snapshot = snapshot
        depths = snapshot.get("queue_depths", {})
        pend = snapshot.get("pending_promotions", [])
        super().__init__(
            f"admission stalled: {snapshot.get('queued_total', 0)} queued "
            f"request(s) cannot reserve KV under the shared HBM envelope "
            f"and no in-flight work remains to free bytes "
            f"(queue depths {depths}, envelope used "
            f"{snapshot.get('budget_used', 0)}/"
            f"{snapshot.get('budget_cap', 0)}, "
            f"ready_frac {snapshot.get('residency_ready_frac', 1.0):.3f}, "
            f"{len(pend)} pending promotion(s))")


class RequestHandle:
    """Mutable per-request view returned by ``submit``."""

    def __init__(self, rid: int, request: Request):
        self.id = rid
        self.request = request
        self.state = RequestState.QUEUED
        self.slot: Optional[int] = None
        self.tokens: List[int] = []      # generated tokens
        # Per-request sampling state (counter-based PRNG keyed by the
        # request's seed; greedy when the request carries no params).
        self.sampler = RequestSampler(request.sampling)
        self._eos_scanned = 0            # tokens already checked for EOS
        # Per-REQUEST speculative acceptance EMA: draft depth adapts from
        # this request's own history only, so its burst boundaries (and
        # therefore its PRNG stream consumption) never depend on which
        # other requests share the batch — bit-reproducibility survives
        # adaptive speculation.
        self.spec_ema = 0.75
        self.submit_s: float = 0.0       # engine clock at submit
        self.stall_at_submit: float = 0.0  # engine stall-clock at submit
        self.ttft_s: float = 0.0         # submit → first token (incl. queue)
        self.first_token_s: float = 0.0  # engine clock at first token
        self.finish_s: float = 0.0       # engine clock at finish
        self.step_times: List[float] = []
        # ---- QoS (repro.serving.scheduler) ---------------------------
        self.qos: str = "standard"       # resolved SLO class
        self.exec_qos: str = "standard"  # execution tier (after downgrades)
        self.enqueue_s: float = 0.0      # queue-aging reference time
        self.preempts = 0                # times this request was evicted
        self._snapshot = None            # SlotSnapshot while evicted
        self._chunk_pos = 0              # prompt tokens prefilled so far
        self.lease: Optional[KVLease] = None   # paged-mode KV block lease
        self.prefix_hit_tokens: int = 0  # prompt tokens served from the trie
        # Modeled stall seconds of forwards this request was RESIDENT for
        # (prefill + decode + spec rounds): host-tier demand fetches and
        # offload misses attributed to the requests they actually delayed.
        # Exposure, not an exclusive share — concurrent residents each
        # record the full stall their step suffered.
        self.stall_exposure_s: float = 0.0
        # Per-request routing telemetry: MoE position → (nsb, E) int64
        # router selections attributed to THIS request's row (prompt tokens
        # at prefill + one per decode step). Populated at admission.
        self.expert_counts: Optional[Dict[str, np.ndarray]] = None
        # ---- fault tolerance -----------------------------------------
        # True once any forward of this request routed through a
        # quarantined (host-served, degraded-quality) expert cell — such
        # requests complete but are excluded from bit-parity guarantees.
        self.degraded = False
        # Engine clock at the last appended token (watchdog progress
        # stamp; 0.0 until the first token).
        self.last_progress_s: float = 0.0

    @property
    def workload(self) -> str:
        return self.request.workload

    def token_array(self) -> np.ndarray:
        return np.asarray(self.tokens, np.int32)

    def __repr__(self):
        return (f"RequestHandle(id={self.id}, state={self.state.value}, "
                f"slot={self.slot}, n_generated={len(self.tokens)})")


class InferenceEngine:
    """Continuous-batching serving loop over a ``ResidencyBackend``."""

    def __init__(self, cfg: ArchConfig, params: Dict,
                 backend: ResidencyBackend,
                 ecfg: Optional[EngineConfig] = None, dist=None, obs=None):
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "InferenceEngine serves decoder-only stacks; encoder-decoder "
                "architectures go through the batch prefill/decode entry "
                "points in repro.models directly.")
        self.cfg = cfg
        self.params = params
        self.backend = backend
        self.ecfg = ecfg if ecfg is not None else EngineConfig()
        # Optional DistContext (expert-parallel / data-parallel serving):
        # every jitted forward traces under it — see ``_dist_wrap``.
        self.dist = dist

        n = self.ecfg.max_slots
        sb = cfg.superblock_or_default()
        self._attn_pos = [str(p) for p, k in enumerate(sb) if k == "attn"]
        self._mamba_pos = [str(p) for p, k in enumerate(sb) if k != "attn"]

        # ---- unified HBM envelope + paged KV pool ----------------------
        # The pool is the single source of truth for KV bytes: both modes
        # size KV from the same block math, and in paged mode every block
        # is reserved against the shared budget the expert hi tier also
        # draws from (see repro.core.budget).
        cap = self.ecfg.hbm_budget_bytes
        self.budget = BudgetTracker(UNBOUNDED if cap is None else cap)
        self.pool: Optional[KVBlockPool] = None
        self.trie: Optional[PrefixTrie] = None
        self._bt = self.ecfg.block_tokens
        if self._attn_pos:
            self._C_attn = self.ecfg.max_len \
                if cfg.attn.sliding_window is None \
                else min(self.ecfg.max_len, cfg.attn.sliding_window)
            self._C_pad = attn_logical_capacity(cfg, self.ecfg.max_len,
                                                self._bt)
            self._nb_per_slot = self._C_pad // self._bt
        else:
            self._C_attn = self._C_pad = self._nb_per_slot = 0
        n_blocks = self.ecfg.kv_blocks if self.ecfg.kv_blocks is not None \
            else 1 + n * self._nb_per_slot
        block_bytes = self._block_bytes()
        if self.ecfg.paged and self._attn_pos:
            if self._nb_per_slot > n_blocks - 1:
                raise ValueError(
                    f"kv_blocks={n_blocks} cannot hold even one sequence "
                    f"({self._nb_per_slot} logical blocks + the trash "
                    f"block); raise kv_blocks or shrink max_len")
            self.pool = KVBlockPool(n_blocks, self._bt, block_bytes,
                                    budget=self.budget.view("kv"),
                                    reclaim=self._reclaim_blocks)
            # Prefix skipping needs leasable sequence state; recurrent
            # (mamba) positions cannot be restored from a cache, so mixed
            # stacks run the pool without the trie.
            if self.ecfg.prefix_sharing and not self._mamba_pos:
                self.trie = PrefixTrie(self.pool)
        # KV bytes reported to the backend = what is actually allocated:
        # the pool's capacity (trash + rounding included) in paged mode,
        # the dense per-slot rows otherwise.
        if self.pool is not None:
            kv_bytes = self.pool.capacity_bytes
        elif self._attn_pos:
            kv_bytes = (block_bytes // self._bt) * n * self._C_attn
        else:
            kv_bytes = 0

        # ---- observability (repro.obs) ---------------------------------
        # The flight recorder's clock is rebound to the ENGINE clock, so
        # virtual-clock replays (``replay(realtime=False)``) stamp events
        # deterministically and traces compare byte-identical in CI. With
        # ``obs=None`` (default) every instrumentation site below is a
        # single pointer check — the decode hot path is untouched.
        self.obs = obs
        self.tracer = obs.tracer if obs is not None else None
        self.metrics = obs.metrics if obs is not None else None
        self._sample_every = max(1, obs.cfg.sample_every) \
            if obs is not None else 1
        self._obs_prev = (0.0, 0.0, 0)   # dispatch-gauge snapshot per step
        if self.tracer is not None:
            self.tracer.clock = self._now
        if obs is not None:
            attach = getattr(backend, "attach_obs", None)
            if attach is not None:
                attach(self.tracer, self.metrics)

        self.banks = backend.materialize_banks(cfg, params, kv_bytes,
                                               budget=self.budget)
        # ---- fault tolerance (repro.fault) ------------------------------
        # Rebind the transfer plane's clocks to the engine clock (virtual
        # under replay) so promotion ages — the watchdog's input — share
        # the time base of every other engine metric.
        bind = getattr(backend, "bind_clock", None)
        if bind is not None:
            bind(self._now)
        self._watchdog = None
        if self.ecfg.promo_deadline_s is not None or \
                self.ecfg.watchdog_no_progress_s is not None:
            from repro.fault.watchdog import Watchdog, WatchdogConfig
            self._watchdog = Watchdog(WatchdogConfig(
                promo_deadline_s=self.ecfg.promo_deadline_s,
                no_progress_s=self.ecfg.watchdog_no_progress_s),
                tracer=self.tracer)
        # Quarantine-degradation marking: a single method call per step
        # when the backend exposes it, skipped entirely otherwise.
        self._degraded_fn = getattr(backend, "degraded_cells", None)
        # MoE dispatch layout + per-row capacity normalization, resolved
        # ONCE here (env changes after construction cannot disagree with
        # already-compiled executables). The decode row cap is static; the
        # prefill cap depends on the length bucket and rides per call.
        self.moe_dispatch = self.ecfg.moe_dispatch \
            if self.ecfg.moe_dispatch is not None \
            else kops.moe_dispatch_default()
        if self.moe_dispatch not in ("padded", "ragged"):
            raise ValueError(f"moe_dispatch={self.moe_dispatch!r}; "
                             f"one of padded|ragged")
        if self.tracer is not None:
            # Trace metadata the offline cost model (repro.obs.costmodel)
            # replays against: dispatch mode, router shape, byte prices.
            self.tracer.meta.update(
                moe_dispatch=self.moe_dispatch,
                num_experts=cfg.moe.num_experts if cfg.is_moe else 0,
                top_k=cfg.moe.top_k if cfg.is_moe else 1,
                lo_bytes=0, hi_bytes=0, backend=backend.name)
            meta_fn = getattr(backend, "obs_meta", None)
            if meta_fn is not None:
                self.tracer.meta.update(meta_fn())
        norm = self.ecfg.row_capacity_norm and cfg.is_moe
        self._row_cap_decode = moe_capacity(
            1, cfg.moe, self.ecfg.capacity_factor) if norm else None
        self._row_cap_norm = norm
        ep_key = 0 if self.dist is None else self.dist.n_token_shards
        self._jit_prefill = self._dist_wrap(functools.partial(
            _prefill_jit, cfg=cfg,
            capacity_factor=self.ecfg.capacity_factor,
            moe_dispatch=self.moe_dispatch, ep=ep_key))
        self._jit_decode = self._dist_wrap(functools.partial(
            _decode_jit, cfg=cfg,
            capacity_factor=self.ecfg.capacity_factor,
            moe_dispatch=self.moe_dispatch,
            row_capacity=self._row_cap_decode, ep=ep_key))
        self._jit_prefill_paged = self._dist_wrap(functools.partial(
            _prefill_paged_jit, cfg=cfg,
            capacity_factor=self.ecfg.capacity_factor,
            moe_dispatch=self.moe_dispatch, ep=ep_key))
        self._jit_decode_paged = self._dist_wrap(functools.partial(
            _decode_paged_jit, cfg=cfg,
            capacity_factor=self.ecfg.capacity_factor,
            moe_dispatch=self.moe_dispatch,
            row_capacity=self._row_cap_decode, ep=ep_key))
        self._jit_scatter = _scatter_rows
        # Dispatch-efficiency gauges (host mirror of MoEAux telemetry).
        self._disp_active_sum = 0.0
        self._disp_pad_sum = 0.0
        self._disp_layers = 0

        if self.pool is not None:
            self.caches = init_paged_caches(cfg, n, self.ecfg.max_len,
                                            self._bt, self.pool.n_blocks)
        else:
            self.caches = init_caches(cfg, n, self.ecfg.max_len)
        self.slots: List[Optional[RequestHandle]] = [None] * n
        self.pos = np.zeros(n, np.int32)        # next write position per slot
        self.tokens = np.full(n, self.ecfg.pad_token_id, np.int32)
        # ---- SLO-tiered scheduling ----------------------------------
        # The scheduler is pure policy; the admission queue is the tiered
        # weighted-aging queue (deque-compatible — FIFO for uniform-class
        # traffic, so the defaults reproduce the untiered engine exactly).
        self.sched = Scheduler(self.ecfg.scheduler)
        self._clock: Optional[float] = None     # virtual clock (replay)
        self.queue: TieredQueue = TieredQueue(self._now,
                                              self.sched.cfg.aging_s)
        self._lo_owner_cache: Dict = {}         # all-lo bank derivation memo
        self._tpot_ema = 0.0                    # per-token latency EMA
        self.last_counts: Dict = {}             # (nsb, E) counts, last forward
        self.last_row_counts: Dict = {}         # (nsb, R, E), last forward
        self.decode_times: List[float] = []     # per-step latency incl. stall
        # Per-TOKEN decode latency accounting: a speculative round's
        # dispatch latency amortizes over every token the round emits, so
        # tpot stays time-per-OUTPUT-token whether or not speculation runs.
        self._tpot_sum = 0.0                    # Σ row-rounds × latency
        self._tpot_tokens = 0                   # decode-emitted tokens
        self.ttfts: List[float] = []            # per-request submit→first-tok
        # Cumulative modeled stall seconds (backend-returned, never slept):
        # a virtual clock running alongside perf_counter, so queue-inclusive
        # latencies charge the stalls of work that ran ahead of a request.
        self._stall_clock = 0.0
        self._ids = itertools.count()
        self.counters = {"steps": 0, "prefills": 0, "admitted": 0,
                         "finished": 0, "prefill_tokens": 0,
                         "prefix_hit_tokens": 0, "kv_cow_copies": 0,
                         "preemptions": 0, "resumes": 0,
                         "shed_requests": 0, "downgraded": 0,
                         "chunk_prefills": 0, "watchdog_cancels": 0}
        # ---- length-bucket ladder -----------------------------------
        # SSD prefill requires sequence length divisible by the chunk size,
        # so for stacks with mamba layers every bucket is a chunk multiple.
        self._seq_mult = cfg.ssm.chunk if self._mamba_pos else 1
        m = self._seq_mult
        cap = (self.ecfg.max_len // m) * m
        if cap <= 0:
            raise ValueError(
                f"max_len={self.ecfg.max_len} below the SSD chunk multiple "
                f"{m}; no prefill bucket fits")
        base = max(1, -(-self.ecfg.bucket_base // m) * m)
        ladder: List[int] = []
        v = base
        while v < cap:
            ladder.append(v)
            v *= 2
        ladder.append(cap)
        self.buckets = tuple(ladder)            # ascending, last == cap
        self._max_prompt = cap
        self._prefill_rows = self.ecfg.prefill_rows \
            if self.ecfg.prefill_rows is not None else min(4, n)
        self.prefill_shapes: set = set()        # (rows, bucket) traced
        # ---- chunked prefill ----------------------------------------
        # Effective chunk size: the largest block-aligned ladder bucket
        # not above the knob, so every chunk prefill hits a bucket shape
        # the normal admission path already compiles (compile count stays
        # O(#buckets)). Chunking needs the paged suffix-prefill path and
        # restartable sequence state: attention-only stacks (SSD prefill
        # takes no initial state, so mamba rows must prefill in one shot),
        # and sliding-window prompts only while they fit the window.
        self._chunk_tokens = 0
        pc = self.sched.cfg.prefill_chunk
        if pc > 0 and self.pool is not None and not self._mamba_pos:
            fits = [b for b in self.buckets
                    if b <= pc and b % self._bt == 0]
            if fits:
                self._chunk_tokens = fits[-1]
        # ---- self-speculative decoding ------------------------------
        self._spec = None
        if self.ecfg.spec_k > 0:
            from repro.serving.spec import SpecDecoder
            self._spec = SpecDecoder(self)

    # ------------------------------------------------------------------
    def _now(self) -> float:
        """The engine's accounting clock. Wall time normally; the replay
        loop installs a VIRTUAL clock for ``realtime=False`` runs so every
        queue-time metric (submit_s, ttft_s, finish_s, queue aging,
        deadlines) is computed by the same code against deterministic
        timestamps — virtual-clock runs report the same submit-inclusive
        accounting realtime ones do, machine speed be damned. Compute
        latencies (decode dt, stalls) always use perf_counter."""
        return time.perf_counter() if self._clock is None else self._clock

    # ------------------------------------------------------------------
    def _dist_wrap(self, fn):
        """Run a jitted forward under the engine's DistContext: the MoE
        layer reads the ambient context at trace time to decide its
        sharding regime (single-device / dp shard_map / expert-parallel
        all-to-all), so every trace — including the speculative decoder's,
        which calls through these same partials — happens inside it. The
        ``ep`` static passed alongside keeps distribution regimes from
        sharing a compile-cache entry."""
        if self.dist is None:
            return fn

        def wrapped(*a, **kw):
            with dist_ctx(self.dist):
                return fn(*a, **kw)
        return wrapped

    # ------------------------------------------------------------------
    def _row_cap_prefill(self, bucket: int) -> Optional[int]:
        """Per-row MoE capacity for a prefill at this length bucket (None
        when normalization is off). Bucket-derived so it is a static compile
        constant per bucket and depends only on the request's own length —
        never on which rows share the batch."""
        if not self._row_cap_norm:
            return None
        return moe_capacity(bucket, self.cfg.moe, self.ecfg.capacity_factor)

    def _note_dispatch(self, counts_np: Dict) -> None:
        """Host mirror of the MoEAux dispatch telemetry: per-layer active
        expert counts and the pad ratio of the layout actually configured
        (padding rows of the (E, C) buffer, or intra-tile slack of the
        bm-aligned ragged layout) — the uniform ``active_experts`` /
        ``dispatch_pad_ratio`` gauges in ``stats()``."""
        if not self.cfg.is_moe or not counts_np:
            return
        E = self.cfg.moe.num_experts
        if self._row_cap_decode is not None:
            C = self.ecfg.max_slots * self._row_cap_decode
        else:
            C = moe_capacity(self.ecfg.max_slots, self.cfg.moe,
                             self.ecfg.capacity_factor)
        for v in counts_np.values():
            v = np.asarray(v)
            if v.ndim == 4:                       # (W, nsb, B, E) spec steps
                per = v.sum(axis=2).reshape(-1, E)
            elif v.ndim == 3:                     # (nsb, B, E) per-row
                per = v.sum(axis=1).reshape(-1, E)
            else:                                 # (nsb, E) aggregated
                per = v.reshape(-1, E)
            per = per.astype(np.float64)
            routed = per.sum(axis=1)
            live = routed > 0
            if not live.any():
                continue
            per = per[live]
            routed = routed[live]
            active = (per > 0).sum(axis=1)
            if self.moe_dispatch == "ragged":
                tiles = np.ceil(per / RAGGED_BM).sum(axis=1)
                pad = 1.0 - routed / np.maximum(tiles * RAGGED_BM, 1.0)
            else:
                kept = np.minimum(per, C).sum(axis=1)
                pad = 1.0 - kept / max(E * C, 1)
            self._disp_active_sum += float(active.sum())
            self._disp_pad_sum += float(pad.sum())
            self._disp_layers += int(active.shape[0])

    def _block_bytes(self) -> int:
        """Bytes of ONE physical block across every attention layer of the
        stack (k+v, bf16). The pool's block math is the only KV size
        accounting in the system."""
        cfg = self.cfg
        if not self._attn_pos:
            return 0
        n_attn = len(self._attn_pos) * cfg.n_superblocks()
        return (2 * self._bt * cfg.attn.n_kv_heads * cfg.attn.head_dim *
                2 * n_attn)

    def _reclaim_blocks(self, need: int) -> int:
        return self.trie.evict(need) if self.trie is not None else 0

    def _quota_blocks(self, plen: int, start: int, max_new: int) -> int:
        """Worst-case physical blocks a request can ever allocate.

        Full attention (positions only grow): exactly the logical blocks
        from the (block-aligned) prefix hit ``start`` to the sequence cap —
        adopted prefix blocks and registered chunks are never rewritten, so
        they can never COW. Sliding-window rings can wrap a write onto ANY
        logical block: one allocation per logical block (lazy append or COW
        of an adopted block) plus one per trie-registrable prompt chunk (a
        block this lease computes, shares, then COWs on a later wrap)."""
        seq_cap = min(self.ecfg.max_len, plen + max_new)
        if self.cfg.attn.sliding_window is None:
            return -(-seq_cap // self._bt) - start // self._bt
        n_write = -(-min(self._C_pad, seq_cap) // self._bt)
        n_reg = plen // self._bt \
            if (self.trie is not None and plen <= self._C_attn) else 0
        return n_write + n_reg

    # ------------------------------------------------------------------
    def submit(self, request: Request) -> RequestHandle:
        """Queue a request; it is admitted on a later ``step()`` as soon as
        a cache slot frees up. Returns immediately with a handle.

        The prompt must fit the largest prefill bucket (``max_len`` rounded
        down to the engine's sequence multiple). A generation budget that
        overruns the slot is fine — common for eos-bounded requests — the
        request is truncated at the sequence capacity (finishes with fewer
        than ``max_new_tokens`` tokens).

        QoS: the request's class (or the scheduler default) is resolved and
        validated here — unknown classes and non-positive deadlines fail
        loudly. Under an active shed policy an overloaded engine may return
        the handle in state ``SHED`` (batch tier, ``reject`` policy) or
        downgrade its execution tier to the all-lo banks — premium is never
        touched."""
        qos = self.sched.resolve(request.qos)
        if request.deadline_ms is not None and request.deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms={request.deadline_ms} must be > 0 (or None)")
        plen = int(np.asarray(request.tokens).shape[-1])
        if plen > self._max_prompt:
            raise ValueError(
                f"prompt of {plen} tokens exceeds the largest prefill "
                f"bucket {self._max_prompt} (max_len={self.ecfg.max_len})")
        if request.sampling is not None:
            # Malformed sampling params fail at the door, not mid-decode.
            request.sampling.validate()
        if self.pool is not None:
            # Loud infeasibility instead of an unbounded queue spin: a
            # request whose worst-case KV quota (no prefix hits) plus the
            # trash block can NEVER fit the envelope — or whose live block
            # footprint exceeds the pool's physical blocks — would block
            # the queue head forever.
            worst = ((1 + self._quota_blocks(plen, 0, request.max_new_tokens))
                     * self.pool.block_bytes)
            if worst > self.budget.cap:
                raise ValueError(
                    f"request needs {worst} bytes of KV worst-case but the "
                    f"HBM envelope caps at {self.budget.cap}; raise "
                    f"hbm_budget_bytes or shorten the request")
        handle = RequestHandle(next(self._ids), request)
        handle.qos = handle.exec_qos = qos
        handle.submit_s = self._now()
        handle.enqueue_s = handle.submit_s
        handle.stall_at_submit = self._stall_clock
        if self.tracer is not None:
            self.tracer.instant("submit", cat="sched", rid=handle.id,
                                qos=qos, prompt=plen)
        action = self.sched.admit_action(qos, self.load_snapshot())
        if action == "shed":
            handle.state = RequestState.SHED
            self.counters["shed_requests"] += 1
            if self.tracer is not None:
                self.tracer.instant("shed", cat="sched", rid=handle.id,
                                    qos=qos, reason="overload")
            return handle
        if action == "downgrade" and handle.exec_qos != "batch":
            handle.exec_qos = "batch"
            self.counters["downgraded"] += 1
            if self.tracer is not None:
                self.tracer.instant("downgrade", cat="sched", rid=handle.id,
                                    qos=qos)
        self.queue.append(handle)
        return handle

    def _bucket_len(self, plen: int) -> int:
        """Smallest ladder bucket that fits ``plen`` tokens."""
        for b in self.buckets:
            if b >= plen:
                return b
        raise ValueError(f"prompt of {plen} tokens exceeds the largest "
                         f"bucket {self.buckets[-1]}")

    @staticmethod
    def _prompt_len(handle: RequestHandle) -> int:
        return int(np.asarray(handle.request.tokens).reshape(-1).shape[0])

    # -- paged-mode helpers --------------------------------------------
    def _apply_copies(self, cows: List[Tuple[int, int]]) -> None:
        """Run the batched (src, dst) block copies on-device; lane count
        padded to a power of two (trash self-copies) to bound compiles."""
        if not cows:
            return
        n = 1 << max(0, len(cows) - 1).bit_length()
        src = np.zeros(n, np.int32)
        dst = np.zeros(n, np.int32)
        for i, (s, d) in enumerate(cows):
            src[i], dst[i] = s, d
        attn_sub = {p: self.caches.blocks[p] for p in self._attn_pos}
        new_sub = _copy_blocks(attn_sub, jnp.asarray(src), jnp.asarray(dst))
        self.caches = DecodeCaches(
            blocks={**self.caches.blocks, **new_sub}, cross=None)
        self.counters["kv_cow_copies"] += len(cows)

    def _block_tables(self) -> np.ndarray:
        """(max_slots, nb) physical block table rows (vacant rows -1)."""
        nb = max(1, self._nb_per_slot)
        out = np.full((self.ecfg.max_slots, nb), -1, np.int32)
        for i, h in enumerate(self.slots):
            if h is not None and h.lease is not None:
                out[i] = h.lease.table
        return out

    def _ensure_write(self, lease: KVLease, pos: int,
                      cows: List[Tuple[int, int]]) -> Tuple[int, int]:
        """Resolve the physical (block, offset) for a write at absolute
        position ``pos``, collecting any COW obligation."""
        s = pos % self._C_pad
        phys, cow = lease.ensure(s // self._bt)
        if cow >= 0:
            cows.append((cow, phys))
        return phys, s % self._bt

    def _write_span_blocks(self, start: int, end: int) -> List[int]:
        """Logical blocks whose ring slots the position span
        ``[start, end)`` writes (ring wrap included). O(#blocks), not
        O(#tokens): the written ring-slot span is contiguous mod C_pad."""
        if end - start >= self._C_pad:
            return list(range(self._nb_per_slot))
        s0 = start % self._C_pad
        s1 = (end - 1) % self._C_pad
        if s0 <= s1:
            return list(range(s0 // self._bt, s1 // self._bt + 1))
        return sorted(set(range(0, s1 // self._bt + 1)) |
                      set(range(s0 // self._bt, self._nb_per_slot)))

    # -- load signals (shedding / benchmark telemetry) ------------------
    def load_snapshot(self) -> Dict[str, float]:
        """The uniform load signals the shed policy keys on: queue depth,
        the decode TPOT EMA, the estimated queue wait they imply (queued
        decode tokens at the measured per-token latency, spread over the
        slots), and the shared HBM envelope's headroom fraction."""
        queued_tokens = sum(
            h.request.max_new_tokens +
            max(0, self._prompt_len(h) - h._chunk_pos)
            for h in self.queue)
        est_wait = (queued_tokens * self._tpot_ema /
                    max(1, self.ecfg.max_slots))
        frac = getattr(self.backend, "ready_frac", None)
        return {"queue_depth": float(len(self.queue)),
                "tpot_ema_s": float(self._tpot_ema),
                "est_wait_s": float(est_wait),
                "budget_headroom_frac": float(self.budget.headroom_frac()),
                "residency_ready_frac":
                    float(frac()) if frac is not None else 1.0}

    def _shed_expired(self) -> None:
        """Drop queued batch-tier work whose deadline already passed —
        serving it late burns decode steps premium traffic is waiting on.
        Only the batch tier is dropped; standard/premium deadlines are
        reported (SLO attainment) but never enforced by discard."""
        if not self.sched.cfg.drop_expired_batch or not self.queue:
            return
        now = self._now()

        def expired(h):
            d = h.request.deadline_ms
            return (h.qos == "batch" and d is not None and
                    (now - h.submit_s) * 1e3 > d)

        for h in self.queue.prune(expired):
            h.state = RequestState.SHED
            self.counters["shed_requests"] += 1
            if self.tracer is not None:
                self.tracer.instant("shed", cat="sched", rid=h.id,
                                    qos=h.qos, reason="deadline")

    # ------------------------------------------------------------------
    def _admit(self, finished: List[RequestHandle]) -> None:
        """Fill free slots from the queue with batched, length-bucketed
        masked prefills: the queue head picks the bucket, same-bucket
        requests behind it join (up to ``prefill_rows`` and the free-slot
        count), the batch right-pads to (prefill_rows, bucket), and each
        prefilled row scatters into its slot of the batched caches. Batch
        rows beyond the group are ``lengths == 0`` pads, so every prefill
        compiles at one of O(#buckets) shapes.

        In paged mode the bucket is chosen by the SUFFIX length (prompt
        minus trie-hit prefix) and admission additionally passes the KV
        quota gate: a request whose worst-case block bytes do not fit the
        shared budget waits in the queue — expert demotions or finishing
        requests free the headroom that admits it. (Stacks without
        attention positions have no KV to page and always take the dense
        path.)"""
        if self.pool is not None:
            self._admit_paged(finished)
        else:
            self._admit_dense(finished)

    def _admit_dense(self, finished: List[RequestHandle]) -> None:
        while self.queue:
            free = [i for i, h in enumerate(self.slots) if h is None]
            if not free:
                return
            head_peek = self.queue.peek()
            if head_peek is not None and head_peek._snapshot is not None:
                # Preempted request at the queue head: resume is a direct
                # cache-row upload, not a prefill.
                self.queue.popleft()
                self._resume_dense(head_peek, free[0])
                continue
            R = self._prefill_rows
            limit = min(len(free), R)
            head = self.queue.popleft()
            bucket = self._bucket_len(self._prompt_len(head))
            group = [head]
            skipped: List[RequestHandle] = []
            while self.queue and len(group) < limit:
                h = self.queue.popleft()
                if h._snapshot is None and \
                        self._bucket_len(self._prompt_len(h)) == bucket:
                    group.append(h)
                else:
                    skipped.append(h)
            self.queue.extendleft(reversed(skipped))

            G = len(group)
            lengths = np.zeros(R, np.int32)
            batch_toks = np.full((R, bucket), self.ecfg.pad_token_id,
                                 np.int32)
            for r, h in enumerate(group):
                p = np.asarray(h.request.tokens, np.int32).reshape(-1)
                lengths[r] = p.shape[0]
                batch_toks[r, :p.shape[0]] = p
            row_caches = init_caches(self.cfg, R, self.ecfg.max_len)
            t0 = time.perf_counter()
            logits, row_caches, counts = self._jit_prefill(
                self.params, {"tokens": jnp.asarray(batch_toks)},
                row_caches, self.banks, jnp.asarray(lengths),
                row_capacity=self._row_cap_prefill(bucket))
            logits.block_until_ready()
            dt = time.perf_counter() - t0
            self.prefill_shapes.add((R, bucket))
            slots_arr = np.asarray(free[:G], np.int32)
            # Scatter the prefilled rows into their slots' batch rows.
            self.caches = DecodeCaches(
                blocks=self._jit_scatter(self.caches.blocks,
                                         row_caches.blocks,
                                         jnp.asarray(slots_arr)),
                cross=None)
            self._post_prefill(group, slots_arr, lengths, counts, dt,
                               logits,
                               [int(x) for x in lengths[:G]], finished)

    def _chunk_eligible(self, handle: RequestHandle) -> bool:
        """Chunked prefill applies to prompts longer than the chunk size on
        stacks where suffix prefill can restart mid-prompt (see the chunk
        resolution in ``__init__``); sliding-window prompts only while the
        whole prompt fits the attention window (a mid-prompt ring wrap
        would change which positions a later chunk may overwrite)."""
        if not self._chunk_tokens:
            return False
        plen = self._prompt_len(handle)
        if plen <= self._chunk_tokens:
            return False
        return (self.cfg.attn.sliding_window is None or
                plen <= self._C_attn)

    def _admit_paged(self, finished: List[RequestHandle]) -> None:
        while self.queue:
            free = [i for i, h in enumerate(self.slots) if h is None]
            if not free:
                return
            head_peek = self.queue.peek()
            if head_peek is not None and head_peek._snapshot is not None:
                self.queue.popleft()
                if not self._resume_paged(head_peek, free[0]):
                    # Blocked on quota/headroom — back to the head; a
                    # finishing request or expert demotion unblocks it.
                    self.queue.appendleft(head_peek)
                    return
                continue
            if head_peek is not None and self._chunk_eligible(head_peek):
                self.queue.popleft()
                if not self._begin_chunked(head_peek, free[0]):
                    self.queue.appendleft(head_peek)
                    return
                continue
            R = self._prefill_rows
            limit = min(len(free), R)
            group: List[Tuple[RequestHandle, KVLease, int]] = []
            skipped: List[RequestHandle] = []
            bucket = None
            while self.queue and len(group) < limit:
                h = self.queue.popleft()
                if h._snapshot is not None or self._chunk_eligible(h):
                    # Resumes and chunked admissions only happen from the
                    # head position — requeue and let a later iteration
                    # (or step) take them.
                    skipped.append(h)
                    continue
                plen = self._prompt_len(h)
                toks = np.asarray(h.request.tokens, np.int32).reshape(-1)
                hits: List[int] = []
                if self.trie is not None:
                    max_hit = min((plen - 1) // self._bt, self._nb_per_slot)
                    hits = self.trie.match(toks, max_blocks=max_hit)
                    # Pin the hits NOW: the quota reservation below may
                    # reclaim trie-exclusive blocks under byte pressure,
                    # and a bare match() holds no reference.
                    for blk in hits:
                        self.pool.retain(blk)
                start = len(hits) * self._bt
                b = self._bucket_len(plen - start)
                if bucket is None:
                    bucket = b
                elif b != bucket:
                    for blk in hits:
                        self.pool.release(blk)
                    skipped.append(h)
                    continue
                # Physical headroom: live lease footprints are bounded by
                # nb_per_slot each (release-before-alloc keeps COW from
                # pinning extras), so admission defers when an UNDERSIZED
                # pool (explicit kv_blocks) cannot physically host one more
                # sequence alongside the running ones — instead of crashing
                # a mid-stream alloc. Default sizing never defers here.
                running = sum(s is not None for s in self.slots) + len(group)
                if (running + 1) * self._nb_per_slot > self.pool.n_blocks - 1:
                    for blk in hits:
                        self.pool.release(blk)
                    skipped.append(h)
                    if not group:
                        break       # wait for a running request to finish
                    continue
                quota = self._quota_blocks(plen, start,
                                           h.request.max_new_tokens)
                if not self.pool.try_reserve_quota(quota):
                    # Shared-envelope backpressure: the request waits for
                    # expert demotions / finishing requests to free bytes.
                    for blk in hits:
                        self.pool.release(blk)
                    skipped.append(h)
                    if not group:
                        break       # head blocked — retry next step
                    continue
                lease = KVLease(self.pool, self._nb_per_slot, quota)
                if hits:
                    lease.adopt_prefix(hits, retained=True)
                    h.prefix_hit_tokens = start
                group.append((h, lease, start))
            self.queue.extendleft(reversed(skipped))
            if not group:
                return
            G = len(group)
            nb = max(1, self._nb_per_slot)
            lengths = np.zeros(R, np.int32)       # TOTAL prompt lengths
            starts = np.zeros(R, np.int32)
            tables = np.full((R, nb), -1, np.int32)
            batch_toks = np.full((R, bucket), self.ecfg.pad_token_id,
                                 np.int32)
            cows: List[Tuple[int, int]] = []
            for r, (h, lease, start) in enumerate(group):
                toks = np.asarray(h.request.tokens, np.int32).reshape(-1)
                plen = toks.shape[0]
                lengths[r], starts[r] = plen, start
                batch_toks[r, :plen - start] = toks[start:]
                # Resolve every block the suffix will write (ring wrap
                # included): fresh allocation or COW of shared blocks.
                for j in self._write_span_blocks(start, plen):
                    phys, cow = lease.ensure(j)
                    if cow >= 0:
                        cows.append((cow, phys))
                tables[r] = lease.table
            self._apply_copies(cows)
            has_prefix = bool((starts > 0).any())
            mamba_rows = init_caches(self.cfg, R, self.ecfg.max_len,
                                     positions=self._mamba_pos).blocks \
                if self._mamba_pos else {}
            call_caches = DecodeCaches(blocks={
                **{p: self.caches.blocks[p] for p in self._attn_pos},
                **mamba_rows}, cross=None)
            t0 = time.perf_counter()
            logits, new_caches, counts = self._jit_prefill_paged(
                self.params, {"tokens": jnp.asarray(batch_toks)},
                call_caches, self.banks, jnp.asarray(tables),
                jnp.asarray(starts), jnp.asarray(lengths),
                has_prefix=has_prefix,
                row_capacity=self._row_cap_prefill(bucket))
            logits.block_until_ready()
            dt = time.perf_counter() - t0
            self.prefill_shapes.add((R, bucket))
            slots_arr = np.asarray(free[:G], np.int32)
            blocks = {p: new_caches.blocks[p] for p in self._attn_pos}
            if self._mamba_pos:
                mamba_new = self._jit_scatter(
                    {p: self.caches.blocks[p] for p in self._mamba_pos},
                    {p: new_caches.blocks[p] for p in self._mamba_pos},
                    jnp.asarray(slots_arr))
                blocks.update(mamba_new)
            self.caches = DecodeCaches(blocks=blocks, cross=None)
            # Register newly computed prompt chunks for future sharing (only
            # prompts that fit the logical cache wholly — ring overwrites
            # would otherwise leave stale chunks in the trie).
            for (h, lease, start) in group:
                plen = self._prompt_len(h)
                if self.trie is not None and plen <= self._C_attn:
                    toks = np.asarray(h.request.tokens,
                                      np.int32).reshape(-1)
                    chain = [int(lease.table[j])
                             for j in range(plen // self._bt)]
                    self.trie.insert(toks, chain)
            for (h, lease, _) in group:
                h.lease = lease
            self._post_prefill([h for h, _, _ in group], slots_arr, lengths,
                               counts, dt, logits,
                               [int(lengths[r] - starts[r])
                                for r in range(G)], finished)

    def _post_prefill(self, group: List[RequestHandle],
                      slots_arr: np.ndarray, lengths: np.ndarray, counts,
                      dt: float, logits,
                      computed: List[int],
                      finished: List[RequestHandle]) -> None:
        """Shared post-prefill bookkeeping: counts → backend, TTFT, slot
        assignment, telemetry. ``logits`` ((R, V) f32, device) are the
        last-token logits each row's sampler draws its FIRST token from
        (emission index 0); an all-greedy group ships only the device
        argmax to host. ``computed[r]`` is the number of prompt tokens this
        prefill actually computed for row r (suffix length in paged mode —
        the prefix-share saving shows up here)."""
        R = self._prefill_rows
        G = len(group)
        amax = np.asarray(jnp.argmax(logits, -1), np.int32)
        samp = self._gather_sampling_rows(
            logits, [r for r, h in enumerate(group)
                     if not h.sampler.greedy])
        counts_np = {k: np.asarray(v) for k, v in counts.items()}
        self.last_row_counts = counts_np
        self.last_counts = {k: v.sum(axis=1) if v.ndim == 3 else v
                            for k, v in counts_np.items()}
        row_valid = np.zeros(R, bool)
        row_valid[:G] = True
        stall = self.backend.observe(counts_np, dt, prefill=True,
                                     row_valid=row_valid)
        self._stall_clock += stall
        self._note_degraded(counts_np, list(enumerate(group)))
        for r, handle in enumerate(group):
            slot = int(slots_arr[r])
            handle.stall_exposure_s += stall
            if self.tracer is not None:
                self.tracer.instant("admit", cat="sched", rid=handle.id,
                                    slot=slot, qos=handle.exec_qos)
            tok = int(amax[r]) if r not in samp else \
                handle.sampler.next_token(samp[r], 0)
            handle.tokens.append(tok)
            # Serving TTFT: submit → first token. The engine clock covers
            # queue wait and the prefills admitted ahead of it (virtual
            # under replay(realtime=False) — same accounting, deterministic
            # timestamps); the stall-clock delta charges every MODELED
            # stall since submit (predecessors' demand misses and this
            # forward's own) that wall time never slept. The backend's own
            # ttft_s tracks per-prefill latency.
            handle.first_token_s = self._now()
            handle.ttft_s = (handle.first_token_s - handle.submit_s +
                             self._stall_clock - handle.stall_at_submit)
            self.ttfts.append(handle.ttft_s)
            handle.state = RequestState.RUNNING
            handle.last_progress_s = handle.first_token_s
            handle.slot = slot
            # Per-request attribution needs row-resolved counts; under
            # shard_map expert parallelism only aggregates exist.
            handle.expert_counts = {
                k: v[:, r].astype(np.int64)
                for k, v in counts_np.items() if v.ndim == 3}
            self.slots[slot] = handle
            self.pos[slot] = int(lengths[r])
            self.tokens[slot] = tok
            self.counters["admitted"] += 1
            self.counters["prefill_tokens"] += computed[r]
            self.counters["prefix_hit_tokens"] += handle.prefix_hit_tokens
            if self._done(handle):
                self._finish(handle, finished)
        self.counters["prefills"] += 1

    @staticmethod
    def _gather_sampling_rows(logits, rows: List[int]) -> Dict[int,
                                                               np.ndarray]:
        """Ship the (·, V) f32 logits of only the given batch rows to host
        (device-side gather first): row index → (V,) np array."""
        if not rows:
            return {}
        sub = np.asarray(logits[jnp.asarray(rows, jnp.int32)])
        return {i: sub[j] for j, i in enumerate(rows)}

    def _done(self, handle: RequestHandle) -> bool:
        req = handle.request
        if req.eos_token_id is not None:
            # A speculative verify step can accept a burst with EOS in the
            # MIDDLE: scan every not-yet-checked token (not just the tail)
            # and truncate the output at the first occurrence.
            toks = handle.tokens
            for t in range(handle._eos_scanned, len(toks)):
                if toks[t] == req.eos_token_id:
                    del toks[t + 1:]
                    handle._eos_scanned = len(toks)
                    return True
            handle._eos_scanned = len(toks)
        if len(handle.tokens) >= req.max_new_tokens:
            return True
        # Out of sequence budget: the slot's cache row is full.
        return int(self.pos[handle.slot]) >= self.ecfg.max_len

    def _finish(self, handle: RequestHandle,
                finished: List[RequestHandle]) -> None:
        handle.state = RequestState.FINISHED
        handle.finish_s = self._now()
        self.slots[handle.slot] = None
        if handle.lease is not None:
            # Release block refs + unspent quota; trie-registered blocks
            # keep the trie's own reference and stay warm for future hits.
            handle.lease.close()
        # The vacated row keeps replaying its last token through the batched
        # decode (shape stability), but row_valid masks it out of MoE
        # dispatch and every router count — vacancy is invisible to hotness
        # and residency accounting.
        self.counters["finished"] += 1
        if self.tracer is not None:
            self.tracer.instant("finish", cat="sched", rid=handle.id,
                                tokens=len(handle.tokens))
        finished.append(handle)

    # ------------------------------------------------------------------
    # Preemption: evict-and-resume under budget pressure. Preempting a
    # request snapshots its sequence state HOST-side and genuinely frees
    # its HBM (the KVLease closes, blocks and quota return to the shared
    # envelope); resume re-admits through the normal admission path,
    # adopting prefix-trie hits where the preempted blocks survived and
    # re-uploading only the lanes that did not. Bit-exactness needs no
    # recompute anywhere: the cache-position invariant (cached tokens =
    # seq[:pos], next input = tokens[-1]) plus counter-keyed per-request
    # sampling make the resumed continuation identical to an
    # uninterrupted run.
    # ------------------------------------------------------------------
    def preempt(self, handle: RequestHandle) -> None:
        """Evict a RUNNING request and re-queue it at the front of its QoS
        tier (original queue age preserved — it keeps climbing)."""
        if handle.state is not RequestState.RUNNING:
            raise ValueError(
                f"preempt of a {handle.state.value} request (only RUNNING "
                f"requests hold evictable slot state)")
        slot = handle.slot
        pos = int(self.pos[slot])
        span_start = max(0, pos - self._C_attn) if self._attn_pos else 0
        snap = SlotSnapshot(pos=pos, span_start=span_start)
        if self._attn_pos:
            if self.pool is not None:
                # Valid lanes only: [pos - C_attn, pos) covers everything
                # attention can still read; ring slots in that span are
                # distinct (span <= C_attn <= C_pad), so each position maps
                # to exactly one (block, offset) lane. Lane count pads to a
                # power of two (trash lanes) to bound gather compiles.
                span = np.arange(span_start, pos, dtype=np.int64)
                s = span % self._C_pad
                blk = np.asarray(
                    [int(handle.lease.table[int(x) // self._bt])
                     for x in s], np.int32)
                off = (s % self._bt).astype(np.int32)
                P = 1 << max(0, int(span.size) - 1).bit_length()
                blk_p = np.zeros(P, np.int32)
                off_p = np.zeros(P, np.int32)
                blk_p[:span.size], off_p[:span.size] = blk, off
                attn_now = {p: self.caches.blocks[p]
                            for p in self._attn_pos}
                lanes = _gather_paged_lanes(attn_now,
                                            jnp.asarray(blk_p[None]),
                                            jnp.asarray(off_p[None]))
                snap.attn_lanes = jax.tree_util.tree_map(
                    lambda v: np.asarray(v)[:, :span.size], lanes)
            else:
                snap.attn_rows = jax.tree_util.tree_map(
                    lambda a: np.asarray(a[:, slot]),
                    {p: self.caches.blocks[p] for p in self._attn_pos})
        if self._mamba_pos:
            snap.mamba_rows = jax.tree_util.tree_map(
                lambda a: np.asarray(a[:, slot]),
                {p: self.caches.blocks[p] for p in self._mamba_pos})
        if handle.lease is not None:
            # Register the full prompt+generated chunks before closing the
            # lease: the trie keeps those blocks warm (its own reference),
            # so an early resume adopts them and skips the host re-upload
            # entirely. Correctness never depends on trie survival — the
            # host snapshot covers every lane.
            if self.trie is not None and pos <= self._C_attn:
                seq = np.concatenate([
                    np.asarray(handle.request.tokens,
                               np.int32).reshape(-1),
                    np.asarray(handle.tokens, np.int32)])
                chain = [int(handle.lease.table[j])
                         for j in range(pos // self._bt)]
                if chain:
                    self.trie.insert(seq[:pos], chain)
            handle.lease.close()
            handle.lease = None
        self.slots[slot] = None
        self.tokens[slot] = self.ecfg.pad_token_id
        handle.slot = None
        handle.state = RequestState.QUEUED
        handle._snapshot = snap
        handle.preempts += 1
        self.counters["preemptions"] += 1
        if self.tracer is not None:
            self.tracer.instant("preempt", cat="sched", rid=handle.id,
                                slot=slot, pos=pos)
        self.queue.appendleft(handle)

    def _maybe_preempt(self) -> None:
        """After admission: if a higher-class request is still queued while
        strictly lower-class work runs, evict one victim (lowest class
        first, most remaining work first) so the head admits next step.
        Eviction counts are capped per request — aged batch work cannot be
        preempted forever."""
        if not self.sched.cfg.preemption or not self.queue:
            return
        head = self.queue.peek()
        if head is None:
            return
        running = [(i, h) for i, h in enumerate(self.slots)
                   if h is not None and h.state is RequestState.RUNNING]
        victim = self.sched.pick_victim(running, head.qos)
        if victim is not None:
            self.preempt(victim[1])

    def _finish_resume(self, handle: RequestHandle, slot: int,
                       snap: SlotSnapshot) -> None:
        self.slots[slot] = handle
        handle.slot = slot
        handle.state = RequestState.RUNNING
        handle._snapshot = None
        self.pos[slot] = snap.pos
        self.tokens[slot] = handle.tokens[-1]
        self.counters["resumes"] += 1
        if self.tracer is not None:
            self.tracer.instant("resume", cat="sched", rid=handle.id,
                                slot=slot, pos=snap.pos)

    def _scatter_snapshot_rows(self, rows: Dict[str, np.ndarray],
                               slot: int) -> None:
        """Upload whole per-slot cache rows (dense attention / mamba state)
        from a host snapshot into ``slot``'s batch row."""
        sub_old = {p: self.caches.blocks[p] for p in rows}
        sub_new = self._jit_scatter(
            sub_old,
            jax.tree_util.tree_map(lambda a: jnp.asarray(a)[:, None], rows),
            jnp.asarray(np.asarray([slot], np.int32)))
        self.caches = DecodeCaches(
            blocks={**self.caches.blocks, **sub_new}, cross=None)

    def _resume_dense(self, handle: RequestHandle, slot: int) -> None:
        """Dense-mode resume: scatter the snapshot rows back (any free
        slot — row contents are position-indexed, not slot-bound). Cannot
        fail: dense rows are preallocated, there is no quota."""
        snap = handle._snapshot
        rows: Dict[str, np.ndarray] = {}
        if snap.attn_rows:
            rows.update(snap.attn_rows)
        if snap.mamba_rows:
            rows.update(snap.mamba_rows)
        if rows:
            self._scatter_snapshot_rows(rows, slot)
        self._finish_resume(handle, slot, snap)

    def _resume_paged(self, handle: RequestHandle, slot: int) -> bool:
        """Paged-mode resume: the same admission discipline as a fresh
        request (trie match → pin, physical headroom, quota gate), then
        scatter the host lanes the trie could not cover. False = blocked
        (quota/headroom) — the caller requeues the handle at the head."""
        snap = handle._snapshot
        pos = snap.pos
        hits: List[int] = []
        if self.trie is not None and pos <= self._C_attn:
            seq = np.concatenate([
                np.asarray(handle.request.tokens, np.int32).reshape(-1),
                np.asarray(handle.tokens, np.int32)])
            max_hit = min(pos // self._bt, self._nb_per_slot)
            hits = self.trie.match(seq[:pos], max_blocks=max_hit)
            for blk in hits:
                self.pool.retain(blk)
        start = len(hits) * self._bt
        running = sum(s is not None for s in self.slots)
        if (running + 1) * self._nb_per_slot > self.pool.n_blocks - 1:
            for blk in hits:
                self.pool.release(blk)
            return False
        remaining = handle.request.max_new_tokens - len(handle.tokens)
        quota = self._quota_blocks(pos, start, remaining)
        if not self.pool.try_reserve_quota(quota):
            for blk in hits:
                self.pool.release(blk)
            return False
        lease = KVLease(self.pool, self._nb_per_slot, quota)
        if hits:
            lease.adopt_prefix(hits, retained=True)
        lo = max(start, snap.span_start)
        span = np.arange(lo, pos, dtype=np.int64)
        if span.size:
            cows: List[Tuple[int, int]] = []
            s = span % self._C_pad
            for j in sorted({int(x) // self._bt for x in s}):
                phys, cow = lease.ensure(j)
                if cow >= 0:
                    cows.append((cow, phys))
            self._apply_copies(cows)
            blk = np.asarray([int(lease.table[int(x) // self._bt])
                              for x in s], np.int32)
            off = (s % self._bt).astype(np.int32)
            sel = (span - snap.span_start).astype(np.int64)
            P = 1 << max(0, int(span.size) - 1).bit_length()
            mask = np.zeros((1, P), bool)
            mask[0, :span.size] = True
            blk_p = np.zeros(P, np.int32)
            off_p = np.zeros(P, np.int32)
            blk_p[:span.size], off_p[:span.size] = blk, off
            def _lane(v):
                lane = v[:, sel]
                pad = np.zeros((1, P - span.size) + lane.shape[2:],
                               lane.dtype)
                return jnp.asarray(np.concatenate([lane, pad], axis=1))

            lanes = jax.tree_util.tree_map(_lane, snap.attn_lanes)
            attn_sub = {p: self.caches.blocks[p] for p in self._attn_pos}
            attn_sub = _restore_paged_lanes(attn_sub, lanes,
                                            jnp.asarray(blk_p[None]),
                                            jnp.asarray(off_p[None]),
                                            jnp.asarray(mask))
            self.caches = DecodeCaches(
                blocks={**self.caches.blocks, **attn_sub}, cross=None)
        if self._mamba_pos and snap.mamba_rows:
            self._scatter_snapshot_rows(snap.mamba_rows, slot)
        handle.lease = lease
        self._finish_resume(handle, slot, snap)
        return True

    # ------------------------------------------------------------------
    # Chunked prefill: long prompts admit immediately (slot + lease +
    # full quota) but prefill one chunk per engine step, interleaved with
    # everyone else's decode — a single long admission stops inflating
    # neighbors' TPOT by the whole prompt's prefill latency. Each chunk is
    # a suffix prefill through the PR-3 paged path (cached prefix ⊕
    # suffix), at an existing ladder-bucket shape.
    # ------------------------------------------------------------------
    def _begin_chunked(self, handle: RequestHandle, slot: int) -> bool:
        """Admit a long prompt for chunked prefill: trie match + quota
        gate exactly like normal admission, but no forward yet — the
        handle enters PREFILLING and ``_advance_chunk_prefills`` feeds it
        chunk by chunk. False = blocked on quota/headroom."""
        toks = np.asarray(handle.request.tokens, np.int32).reshape(-1)
        plen = toks.shape[0]
        hits: List[int] = []
        if self.trie is not None:
            max_hit = min((plen - 1) // self._bt, self._nb_per_slot)
            hits = self.trie.match(toks, max_blocks=max_hit)
            for blk in hits:
                self.pool.retain(blk)
        start = len(hits) * self._bt
        running = sum(s is not None for s in self.slots)
        if (running + 1) * self._nb_per_slot > self.pool.n_blocks - 1:
            for blk in hits:
                self.pool.release(blk)
            return False
        quota = self._quota_blocks(plen, start,
                                   handle.request.max_new_tokens)
        if not self.pool.try_reserve_quota(quota):
            for blk in hits:
                self.pool.release(blk)
            return False
        lease = KVLease(self.pool, self._nb_per_slot, quota)
        if hits:
            lease.adopt_prefix(hits, retained=True)
            handle.prefix_hit_tokens = start
        handle.lease = lease
        handle._chunk_pos = start
        handle.state = RequestState.PREFILLING
        handle.slot = slot
        self.slots[slot] = handle
        self.pos[slot] = 0
        self.tokens[slot] = self.ecfg.pad_token_id
        self.counters["admitted"] += 1
        self.counters["prefix_hit_tokens"] += start
        return True

    def _advance_chunk_prefills(self, finished: List[RequestHandle]) -> None:
        """Advance chunked prefills by ONE chunk this step (one batched
        suffix-prefill forward over same-bucket chunk rows — per-step cost
        stays bounded by one prefill dispatch). The final chunk emits the
        request's first token and flips it to RUNNING, so it decodes with
        everyone else from this very step."""
        chunking = [(i, h) for i, h in enumerate(self.slots)
                    if h is not None and
                    h.state is RequestState.PREFILLING]
        if not chunking:
            return

        def next_chunk(h: RequestHandle) -> int:
            return min(self._chunk_tokens,
                       self._prompt_len(h) - h._chunk_pos)

        R = self._prefill_rows
        bucket = self._bucket_len(next_chunk(chunking[0][1]))
        group = [(i, h) for i, h in chunking
                 if self._bucket_len(next_chunk(h)) == bucket][:R]
        G = len(group)
        nb = max(1, self._nb_per_slot)
        lengths = np.zeros(R, np.int32)      # prefix + chunk (total so far)
        starts = np.zeros(R, np.int32)
        tables = np.full((R, nb), -1, np.int32)
        batch_toks = np.full((R, bucket), self.ecfg.pad_token_id, np.int32)
        cows: List[Tuple[int, int]] = []
        for r, (i, h) in enumerate(group):
            toks = np.asarray(h.request.tokens, np.int32).reshape(-1)
            cpos = h._chunk_pos
            clen = next_chunk(h)
            starts[r], lengths[r] = cpos, cpos + clen
            batch_toks[r, :clen] = toks[cpos:cpos + clen]
            for j in self._write_span_blocks(cpos, cpos + clen):
                phys, cow = h.lease.ensure(j)
                if cow >= 0:
                    cows.append((cow, phys))
            tables[r] = h.lease.table
        self._apply_copies(cows)
        call_caches = DecodeCaches(
            blocks={p: self.caches.blocks[p] for p in self._attn_pos},
            cross=None)
        t0 = time.perf_counter()
        logits, new_caches, counts = self._jit_prefill_paged(
            self.params, {"tokens": jnp.asarray(batch_toks)},
            call_caches, self.banks, jnp.asarray(tables),
            jnp.asarray(starts), jnp.asarray(lengths),
            has_prefix=True, row_capacity=self._row_cap_prefill(bucket))
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.prefill_shapes.add((R, bucket))
        self.caches = DecodeCaches(
            blocks={**self.caches.blocks,
                    **{p: new_caches.blocks[p] for p in self._attn_pos}},
            cross=None)
        counts_np = {k: np.asarray(v) for k, v in counts.items()}
        self.last_row_counts = counts_np
        self.last_counts = {k: v.sum(axis=1) if v.ndim == 3 else v
                            for k, v in counts_np.items()}
        row_valid = np.zeros(R, bool)
        row_valid[:G] = True
        stall = self.backend.observe(counts_np, dt, prefill=True,
                                     row_valid=row_valid)
        self._stall_clock += stall
        for _, h in group:
            h.stall_exposure_s += stall
        self._note_degraded(counts_np, [(r, h) for r, (i, h)
                                        in enumerate(group)])
        amax = np.asarray(jnp.argmax(logits, -1), np.int32)
        samp = self._gather_sampling_rows(
            logits, [r for r, (i, h) in enumerate(group)
                     if not h.sampler.greedy and
                     int(lengths[r]) == self._prompt_len(h)])
        for r, (i, h) in enumerate(group):
            clen = int(lengths[r] - starts[r])
            h._chunk_pos = int(lengths[r])
            self.counters["prefill_tokens"] += clen
            sub = {k: v[:, r].astype(np.int64)
                   for k, v in counts_np.items() if v.ndim == 3}
            if h.expert_counts is None:
                h.expert_counts = sub
            else:
                for k, v in sub.items():
                    if k in h.expert_counts:
                        h.expert_counts[k] += v
            plen = self._prompt_len(h)
            if h._chunk_pos < plen:
                continue                     # more chunks to go
            # Final chunk: register the whole prompt for sharing, emit the
            # first token, flip to RUNNING.
            if self.trie is not None and plen <= self._C_attn:
                toks = np.asarray(h.request.tokens, np.int32).reshape(-1)
                chain = [int(h.lease.table[j])
                         for j in range(plen // self._bt)]
                self.trie.insert(toks, chain)
            tok = int(amax[r]) if r not in samp else \
                h.sampler.next_token(samp[r], 0)
            h.tokens.append(tok)
            h.first_token_s = self._now()
            h.ttft_s = (h.first_token_s - h.submit_s +
                        self._stall_clock - h.stall_at_submit)
            self.ttfts.append(h.ttft_s)
            h.state = RequestState.RUNNING
            h.last_progress_s = h.first_token_s
            self.pos[i] = plen
            self.tokens[i] = tok
            if self._done(h):
                self._finish(h, finished)
        self.counters["prefills"] += 1
        self.counters["chunk_prefills"] += 1

    # ------------------------------------------------------------------
    def step(self) -> List[RequestHandle]:
        """One engine step: drop expired batch work, admit queued requests
        into free slots (resumes and chunked admissions included), preempt
        for a blocked higher class, advance chunked prefills by one chunk,
        then advance every running request grouped by execution tier —
        premium/standard on the mixed-precision banks (with speculative
        bursts when enabled), batch tier on the all-lo banks. One group —
        uniform-class traffic — is exactly the untiered engine. Returns
        the handles that finished this step."""
        finished: List[RequestHandle] = []
        if self._watchdog is not None:
            # Scan BEFORE this step makes progress: a request that wedged
            # during prior steps still carries its stale stamp here, and a
            # cancelled promotion's slot is re-admittable this same step.
            self._watchdog.scan(self)
        ready = getattr(self.backend, "serving_ready", None)
        if ready is not None and not ready():
            # Streaming cold start: the residency ladder is still
            # materializing — keep the backend's staging windows running
            # and hold admission (requests queue; no forward may observe
            # a partially materialized expert).
            self.backend.tick()
            return finished
        self._shed_expired()
        self._admit(finished)
        self._maybe_preempt()
        self._advance_chunk_prefills(finished)
        active = [(i, h) for i, h in enumerate(self.slots)
                  if h is not None and h.state is RequestState.RUNNING]
        if active:
            groups = self.sched.decode_groups(active,
                                              self._spec is not None)
            guard = len(groups) > 1 and bool(self._mamba_pos)
            for kind, rows in groups:
                # The speculative round falls back to the single-token
                # step when no row has draft headroom (e.g. one token
                # remaining).
                if kind == "spec" and self._spec.round(rows, finished):
                    continue
                self._decode_one(rows, finished, lo=(kind == "lo"),
                                 guard_ssm=guard)
        self.backend.tick()
        if self.obs is not None:
            self._step_obs()
        return finished

    def _step_obs(self) -> None:
        """Step-boundary observability: one ``step`` trace instant with the
        per-step gauges, plus the metrics sampling cadence. Every value is
        count-derived or modeled (never a wall-clock duration), so
        virtual-clock replays trace byte-identically."""
        a0, p0, l0 = self._obs_prev
        self._obs_prev = (self._disp_active_sum, self._disp_pad_sum,
                          self._disp_layers)
        d_lay = self._disp_layers - l0
        active = (self._disp_active_sum - a0) / d_lay if d_lay else 0.0
        pad = (self._disp_pad_sum - p0) / d_lay if d_lay else 0.0
        mix_fn = getattr(self.backend, "residency_mix", None)
        mix = mix_fn() if mix_fn is not None else {"hi": 0, "lo": 0,
                                                   "host": 0}
        headroom = float(self.budget.headroom_frac())
        depths = self.queue.depths()
        running = sum(h is not None for h in self.slots)
        step = self.counters["steps"]
        if self.tracer is not None:
            self.tracer.instant(
                "step", cat="engine", step=step,
                active_experts=round(active, 4), pad_ratio=round(pad, 4),
                hi=mix["hi"], lo=mix["lo"], host=mix["host"],
                headroom=round(headroom, 6), queued=len(self.queue),
                running=running)
        if self.metrics is not None and step % self._sample_every == 0:
            m = self.metrics
            m.gauge("engine_active_experts",
                    "mean experts with routed tokens per layer-step").set(
                        active)
            m.gauge("engine_dispatch_pad_ratio",
                    "padding fraction of the MoE dispatch layout").set(pad)
            m.gauge("residency_hi_cells").set(mix["hi"])
            m.gauge("residency_lo_cells").set(mix["lo"])
            m.gauge("residency_host_cells").set(mix["host"])
            m.gauge("budget_headroom_frac",
                    "shared HBM envelope headroom").set(headroom)
            for q, d in depths.items():
                m.gauge(f"queue_depth_{q}").set(d)
            if self._spec is not None:
                m.gauge("spec_accept_rate").set(
                    self._spec.accepted_total /
                    max(1, self._spec.draft_total))
            m.sample(step=step, active_experts=round(active, 4),
                     pad_ratio=round(pad, 4), hi=mix["hi"], lo=mix["lo"],
                     host=mix["host"], headroom=round(headroom, 6),
                     **{f"queued_{q}": d for q, d in depths.items()})

    def _decode_one(self, active, finished: List[RequestHandle],
                    lo: bool = False, guard_ssm: bool = False) -> None:
        """Advance the given active rows by exactly one sampled token.
        ``lo=True`` dispatches on the all-lo expert banks (batch tier):
        the same buffers with every hi slot disowned — same pytree, so the
        already-compiled decode executables serve both tiers. Rows of
        other groups ride along masked out of dispatch and counts;
        ``guard_ssm`` protects their recurrent state (see _merge_rows)."""
        row_valid = np.zeros(self.ecfg.max_slots, bool)
        for i, _ in active:
            row_valid[i] = True
        banks = all_lo_banks(self.banks, self._lo_owner_cache) if lo \
            else self.banks
        # The decode dispatch advances recurrent (SSM/conv) state for every
        # row, valid or not — copy the pre-step leaves so rows belonging to
        # *other* tier groups can be merged back afterwards. (Copy, not
        # alias: the decode jits donate the cache argument.)
        ssm_old = {p: jnp.array(self.caches.blocks[p])
                   for p in self._mamba_pos} if guard_ssm else None
        t0 = time.perf_counter()
        if self.pool is not None:
            n = self.ecfg.max_slots
            wblk = np.zeros(n, np.int32)     # vacant rows → trash block
            woff = np.zeros(n, np.int32)
            cows: List[Tuple[int, int]] = []
            for i, h in active:
                wblk[i], woff[i] = self._ensure_write(
                    h.lease, int(self.pos[i]), cows)
            self._apply_copies(cows)
            logits, self.caches, counts = self._jit_decode_paged(
                self.params, jnp.asarray(self.tokens),
                jnp.asarray(self.pos), self.caches, banks,
                jnp.asarray(row_valid),
                jnp.asarray(self._block_tables()),
                jnp.asarray(wblk), jnp.asarray(woff))
        else:
            logits, self.caches, counts = self._jit_decode(
                self.params, jnp.asarray(self.tokens),
                jnp.asarray(self.pos), self.caches, banks,
                jnp.asarray(row_valid))
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        if ssm_old is not None:
            sub_new = {p: self.caches.blocks[p] for p in self._mamba_pos}
            merged = _merge_rows(sub_new, ssm_old, jnp.asarray(row_valid))
            self.caches = DecodeCaches(
                blocks={**self.caches.blocks, **merged}, cross=None)
        counts_np = {k: np.asarray(v) for k, v in counts.items()}
        self.last_row_counts = counts_np
        self.last_counts = {k: v.sum(axis=1) if v.ndim == 3 else v
                            for k, v in counts_np.items()}
        self._note_dispatch(counts_np)
        stall = self.backend.observe(counts_np, dt, prefill=False,
                                     row_valid=row_valid)
        self._stall_clock += stall
        if stall:
            for _, h in active:
                h.stall_exposure_s += stall
        latency = dt + stall
        self.decode_times.append(latency)
        self._tpot_sum += latency * len(active)
        self._tpot_tokens += len(active)
        self._tpot_ema = latency if self._tpot_ema == 0.0 else \
            0.9 * self._tpot_ema + 0.1 * latency
        # Greedy fast path: only the (B,) device argmax crosses to host;
        # full (·, V) logits rows ship only for requests that sample
        # (device-gathered, so greedy neighbors stay off the transfer).
        amax = np.asarray(jnp.argmax(logits, -1), np.int32)
        samp = self._gather_sampling_rows(
            logits, [i for i, h in active if not h.sampler.greedy])
        self._note_degraded(counts_np, active)
        for i, handle in active:
            tok = int(amax[i]) if i not in samp else \
                handle.sampler.next_token(samp[i], len(handle.tokens))
            handle.tokens.append(tok)
            handle.step_times.append(latency)
            if self._watchdog is not None:
                handle.last_progress_s = self._now()
            for k, v in counts_np.items():
                if v.ndim == 3 and k in handle.expert_counts:
                    handle.expert_counts[k] += v[:, i]
            self.tokens[i] = tok
            self.pos[i] += 1
            if self._done(handle):
                self._finish(handle, finished)
        self.counters["steps"] += 1

    def _note_degraded(self, counts_np: Dict[str, np.ndarray],
                       rows) -> None:
        """Flag requests whose forward routed through a quarantined expert
        cell (host-served after repeated staging failures): they complete,
        but at degraded quality — the chaos-parity contract excludes them.
        ``rows``: (row index into the counts' row dim, handle) pairs."""
        if self._degraded_fn is None:
            return
        cells = self._degraded_fn()
        if not cells:
            return
        for pos, q in cells.items():
            v = counts_np.get(pos)
            if v is None or v.ndim != 3:       # (nsb, R, E)
                continue
            hit = ((v > 0) & q[:, None, :]).any(axis=(0, 2))
            for r, h in rows:
                if hit[r]:
                    h.degraded = True

    def drain(self) -> List[RequestHandle]:
        """Run ``step()`` until no request is queued or running; returns the
        handles finished during the drain, in completion order.

        A queued request blocked on the shared HBM envelope normally waits
        for in-flight work (finishing requests, expert demotions) to free
        bytes. If the engine goes fully idle and hundreds of consecutive
        steps (each of which ticks the backend, so pending transitions and
        demotions do get their chance) admit nothing, no future step can
        change anything — raise instead of busy-spinning forever."""
        done: List[RequestHandle] = []
        stalled = 0
        while self.queue or any(h is not None for h in self.slots):
            before = len(self.queue)
            done.extend(self.step())
            stalled = self._check_admission_stall(stalled, before)
        return done

    def _check_admission_stall(self, stalled: int, queue_before: int) -> int:
        """Post-step progress accounting for the serving loops: bump (and
        eventually trip) the stall counter when the engine sits fully idle
        with queued work it could not admit."""
        ready = getattr(self.backend, "serving_ready", None)
        if ready is not None and not ready():
            return 0    # cold start still staging — queueing is progress
        idle = not any(h is not None for h in self.slots)
        if self.queue and idle and len(self.queue) == queue_before:
            stalled += 1
            if stalled > 256:
                raise EngineStallError(self._stall_snapshot())
            return stalled
        return 0

    def _stall_snapshot(self) -> Dict[str, object]:
        """Diagnostic state for ``EngineStallError``: everything an
        operator needs to tell a budget wedge from a stuck transfer from
        a cold start that never finished."""
        now = self._now()
        frac = getattr(self.backend, "ready_frac", None)
        pend_fn = getattr(self.backend, "pending_promotions", None)
        pending = []
        if pend_fn is not None:
            pending = [{"pos": str(pos), "layer": int(l), "expert": int(e),
                        "age_s": float(age)}
                       for pos, l, e, age in pend_fn(now)]
        return {
            "queued_total": len(self.queue),
            "queue_depths": self.queue.depths(),
            "running": sum(1 for h in self.slots if h is not None),
            "budget_used": int(self.budget.used),
            "budget_cap": int(self.budget.cap),
            "budget_headroom_frac": float(self.budget.headroom_frac()),
            "residency_ready_frac":
                float(frac()) if frac is not None else 1.0,
            "pending_promotions": pending,
            "counters": dict(self.counters),
        }

    def replay(self, stream, realtime: bool = True,
               virtual_step_s: float = 2e-3) -> List[RequestHandle]:
        """Serve an arrival-timed request stream (e.g. ``RequestStream``).

        ``realtime=True`` (benchmarks): each request is submitted once the
        wall clock — measured from replay start — passes its ``arrival_s``
        offset, so queueing delay and TTFT reflect the offered load. When
        the engine goes idle before the next arrival it skips ahead instead
        of spinning.

        ``realtime=False`` (CI / tests): a **virtual clock** replaces
        ``perf_counter`` — it advances ``virtual_step_s`` per engine step
        and fast-forwards across idle gaps — so the interleaving of
        arrivals with admissions (and therefore every generated token) is
        fully deterministic, machine speed be damned.

        Returns handles in arrival order; all are FINISHED on return."""
        requests = list(stream)
        handles: List[RequestHandle] = []
        i = 0
        now = 0.0
        stalled = 0
        t0 = time.perf_counter()
        try:
            if not realtime:
                # Route ALL engine time accounting (submit/enqueue stamps,
                # ttft, finish, queue aging, deadline expiry) through the
                # virtual clock, so virtual-clock runs report the same
                # accounting semantics realtime ones do.
                self._clock = now
            while i < len(requests) or self.queue or \
                    any(h is not None for h in self.slots):
                if realtime:
                    now = time.perf_counter() - t0
                while i < len(requests) and requests[i].arrival_s <= now:
                    handles.append(self.submit(requests[i]))
                    i += 1
                if i < len(requests) and not self.queue and \
                        all(h is None for h in self.slots):
                    # Idle gap until the next arrival — fast-forward.
                    if not realtime:
                        now = requests[i].arrival_s
                        self._clock = now
                    handles.append(self.submit(requests[i]))
                    i += 1
                before = len(self.queue)
                self.step()
                if i >= len(requests):
                    # All arrivals in: the same dead-admission detection as
                    # drain() (a permanently envelope-blocked head would
                    # otherwise spin this loop forever).
                    stalled = self._check_admission_stall(stalled, before)
                if not realtime:
                    now += virtual_step_s
                    self._clock = now
        finally:
            self._clock = None
        return handles

    def flush(self) -> None:
        """Barrier on the backend's in-flight residency transitions."""
        self.backend.flush()

    # ------------------------------------------------------------------
    def generate(self, batch: Dict, n_tokens: int, sampling=None,
                 qos=None, deadline_ms=None):
        """Whole-batch compat shim over submit + drain.

        ``batch``: ``{"tokens": (B, S)}`` with B ≤ ``max_slots``.
        ``sampling``: optional ``SamplingParams`` applied to every row
        (default greedy — bit-identical to the pre-sampler shim); validated
        at ``submit`` like any request. Returns ``(tokens (B, n_tokens),
        ttft_s, per_step_s)`` token-for-token identical to driving
        submit/step/drain directly.
        Token-only: multimodal batches (``image_embeds``/``audio_embeds``)
        are not supported by the request path and are rejected loudly.
        """
        extra = set(batch) - {"tokens"}
        if extra:
            raise NotImplementedError(
                f"InferenceEngine serves token-only requests; unsupported "
                f"batch keys: {sorted(extra)}. Use repro.models.prefill/"
                f"decode_step directly for multimodal batches.")
        toks = np.asarray(batch["tokens"])
        B = toks.shape[0]
        if B > self.ecfg.max_slots:
            raise ValueError(f"batch {B} > max_slots={self.ecfg.max_slots}")
        if toks.shape[1] + n_tokens - 1 > self.ecfg.max_len:
            # The shim stacks a dense (B, n_tokens) grid — truncation would
            # break it, so the whole batch must fit the slot budget.
            raise ValueError(
                f"{toks.shape[1]}-token prompts + {n_tokens} new tokens "
                f"exceed max_len={self.ecfg.max_len}")
        handles = [self.submit(Request(tokens=toks[i],
                                       max_new_tokens=n_tokens,
                                       sampling=sampling, qos=qos,
                                       deadline_ms=deadline_ms))
                   for i in range(B)]
        n_before = len(self.decode_times)
        self.drain()
        out = jnp.asarray(np.stack([h.token_array() for h in handles], 0))
        ttft = float(np.mean([h.ttft_s for h in handles]))
        return out, ttft, self.decode_times[n_before:]

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Backend's uniform serving stats merged with engine counters.
        ``ttft_s`` is the request-level submit→first-token mean (queue wait
        included); the backend's per-prefill latency stays available via
        ``backend.stats()``. Paged engines add the KV-pool gauges:
        ``kv_blocks_in_use`` / ``kv_bytes_in_use`` (pool accounting, quota
        included) and the prefix-sharing meters ``prefix_hit_tokens`` /
        ``prefill_tokens`` (prompt tokens served from the trie vs actually
        computed)."""
        out = dict(self.backend.stats())
        if self.ttfts:
            out["ttft_s"] = float(np.mean(self.ttfts))
        if self._tpot_tokens:
            # Time per OUTPUT token: a speculative round's latency spreads
            # over every token it emitted (the backend's own tpot_s stays
            # per-forward — per-dispatch latency).
            out["tpot_s"] = self._tpot_sum / self._tpot_tokens
        out.update({k: float(v) for k, v in self.counters.items()})
        out["prefill_compiles"] = float(len(self.prefill_shapes))
        if self._disp_layers:
            out["active_experts"] = self._disp_active_sum / self._disp_layers
            out["dispatch_pad_ratio"] = self._disp_pad_sum / \
                self._disp_layers
        out["spec_row_rounds"] = 0.0
        if self._spec is not None:
            out.update(self._spec.stats())
        # ENGINE_STAT_KEYS are emitted unconditionally (zeros where N/A) so
        # the stats schema is configuration-independent.
        if self.pool is not None:
            out["kv_blocks_in_use"] = float(self.pool.blocks_in_use)
            out["kv_bytes_in_use"] = float(self.pool.bytes_in_use)
        else:
            out["kv_blocks_in_use"] = 0.0
            out["kv_bytes_in_use"] = 0.0
        out["prefix_trie_nodes"] = float(self.trie.n_nodes) \
            if self.trie is not None else 0.0
        return out

    def device_bytes(self) -> int:
        return self.backend.device_bytes()
