"""Deterministic fault injection + fault tolerance for the transfer plane.

Every transfer/IO boundary in the residency ladder — promotion H2D copies,
host-tier hi/lo loads, lo staging, streaming shard reads, EP migrations,
demand host fetches — can be made to fail, stall, or corrupt on a seeded,
counter-based schedule (`FaultPlan` / `FaultInjector`).  The machinery that
survives those faults lives next to it: a shared exponential-backoff retry
policy with Philox jitter (`RetryPolicy` / `retry_call`) and an engine-step
watchdog (`Watchdog`) that cancels promotions stuck past a deadline and
requeues requests that stopped making progress.

Zero overhead when disabled: every injection point is a single
``injector is None`` pointer check, the same pattern the obs subsystem uses
for tracers.
"""
from repro.fault.inject import (Fault, FaultInjector, FaultPlan, FaultRule,
                                TransferFault)
from repro.fault.retry import RetryExhausted, RetryPolicy, retry_call
from repro.fault.watchdog import Watchdog, WatchdogConfig

__all__ = [
    "Fault",
    "FaultInjector",
    "FaultPlan",
    "FaultRule",
    "RetryExhausted",
    "RetryPolicy",
    "TransferFault",
    "Watchdog",
    "WatchdogConfig",
    "retry_call",
]
