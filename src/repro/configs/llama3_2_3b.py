"""Llama-3.2-3B — small llama3 dense decoder. [hf:meta-llama/Llama-3.2-1B]"""
from repro.models.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    vocab_size=128256,
    d_ff=8192,
    attn=AttnConfig(n_heads=24, n_kv_heads=8, head_dim=128,
                    rope_theta=500000.0),
    norm_eps=1e-5,
    max_seq_len=131072,
    source="hf:meta-llama/Llama-3.2-1B family",
)
