"""Paper Figs 6–9 at production scale — trace-driven simulation.

The CPU-measured benchmark (serving_perf.py) is dominated by interpreter
compute on a toy model; the paper's comparison is about TRANSFER VOLUME on
vs. off the critical path at Qwen3-30B scale. This module simulates exactly
that, with every parameter either measured here or taken from hardware specs:

* routing: per-token top-8 draws over 128 experts with a Zipf popularity
  whose exponent is FIT to the trained bench model's measured router counts,
  and a workload-dependent permutation (the measured hot-set shift);
* compute time per step: 2·N_active·tokens / eff_FLOPs + weight-bytes/HBM_bw
  (A6000-class: 65 TFLOP/s effective bf16, 768 GB/s HBM);
* offloading baseline: LRU expert cache per layer + next-step prefetcher;
  demand misses stall the step at PCIe speed beyond the compute-overlap
  window (paper Fig. 1's mechanism);
* DynaExq: int4 lo tier always resident (reads are 4× cheaper), hot set in
  bf16, promotions ride the migration stream (rate-limited, off-path);
* static int4: no transfers at all.

Reported: TTFT, TPOP, e2e latency, throughput vs batch; derived columns are
the DynaExq/offload throughput ratio (paper: up to 2.73×).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import eval_batches, trained_model
from benchmarks.hw import PCIE_GBPS
from benchmarks.quality_common import hotness_from_counts
from repro.serving import LRUSet, STAT_KEYS

# Qwen3-30B-A3B geometry (paper Table 3)
L, E, K = 48, 128, 8
D_MODEL, D_FF = 2048, 768
N_ACTIVE = 3.3e9
EXPERT_BYTES_BF16 = 3 * D_MODEL * D_FF * 2
EXPERT_BYTES_INT4 = EXPERT_BYTES_BF16 // 4 + 3 * (D_MODEL // 64) * D_FF * 2
EFF_FLOPS = 65e12
HBM = 768e9
HI_FRAC = 0.125               # DynaExq hi budget: 16 of 128 experts/layer
CACHE_FRAC = 0.75             # offload: A6000 48GB holds ~75% of the 57GB
                              # fp16 model (the paper's same-budget setting)
REROUTE_FRAC = 0.7            # ExpertFlow's cache-aware routing serves this
                              # fraction of would-be misses from cached
                              # experts instead of fetching (its accuracy
                              # cost is why the paper reports it separately)


def fit_zipf(counts: np.ndarray) -> float:
    """Fit a Zipf exponent to measured per-expert counts (all layers)."""
    c = np.sort(counts.sum(0))[::-1].astype(float) + 1
    r = np.arange(1, len(c) + 1)
    return float(-np.polyfit(np.log(r), np.log(c), 1)[0])


PAPER_TABLE1 = {1: 6.3, 2: 11.6, 4: 20.1, 8: 31.9, 16: 46.5, 32: 62.0}


def expected_active_frac(s: float, tokens: int, trials: int = 5) -> float:
    rng = np.random.default_rng(7)
    p = 1.0 / np.arange(1, E + 1) ** s
    p /= p.sum()
    return float(np.mean([len(np.unique(rng.choice(E, tokens * K, p=p))) / E
                          for _ in range(trials)]))


def calibrate_zipf_to_paper() -> float:
    """Pick the Zipf exponent whose unique-expert curve matches the paper's
    measured Qwen3-30B decode activation ratios (Table 1)."""
    best, best_err = 0.5, 1e9
    for s in np.linspace(0.2, 2.5, 24):
        err = sum((expected_active_frac(s, bs) * 100 - v) ** 2
                  for bs, v in PAPER_TABLE1.items())
        if err < best_err:
            best, best_err = float(s), err
    return best


def routing_probs(s: float, rng) -> np.ndarray:
    p = 1.0 / np.arange(1, E + 1) ** s
    p /= p.sum()
    return p[rng.permutation(E)]


def draw_active(p, tokens, rng):
    """Set of activated experts for one layer given `tokens` top-K draws."""
    n_draw = tokens * K
    idx = rng.choice(E, size=n_draw, p=p)
    return np.unique(idx)


def simulate(batch: int, n_steps: int, kind: str, s: float, seed: int = 0,
             prompt: int = 512):
    """Returns the uniform serving-stats schema (see repro.serving.STAT_KEYS):
    same key names/units as the measured backend ``stats()`` rows. The
    underlying accounting model is deliberately different — this sim adds
    ExpertFlow's cache-aware rerouting and compute-overlapped misses at
    Qwen3-30B scale, so its stall_s/bytes_moved are not numerically
    comparable to an OffloadBackend run, only column-aligned."""
    rng = np.random.default_rng(seed)
    rng2 = np.random.default_rng(seed + 1)
    probs = [routing_probs(s, rng) for _ in range(L)]
    pcie = PCIE_GBPS * 1e9
    acct = {k: 0.0 for k in STAT_KEYS}
    # residency state: device LRU cache per layer, pre-warmed with the most
    # popular experts (OrderedDict LRU — same structure the backend uses)
    if kind == "offload":
        cache = [LRUSet(int(E * CACHE_FRAC),
                        init=np.argsort(-p)[:int(E * CACHE_FRAC)][::-1])
                 for p in probs]
        prev = [set() for _ in range(L)]
    hot = [set(np.argsort(-p)[:int(E * HI_FRAC)]) for p in probs]

    def weight_bytes(active_sets):
        total = 0
        for l, acts in enumerate(active_sets):
            na = len(acts)
            if kind == "static":
                total += na * EXPERT_BYTES_INT4
            elif kind == "dynaexq":
                nhot = len(set(acts) & hot[l])
                total += nhot * EXPERT_BYTES_BF16 + \
                    (na - nhot) * EXPERT_BYTES_INT4
            else:
                total += na * EXPERT_BYTES_BF16
        return total

    def step_time(tokens, active_sets):
        t_comp = max(2 * N_ACTIVE * tokens / EFF_FLOPS,
                     weight_bytes(active_sets) / HBM)
        stall = 0.0
        if kind == "offload":
            miss_bytes = 0
            for l, acts in enumerate(active_sets):
                lru = cache[l]
                # prefetch: previous step's activated set
                for e in prev[l]:
                    lru.touch(int(e))
                for e in acts:
                    if lru.hit(int(e)):
                        pass
                    elif rng2.random() > REROUTE_FRAC:
                        # true demand fetch (not reroutable)
                        miss_bytes += EXPERT_BYTES_BF16
                        lru.add(int(e))
                prev[l] = set(int(x) for x in acts)
            # transfers overlap with compute (layer-pipelined prefetch);
            # only the excess stalls the step (paper Fig. 1's regime)
            stall = max(0.0, miss_bytes / pcie - t_comp)
            acct["stall_s"] += stall
            acct["bytes_moved"] += miss_bytes
        return t_comp + stall

    # prefill (near-dense activation) then decode steps
    pre_active = [draw_active(probs[l], batch * prompt, rng) for l in range(L)]
    acct["ttft_s"] = step_time(batch * prompt, pre_active)
    times = []
    for _ in range(n_steps):
        acts = [draw_active(probs[l], batch, rng) for l in range(L)]
        times.append(step_time(batch, acts))
    acct["tpot_s"] = float(np.mean(times))
    acct["e2e_s"] = acct["ttft_s"] + float(np.sum(times))
    return acct


def run(report):
    cfg, params, task = trained_model()
    counts = hotness_from_counts(cfg, params, eval_batches(task, cfg, n=3))
    report("serving_sim/toy_model_zipf_exponent", 0.0,
           round(fit_zipf(counts), 3))
    s = calibrate_zipf_to_paper()
    report("serving_sim/zipf_calibrated_to_table1", 0.0, round(s, 3))
    for bs, v in PAPER_TABLE1.items():
        report(f"serving_sim/activation_frac_model/bs{bs}", 0.0,
               round(expected_active_frac(s, bs) * 100, 1))
    n_steps = 64
    for batch in (1, 4, 8, 16, 32):
        row = {}
        for kind in ("static", "dynaexq", "offload"):
            st = simulate(batch, n_steps, kind, s, seed=batch)
            tput = batch * n_steps / st["e2e_s"]
            row[kind] = tput
            report(f"serving_sim/ttft_ms/{kind}/bs{batch}", 0.0,
                   round(st["ttft_s"] * 1e3, 2))
            report(f"serving_sim/tpop_ms/{kind}/bs{batch}", 0.0,
                   round(st["tpot_s"] * 1e3, 3))
            report(f"serving_sim/stall_ms/{kind}/bs{batch}", 0.0,
                   round(st["stall_s"] * 1e3, 3))
            report(f"serving_sim/throughput_tps/{kind}/bs{batch}", 0.0,
                   round(tput, 1))
        report(f"serving_sim/dynaexq_vs_offload_x/bs{batch}", 0.0,
               round(row["dynaexq"] / row["offload"], 2))
        report(f"serving_sim/dynaexq_vs_static_x/bs{batch}", 0.0,
               round(row["dynaexq"] / row["static"], 2))
