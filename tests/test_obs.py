"""Observability layer: flight recorder, metrics registry, trace cost model,
stats-schema contract, stall attribution, and trace determinism.

The schema tests are CONTRACTS, not snapshots: ``backend.stats()`` must
return exactly ``STAT_KEYS + type(backend).STAT_EXTRAS`` and the engine adds
exactly ``ENGINE_STAT_KEYS`` — independent of configuration or what happened
during the run, so downstream benchmark tables never grow holes when a
feature sits idle.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.obs import (FlightRecorder, MetricsRegistry, ObsConfig,
                       Observability, costmodel, load_chrome_trace)
from repro.serving import (OffloadConfig, Request, RequestStream, STAT_KEYS)
from repro.serving.backends import (DynaExqBackend, Fp16Backend,
                                    OffloadBackend, StaticPTQBackend)
from repro.serving.engine import ENGINE_STAT_KEYS, LOAD_SNAPSHOT_KEYS


# ---------------------------------------------------------------------------
# FlightRecorder unit behavior
# ---------------------------------------------------------------------------

def test_ring_buffer_bounds_and_drop_count():
    tr = FlightRecorder(capacity=4, clock=lambda: 0.0)
    for i in range(10):
        tr.instant("e", cat="t", i=i)
    assert len(tr) == 4
    assert tr.dropped == 6
    # Oldest dropped, newest kept.
    assert [e.args["i"] for e in tr.events()] == [6, 7, 8, 9]
    assert tr.to_chrome()["otherData"]["dropped_events"] == 6


def test_async_span_pairing():
    t = iter(np.arange(0.0, 10.0, 0.5))
    tr = FlightRecorder(clock=lambda: float(next(t)))
    a, b = tr.next_id(), tr.next_id()
    assert a != b
    tr.async_begin("promotion", a, cat="residency", layer=0)
    tr.async_begin("promotion", b, cat="residency", layer=1)
    tr.async_end("promotion", b, published=1)
    tr.async_end("promotion", a, published=0)
    # An unmatched begin stays open and is omitted.
    tr.async_begin("promotion", tr.next_id())
    spans = tr.spans("promotion")
    assert len(spans) == 2
    for bg, en in spans:
        assert bg.id == en.id and bg.ts < en.ts
    # Pairs are keyed by id, not arrival order: b ended first.
    assert spans[0][1].args["published"] == 1
    assert spans[1][1].args["published"] == 0


def test_chrome_export_round_trip(tmp_path):
    tr = FlightRecorder(clock=lambda: 1.0)
    tr.meta.update(num_experts=4, top_k=2)
    tr.instant("moe_forward", cat="engine", routed=8)
    path = str(tmp_path / "t.trace.json")
    tr.save(path)
    obj = load_chrome_trace(path)
    (ev,) = obj["traceEvents"]
    assert ev["name"] == "moe_forward" and ev["ph"] == "i"
    assert ev["ts"] == 1e6 and ev["tid"] == "engine"   # µs + cat lane
    assert ev["args"] == {"routed": 8}
    assert obj["otherData"]["num_experts"] == 4
    # Determinism: a second save writes identical bytes.
    path2 = str(tmp_path / "t2.trace.json")
    tr.save(path2)
    assert open(path, "rb").read() == open(path2, "rb").read()


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

def test_metrics_registry_kinds_and_values():
    m = MetricsRegistry()
    m.counter("c").inc()
    m.counter("c").inc(2.5)
    assert m.counter("c").value == 3.5
    with pytest.raises(ValueError):
        m.counter("c").inc(-1)
    m.gauge("g").set(7)
    m.gauge("g").set(4)
    with pytest.raises(TypeError):
        m.gauge("c")          # kind mismatch on an existing name
    h = m.histogram("h")
    for v in np.linspace(0.001, 0.1, 100):
        h.observe(v)
    snap = m.snapshot()
    assert snap["c"] == 3.5 and snap["g"] == 4.0
    assert snap["h_count"] == 100
    assert snap["h_p50"] == pytest.approx(np.percentile(
        np.linspace(0.001, 0.1, 100), 50))
    assert snap["h_p50"] <= snap["h_p95"]


def test_prometheus_exposition_and_jsonl_sink(tmp_path):
    path = str(tmp_path / "m.jsonl")
    m = MetricsRegistry(jsonl_path=path)
    m.counter("reqs", "total requests").inc(3)
    m.gauge("depth").set(2)
    m.histogram("lat").observe(0.002)
    m.sample(step=1, depth=2)
    m.sample(step=2, depth=0)
    m.close()
    text = m.to_prometheus()
    assert "# HELP reqs total requests" in text
    assert "# TYPE reqs counter" in text
    assert "reqs 3" in text
    assert "# TYPE lat histogram" in text
    assert 'lat_bucket{le="+Inf"} 1' in text
    assert "lat_count 1" in text
    rows = [json.loads(ln) for ln in open(path)]
    assert rows == [{"step": 1, "depth": 2}, {"step": 2, "depth": 0}]
    m.close()                  # idempotent


# ---------------------------------------------------------------------------
# Cost model (trace replayer) on a synthetic trace
# ---------------------------------------------------------------------------

def _synthetic_trace():
    """A hand-built trace whose measured traffic matches the roofline
    prediction exactly for the degenerate single-token case (every expert
    distinct, k per token), so residuals must be 0."""
    t = iter(np.arange(0.0, 100.0, 0.25))
    tr = FlightRecorder(clock=lambda: float(next(t)))
    tr.meta.update(moe_dispatch="padded", num_experts=8, top_k=2,
                   lo_bytes=100, hi_bytes=400)
    for step in range(5):
        pub = 2 * 4            # 2 hi slots on each of 4 layers
        tr.instant("moe_forward", cat="engine", routed=1 * 4 * 2, layers=4,
                   active_hi=4, active_lo=4, active_host=0,
                   published_hi=pub, prefill=0)
    tr.instant("moe_forward", cat="engine", routed=64, layers=4,
               active_hi=0, active_lo=0, active_host=0, published_hi=0,
               prefill=1)      # prefill: excluded from decode folding
    sid = tr.next_id()
    tr.async_begin("promotion", sid, cat="residency", layer=0, expert=3)
    tr.async_end("promotion", sid, cat="residency", published=1)
    sid = tr.next_id()
    tr.async_begin("promotion", sid, cat="residency", layer=1, expert=5)
    tr.async_end("promotion", sid, cat="residency", published=0)
    return tr


def test_costmodel_fold_and_residuals(tmp_path):
    tr = _synthetic_trace()
    samples = costmodel.fold_steps(tr)
    assert len(samples) == 5                       # prefill excluded
    assert all(s["tokens"] == 1.0 for s in samples)
    # padded: 4 layers × 8 experts × 100 B lo + 8 hi slots × 400 B
    assert samples[0]["measured_bpt"] == 4 * 8 * 100 + 8 * 400
    rep = costmodel.residual_report(tr)
    assert rep["n_steps"] == 5
    assert rep["max_abs_rel_residual"] == 0.0      # 1 token ⇒ model exact
    prom = costmodel.promotion_report(tr)
    assert prom["n_published"] == 1 and prom["n_cancelled"] == 1
    assert prom["publish_latency_p50_s"] == pytest.approx(0.25)
    # Identical numbers replayed from the saved file.
    path = str(tmp_path / "t.trace.json")
    tr.save(path)
    assert costmodel.report(path) == costmodel.report(tr)


def test_costmodel_requires_meta():
    tr = FlightRecorder(clock=lambda: 0.0)
    tr.instant("moe_forward", cat="engine", routed=8, layers=4)
    with pytest.raises(ValueError, match="metadata missing"):
        costmodel.fold_steps(tr)


# ---------------------------------------------------------------------------
# Stats-schema contract
# ---------------------------------------------------------------------------

_BACKEND_CLASSES = {"fp16": Fp16Backend, "static": StaticPTQBackend,
                    "dynaexq": DynaExqBackend, "offload": OffloadBackend}


def test_stat_extras_pinned():
    """The per-class extras are part of the public schema — changing them
    must be a deliberate act that also updates this pin."""
    assert _BackendExtras("fp16") == ()
    assert _BackendExtras("static") == ()
    assert _BackendExtras("dynaexq") == (
        "deferred", "lo_resident_frac", "hi_loads", "residency_ready_frac",
        "migrations", "quarantined")
    assert {"retries", "fault_cancels"} <= set(STAT_KEYS)
    assert "watchdog_cancels" in ENGINE_STAT_KEYS
    assert _BackendExtras("offload") == ("hits", "misses")
    assert len(STAT_KEYS) == len(set(STAT_KEYS))
    assert len(ENGINE_STAT_KEYS) == len(set(ENGINE_STAT_KEYS))
    # The overlap is exactly the scheduler counters the engine overwrites
    # on top of the backends' uniform defaults.
    assert set(STAT_KEYS) & set(ENGINE_STAT_KEYS) == {
        "preemptions", "resumes", "shed_requests", "downgraded"}


def _BackendExtras(kind):
    return _BACKEND_CLASSES[kind].STAT_EXTRAS


@pytest.mark.parametrize("kind", sorted(_BACKEND_CLASSES))
def test_stats_schema_exact(engine_factory, serving_setup, kind):
    """After a real run, ``engine.stats()`` contains exactly the uniform
    keys + the backend's declared extras + the engine's keys — no more, no
    fewer — regardless of which features the run exercised."""
    cfg, _ = serving_setup
    kw = {"ocfg": OffloadConfig(cache_experts_per_layer=1)} \
        if kind == "offload" else {}
    eng = engine_factory(kind, **kw)
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(Request(tokens=rng.integers(0, cfg.vocab_size, size=10),
                           max_new_tokens=4))
    eng.drain()
    eng.flush()
    st = eng.stats()
    expect = set(STAT_KEYS) | set(_BackendExtras(kind)) \
        | set(ENGINE_STAT_KEYS)
    assert set(st) == expect, (
        f"{kind}: stats schema drift — extra {sorted(set(st) - expect)}, "
        f"missing {sorted(expect - set(st))}")
    assert all(isinstance(v, float) for v in st.values())


def test_load_snapshot_schema(engine_factory):
    eng = engine_factory("static")
    assert set(eng.load_snapshot()) == set(LOAD_SNAPSHOT_KEYS)


# ---------------------------------------------------------------------------
# Engine integration: events, meta, sampling, stall attribution
# ---------------------------------------------------------------------------

def _run(engine_factory, cfg, kind, obs, n=4, new=6, **kw):
    eng = engine_factory(kind, obs=obs, **kw)
    rng = np.random.default_rng(7)
    handles = [eng.submit(Request(
        tokens=rng.integers(0, cfg.vocab_size, size=12), max_new_tokens=new))
        for _ in range(n)]
    eng.drain()
    eng.flush()
    return eng, handles


def test_engine_emits_lifecycle_and_forward_events(engine_factory,
                                                   serving_setup):
    cfg, _ = serving_setup
    obs = Observability(ObsConfig())
    eng, handles = _run(engine_factory, cfg, "dynaexq", obs)
    tr = obs.tracer
    names = {e.name for e in tr.events()}
    assert {"submit", "admit", "finish", "step", "moe_forward"} <= names
    assert len(tr.instants("submit")) == len(handles)
    assert len(tr.instants("finish")) == len(handles)
    # Engine meta carries everything the cost model needs.
    assert all(k in tr.meta for k in costmodel.META_KEYS)
    assert tr.meta["backend"] == "dynaexq"
    assert tr.meta["lo_bytes"] > 0 and tr.meta["hi_bytes"] > 0
    # The replayer runs off the live recorder without error and sees steps.
    rep = costmodel.report(tr)
    assert rep["roofline"]["n_steps"] > 0
    # Metrics sampled at step cadence.
    snap = obs.metrics.snapshot()
    assert "engine_active_experts" in snap
    assert snap["residency_hi_cells"] > 0


def test_promotion_lifecycle_spans(engine_factory, serving_setup):
    """Every completed promotion span ends with ``published`` ∈ {0, 1};
    a published end means the copy's result arrays were ready before any
    forward referenced the slot — the half-materialization audit."""
    cfg, _ = serving_setup
    obs = Observability(ObsConfig())
    eng, _ = _run(engine_factory, cfg, "dynaexq", obs)
    spans = obs.tracer.spans("promotion")
    assert spans, "dynaexq run produced no promotion lifecycle spans"
    assert any(e.args["published"] == 1 for _, e in spans)
    for b, e in spans:
        assert e.args["published"] in (0, 1)
        assert e.ts >= b.ts
        assert b.args["layer"] >= 0 and b.args["bytes"] > 0
    # Published count in the trace matches the backend's own accounting.
    n_pub = sum(e.args["published"] for _, e in spans)
    assert n_pub <= eng.stats()["promotions"] + len(spans)
    # Publish-latency histogram fed by the same spans.
    snap = obs.metrics.snapshot()
    assert snap["promotion_publish_latency_seconds_count"] == n_pub


def test_stall_exposure_attribution(engine_factory, serving_setup):
    """Offload demand misses stall the step; every handle active during a
    stalled step accrues the stall in its ``stall_exposure_s`` (exposure,
    not exclusive share — concurrent handles each saw the full wait)."""
    cfg, _ = serving_setup
    eng, handles = _run(engine_factory, cfg, "offload", None,
                        ocfg=OffloadConfig(cache_experts_per_layer=1))
    st = eng.stats()
    assert st["stall_s"] > 0
    exposed = [h.stall_exposure_s for h in handles]
    assert max(exposed) > 0
    # Exposure is bounded by the total stalled wall each handle could see.
    assert max(exposed) <= st["stall_s"] + 1e-9
    # A stall-free backend attributes nothing.
    eng2, handles2 = _run(engine_factory, cfg, "static", None)
    assert all(h.stall_exposure_s == 0.0 for h in handles2)


def test_disabled_tracer_records_nothing(engine_factory, serving_setup):
    cfg, _ = serving_setup
    obs = Observability(ObsConfig(trace=False, metrics=True))
    assert obs.tracer is None
    eng, _ = _run(engine_factory, cfg, "dynaexq", obs)
    assert eng.tracer is None
    assert "engine_active_experts" in obs.metrics.snapshot()
    with pytest.raises(ValueError):
        obs.save_trace("/tmp/never.json")


def test_obs_none_leaves_engine_bare(engine_factory, serving_setup):
    cfg, _ = serving_setup
    eng, _ = _run(engine_factory, cfg, "static", None)
    assert eng.obs is None and eng.tracer is None and eng.metrics is None
    assert eng.backend.tracer is None


# ---------------------------------------------------------------------------
# Trace determinism under the virtual clock
# ---------------------------------------------------------------------------

def test_virtual_clock_replay_traces_byte_identical(engine_factory,
                                                    serving_setup, tmp_path):
    """Two ``replay(realtime=False)`` runs of the same stream write
    byte-identical trace files: every event arg is count-derived and every
    timestamp comes off the virtual clock. Static backend — its residency
    never depends on wall-clock cadence."""
    cfg, _ = serving_setup

    def one(tag):
        obs = Observability(ObsConfig(metrics=False))
        eng = engine_factory("static", obs=obs)
        stream = RequestStream(cfg.vocab_size, phases=[("text", 5)],
                               prompt_len=10, prompt_len_jitter=3,
                               max_new_tokens=5, arrival_rate_rps=200.0,
                               seed=11)
        handles = eng.replay(stream, realtime=False)
        assert all(h.tokens for h in handles)
        path = str(tmp_path / f"{tag}.trace.json")
        obs.save_trace(path)
        return open(path, "rb").read()

    a, b = one("a"), one("b")
    assert len(a) > 200
    assert a == b
    # And the events are genuinely virtual-clock stamped: the first event
    # sits near t=0, not at perf_counter's epoch.
    first = json.loads(a)["traceEvents"][0]
    assert first["ts"] < 60e6
