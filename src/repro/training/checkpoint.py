"""Checkpointing: pytree → directory of .npy leaves + a treedef manifest.

No pickle of arrays (portable, memory-mappable); QuantizedTensor leaves
round-trip through their registered flatten/unflatten.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _sanitize(key: str) -> str:
    return key.replace("/", "_").replace("[", "(").replace("]", ")")


def save_checkpoint(path: str, tree, step: int | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten(tree)
    manifest = {"n_leaves": len(flat), "treedef": str(treedef), "step": step}
    dtypes = []
    for i, leaf in enumerate(flat):
        arr = np.asarray(leaf)
        dtypes.append(str(arr.dtype))
        if arr.dtype.kind not in "biufc":   # ml_dtypes (bf16): store as f32,
            arr = arr.astype(np.float32)    # lossless superset of bf16
        np.save(os.path.join(path, f"leaf_{i:05d}.npy"), arr)
    manifest["dtypes"] = dtypes
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def load_checkpoint(path: str, like_tree):
    """Restore into the structure of ``like_tree`` (shapes must match)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree_util.tree_flatten(like_tree)
    if manifest["n_leaves"] != len(flat_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(flat_like)}")
    out = []
    for i, like in enumerate(flat_like):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        out.append(jax.numpy.asarray(arr).astype(like.dtype)
                   if hasattr(like, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest.get("step")
