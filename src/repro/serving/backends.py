"""Pluggable expert-residency backends for the serving engine.

The paper's DynaExq controller is one point in a family of budget-constrained
residency strategies (static PTQ, offloading/prefetch, dense fp16). Each
strategy is a ``ResidencyBackend``: the engine owns requests, caches and the
jitted forward closures; the backend owns *where expert weights live* and
what moving them costs. All four backends run through literally the same
``InferenceEngine.step()`` loop, so the DynaExq-vs-offload comparison is
structural, not an artifact of two different serving loops.

Protocol (one backend instance per engine):

* ``materialize_banks(cfg, params, kv_bytes, budget=None)`` — build the
  device-resident weight tiers; returns the per-MoE-position bank mapping
  the engine passes into the jitted forward (``None`` ⇒ dense bf16 experts
  from ``params``). ``kv_bytes`` is the KV pool's own accounting (the
  engine's block math — no backend re-derives KV sizes); ``budget`` is the
  engine's shared ``BudgetTracker``: residency strategies that gate byte
  movement (DynaExq's hi tier) reserve through account-scoped views of it,
  so expert promotions and KV block admission contend for ONE HBM envelope.
* ``observe(counts, compute_s, prefill, row_valid)`` — per-forward
  router-trace hook; returns modeled *stall seconds* to charge to the
  step's critical path (non-zero only for demand-fetch strategies like
  offloading). ``counts`` values are either pre-masked (L, E) aggregates or
  row-resolved (L, R, E) arrays, in which case ``row_valid`` ((R,) bool)
  masks vacant/padding rows before they reach hotness or residency
  accounting — no backend ever sees phantom traffic.
* ``tick()`` — window boundary: run policies, publish completed transitions.
* ``device_bytes()`` — resident expert bytes under this strategy's budget.
* ``stats()`` — uniform serving stats: ``{ttft_s, tpot_s, stall_s,
  bytes_moved, promotions, demotions}`` (zeros where N/A), plus
  backend-specific extras.
* ``flush()`` — barrier on in-flight transitions (shutdown / tests).
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from collections import OrderedDict, deque
from typing import (Dict, Iterable, Optional, Protocol, Tuple,
                    runtime_checkable)

import jax
import numpy as np

from repro.core import (ControllerConfig, DynaExqController, build_bank,
                        expert_hi_nbytes, expert_lo_nbytes, plan_budget)
from repro.core.allocator import AllocatorConfig, GlobalAllocator
from repro.core.budget import BudgetTracker
from repro.core.controller import EPCoordinator, RebalanceConfig
from repro.core.hotness import mask_row_counts
from repro.core.ver import build_bank_empty
from repro.fault.inject import FaultInjector, FaultPlan
from repro.fault.retry import RetryExhausted, RetryPolicy
from repro.models.config import ArchConfig
from repro.quant.sensitivity import load_sensitivity, normalize
from repro.serving.hoststore import FetchModel, HostExpertStore
from repro.serving.streaming import ShardSource, hotness_stage_order

GiB = 1 << 30

#: Keys every backend's ``stats()`` must return (zeros where N/A). The
#: speculative-decoding meters (``accept_rate``/``draft_tokens``/
#: ``verified_tokens``/``spec_rounds``) are part of the uniform schema so
#: every benchmark row is machine-comparable whether or not speculation ran;
#: the engine overwrites them with live values when its SpecDecoder is on.
#: Likewise the MoE dispatch gauges: ``active_experts`` (mean experts with
#: ≥1 routed token per layer-step) and ``dispatch_pad_ratio`` (fraction of
#: expert-GEMM rows that were padding under the configured layout) — the
#: engine fills them from its per-forward router counts.
#: The QoS-scheduler meters (``preemptions``/``resumes``/``shed_requests``/
#: ``downgraded``) join the schema the same way: zeros from every backend,
#: overwritten by the engine's live scheduler counters.
#: ``host_fetches`` counts demand reads from the host tier — OffloadBackend's
#: cache misses and DynaExq's routed-but-host-resident experts land in the
#: same column, so "how often did the critical path touch host memory" is
#: directly comparable across residency strategies.
#: The fault-tolerance meters (``retries``: transfer attempts retried under
#: the shared backoff policy; ``fault_cancels``: promotions/migrations
#: cancelled by a fault, timeout, or publish-time integrity check) join the
#: uniform schema: zeros everywhere the transfer plane is fault-free.
STAT_KEYS = ("ttft_s", "tpot_s", "stall_s", "bytes_moved",
             "promotions", "demotions",
             "accept_rate", "draft_tokens", "verified_tokens", "spec_rounds",
             "active_experts", "dispatch_pad_ratio",
             "preemptions", "resumes", "shed_requests", "downgraded",
             "host_fetches", "retries", "fault_cancels")

#: The schema contract: ``backend.stats()`` returns EXACTLY
#: ``STAT_KEYS + type(backend).STAT_EXTRAS`` — extras are declared per
#: class, not leaked ad hoc, so downstream consumers (benchmark JSON,
#: metrics export, report tables) can pin columns. Enforced by
#: ``tests/test_obs.py``.


def _param_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "dtype"))


@runtime_checkable
class ResidencyBackend(Protocol):
    """Structural interface the engine programs against (no isinstance /
    mode-string branching anywhere in the serving loop)."""

    name: str

    def materialize_banks(self, cfg: ArchConfig, params: Dict,
                          kv_bytes: int, budget=None) -> Optional[Dict]: ...

    def observe(self, counts: Dict, compute_s: float = 0.0,
                prefill: bool = False,
                row_valid: Optional[np.ndarray] = None) -> float: ...

    def tick(self) -> None: ...

    def device_bytes(self) -> int: ...

    def stats(self) -> Dict[str, float]: ...

    def flush(self) -> None: ...


class LRUSet:
    """O(1) LRU set over expert ids (OrderedDict: ``move_to_end`` on hit,
    ``popitem(last=False)`` on eviction). Replaces the earlier O(n)
    list-based LRU in the offload path."""

    def __init__(self, size: int, init: Optional[Iterable[int]] = None):
        self.size = size
        self._od: OrderedDict[int, None] = OrderedDict()
        if init is not None:
            for e in init:
                self.add(int(e))

    def __contains__(self, e: int) -> bool:
        return e in self._od

    def __len__(self) -> int:
        return len(self._od)

    def hit(self, e: int) -> bool:
        """Refresh ``e`` if cached; returns whether it was a hit."""
        if e in self._od:
            self._od.move_to_end(e)
            return True
        return False

    def add(self, e: int) -> None:
        """Insert ``e`` as most-recent, evicting the LRU entry on overflow."""
        self._od[e] = None
        self._od.move_to_end(e)
        while len(self._od) > self.size:
            self._od.popitem(last=False)

    def touch(self, e: int) -> bool:
        """Hit-or-insert; returns True on hit (classic LRU access)."""
        if self.hit(e):
            return True
        self.add(e)
        return False

    def order(self) -> list[int]:
        """Entries LRU-first (introspection/tests)."""
        return list(self._od)


class _BackendBase:
    """Shared accounting: latency aggregation (TTFT/TPOT as observed by the
    engine) and router-count accumulation (the uniform hotness signal)."""

    name = "base"

    #: Stats keys this class emits beyond the uniform ``STAT_KEYS``.
    STAT_EXTRAS: Tuple[str, ...] = ()

    def __init__(self):
        self._ttft: list[float] = []
        self._tpot: list[float] = []
        self._counts_sum: Dict[str, np.ndarray] = {}
        self.cfg: Optional[ArchConfig] = None
        self.budget = None                  # engine's shared BudgetTracker
        self.moe_positions: list[int] = []
        self.tracer = None                  # obs.FlightRecorder | None
        self.metrics = None                 # obs.MetricsRegistry | None

    # -- observability ---------------------------------------------------
    def attach_obs(self, tracer=None, metrics=None) -> None:
        """Wire the engine's flight recorder / metrics registry in.
        Subclasses propagate to owned components (TransitionManager,
        EPCoordinator, HostExpertStore). ``None`` detaches — every
        instrumentation site is a pointer check, so detached backends
        compile to the pre-obs behavior."""
        self.tracer = tracer
        self.metrics = metrics

    def obs_meta(self) -> Dict[str, int]:
        """Byte prices the trace cost model replays against:
        ``{"lo_bytes", "hi_bytes"}`` per expert-layer cell (zeros where a
        tier doesn't exist under this strategy)."""
        return {}

    # -- lifecycle -------------------------------------------------------
    def materialize_banks(self, cfg: ArchConfig, params: Dict,
                          kv_bytes: int, budget=None) -> Optional[Dict]:
        self.cfg = cfg
        self.budget = budget
        sb = cfg.superblock_or_default()
        self.moe_positions = [p for p, _ in enumerate(sb)
                              if cfg.ffn_kind(p) == "moe"] if cfg.is_moe \
            else []
        return self._materialize(cfg, params, kv_bytes)

    def _materialize(self, cfg: ArchConfig, params: Dict,
                     kv_bytes: int) -> Optional[Dict]:
        return None

    # -- per-forward hook ------------------------------------------------
    def observe(self, counts: Dict, compute_s: float = 0.0,
                prefill: bool = False,
                row_valid: Optional[np.ndarray] = None) -> float:
        """Accumulate one forward's router counts and run residency
        accounting. Values may be (L, E) aggregates (accumulated as-is) or
        row-resolved (L, R, E), in which case ``row_valid`` masks vacant/
        padding rows before the sum (``core.hotness.mask_row_counts`` — the
        one scrub rule every residency strategy shares)."""
        cleaned: Dict[str, np.ndarray] = {}
        for k, c in counts.items():
            c = mask_row_counts(c, row_valid)
            cleaned[k] = c
            acc = self._counts_sum.get(k)
            self._counts_sum[k] = c.copy() if acc is None else acc + c
        stall = self._observe_residency(cleaned, compute_s)
        if self.tracer is not None:
            # The per-forward traffic record the cost model replays: routed
            # assignments plus the active-cell tier split at THIS forward's
            # residency. Args are counts only (no wall-clock durations), so
            # virtual-clock replays trace byte-identically.
            hi, lo, host, pub = self._tier_counts(cleaned)
            self.tracer.instant(
                "moe_forward", cat="engine",
                routed=int(sum(int(c.sum()) for c in cleaned.values())),
                layers=int(sum(c.shape[0] for c in cleaned.values())),
                active_hi=hi, active_lo=lo, active_host=host,
                published_hi=pub, prefill=int(prefill))
        (self._ttft if prefill else self._tpot).append(compute_s + stall)
        return stall

    def _observe_residency(self, counts: Dict, compute_s: float) -> float:
        return 0.0

    def _tier_counts(self, cleaned: Dict) -> Tuple[int, int, int, int]:
        """One forward's ``(active_hi, active_lo, active_host,
        published_hi)`` cell counts. Base strategy: everything serves from
        an always-resident lo tier (StaticPTQ's truth; overridden where the
        ladder is richer)."""
        act = sum(int((c > 0).sum()) for c in cleaned.values())
        return 0, act, 0, 0

    def tick(self) -> None:
        pass

    def flush(self) -> None:
        pass

    # -- cold-start readiness --------------------------------------------
    def serving_ready(self) -> bool:
        """Whether forwards may run (False only mid-streaming-cold-start —
        the engine idles admission and keeps ticking the backend)."""
        return True

    def ready_frac(self) -> float:
        """Residency build-out progress in [0, 1] (1.0 once serving)."""
        return 1.0

    # -- introspection ---------------------------------------------------
    def router_counts(self) -> Dict[str, np.ndarray]:
        """Accumulated router-selection counts per MoE position, (L, E)."""
        return dict(self._counts_sum)

    def residency_mix(self) -> Dict[str, int]:
        """Current (layer, expert)-cell residency census:
        ``{"hi", "lo", "host"}`` counts (the per-step gauge the metrics
        sampler records)."""
        return {"hi": 0, "lo": 0, "host": 0}

    def device_bytes(self) -> int:
        raise NotImplementedError

    def stats(self) -> Dict[str, float]:
        out = {k: 0.0 for k in STAT_KEYS}
        if self._ttft:
            out["ttft_s"] = float(np.mean(self._ttft))
        if self._tpot:
            out["tpot_s"] = float(np.mean(self._tpot))
        out.update(self._residency_stats())
        return out

    def _residency_stats(self) -> Dict[str, float]:
        return {}


class Fp16Backend(_BackendBase):
    """Dense bf16 experts, fully device-resident — the quality/latency
    reference (and the compute substrate the offload model prices)."""

    name = "fp16"

    def __init__(self):
        super().__init__()
        self._dense_bytes = 0
        self._cells = 0
        self._cell_bytes = 0

    def _materialize(self, cfg, params, kv_bytes):
        self._dense_bytes = sum(
            _param_bytes(params["blocks"][str(p)]["moe"]["experts"])
            for p in self.moe_positions)
        self._cells = sum(
            int(np.prod(params["blocks"][str(p)]["moe"]["experts"]
                        ["w_gate"].shape[:2]))
            for p in self.moe_positions)
        self._cell_bytes = self._dense_bytes // max(1, self._cells)
        return None        # forward uses the dense experts in params

    def _tier_counts(self, cleaned):
        # Dense experts: every active cell streams at full precision.
        act = sum(int((c > 0).sum()) for c in cleaned.values())
        cells = sum(int(c.size) for c in cleaned.values())
        return act, 0, 0, cells

    def residency_mix(self) -> Dict[str, int]:
        return {"hi": self._cells, "lo": 0, "host": 0}

    def obs_meta(self) -> Dict[str, int]:
        return {"lo_bytes": 0, "hi_bytes": self._cell_bytes}

    def device_bytes(self) -> int:
        return self._dense_bytes


class StaticPTQBackend(_BackendBase):
    """Uniform static PTQ (the paper's static baseline): every expert serves
    from the always-resident lo tier; no hi pool, no transfers, ever."""

    name = "static"

    def __init__(self, lo_bits: int = 4, group_size: int = 64):
        super().__init__()
        self.lo_bits = lo_bits
        self.group_size = group_size
        self.banks: Dict = {}
        self._lo_bytes = 0
        self._cells = 0
        self._lo_per = 0

    def _materialize(self, cfg, params, kv_bytes):
        for pos in self.moe_positions:
            experts = params["blocks"][str(pos)]["moe"]["experts"]
            shapes = {k: tuple(v.shape) for k, v in experts.items()}
            L, E = experts["w_gate"].shape[:2]
            self._lo_per = expert_lo_nbytes(
                shapes, self.lo_bits, self.group_size)
            self._lo_bytes += self._lo_per * L * E
            self._cells += L * E
            self.banks[str(pos)] = build_bank(
                experts, n_hi=0, lo_bits=self.lo_bits,
                group_size=self.group_size)
            # Free the dense copies — the bank is the only residency now.
            params["blocks"][str(pos)]["moe"]["experts"] = None
        return self.banks

    def residency_mix(self) -> Dict[str, int]:
        return {"hi": 0, "lo": self._cells, "host": 0}

    def obs_meta(self) -> Dict[str, int]:
        return {"lo_bytes": self._lo_per, "hi_bytes": 0}

    def device_bytes(self) -> int:
        return self._lo_bytes


class DynaExqBackend(_BackendBase):
    """The paper's system, extended to the full residency ladder: a hi-bf16
    pool, the always-materializable lo tier, and (optionally) a host-DRAM
    third tier — governed by ONE ``GlobalAllocator`` knapsack across every
    layer of every MoE position. Promotions ride the migration stream (off
    the critical path) — ``observe`` only feeds hotness; ``tick`` runs the
    allocation window.

    ``global_alloc`` (default on for single-shard serving): replaces the L
    independent per-layer top-n policies with one cross-layer allocation —
    a hot layer may hold more hi slots than a cold layer at the same total
    byte budget. Each bank's physical per-layer slot pool is built with
    ``slots_slack`` headroom over the uniform share so the allocator has
    room to skew. ``global_alloc=False`` restores the paper's per-layer
    rule (and is forced under expert parallelism, where hi slots are
    shard-local and cannot be reassigned across layers).

    ``lo_resident_total`` enables the host tier: only that many (layer,
    expert) cells count as device-lo-resident; the rest live in host DRAM
    and pay a ``fetch``-modeled demand stall when routed. ``sensitivity``
    (dict or ``.npz`` path from ``quant.sensitivity``) reweights hotness so
    fragile experts win hi slots at lower traffic.

    ``stream`` (a ``streaming.ShardSource`` or its path) turns on the
    streaming cold start: banks are built EMPTY, ``serving_ready()`` stays
    False while ``tick`` backfills lo rows from the checkpoint shards
    (``stream_experts_per_tick`` per window, hottest-first when a
    ``hotness_path`` snapshot from a previous run exists), and the hi/host
    tiers materialize lazily behind promotions — the dense experts never
    need to exist in device memory all at once.

    Expert parallelism (``ep_shards > 1``): every MoE position's hi-slot
    pool is split into per-shard slot ranges with per-shard budget accounts
    (shard j's promotions bill shard j's local HBM, never a neighbour's),
    and an ``EPCoordinator`` periodically rebalances expert *ownership*
    across shards from the globally-psum'd hotness (``tick`` drives its
    window alongside the per-position controllers)."""

    name = "dynaexq"

    STAT_EXTRAS = ("deferred", "lo_resident_frac", "hi_loads",
                   "residency_ready_frac", "migrations", "quarantined")

    def __init__(self, lo_bits: int = 4, hi_bits: int = 16,
                 group_size: int = 64,
                 n_hi_per_layer: Optional[int] = None,
                 hbm_gb: Optional[float] = None,
                 activation_slack_bytes: int = 64 << 20,
                 controller: Optional[ControllerConfig] = None,
                 ep_shards: int = 1,
                 rebalance: Optional[RebalanceConfig] = None,
                 global_alloc: Optional[bool] = None,
                 slots_slack: float = 2.0,
                 sensitivity=None,
                 lo_resident_total: Optional[int] = None,
                 fetch: Optional[FetchModel] = None,
                 hotness_path: Optional[str] = None,
                 stream=None,
                 stream_experts_per_tick: int = 16,
                 fault=None,
                 retry: Optional[RetryPolicy] = None):
        super().__init__()
        if ep_shards < 1:
            raise ValueError("ep_shards must be >= 1")
        if global_alloc is None:
            global_alloc = ep_shards == 1
        if global_alloc and ep_shards > 1:
            raise ValueError(
                "global_alloc requires ep_shards == 1: hi slots are "
                "shard-local HBM under expert parallelism and cannot be "
                "reassigned across layers by a global knapsack")
        if (stream is not None or lo_resident_total) and not global_alloc:
            raise ValueError(
                "the host tier and streaming cold start require the "
                "global allocator (single-shard serving)")
        if slots_slack < 1.0:
            raise ValueError("slots_slack must be >= 1.0")
        if lo_resident_total is not None and lo_resident_total < 1:
            raise ValueError("lo_resident_total must be >= 1")
        self.lo_bits = lo_bits
        self.hi_bits = hi_bits
        self.group_size = group_size
        self.n_hi_per_layer = n_hi_per_layer
        self.hbm_gb = hbm_gb
        self.activation_slack_bytes = activation_slack_bytes
        self.controller_cfg = controller
        self.ep_shards = int(ep_shards)
        self.coordinator: Optional[EPCoordinator] = \
            EPCoordinator(self.ep_shards, rebalance) if ep_shards > 1 else None
        self.controllers: Dict[str, DynaExqController] = {}
        self.banks: Dict = {}
        # -- residency-ladder configuration --------------------------------
        self.global_alloc = bool(global_alloc)
        self.slots_slack = float(slots_slack)
        self.sensitivity = sensitivity      # dict pos→(L,E) | .npz path
        self.lo_resident_total = lo_resident_total
        self.fetch = fetch if fetch is not None else FetchModel()
        self.hotness_path = hotness_path
        self.stream = stream                # ShardSource | path | None
        self.stream_experts_per_tick = int(stream_experts_per_tick)
        self.stores: Dict[str, HostExpertStore] = {}
        self.allocator: Optional[GlobalAllocator] = None
        self._global_root: Optional[BudgetTracker] = None
        self._row_caps: Optional[np.ndarray] = None
        self._row_pos: list = []            # global row → (pos, layer)
        self._row_offsets: Dict[str, int] = {}
        self._sens: Dict[str, np.ndarray] = {}
        self._lo_b: Dict[str, int] = {}
        self._hi_b: Dict[str, int] = {}
        self._pump_queue: deque = deque()
        self._lo_quota_left = lo_resident_total or 0
        self._serving_ready = True
        self._last_global = time.monotonic()
        self._cadence = (controller.update_interval_s if controller
                         is not None else ControllerConfig().update_interval_s)
        self._host_acct = {"host_fetches": 0, "host_fetch_bytes": 0,
                           "hotness_restored": 0}
        # -- fault tolerance ------------------------------------------------
        # ``fault``: a FaultPlan, a prebuilt FaultInjector, or a JSON
        # string/path (the launcher's --fault-plan). None = zero overhead:
        # every site is a single pointer check.
        if fault is None or isinstance(fault, FaultInjector):
            self.injector = fault
        elif isinstance(fault, FaultPlan):
            self.injector = fault.injector()
        else:
            self.injector = FaultPlan.parse(fault).injector()
        self.retry = retry if retry is not None else RetryPolicy()
        self._fault_acct = {"retries": 0}

    # -- materialization ---------------------------------------------------
    def _derive_n_hi(self, params, kv_bytes, shapes, L, E, hi_b, lo_b):
        ep = self.ep_shards
        if self.n_hi_per_layer is not None:
            n_hi = self.n_hi_per_layer
            if ep > 1 and n_hi % ep:
                raise ValueError(
                    f"n_hi_per_layer={n_hi} not divisible by "
                    f"ep_shards={ep} (each shard owns n_hi/ep slots)")
            return n_hi
        if self.hbm_gb is not None:
            nonexp = _param_bytes({k: v for k, v in params.items()
                                   if k != "blocks"})
            plan = plan_budget(
                m_total=int(self.hbm_gb * GiB),
                m_fixed=nonexp + kv_bytes + self.activation_slack_bytes,
                lo_bytes_total=lo_b * L * E,
                hi_bytes_per_expert_layer=hi_b,
                n_layers=L, num_experts=E, align=ep)
            return plan.n_hi_per_layer
        n_hi = max(1, E // 8)
        if ep > 1:
            # round to a shard-divisible count (≥ one slot per shard)
            n_hi = max(ep, n_hi // ep * ep)
        return n_hi

    def _materialize(self, cfg, params, kv_bytes):
        src = None
        if self.stream is not None:
            src = self.stream if hasattr(self.stream, "lo_layer") \
                else ShardSource(self.stream)
            self.stream = src
        sens = self.sensitivity
        if isinstance(sens, str):
            sens = load_sensitivity(sens)
        # Phase 1 — metadata prepass: slot counts and byte prices for every
        # position BEFORE building anything, so the global envelope and the
        # knapsack budget are sums over the whole model, not one position.
        metas = []
        for pos in self.moe_positions:
            pos = str(pos)
            experts = params["blocks"][pos]["moe"]["experts"]
            if experts is not None:
                shapes = {k: tuple(v.shape) for k, v in experts.items()}
            elif src is not None:
                shapes = src.shapes(pos)
            else:
                raise ValueError(
                    f"position {pos}: experts are None and no stream "
                    f"source configured")
            hi_b = expert_hi_nbytes(shapes, hi_bits=self.hi_bits,
                                    group_size=self.group_size)
            lo_b = expert_lo_nbytes(shapes, self.lo_bits, self.group_size)
            L, E = next(iter(shapes.values()))[:2]
            if self.ep_shards > 1 and E % self.ep_shards:
                raise ValueError(f"num_experts={E} not divisible by "
                                 f"ep_shards={self.ep_shards}")
            n_hi = self._derive_n_hi(params, kv_bytes, shapes, L, E,
                                     hi_b, lo_b)
            metas.append((pos, experts, shapes, L, E, hi_b, lo_b, n_hi))
        self._build_global_structures(metas, sens)
        for pos, experts, shapes, L, E, hi_b, lo_b, n_hi in metas:
            self._lo_b[pos] = lo_b
            self._hi_b[pos] = hi_b
            slots = n_hi
            if self.global_alloc and n_hi > 0:
                # Physical per-layer pool ceiling: headroom over the
                # uniform share so the knapsack can skew slots toward hot
                # layers. Byte accounting stays at n_hi·L·hi_b — extra
                # slots are capacity, not budget.
                slots = min(E, max(n_hi,
                                   math.ceil(n_hi * self.slots_slack)))
            streaming = experts is None
            if streaming:
                bank = build_bank_empty(shapes, n_hi=slots,
                                        lo_bits=self.lo_bits,
                                        group_size=self.group_size)
                store = HostExpertStore(
                    shapes,
                    hi_loader=lambda l, e, p=pos: src.hi_expert(p, l, e),
                    lo_loader=lambda l, p=pos: src.lo_layer(p, l),
                    lo_valid_init=False)
            else:
                bank = build_bank(experts, n_hi=slots, lo_bits=self.lo_bits,
                                  group_size=self.group_size,
                                  hi_bits=self.hi_bits)
                store = HostExpertStore(
                    shapes, hi={k: np.asarray(v)
                                for k, v in experts.items()})
            self.banks[pos] = bank
            self.stores[pos] = store
            if n_hi > 0:
                ctl = DynaExqController(
                    bank, store, n_hi_per_layer=n_hi,
                    hi_bytes_per_expert=hi_b, cfg=self.controller_cfg,
                    tracker=self._tracker_for(pos, n_hi, L, hi_b),
                    ep_shards=self.ep_shards,
                    shard_trackers=self._shard_trackers_for(
                        pos, n_hi, L, hi_b))
                self.controllers[pos] = ctl
                self._restore_hotness(pos, ctl)
                if self.coordinator is not None:
                    # The moe params dict outlives the experts=None free
                    # below — the coordinator swaps its router leaf in
                    # place on migration.
                    self.coordinator.register(
                        ctl, params["blocks"][pos]["moe"])
            if streaming:
                self._serving_ready = False
            params["blocks"][pos]["moe"]["experts"] = None
        if not self._serving_ready:
            self._build_pump_queue()
        self._propagate_faults()
        self._propagate_obs()   # components built after attach_obs
        return self.banks

    # -- fault tolerance ---------------------------------------------------
    def _propagate_faults(self) -> None:
        """Push the injector + retry policy into every transfer-plane
        component (transition managers, host stores, the shard source, the
        EP coordinator)."""
        for ctl in self.controllers.values():
            ctl.tm.injector = self.injector
            ctl.tm.retry = self.retry
        for store in self.stores.values():
            store.injector = self.injector
            store.retry = self.retry
        if self.coordinator is not None:
            self.coordinator.injector = self.injector
        if self.stream is not None and hasattr(self.stream, "lo_layer"):
            self.stream.injector = self.injector

    def bind_clock(self, clock) -> None:
        """Rebind the transfer plane to the engine clock (virtual under
        replay) — promotion issue timestamps feed the watchdog."""
        for ctl in self.controllers.values():
            ctl.tm.clock = clock

    def cancel_stuck_promotions(self, now: float, deadline_s: float) -> int:
        """Watchdog hook: cancel promotions in flight past the deadline
        (slot freed, reservation refunded, expert keeps serving lo)."""
        n = 0
        for ctl in self.controllers.values():
            n += ctl.tm.cancel_stuck(now, deadline_s)
        return n

    def pending_promotions(self, now: float) -> list:
        """(pos, layer, expert, age_s) for every in-flight promotion —
        the stall-diagnostic snapshot."""
        out = []
        for pos, ctl in self.controllers.items():
            out += [(pos, l, e, a) for l, e, a in ctl.tm.pending_ages(now)]
        return out

    def degraded_cells(self) -> Dict[str, np.ndarray]:
        """pos → (L, E) quarantine mask, positions with none omitted —
        the engine flags requests routed through these as degraded."""
        return {pos: s.quarantined for pos, s in self.stores.items()
                if s.quarantined.any()}

    def _heal_quarantined(self, per_tick: int = 2) -> None:
        """Opportunistically re-stage quarantined cells (a bounded number
        per window); a staging that finally lands clears the flag at
        publish. Repeated failures just keep the cell quarantined."""
        healed = 0
        for pos, store in self.stores.items():
            if not store.quarantined.any():
                continue
            for l, e in zip(*np.nonzero(store.quarantined)):
                if healed >= per_tick:
                    return
                resident = True
                if self.lo_resident_total is not None:
                    resident = self._lo_quota_left > 0
                    if resident:
                        self._lo_quota_left -= 1
                try:
                    store.stage_lo(self.banks[pos], int(l), int(e),
                                   resident=resident)
                except RetryExhausted:
                    if resident and self.lo_resident_total is not None:
                        self._lo_quota_left += 1
                    continue
                healed += 1

    # -- observability -----------------------------------------------------
    def attach_obs(self, tracer=None, metrics=None) -> None:
        super().attach_obs(tracer, metrics)
        self._propagate_obs()

    def _propagate_obs(self) -> None:
        """Push the recorder/registry into owned components. Idempotent and
        order-independent: runs both at attach time and at the end of
        ``_materialize`` (whichever comes second sees everything)."""
        hist = self.metrics.histogram(
            "promotion_publish_latency_seconds",
            "copy issue -> publish latency of hi promotions") \
            if self.metrics is not None else None
        for ctl in self.controllers.values():
            ctl.tm.tracer = self.tracer
            ctl.tm.publish_hist = hist
        if self.coordinator is not None:
            self.coordinator.tracer = self.tracer
        for store in self.stores.values():
            store.tracer = self.tracer
        if self.injector is not None:
            self.injector.tracer = self.tracer
        if self.tracer is not None:
            # Promotion issue timestamps and publish latencies on ONE clock
            # (the engine rebinds the recorder's clock to its own).
            for ctl in self.controllers.values():
                ctl.tm.clock = self.tracer.clock

    def obs_meta(self) -> Dict[str, int]:
        if not self._lo_b:
            return {}
        return {"lo_bytes": int(next(iter(self._lo_b.values()))),
                "hi_bytes": int(next(iter(self._hi_b.values())))}

    def _tier_counts(self, cleaned):
        hi = lo = host = pub = 0
        for k, c in cleaned.items():
            act = c > 0
            ctl = self.controllers.get(k)
            hi_mask = ctl.tm.slot_map_h >= 0 if ctl is not None \
                else np.zeros(c.shape, bool)
            store = self.stores.get(k)
            if store is not None and self.lo_resident_total:
                host_mask = ~store.lo_resident & store.lo_valid
            else:
                host_mask = np.zeros(c.shape, bool)
            if store is not None:
                host_mask = host_mask | store.quarantined
            pub += int(hi_mask.sum())
            hi += int((act & hi_mask).sum())
            host += int((act & ~hi_mask & host_mask).sum())
            lo += int((act & ~hi_mask & ~host_mask).sum())
        return hi, lo, host, pub

    def residency_mix(self) -> Dict[str, int]:
        hi = lo = host = 0
        for ctl in self.controllers.values():
            hi += int((ctl.tm.slot_map_h >= 0).sum())
        for store in self.stores.values():
            lo += int(store.lo_resident.sum())
            host += int((~store.lo_resident & store.lo_valid).sum())
        return {"hi": hi, "lo": lo, "host": host}

    def _build_global_structures(self, metas, sens) -> None:
        """Global-mode scaffolding: the cross-layer knapsack (row = one
        layer of one position), its per-row slot ceilings, the shared byte
        envelope, and the normalized sensitivity weights."""
        if not self.global_alloc:
            return
        rows = [(pos, L, E, n_hi, hi_b)
                for pos, _, _, L, E, hi_b, _, n_hi in metas if n_hi > 0]
        if not rows:
            return
        Es = {E for _, _, E, _, _ in rows}
        if len(Es) != 1:
            raise ValueError(
                f"global allocation needs a uniform expert count across "
                f"MoE positions, got {sorted(Es)}")
        total_hi = sum(n_hi * L for _, L, _, n_hi, _ in rows)
        total_cap = sum(n_hi * L * hi_b for _, L, _, n_hi, hi_b in rows)
        caps = []
        for pos, L, E, n_hi, _ in rows:
            self._row_offsets[pos] = len(self._row_pos)
            slots = min(E, max(n_hi, math.ceil(n_hi * self.slots_slack)))
            for l in range(L):
                self._row_pos.append((pos, l))
                caps.append(slots)
        self._row_caps = np.asarray(caps, np.int64)
        ctl_cfg = self.controller_cfg if self.controller_cfg is not None \
            else ControllerConfig()
        max_tr = ctl_cfg.max_transitions_per_layer * len(self._row_pos) \
            if ctl_cfg.max_transitions_per_layer else 0
        self.allocator = GlobalAllocator(AllocatorConfig(
            total_hi=total_hi,
            slots_per_layer=int(self._row_caps.max()),
            margin=ctl_cfg.margin,
            max_transitions=max_tr,
            lo_resident_total=self.lo_resident_total or 0,
            lo_margin=ctl_cfg.margin))
        # One byte envelope for the whole hi tier: either the engine's
        # shared tracker (promotions contend with KV admission) or a
        # private global tracker at the classic summed cap. Per-position
        # accounts carry NO own cap — the global slot budget is the
        # allocator's to spend across layers and positions.
        self._global_root = self.budget if self.budget is not None \
            else BudgetTracker(total_cap)
        if sens:
            for pos, L, E, _, _ in rows:
                s = sens.get(pos)
                if s is None:
                    continue
                s = np.asarray(s, np.float64)
                if s.shape != (L, E):
                    raise ValueError(
                        f"sensitivity for position {pos} has shape "
                        f"{s.shape}, expected ({L}, {E})")
                self._sens[pos] = normalize(s)

    def _tracker_for(self, pos, n_hi, L, hi_b):
        if self.global_alloc and self.allocator is not None:
            return self._global_root.view(f"hi:{pos}")
        if self.budget is not None:
            # Under an engine-shared budget each position's hi tier is an
            # account-scoped view: its own cap is the classic n_hi·L·hi_b
            # pool, but every reservation also passes through the ONE
            # envelope KV blocks draw from — KV pressure defers
            # promotions, demotions free admission headroom.
            return self.budget.view(f"hi:{pos}", cap=n_hi * L * hi_b)
        return None

    def _shard_trackers_for(self, pos, n_hi, L, hi_b):
        ep = self.ep_shards
        if ep <= 1:
            return None
        # One account per shard: a shard's promotions reserve against ITS
        # slice of the pool (its local HBM), so a hot shard saturating its
        # slots cannot starve — or borrow from — a neighbour's budget.
        per_cap = (n_hi // ep) * L * hi_b
        if self.budget is not None:
            return [self.budget.view(f"hi:{pos}:s{j}", cap=per_cap)
                    for j in range(ep)]
        return [BudgetTracker(per_cap) for _ in range(ep)]

    def _restore_hotness(self, pos, ctl) -> None:
        if not self.hotness_path:
            return
        path = f"{self.hotness_path}_p{pos}.npz"
        if not os.path.exists(path):
            return
        try:
            ctl.hotness.load(path)
            self._host_acct["hotness_restored"] += 1
        except ValueError:
            pass    # resized model: a stale prior must not crash serving

    def _build_pump_queue(self) -> None:
        """Round-robin merge of per-position staging orders (hottest-first
        under a restored hotness prior, row-major otherwise) — positions
        backfill evenly instead of position 0 hogging the early windows."""
        per_pos = []
        for pos, store in self.stores.items():
            ctl = self.controllers.get(pos)
            scores = ctl.hotness.scores if ctl is not None else None
            order = hotness_stage_order(scores, store.L, store.E)
            per_pos.append([(pos, l, e) for l, e in order])
        for group in zip(*per_pos) if per_pos else []:
            self._pump_queue.extend(group)

    # -- per-forward hook --------------------------------------------------
    def _observe_residency(self, counts, compute_s):
        stall = 0.0
        for k, ctl in self.controllers.items():
            c = counts.get(k)
            if c is None:
                continue
            c = np.asarray(c)
            ctl.observe(c)
            store = self.stores.get(k)
            if store is None:
                continue
            # Routed experts whose lo residency was ceded to the host tier
            # pay a demand fetch on the critical path (their device rows
            # are valid — the stall models the configuration where a
            # host-resident row would not be kept on device). Quarantined
            # cells are ALWAYS host-served (their device rows are unreal),
            # regardless of whether the host tier is enabled.
            miss = np.zeros(c.shape, bool)
            if self.lo_resident_total:
                miss = ~store.lo_resident & store.lo_valid
            miss = (c > 0) & (miss | store.quarantined)
            n = int(miss.sum())
            if n:
                demand = n * self._lo_b[k]
                self._host_acct["host_fetches"] += n
                self._host_acct["host_fetch_bytes"] += demand
                s = self.fetch.stall_s(demand)
                if self.injector is not None:
                    f = self.injector.fire("host_fetch", pos=k, experts=n)
                    if f is not None:
                        # A failed (or slow) demand fetch is retried
                        # synchronously on the critical path: one extra
                        # full transfer plus any injected stall —
                        # availability is never lost, only latency.
                        extra = s + (f.stall_s if f.kind == "stall" else 0.0)
                        s += extra
                        self._fault_acct["retries"] += 1
                        if self.tracer is not None:
                            self.tracer.instant("retry", cat="fault",
                                                site="host_fetch", pos=k,
                                                backoff_s=round(extra, 9))
                stall += s
                if self.tracer is not None:
                    # stall_s is modeled from bytes (deterministic), safe
                    # for byte-identical replay traces.
                    self.tracer.instant("host_fetch", cat="host", pos=k,
                                        experts=n, bytes=demand, stall_s=s)
        return stall

    # -- windows -----------------------------------------------------------
    def tick(self) -> None:
        if not self._serving_ready:
            self._pump()
            return
        if self.allocator is not None:
            self._global_tick()
        else:
            for ctl in self.controllers.values():
                ctl.maybe_update()
        if self.coordinator is not None:
            self.coordinator.maybe_rebalance()
        self._heal_quarantined()
        for store in self.stores.values():
            store.publish_lo()

    def _pump(self) -> None:
        """One streaming-cold-start window: stage up to
        ``stream_experts_per_tick`` experts' lo rows, publish completed
        copies, and open serving once every cell is materialized."""
        staged = 0
        batch: Dict[Tuple[str, int], Tuple[list, list]] = {}
        while self._pump_queue and staged < self.stream_experts_per_tick:
            pos, l, e = self._pump_queue.popleft()
            if self.stores[pos].lo_valid[l, e]:
                continue
            resident = True
            if self.lo_resident_total is not None:
                resident = self._lo_quota_left > 0
                if resident:
                    self._lo_quota_left -= 1
            ex, res = batch.setdefault((pos, l), ([], []))
            ex.append(e)
            res.append(resident)
            staged += 1
        for (pos, l), (ex, res) in batch.items():
            # One scatter per (layer, leaf): the pump is dispatch-bound on
            # tiny rows, so cell-at-a-time writes would dominate TTFT.
            try:
                self.stores[pos].stage_lo_batch(self.banks[pos], l, ex, res)
            except RetryExhausted:
                # The staging source exhausted its retries: quarantine the
                # batch (served from host, healed by later re-stages) so
                # one unreadable shard can never hold ``serving_ready()``
                # hostage; refund the residency quota it reserved.
                self.stores[pos].quarantine(l, ex)
                if self.lo_resident_total is not None:
                    self._lo_quota_left += sum(res)
        for store in self.stores.values():
            store.publish_lo()
        if not self._pump_queue:
            for store in self.stores.values():
                store.publish_lo(wait=True)
            if all(s.lo_complete for s in self.stores.values()):
                self._serving_ready = True

    def _global_tick(self, now: Optional[float] = None) -> bool:
        now = now if now is not None else time.monotonic()
        # Read the cadence live from the controllers (not the construction-
        # time snapshot): callers freeze/retune policy by mutating ctl.cfg,
        # exactly as the per-layer maybe_update path honors it.
        cadence = min((ctl.cfg.update_interval_s
                       for ctl in self.controllers.values()),
                      default=self._cadence)
        if now - self._last_global < cadence:
            # Still publish any copies that completed since last window.
            for ctl in self.controllers.values():
                ctl.tm.publish_ready()
            return False
        self._last_global = now
        self._global_update()
        return True

    def _global_update(self) -> None:
        """One global allocation window: fold every position's hotness,
        weight by sensitivity, stack all layers into one (R, E) value
        matrix, solve the knapsack ONCE, then hand each position's
        controller its slice of the plan (globally ordered, so under a
        rate limit the hottest promotions anywhere in the model go
        first)."""
        R = len(self._row_pos)
        if R == 0:
            return
        E = self.stores[self._row_pos[0][0]].E
        value = np.zeros((R, E))
        cur_hi = [set() for _ in range(R)]
        use_lo = bool(self.lo_resident_total)
        cur_lo = [set() for _ in range(R)] if use_lo else None
        for pos, off in self._row_offsets.items():
            ctl = self.controllers[pos]
            w = ctl.folded_scores()     # fold + failure-decay penalty
            s = self._sens.get(pos)
            if s is not None:
                w = w * s
            L = ctl.tm.state.shape[0]
            value[off:off + L] = w
            store = self.stores[pos]
            for l in range(L):
                cur_hi[off + l] = ctl.tm.hi_set(l) | \
                    ctl.tm.pending_experts(l)
                if use_lo:
                    cur_lo[off + l] = set(
                        np.nonzero(store.lo_resident[l])[0].tolist())
        asn = self.allocator.allocate(value, cur_hi, cur_lo,
                                      row_caps=self._row_caps)
        if use_lo:
            for r, e in asn.lo_demotions:
                pos, l = self._row_pos[r]
                self.stores[pos].lo_resident[l, e] = False
            for r, e in asn.lo_promotions:
                pos, l = self._row_pos[r]
                store = self.stores[pos]
                if store.lo_valid[l, e]:
                    store.lo_resident[l, e] = True
                else:
                    try:
                        store.stage_lo(self.banks[pos], l, e, resident=True)
                    except RetryExhausted:
                        # Failed lo staging falls back to the host demand
                        # path: the cell stays host-resident (paying the
                        # modeled fetch stall when routed) and the allocator
                        # re-candidates it next window.
                        continue
        promos: Dict[str, list] = {p: [] for p in self.controllers}
        demos: Dict[str, list] = {p: [] for p in self.controllers}
        for r, e in asn.promotions:
            pos, l = self._row_pos[r]
            promos[pos].append((l, e))
        for r, e in asn.demotions:
            pos, l = self._row_pos[r]
            demos[pos].append((l, e))
        for pos, ctl in self.controllers.items():
            ctl.apply_plan(promos[pos], demos[pos])

    def force_update(self) -> None:
        if not self._serving_ready:
            self.flush()
        if self.allocator is not None:
            self._global_update()
        else:
            for ctl in self.controllers.values():
                ctl.update()

    def flush(self) -> None:
        while not self._serving_ready:
            self._pump()
        for ctl in self.controllers.values():
            ctl.flush()
        for store in self.stores.values():
            store.publish_lo(wait=True)
            store.check_invariants()

    # -- readiness ---------------------------------------------------------
    def serving_ready(self) -> bool:
        return self._serving_ready

    def ready_frac(self) -> float:
        if self._serving_ready or not self.stores:
            return 1.0
        return float(np.mean([s.lo_valid.mean()
                              for s in self.stores.values()]))

    def save_hotness(self, path: Optional[str] = None) -> None:
        """Persist every position's traffic history (``hotness_path``
        prefix by default) — the next cold start stages hottest-first and
        the allocator opens with a warm prior instead of uniform."""
        prefix = path if path is not None else self.hotness_path
        if not prefix:
            raise ValueError("no hotness path configured")
        for pos, ctl in self.controllers.items():
            ctl.hotness.save(f"{prefix}_p{pos}.npz")

    # -- introspection -----------------------------------------------------
    def hi_sets(self) -> Dict[str, list]:
        out = {}
        for k, ctl in self.controllers.items():
            L = ctl.tm.slot_map_h.shape[0]
            out[k] = [sorted(ctl.tm.hi_set(l)) for l in range(L)]
        return out

    def device_bytes(self) -> int:
        total = 0
        for pos, bank in self.banks.items():
            shapes = {n: tuple(q.shape) for n, q in bank.lo.items()}
            L, E = bank.slot_map.shape
            per_lo = expert_lo_nbytes(shapes, self.lo_bits, self.group_size)
            per_hi = expert_hi_nbytes(shapes, hi_bits=self.hi_bits,
                                      group_size=self.group_size)
            store = self.stores.get(pos)
            n_lo = int(store.lo_resident.sum()) \
                if store is not None and self.lo_resident_total else L * E
            n_hi_res = int((np.asarray(bank.slot_owner) >= 0).sum())
            total += per_lo * n_lo + n_hi_res * per_hi
        return total

    def _residency_stats(self):
        # Every STAT_EXTRAS key gets a default so the emitted schema is
        # exactly STAT_KEYS + STAT_EXTRAS regardless of configuration.
        agg = {"stall_s": 0.0, "bytes_moved": 0.0,
               "promotions": 0.0, "demotions": 0.0, "deferred": 0.0,
               "lo_resident_frac": 1.0, "hi_loads": 0.0, "migrations": 0.0,
               "host_fetches": float(self._host_acct["host_fetches"]),
               "retries": float(self._fault_acct["retries"]),
               "fault_cancels": 0.0, "quarantined": 0.0}
        for ctl in self.controllers.values():
            agg["bytes_moved"] += ctl.tm.stats["bytes_moved"]
            agg["promotions"] += ctl.tm.stats["promoted"]
            agg["demotions"] += ctl.tm.stats["demoted"]
            agg["deferred"] += ctl.tm.stats["deferred"]
            agg["retries"] += ctl.tm.stats["retries"]
            agg["fault_cancels"] += ctl.tm.stats["fault_cancels"]
        agg["bytes_moved"] += self._host_acct["host_fetch_bytes"]
        if self.stores:
            agg["lo_resident_frac"] = float(np.mean(
                [s.lo_resident.mean() for s in self.stores.values()]))
            agg["hi_loads"] = float(sum(
                s.stats["hi_loads"] for s in self.stores.values()))
            agg["bytes_moved"] += sum(
                s.stats["lo_bytes_staged"] for s in self.stores.values())
            agg["retries"] += sum(
                s.stats["retries"] for s in self.stores.values())
            # Live gauge (not a counter): cells currently host-served
            # because their staging source kept failing.
            agg["quarantined"] = float(sum(
                int(s.quarantined.sum()) for s in self.stores.values()))
        agg["residency_ready_frac"] = self.ready_frac()
        if self.coordinator is not None:
            agg["migrations"] = float(self.coordinator.stats["migrations"])
            agg["bytes_moved"] += self.coordinator.stats["bytes_moved"]
            agg["fault_cancels"] += \
                self.coordinator.stats["aborted_migrations"]
        return agg


@dataclasses.dataclass
class OffloadConfig:
    cache_experts_per_layer: int = 16
    pcie_gbps: float = 16.0          # PCIe gen4 x16 — the paper's A6000
    prefetch: bool = True


class OffloadBackend(_BackendBase):
    """ExpertFlow-like offloading/prefetch baseline (paper §5.3 comparator).

    Experts live in host memory; the device keeps an LRU cache of
    ``cache_experts_per_layer`` experts per layer in bf16. Each forward the
    router's activated set is compared against the cache: misses must be
    fetched over PCIe *on the critical path* (minus whatever an optimistic
    prefetcher overlapped) — exactly the structural cost the paper's Fig. 1
    measures. The transfer cost is a deterministic model
    (bytes / pcie_gbps) layered on the measured compute time, so the
    DynaExq-vs-offload comparison reflects transfer volume, not CPU noise.

    Prefetch model: before each step the predictor prefetches the previous
    step's activated set (a strong next-step predictor for decode — routing
    is temporally correlated); prefetched bytes overlap with compute up to
    ``compute_s × pcie`` bytes per step, the rest spills into the stall.
    """

    name = "offload"

    STAT_EXTRAS = ("hits", "misses")

    def __init__(self, ocfg: Optional[OffloadConfig] = None):
        super().__init__()
        self.ocfg = ocfg if ocfg is not None else OffloadConfig()
        # The transfer-cost model is the residency ladder's FetchModel —
        # the offload baseline and DynaExq's host tier price host↔device
        # bytes identically, so their stall columns are comparable.
        self.fetch = FetchModel(gbps=self.ocfg.pcie_gbps)
        self.expert_bytes = 0
        self.n_moe_layers = 0
        self.lru: Dict[int, LRUSet] = {}
        self.prev_active: Dict[int, set] = {}
        self._acct = {"hits": 0, "misses": 0, "stall_s": 0.0,
                      "bytes_moved": 0}

    def _materialize(self, cfg, params, kv_bytes):
        # Per-expert bf16 bytes (w_gate + w_up + w_down).
        self.expert_bytes = 3 * cfg.d_model * cfg.moe.d_ff_expert * 2
        self.n_moe_layers = len(self.moe_positions) * cfg.n_superblocks()
        self.lru = {l: LRUSet(self.ocfg.cache_experts_per_layer)
                    for l in range(self.n_moe_layers)}
        self.prev_active = {l: set() for l in range(self.n_moe_layers)}
        return None        # computes dense; residency is modeled

    def _observe_residency(self, counts, compute_s):
        activated: Dict[int, np.ndarray] = {}
        li = 0
        for pos in self.moe_positions:
            c = np.asarray(counts[str(pos)])       # (nsb, E)
            for sbi in range(c.shape[0]):
                activated[li] = np.nonzero(c[sbi] > 0)[0]
                li += 1
        miss_bytes = 0
        prefetched_bytes = 0
        for l, acts in activated.items():
            lru = self.lru[l]
            if self.ocfg.prefetch:
                for e in self.prev_active[l]:
                    if e not in lru:
                        prefetched_bytes += self.expert_bytes
                    lru.touch(int(e))
            for e in acts:
                if lru.touch(int(e)):
                    self._acct["hits"] += 1
                else:
                    self._acct["misses"] += 1
                    miss_bytes += self.expert_bytes
            self.prev_active[l] = set(int(x) for x in acts)
        # Prefetches overlap with compute; anything beyond the overlap
        # window spills into the critical path with the demand misses.
        stall = self.fetch.stall_s(miss_bytes, prefetched_bytes, compute_s)
        self._acct["stall_s"] += stall
        self._acct["bytes_moved"] += miss_bytes + prefetched_bytes
        return stall

    def _tier_counts(self, cleaned):
        # Computes dense: every active cell streams full-precision rows.
        act = sum(int((c > 0).sum()) for c in cleaned.values())
        cells = sum(int(c.size) for c in cleaned.values())
        return act, 0, 0, cells

    def residency_mix(self) -> Dict[str, int]:
        hi = sum(len(lru) for lru in self.lru.values())
        E = self.cfg.moe.num_experts if self.cfg is not None and \
            self.cfg.moe is not None else 0
        total = self.n_moe_layers * E
        return {"hi": hi, "lo": 0, "host": max(0, total - hi)}

    def obs_meta(self) -> Dict[str, int]:
        return {"lo_bytes": 0, "hi_bytes": self.expert_bytes}

    def device_bytes(self) -> int:
        """Device-resident cache footprint under the offload budget."""
        return (self.n_moe_layers * self.ocfg.cache_experts_per_layer *
                self.expert_bytes)

    def _residency_stats(self):
        return {"stall_s": self._acct["stall_s"],
                "bytes_moved": float(self._acct["bytes_moved"]),
                "hits": float(self._acct["hits"]),
                "misses": float(self._acct["misses"]),
                "host_fetches": float(self._acct["misses"])}


BACKENDS = {
    "fp16": Fp16Backend,
    "static": StaticPTQBackend,
    "dynaexq": DynaExqBackend,
    "offload": OffloadBackend,
}


def make_backend(name: str, **kwargs) -> ResidencyBackend:
    """Registry factory: ``make_backend("dynaexq", n_hi_per_layer=2)``."""
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; "
                       f"one of {sorted(BACKENDS)}") from None
    return cls(**kwargs)
