"""Quickstart: build a reduced MoE, train it briefly, quantize it, and serve
it with the request-level InferenceEngine + a DynaExq residency backend.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.core import ControllerConfig
from repro.models import init_params
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           make_backend, make_prompts)
from repro.training import SyntheticLMTask, TrainConfig, train_loop
from repro.training.adamw import AdamWConfig


def main():
    # 1. A reduced Qwen3-MoE-family config (any of the ten assigned archs
    #    works: get_config("<arch-id>") for the full production config).
    cfg = get_config("granite-moe-1b-a400m", reduced=True)
    print(f"arch={cfg.name}  layers={cfg.n_layers}  experts/layer="
          f"{cfg.moe.num_experts} top-{cfg.moe.top_k}")

    # 2. Train a few steps on the synthetic LM task (real learned weights
    #    make the quality comparison meaningful).
    params = init_params(jax.random.PRNGKey(0), cfg)
    task = SyntheticLMTask(cfg.vocab_size, seed=0)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=2e-3, total_steps=60))
    params, _, _ = train_loop(cfg, params, task.batches(16, 65, 60), tcfg,
                              log_every=20)

    # 3. Serve with DynaExq: int4 lo tier always resident, a budget-limited
    #    bf16 hi pool, residency driven online by router traces. The backend
    #    is pluggable — swap "dynaexq" for "fp16", "static" or "offload" and
    #    the exact same engine loop runs that strategy instead.
    backend = make_backend("dynaexq", lo_bits=4, n_hi_per_layer=1,
                           controller=ControllerConfig(update_interval_s=0.0))
    engine = InferenceEngine(cfg, params, backend,
                             EngineConfig(max_slots=4, max_len=96))

    # 4. Request-level serving: submit → step/drain → handles. Requests are
    #    admitted into KV-cache slots as they free up (continuous batching).
    prompts = make_prompts("text", cfg.vocab_size, 4, 32)
    handles = [engine.submit(Request(tokens=prompts[i], max_new_tokens=8))
               for i in range(4)]
    engine.drain()
    engine.flush()
    st = engine.stats()
    print(f"generated {[len(h.tokens) for h in handles]} tokens/request  "
          f"TTFT={st['ttft_s']*1e3:.1f}ms  TPOT={st['tpot_s']*1e3:.1f}ms")
    print("hi-precision residency per layer:", backend.hi_sets()["0"])
    print("uniform serving stats:", {k: round(v, 4) for k, v in st.items()})


if __name__ == "__main__":
    main()
