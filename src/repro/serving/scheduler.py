"""SLO-tiered QoS scheduling: precision as a quality-of-service knob.

DynaExq treats precision as a budget-constrained runtime resource; this
module turns it into a per-request SERVICE level. Every request carries a
QoS class:

* ``premium`` — highest admission priority, decodes on the mixed-precision
  banks (hi tier + lo fallback) with speculative bursts when the engine's
  SpecDecoder is on, never shed, never downgraded, never preempted;
* ``standard`` — the default; mixed-precision decode, sheds to the lo tier
  only under explicit ``downgrade`` pressure policies;
* ``batch`` — throughput-tier work that decodes on the **all-lo banks**
  (the same ``slot_owner = -1`` derivation the speculative drafter uses, so
  no extra weights and no extra executables), yields the queue to higher
  tiers, and is the first work preempted or shed under overload.

The pieces, each consumed by the engine:

* ``TieredQueue`` — drop-in replacement for the engine's admission
  ``deque``: three per-class FIFOs popped by **weighted aging** — effective
  priority = class weight + time-in-queue / ``aging_s`` — so premium work
  jumps the line while aged batch work still drains (no starvation).
* ``SchedulerConfig`` / ``Scheduler`` — policy knobs + the pure decision
  logic: QoS resolution/validation, decode-group planning (which rows run
  on which banks this step), overload detection from the uniform stats
  (queue depth, TPOT EMA, budget headroom), shed/downgrade decisions, and
  preemption victim selection.
* ``SlotSnapshot`` — the host-side state of a preempted request: the valid
  KV lanes (paged) or cache rows (dense), recurrent (mamba) row state, and
  the decode position. Preemption genuinely frees HBM (the ``KVLease``
  closes, blocks return to the pool); resume re-admits through the normal
  admission path, adopting prefix-trie hits where the preempted blocks
  survived and re-uploading only the lanes that did not.

Nothing here touches device state: the scheduler is pure host-side policy,
the engine owns every forward.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

#: Valid QoS classes, lowest to highest service level.
QOS_CLASSES = ("batch", "standard", "premium")

#: Aging weights: effective priority = weight + age/aging_s. A batch
#: request older than ``aging_s * (QOS_WEIGHT[premium] - QOS_WEIGHT[batch])``
#: seconds outranks a fresh premium one — bounded starvation by design.
QOS_WEIGHT = {"batch": 0.0, "standard": 1.0, "premium": 2.0}

#: Rank for preemption/shedding comparisons (higher = more protected).
QOS_RANK = {q: i for i, q in enumerate(QOS_CLASSES)}

#: Benchmark workload tags → QoS classes: interactive code assistance is
#: latency-critical, bulk math scoring is throughput work, text is the
#: default tier. Opt-in (``RequestStream(qos="workload")`` and the SLO
#: benchmark); requests without an explicit class resolve to
#: ``SchedulerConfig.qos_default``, never through this map.
WORKLOAD_QOS = {"text": "standard", "math": "batch", "code": "premium"}

SHED_POLICIES = ("none", "downgrade", "reject")


@dataclasses.dataclass
class SchedulerConfig:
    """Policy knobs for SLO-tiered serving. The defaults reproduce the
    untiered engine exactly for all-default-class traffic: one FIFO order,
    every row decoding on the mixed banks, no shedding, no preemption
    unless a higher class is actually blocked behind a lower one."""
    qos_default: str = "standard"    # class for requests that carry none
    aging_s: float = 5.0             # seconds of queue age per priority unit
    # Which classes ride speculative bursts when EngineConfig.spec_k > 0.
    # Batch-tier drafting against itself would verify lo-vs-lo — pointless.
    spec_tiers: Tuple[str, ...] = ("standard", "premium")
    # ---- load shedding ------------------------------------------------
    # "none": admit everything. "downgrade": under overload, standard and
    # batch EXECUTE on the all-lo banks (service degrades, nothing drops).
    # "reject": under overload, batch-class submissions are refused
    # (RequestState.SHED) and standard-class ones are downgraded — premium
    # is never touched.
    shed_policy: str = "none"
    shed_queue_depth: int = 8        # queued requests that mean "overload"
    shed_wait_s: float = 2.0         # est. queue wait that means "overload"
    # HBM headroom fraction below which the engine counts as overloaded —
    # byte pressure on the shared envelope (KV blocks + expert hi tier) is
    # an overload signal even with an empty queue: admitting more work
    # would stall on block reclaim / defer every promotion. 0 disables.
    shed_headroom_frac: float = 0.05
    # Queued batch-tier requests whose deadline already passed are dropped
    # at admission time (state SHED) instead of burning decode steps.
    drop_expired_batch: bool = True
    # Residency build-out fraction below which the engine counts as
    # overloaded (streaming cold start: the ladder is still materializing,
    # so batch traffic sheds/downgrades instead of piling onto a queue the
    # engine cannot drain yet). 0 disables — a warm engine always reports
    # ready_frac 1.0, so the default changes nothing.
    shed_min_ready_frac: float = 0.0
    # ---- preemption ---------------------------------------------------
    preemption: bool = True          # evict lower tiers for blocked higher
    max_preempts: int = 2            # per-request eviction cap (liveness)
    # ---- chunked prefill ----------------------------------------------
    # Split prompts longer than this many tokens into chunk-sized suffix
    # prefills interleaved with decode steps (0 = off). Rounded DOWN to a
    # block-aligned bucket of the engine's existing ladder so chunk
    # prefills reuse the already-compiled bucket executables.
    prefill_chunk: int = 0

    def validate(self) -> None:
        if self.qos_default not in QOS_CLASSES:
            raise ValueError(
                f"qos_default={self.qos_default!r}; one of {QOS_CLASSES}")
        if self.shed_policy not in SHED_POLICIES:
            raise ValueError(
                f"shed_policy={self.shed_policy!r}; one of {SHED_POLICIES}")
        for t in self.spec_tiers:
            if t not in QOS_CLASSES:
                raise ValueError(
                    f"spec_tiers entry {t!r}; one of {QOS_CLASSES}")
        if self.aging_s <= 0:
            raise ValueError("aging_s must be > 0")
        if not 0.0 <= self.shed_headroom_frac < 1.0:
            raise ValueError(
                f"shed_headroom_frac={self.shed_headroom_frac} must be in "
                f"[0, 1)")
        if not 0.0 <= self.shed_min_ready_frac <= 1.0:
            raise ValueError(
                f"shed_min_ready_frac={self.shed_min_ready_frac} must be "
                f"in [0, 1]")
        if self.prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0")


def resolve_qos(qos: Optional[str], default: str) -> str:
    """Submit-time QoS resolution: ``None`` → the scheduler default;
    unknown classes fail loudly at the door, not mid-schedule."""
    q = default if qos is None else qos
    if q not in QOS_CLASSES:
        raise ValueError(f"unknown QoS class {q!r}; one of {QOS_CLASSES}")
    return q


class TieredQueue:
    """Priority admission queue with weighted aging.

    Deque-compatible where the engine needs it (``append`` / ``popleft`` /
    ``appendleft`` / ``extendleft`` / ``len`` / truthiness / iteration), but
    ``popleft`` returns the handle with the highest **effective priority**:
    its class weight plus its queue age in units of ``aging_s``. Within a
    class the order is strictly FIFO (each class is a real deque), so aging
    never reorders peers — it only decides *which class's head* goes next.

    Handles carry their own ``enqueue_s`` (set by the engine at submit and
    preserved across preempt/re-admit), so requeueing via ``appendleft`` /
    ``extendleft`` keeps original ages — a skipped or preempted request
    keeps climbing, it never resets to the back of the line.
    """

    def __init__(self, clock: Callable[[], float],
                 aging_s: float = 5.0):
        self._clock = clock
        self._aging_s = float(aging_s)
        self._tiers: Dict[str, deque] = {q: deque() for q in QOS_CLASSES}

    @staticmethod
    def _tier_of(handle) -> str:
        q = getattr(handle, "qos", None)
        return q if q in QOS_CLASSES else "standard"

    def append(self, handle) -> None:
        self._tiers[self._tier_of(handle)].append(handle)

    def appendleft(self, handle) -> None:
        self._tiers[self._tier_of(handle)].appendleft(handle)

    def extendleft(self, handles) -> None:
        for h in handles:
            self.appendleft(h)

    def _head_priority(self, q: str, now: float) -> Optional[float]:
        d = self._tiers[q]
        if not d:
            return None
        age = max(0.0, now - getattr(d[0], "enqueue_s", now))
        return QOS_WEIGHT[q] + age / self._aging_s

    def _best_tier(self) -> Optional[str]:
        now = self._clock()
        best, best_p = None, -np.inf
        # Iterate high→low so ties break toward the higher class.
        for q in reversed(QOS_CLASSES):
            p = self._head_priority(q, now)
            if p is not None and p > best_p:
                best, best_p = q, p
        return best

    def peek(self):
        """The handle ``popleft`` would return, without removing it."""
        q = self._best_tier()
        return self._tiers[q][0] if q is not None else None

    def popleft(self):
        q = self._best_tier()
        if q is None:
            raise IndexError("pop from an empty TieredQueue")
        return self._tiers[q].popleft()

    def prune(self, pred) -> List:
        """Remove and return every queued handle matching ``pred`` (used to
        drop expired batch-tier work without disturbing FIFO order)."""
        out: List = []
        for q, d in self._tiers.items():
            keep = deque()
            for h in d:
                (out if pred(h) else keep).append(h)
            self._tiers[q] = keep
        return out

    def depths(self) -> Dict[str, int]:
        """Per-QoS-class queue depth snapshot (metrics sampling)."""
        return {q: len(d) for q, d in self._tiers.items()}

    def __len__(self) -> int:
        return sum(len(d) for d in self._tiers.values())

    def __bool__(self) -> bool:
        return any(self._tiers.values())

    def __iter__(self):
        for q in reversed(QOS_CLASSES):
            yield from self._tiers[q]


@dataclasses.dataclass
class SlotSnapshot:
    """Host-side state of a preempted request — everything needed to resume
    bit-exactly without recompute. ``pos`` is the next decode position; the
    cached span is ``[span_start, pos)`` (full history for full attention,
    the last window for sliding-window rings).

    Paged mode stores per-position KV lanes (``attn_lanes[leaf]``:
    ``(1, n_span, nsb, Hkv, hd)`` — the `_gather_paged_lanes` layout);
    dense mode stores whole cache rows. Mamba rows are whole-state either
    way (recurrent state has no per-position structure)."""
    pos: int
    span_start: int
    attn_lanes: Optional[Dict[str, np.ndarray]] = None   # paged lanes
    attn_rows: Optional[Dict[str, np.ndarray]] = None    # dense rows
    mamba_rows: Optional[Dict[str, np.ndarray]] = None


class Scheduler:
    """Pure policy half of SLO-tiered serving (the engine owns all device
    state and every forward; this object only decides)."""

    def __init__(self, cfg: Optional[SchedulerConfig] = None):
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.cfg.validate()

    # -- QoS resolution -------------------------------------------------
    def resolve(self, qos: Optional[str]) -> str:
        return resolve_qos(qos, self.cfg.qos_default)

    # -- decode-group planning -----------------------------------------
    def decode_groups(self, active, spec_on: bool):
        """Partition the active ``(slot, handle)`` rows into per-step
        dispatch groups: ``[(kind, rows), ...]`` with kind ∈
        {"spec", "mixed", "lo"}. Higher tiers dispatch first (their tokens
        emit earlier within the step). One group — the common case when
        every row shares a tier — is exactly the untiered engine."""
        spec_rows, mixed_rows, lo_rows = [], [], []
        for i, h in active:
            tier = getattr(h, "exec_qos", "standard")
            if tier == "batch":
                lo_rows.append((i, h))
            elif spec_on and tier in self.cfg.spec_tiers:
                spec_rows.append((i, h))
            else:
                mixed_rows.append((i, h))
        groups = []
        if spec_rows:
            groups.append(("spec", spec_rows))
        if mixed_rows:
            groups.append(("mixed", mixed_rows))
        if lo_rows:
            groups.append(("lo", lo_rows))
        return groups

    # -- overload / shedding --------------------------------------------
    def overloaded(self, load: Dict[str, float]) -> bool:
        """Overload = the uniform stats say queued work cannot clear in
        time — queue depth past the knob, or estimated queue wait (queued
        decode tokens at the measured TPOT, spread over the slots) past the
        wait knob — OR the shared HBM envelope is nearly exhausted
        (``budget_headroom_frac`` below the headroom knob): byte pressure
        sheds even with an empty queue, since the next admission would
        stall on reclaim and every promotion already defers."""
        if load.get("queue_depth", 0.0) > self.cfg.shed_queue_depth:
            return True
        if load.get("est_wait_s", 0.0) > self.cfg.shed_wait_s:
            return True
        if self.cfg.shed_min_ready_frac and \
                load.get("residency_ready_frac", 1.0) < \
                self.cfg.shed_min_ready_frac:
            return True    # cold start: the ladder is still materializing
        return (load.get("budget_headroom_frac", 1.0) <
                self.cfg.shed_headroom_frac)

    def admit_action(self, qos: str, load: Dict[str, float]) -> str:
        """Submit-time decision: "admit", "downgrade" (execute on the lo
        tier) or "shed" (refuse). Premium is never touched."""
        if self.cfg.shed_policy == "none" or qos == "premium" or \
                not self.overloaded(load):
            return "admit"
        if self.cfg.shed_policy == "downgrade":
            return "downgrade"
        return "shed" if qos == "batch" else "downgrade"

    # -- preemption -----------------------------------------------------
    def pick_victim(self, running, head_qos: str):
        """Choose the running ``(slot, handle)`` to evict for a blocked
        higher-class head: strictly lower class only, lowest class first,
        most remaining work first (evicting nearly-done work wastes the
        most compute), preempt-count capped for liveness. None = nobody
        preemptible."""
        if not self.cfg.preemption:
            return None
        best, key = None, None
        for i, h in running:
            if QOS_RANK[h.qos] >= QOS_RANK[head_qos]:
                continue
            if getattr(h, "preempts", 0) >= self.cfg.max_preempts:
                continue
            rem = h.request.max_new_tokens - len(h.tokens)
            k = (QOS_RANK[h.qos], -rem)
            if key is None or k < key:
                best, key = (i, h), k
        return best
