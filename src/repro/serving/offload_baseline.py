"""ExpertFlow-like offloading/prefetch baseline (paper §5.3 comparator).

Experts live in host memory; the device keeps an LRU cache of ``cache_size``
experts per layer in bf16. Each step the router's activated set is compared
against the cache: misses must be fetched over PCIe *on the critical path*
(minus whatever an optimistic prefetcher overlapped), exactly the structural
cost the paper's Figure 1 measures. The transfer cost is a deterministic
model (bytes / pcie_gbps) layered on top of the measured compute time, so the
DynaExq-vs-offload comparison reflects transfer volume, not CPU noise.

Prefetch model: before each step the predictor prefetches the previous
step's activated set (a strong next-step predictor for decode — routing is
temporally correlated); prefetched bytes overlap with compute up to
``overlap_s × pcie`` bytes per step, the rest of the misses stall.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.models.config import ArchConfig
from repro.serving.engine import MoEServer, ServeConfig


@dataclasses.dataclass
class OffloadConfig:
    cache_experts_per_layer: int = 16
    pcie_gbps: float = 16.0          # PCIe gen4 x16 — matches the paper's A6000
    prefetch: bool = True


class _LRU:
    def __init__(self, size: int):
        self.size = size
        self.order: list[int] = []

    def touch(self, e: int) -> bool:
        """Returns True on hit."""
        hit = e in self.order
        if hit:
            self.order.remove(e)
        self.order.append(e)
        while len(self.order) > self.size:
            self.order.pop(0)
        return hit


class OffloadServer:
    """Wraps an fp16 engine; adds the residency/transfer accounting."""

    def __init__(self, cfg: ArchConfig, params: Dict, ocfg: OffloadConfig,
                 batch: int, max_len: int = 512, capacity_factor: float = 2.0):
        self.engine = MoEServer(
            cfg, params, ServeConfig(mode="fp16", max_len=max_len,
                                     capacity_factor=capacity_factor), batch)
        self.cfg = cfg
        self.ocfg = ocfg
        # Per-expert bf16 bytes (w_gate + w_up + w_down).
        m = cfg.moe
        self.expert_bytes = 3 * cfg.d_model * m.d_ff_expert * 2
        sb = cfg.superblock_or_default()
        self.moe_layers = []
        for pos, _ in enumerate(sb):
            if cfg.ffn_kind(pos) == "moe":
                self.moe_layers.append(pos)
        self.n_moe_layers = len(self.moe_layers) * cfg.n_superblocks()
        self.caches = {l: _LRU(ocfg.cache_experts_per_layer)
                       for l in range(self.n_moe_layers)}
        self.prev_active: dict[int, set] = {l: set() for l in range(self.n_moe_layers)}
        self.stats = {"hits": 0, "misses": 0, "stall_s": 0.0,
                      "bytes_fetched": 0}

    def _account(self, counts: Dict, compute_s: float) -> float:
        """Update caches from the activated sets; return modeled stall secs."""
        activated: dict[int, np.ndarray] = {}
        li = 0
        for pos in self.moe_layers:
            c = np.asarray(counts[str(pos)])       # (nsb, E)
            for sbi in range(c.shape[0]):
                activated[li] = np.nonzero(c[sbi] > 0)[0]
                li += 1
        miss_bytes = 0
        prefetched_bytes = 0
        for l, acts in activated.items():
            lru = self.caches[l]
            if self.ocfg.prefetch:
                for e in self.prev_active[l]:
                    if e not in lru.order:
                        prefetched_bytes += self.expert_bytes
                    lru.touch(int(e))
            for e in acts:
                if lru.touch(int(e)):
                    self.stats["hits"] += 1
                else:
                    self.stats["misses"] += 1
                    miss_bytes += self.expert_bytes
            self.prev_active[l] = set(int(x) for x in acts)
        pcie = self.ocfg.pcie_gbps * 1e9
        # Prefetches overlap with compute; anything beyond the overlap window
        # spills into the critical path together with demand misses.
        overlap_budget = compute_s * pcie
        spill = max(0.0, prefetched_bytes - overlap_budget)
        stall = (miss_bytes + spill) / pcie
        self.stats["stall_s"] += stall
        self.stats["bytes_fetched"] += miss_bytes + prefetched_bytes
        return stall

    # Engine-compatible API (returns latency incl. modeled stall) --------
    def start(self, batch: Dict):
        logits, dt = self.engine.start(batch)
        counts = self._last_counts()
        stall = self._account(counts, dt)
        return logits, dt + stall

    def step(self, tokens):
        logits, dt = self.engine.step(tokens)
        counts = self._last_counts()
        stall = self._account(counts, dt)
        return logits, dt + stall

    def _last_counts(self):
        return self.engine._counts_last

    def generate(self, batch: Dict, n_tokens: int):
        import jax.numpy as jnp
        logits, ttft = self.start(batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out, times = [tok], []
        for _ in range(n_tokens - 1):
            logits, dt = self.step(tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(tok)
            times.append(dt)
        return jnp.stack(out, 1), ttft, times
