"""Offline per-expert quantization-sensitivity scores.

The global allocator ranks (layer, expert) cells by ``hotness ×
sensitivity``: an expert whose weights survive int4/int2 nearly unchanged
can serve hot traffic from the lo tier, while a fragile one earns a hi slot
at lower traffic. Sensitivity is measured offline (one pass over the
checkpoint, no calibration data needed for the default):

* **weight-space** (default): relative Frobenius quantization error
  ``‖W − dq(q(W))‖_F / ‖W‖_F`` per (layer, expert), averaged over the
  expert's projection matrices. Cheap, deterministic, data-free.
* **activation-aware** (``probes > 0``): the same ratio measured through
  random probe activations ``‖x(W − Ŵ)‖_F / ‖xW‖_F`` — weights that only
  err in rarely-excited directions stop looking fragile.

Scores are consumed *normalized to unit mean* (``normalize``), so they bend
the hotness ranking without rescaling the budget currency, and persist via
``save_sensitivity``/``load_sensitivity`` (one ``.npz``, a key per MoE
position) so serving never recomputes them.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.qtensor import quantize


def expert_sensitivity(experts: Dict[str, jax.Array], lo_bits: int,
                       group_size: int = 64, probes: int = 0,
                       seed: int = 0) -> np.ndarray:
    """(L, E) sensitivity of one MoE stack's experts to the lo-tier
    quantizer. ``experts``: name → (L, E, K, N) dense weights."""
    per_name = []
    key = jax.random.PRNGKey(seed)
    for name in sorted(experts):
        w = jnp.asarray(experts[name], jnp.float32)
        err = w - quantize(w, bits=lo_bits,
                           group_size=group_size).dequantize(jnp.float32)
        if probes > 0:
            key, sub = jax.random.split(key)
            x = jax.random.normal(sub, (probes, w.shape[-2]), jnp.float32)
            w = jnp.einsum("pk,lekn->lepn", x, w)
            err = jnp.einsum("pk,lekn->lepn", x, err)
        num = jnp.sqrt(jnp.sum(err * err, axis=(-2, -1)))
        den = jnp.sqrt(jnp.sum(w * w, axis=(-2, -1)))
        per_name.append(np.asarray(num / jnp.maximum(den, 1e-12)))
    return np.mean(np.stack(per_name, 0), axis=0)


def normalize(sens: np.ndarray) -> np.ndarray:
    """Unit-mean scores: sensitivity bends the hotness ranking, it must not
    rescale the shared budget currency (all-equal scores are a no-op)."""
    s = np.asarray(sens, np.float64)
    m = s.mean()
    if not np.isfinite(m) or m <= 0:
        return np.ones_like(s)
    return s / m


def model_sensitivity(params: Dict, moe_positions, lo_bits: int,
                      group_size: int = 64, probes: int = 0,
                      seed: int = 0) -> Dict[str, np.ndarray]:
    """Sensitivity for every MoE position of a params tree: position key →
    (L, E) raw scores (normalize at the point of use)."""
    out: Dict[str, np.ndarray] = {}
    for pos in moe_positions:
        experts = params["blocks"][str(pos)]["moe"]["experts"]
        if experts is None:
            raise ValueError(
                f"position {pos}: experts already freed — run the "
                f"sensitivity pass before bank materialization")
        out[str(pos)] = expert_sensitivity(
            experts, lo_bits, group_size=group_size, probes=probes,
            seed=seed)
    return out


def save_sensitivity(path: str, sens_by_pos: Dict[str, np.ndarray]) -> None:
    np.savez(path, **{f"pos_{k}": np.asarray(v, np.float64)
                      for k, v in sens_by_pos.items()})


def load_sensitivity(path: str) -> Dict[str, np.ndarray]:
    with np.load(path) as z:
        return {k[len("pos_"):]: z[k] for k in z.files
                if k.startswith("pos_")}
