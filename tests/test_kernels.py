"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU): shape/dtype/
bit-width sweeps per the deliverable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (flash_decode_op, flash_decode_paged_op,
                               grouped_quant_matmul_op, quant_matmul_op)
from repro.quant import quantize


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (256, 512, 256),
                                   (128, 1024, 384)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_quant_matmul_sweep(bits, m, k, n, dtype):
    key = jax.random.PRNGKey(m + k + n + bits)
    x = jax.random.normal(key, (m, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    qt = quantize(w, bits=bits, group_size=64)
    out = quant_matmul_op(x, qt, bm=128, bn=128, bk=256)
    want = ref.quant_matmul_ref(x, qt.packed, qt.scales, bits, 64)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=4e-2, atol=4e-1)


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("e,c,k,n", [(4, 128, 256, 128), (8, 256, 128, 256)])
def test_grouped_quant_matmul_sweep(bits, e, c, k, n):
    key = jax.random.PRNGKey(e + c + bits)
    xg = jax.random.normal(key, (e, c, k), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(2), (e, k, n), jnp.float32)
    qt = quantize(w, bits=bits, group_size=64)
    out = grouped_quant_matmul_op(xg, qt, bm=128, bn=128, bk=128)
    want = ref.grouped_quant_matmul_ref(xg, qt.packed, qt.scales, bits, 64)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=4e-2, atol=4e-1)


@pytest.mark.parametrize("h,hkv", [(8, 8), (8, 2), (4, 1)])
@pytest.mark.parametrize("s,bs", [(1024, 256), (2048, 512)])
def test_flash_decode_sweep(h, hkv, s, bs):
    B, hd = 2, 64
    key = jax.random.PRNGKey(h * s)
    q = jax.random.normal(key, (B, h, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(3), (B, s, hkv, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(4), (B, s, hkv, hd), jnp.bfloat16)
    # ragged validity incl. one very short row (stresses the -inf guards)
    valid = jnp.arange(s)[None, :] < jnp.array([[17], [s]])
    out = flash_decode_op(q, k, v, valid, bs=bs)
    want = ref.flash_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_decode_matches_model_attention():
    """Kernel semantics == the model's decode attention (full cache)."""
    from repro.models.config import AttnConfig
    from repro.models import layers as L
    cfg = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=64, use_rope=False)
    key = jax.random.PRNGKey(0)
    p = L.init_attention(key, 128, cfg)
    B, S, pos = 2, 256, 100
    cache = L.init_kv_cache(B, S, cfg)
    ks = jax.random.normal(key, (B, 2, S, 64), jnp.bfloat16)
    vs = jax.random.normal(jax.random.PRNGKey(1), (B, 2, S, 64), jnp.bfloat16)
    cache = L.KVCache(ks, vs)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, 1, 128), jnp.bfloat16)
    out_model, cache2 = L.attention_decode(p, cfg, x, jnp.int32(pos), cache)
    # reproduce with the kernel: q from the same projection path; the kernel
    # takes the seq-major (B, S, Hkv, hd) layout
    q = (x @ p["wq"]).reshape(B, 1, 4, 64)[:, 0]
    valid = (jnp.arange(S)[None, :] <= pos) * jnp.ones((B, 1), bool)
    out_kernel = flash_decode_op(q, cache2.k.transpose(0, 2, 1, 3),
                                 cache2.v.transpose(0, 2, 1, 3), valid, bs=64)
    want = out_kernel.reshape(B, 1, 256) @ p["wo"]
    np.testing.assert_allclose(np.asarray(out_model, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-1)


@pytest.mark.parametrize("h,hkv,bt,nb", [(4, 2, 16, 4), (8, 2, 32, 3),
                                         (4, 4, 64, 2)])
def test_flash_decode_paged_matches_gathered_dense(h, hkv, bt, nb):
    """Block-table flash decode == dense flash decode over the logical view
    the (scrambled, partially unallocated) tables gather — and it consumes
    the ``PagedKVCache`` (N, Hkv, bt, hd) pool layout directly, matching
    ``layers.paged_view``."""
    from repro.models import layers as L
    from repro.models.config import AttnConfig

    B, hd = 2, 64
    S = nb * bt
    N = 1 + B * nb                      # trash block + B full tables
    acfg = AttnConfig(n_heads=h, n_kv_heads=hkv, head_dim=hd)
    pool = L.init_paged_kv_cache(N, bt, acfg)
    kp = jax.random.normal(jax.random.PRNGKey(1), pool.k.shape, jnp.bfloat16)
    vp = jax.random.normal(jax.random.PRNGKey(2), pool.v.shape, jnp.bfloat16)
    pool = L.PagedKVCache(kp, vp)
    q = jax.random.normal(jax.random.PRNGKey(h * bt + nb), (B, h, hd),
                          jnp.bfloat16)
    rng = np.random.default_rng(0)
    table = rng.permutation(np.arange(1, N, dtype=np.int32)).reshape(B, nb)
    table[0, -1] = -1                   # one unallocated logical block
    valid = np.zeros((B, S), bool)
    valid[0, :S - bt - 3] = True        # stays clear of the -1 block
    valid[1, :S - 1] = True
    # dense reference over the same logical view the model gathers
    k_log, v_log = L.paged_view(pool, jnp.asarray(table))  # (B,Hkv,S,hd)
    want = flash_decode_op(q, jnp.moveaxis(k_log, 1, 2),
                           jnp.moveaxis(v_log, 1, 2),
                           jnp.asarray(valid), bs=bt)
    out = flash_decode_paged_op(q, pool.k, pool.v, jnp.asarray(table),
                                jnp.asarray(valid))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_quant_matmul_rejects_bad_tiling():
    x = jnp.ones((100, 256), jnp.bfloat16)
    w = jnp.ones((256, 128), jnp.float32)
    qt = quantize(w, bits=4, group_size=64)
    with pytest.raises(ValueError):
        quant_matmul_op(x, qt, bm=64)  # 100 % 64 != 0
