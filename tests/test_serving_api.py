"""Unified serving API: ResidencyBackend protocol conformance, continuous
batching (slot reuse / mid-stream admission), the generate() compat shim,
arrival-timed replay, the OrderedDict LRU, and the public transition
accessors. Engines come from the shared ``engine_factory`` fixture."""
import jax.numpy as jnp
import numpy as np

from repro.core import (BudgetTracker, DynaExqController, TransitionManager,
                        build_bank, expert_hi_nbytes)
from repro.serving import (BACKENDS, LRUSet, Request, RequestState,
                           RequestStream, ResidencyBackend, STAT_KEYS,
                           make_prompts)


# ---------------------------------------------------------------------------
# Backend protocol / parity
# ---------------------------------------------------------------------------

def test_backend_parity_shapes_and_footprint(serving_setup, engine_factory):
    """All backends produce the same-shaped greedy output through the SAME
    engine loop; device_bytes orders static < dynaexq < fp16."""
    cfg, _ = serving_setup
    toks = np.asarray(make_prompts("text", cfg.vocab_size, 3, 20))
    bytes_by = {}
    for name in ("fp16", "static", "dynaexq", "offload"):
        eng = engine_factory(name, max_slots=3)
        assert isinstance(eng.backend, ResidencyBackend)
        out, ttft, times = eng.generate({"tokens": toks}, 4)
        eng.flush()
        assert out.shape == (3, 4)
        assert out.dtype == jnp.int32
        assert ttft > 0 and len(times) == 3
        bytes_by[name] = eng.device_bytes()
    assert bytes_by["static"] < bytes_by["dynaexq"] < bytes_by["fp16"]


def test_stats_schema_uniform(serving_setup, engine_factory):
    """Every backend's stats() carries the full uniform key set (zeros where
    the concept does not apply)."""
    cfg, _ = serving_setup
    toks = np.asarray(make_prompts("text", cfg.vocab_size, 2, 12))
    for name in BACKENDS:
        eng = engine_factory(name, max_slots=2)
        eng.generate({"tokens": toks}, 3)
        st = eng.backend.stats()
        assert set(STAT_KEYS) <= set(st), (name, st)
        assert st["ttft_s"] > 0 and st["tpot_s"] > 0
        if name in ("fp16", "static"):
            assert st["stall_s"] == 0 and st["bytes_moved"] == 0
            assert st["promotions"] == 0 and st["demotions"] == 0
        if name == "offload":
            assert st["promotions"] == 0 and st["demotions"] == 0


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

def test_slot_reuse_mid_stream(serving_setup, engine_factory):
    """A queued request is admitted into a freed slot while another request
    is still mid-decode — the continuous-batching property."""
    cfg, _ = serving_setup
    eng = engine_factory("static", max_slots=2)
    p = [make_prompts("text", cfg.vocab_size, 1, ln, seed=s)[0]
         for s, ln in enumerate((10, 14, 12))]
    short = eng.submit(Request(tokens=p[0], max_new_tokens=3))
    long = eng.submit(Request(tokens=p[1], max_new_tokens=7))
    waiting = eng.submit(Request(tokens=p[2], max_new_tokens=3))

    eng.step()                       # admits short+long; both decode once
    assert short.state == RequestState.RUNNING
    assert waiting.state == RequestState.QUEUED   # no free slot yet
    eng.step()                       # short finishes (3 tokens), frees slot
    assert short.state == RequestState.FINISHED
    eng.step()                       # waiting admitted into the freed slot
    assert waiting.state == RequestState.RUNNING
    assert waiting.slot == short.slot             # literally the same slot
    assert long.state == RequestState.RUNNING     # still mid-stream

    done = eng.drain()
    assert {h.id for h in done} == {long.id, waiting.id}
    for h in (short, long, waiting):
        assert h.state == RequestState.FINISHED
        assert len(h.tokens) == h.request.max_new_tokens
        assert h.ttft_s > 0 and not np.isnan(h.token_array()).any()


def test_variable_length_prompts_same_engine(serving_setup, engine_factory):
    cfg, _ = serving_setup
    eng = engine_factory("dynaexq", max_slots=3)
    handles = [eng.submit(Request(
        tokens=make_prompts("math", cfg.vocab_size, 1, ln, seed=ln)[0],
        max_new_tokens=3)) for ln in (6, 17, 11)]
    eng.drain()
    eng.flush()
    assert all(len(h.tokens) == 3 for h in handles)


def test_continuous_batching_matches_solo_decode(serving_setup,
                                                 engine_factory):
    """Reference parity for the per-slot position vectorization: requests
    served through staggered continuous batching (mixed lengths, slot reuse
    mid-stream) produce token-for-token the same greedy output as each
    request decoded alone in a batch-1 engine."""
    cfg, _ = serving_setup
    prompts = [make_prompts("text", cfg.vocab_size, 1, ln, seed=ln)[0]
               for ln in (9, 13, 11)]
    eng = engine_factory("fp16", max_slots=2)
    handles = [eng.submit(Request(tokens=p, max_new_tokens=n))
               for p, n in zip(prompts, (3, 6, 4))]
    eng.drain()
    for p, h in zip(prompts, handles):
        solo = engine_factory("fp16", max_slots=1, max_len=64)
        ref = solo.submit(Request(tokens=p,
                                  max_new_tokens=h.request.max_new_tokens))
        solo.drain()
        assert ref.tokens == h.tokens, (ref.tokens, h.tokens)


def test_generate_shim_matches_submit_step(serving_setup, engine_factory):
    """The whole-batch generate() compat shim is token-for-token identical
    to driving submit + step + drain by hand."""
    cfg, _ = serving_setup
    toks = np.asarray(make_prompts("code", cfg.vocab_size, 3, 16))
    n_new = 5

    eng_a = engine_factory("static", max_slots=3)
    out_a, _, _ = eng_a.generate({"tokens": toks}, n_new)

    eng_b = engine_factory("static", max_slots=3)
    handles = [eng_b.submit(Request(tokens=toks[i], max_new_tokens=n_new))
               for i in range(3)]
    while eng_b.queue or any(s is not None for s in eng_b.slots):
        eng_b.step()
    out_b = np.stack([h.token_array() for h in handles], 0)

    np.testing.assert_array_equal(np.asarray(out_a), out_b)


def test_request_stream_replay(serving_setup, engine_factory):
    """RequestStream arrival times are consumed by engine.replay(): requests
    enter in arrival order and every handle completes."""
    cfg, _ = serving_setup
    stream = RequestStream(cfg.vocab_size,
                           phases=[("text", 2), ("math", 2)],
                           prompt_len=10, prompt_len_jitter=3,
                           max_new_tokens=2, arrival_rate_rps=200.0, seed=3)
    reqs = list(stream)
    assert len(reqs) == len(stream) == 4
    assert [r.workload for r in reqs] == ["text", "text", "math", "math"]
    arrivals = [r.arrival_s for r in reqs]
    assert arrivals == sorted(arrivals) and arrivals[-1] > 0
    eng = engine_factory("fp16", max_slots=2)
    handles = eng.replay(stream)
    assert [h.request.arrival_s for h in handles] == arrivals
    assert all(h.state == RequestState.FINISHED for h in handles)
    assert all(len(h.tokens) == 2 for h in handles)
    # the engine saw router traffic for every request (counts accumulated)
    assert eng.backend.router_counts()["0"].sum() > 0


# ---------------------------------------------------------------------------
# Satellites: LRU, controller config sharing, public transition accessor
# ---------------------------------------------------------------------------

def test_lru_hit_and_evict_order():
    lru = LRUSet(3)
    assert not lru.touch(1) and not lru.touch(2) and not lru.touch(3)
    assert lru.order() == [1, 2, 3]
    assert lru.touch(1)                  # hit refreshes recency
    assert lru.order() == [2, 3, 1]
    assert not lru.touch(4)              # evicts LRU entry: 2
    assert lru.order() == [3, 1, 4]
    assert 2 not in lru and 1 in lru and len(lru) == 3
    assert lru.hit(3) and lru.order() == [1, 4, 3]
    lru.add(5)                           # explicit insert evicts 1
    assert lru.order() == [4, 3, 5]
    warm = LRUSet(2, init=[7, 8, 9])
    assert warm.order() == [8, 9]


def _mini_bank(L=2, E=4, n_hi=2):
    w = {n: jnp.ones((L, E, 8, 8), jnp.bfloat16)
         for n in ("w_gate", "w_up", "w_down")}
    bank = build_bank(w, n_hi=n_hi, lo_bits=4, group_size=8)
    host = {n: np.asarray(v) for n, v in w.items()}
    hi_b = expert_hi_nbytes({n: tuple(v.shape) for n, v in w.items()})
    return bank, host, hi_b


def test_controller_configs_not_shared():
    """Regression: a dataclass-instance default arg would be one shared
    (mutable) config across all controllers."""
    (b1, h1, hb), (b2, h2, _) = _mini_bank(), _mini_bank()
    c1 = DynaExqController(b1, h1, n_hi_per_layer=2, hi_bytes_per_expert=hb)
    c2 = DynaExqController(b2, h2, n_hi_per_layer=2, hi_bytes_per_expert=hb)
    assert c1.cfg is not c2.cfg
    c1.cfg.update_interval_s = 123.0
    assert c2.cfg.update_interval_s != 123.0


def test_pending_experts_public_accessor():
    bank, host, hi_b = _mini_bank()
    tm = TransitionManager(bank, host, BudgetTracker(4 * hi_b), hi_b)
    tm.request_promotion(0, 1)
    tm.request_promotion(1, 3)
    tm.drain()                            # issue copies, not yet published
    assert tm.pending_experts(0) == {1}
    assert tm.pending_experts(1) == {3}
    tm.publish_ready(wait=True)
    assert tm.pending_experts(0) == set()
    assert tm.hi_set(0) == {1} and tm.hi_set(1) == {3}
    tm.check_invariants()
