"""Assigned input shapes + abstract (ShapeDtypeStruct) input builders.

``build_dryrun`` assembles, for one (arch × shape × mesh): the step function
to lower (train_step / prefill_step / serve_step), the abstract inputs (no
device allocation — the shannon/kernels input_specs pattern), and the
sharding tree from the planner. The dry-run and the roofline both consume it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.ver import build_bank
from repro.launch.sharding import ShardingPlanner
from repro.models import (decode_step, forward_train, init_caches,
                          init_params, prefill)
from repro.models.config import ArchConfig
from repro.training.adamw import adamw_init
from repro.training.train import TrainConfig, make_train_step

SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k":    dict(kind="train",   seq=4096,    batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768,   batch=32),
    "decode_32k":  dict(kind="decode",  seq=32768,   batch=128),
    "long_500k":   dict(kind="decode",  seq=524288,  batch=1, long=True),
}

LONG_SWA_WINDOW = 8192

# whisper-tiny: enc-dec ASR with a 448-token decoder context — 500k-token
# decode is meaningless for the family (DESIGN.md §5).
SKIPS = {("whisper-tiny", "long_500k"): "enc-dec ASR: no 500k decode context"}


def arch_for_shape(arch: str, shape: str) -> ArchConfig:
    """Shape-specific config variant: long_500k forces sub-quadratic
    attention (SWA window 8192) on full-attention archs."""
    cfg = get_config(arch)
    if SHAPES[shape].get("long") and cfg.attn is not None \
            and cfg.attn.sliding_window is None:
        cfg = dataclasses.replace(
            cfg, attn=dataclasses.replace(cfg.attn,
                                          sliding_window=LONG_SWA_WINDOW))
    return cfg


def _token_inputs(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    text = seq
    if cfg.family == "vlm":
        text = seq - cfg.num_image_tokens
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        out["audio_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    out["tokens"] = jax.ShapeDtypeStruct((batch, text), jnp.int32)
    return out


@dataclasses.dataclass
class DryrunSpec:
    name: str
    step_fn: Callable
    args: tuple                       # abstract args, ShapeDtypeStructs
    in_shardings: tuple
    donate_argnums: tuple
    cfg: ArchConfig
    kind: str
    tokens_per_step: int
    notes: list


def _abstract(fn, *a, **k):
    return jax.eval_shape(fn, *a, **k)


def build_dryrun(arch: str, shape: str, mesh, *, lo_bits: int = 4,
                 n_hi: Optional[int] = None, planner_kw: Optional[dict] = None,
                 capacity_factor: float = 1.25,
                 nsb_override: Optional[int] = None,
                 microbatches: int = 1) -> DryrunSpec:
    """``nsb_override``: reduce the stack to N super-blocks (the roofline's
    two-point loop-cost extrapolation compiles nsb=2 and nsb=4 variants —
    XLA's cost_analysis counts while-loop bodies once, so per-layer costs are
    recovered by differencing)."""
    if (arch, shape) in SKIPS:
        raise ValueError(f"skip {arch}×{shape}: {SKIPS[(arch, shape)]}")
    info = SHAPES[shape]
    cfg = arch_for_shape(arch, shape)
    if nsb_override is not None:
        sb_len = len(cfg.superblock_or_default())
        cfg = dataclasses.replace(
            cfg, n_layers=sb_len * nsb_override,
            n_encoder_layers=min(cfg.n_encoder_layers, nsb_override)
            if cfg.is_encoder_decoder else 0)
    notes: list = []
    pkw = dict(planner_kw or {})
    if info["kind"] == "train":
        pkw.setdefault("fsdp", True)
    planner = ShardingPlanner(cfg, mesh, notes=notes, **pkw)

    # Distribution context for the shard_map MoE dispatch.
    from repro.launch.dist import DistContext, dist_ctx
    dctx = DistContext(
        mesh=mesh,
        dp_axes=tuple(a for a in mesh.axis_names if a != "model"),
        tokens_dp_sharded=(info["batch"] % planner.dp_n == 0))

    def with_ctx(fn):
        def wrapped(*a, **k):
            with dist_ctx(dctx):
                return fn(*a, **k)
        return wrapped

    key = jax.random.PRNGKey(0)
    params_abs = _abstract(lambda k: init_params(k, cfg), key)
    params_sh = planner.tree_shardings(params_abs, "param")

    batch, seq = info["batch"], info["seq"]

    if info["kind"] == "train":
        tcfg = TrainConfig(capacity_factor=capacity_factor,
                           microbatches=microbatches)
        step = make_train_step(cfg, tcfg)
        opt_abs = _abstract(adamw_init, params_abs)
        opt_sh = planner.tree_shardings(opt_abs, "param")
        batch_abs = _token_inputs(cfg, batch, seq)
        batch_abs["labels"] = jax.ShapeDtypeStruct(
            batch_abs["tokens"].shape, jnp.int32)
        batch_sh = planner.tree_shardings(batch_abs, "input")
        return DryrunSpec(
            name=f"{arch}×{shape}", step_fn=with_ctx(step),
            args=(params_abs, opt_abs, batch_abs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            donate_argnums=(0, 1), cfg=cfg, kind="train",
            tokens_per_step=batch * seq, notes=notes)

    # ---- serving shapes -------------------------------------------------
    bank_abs = None
    if cfg.is_moe:
        sb = cfg.superblock_or_default()
        banks = {}
        for pos, _ in enumerate(sb):
            if cfg.ffn_kind(pos) != "moe":
                continue
            E = cfg.moe.num_experts
            # Per-shard budget semantics (DESIGN §2): each model-parallel
            # rank owns E/16 experts and an integer number of hi slots, so
            # the global n_hi is a multiple of the model axis — replicating
            # the hi pool costs ~GBs/device on coarse-expert archs (jamba).
            mn = mesh.shape["model"]
            nh = n_hi if n_hi is not None else min(E, max(mn, E // 8))
            nsb = cfg.n_superblocks()
            ew = {
                "w_gate": jax.ShapeDtypeStruct(
                    (nsb, E, cfg.d_model, cfg.moe.d_ff_expert), jnp.bfloat16),
                "w_up": jax.ShapeDtypeStruct(
                    (nsb, E, cfg.d_model, cfg.moe.d_ff_expert), jnp.bfloat16),
                "w_down": jax.ShapeDtypeStruct(
                    (nsb, E, cfg.moe.d_ff_expert, cfg.d_model), jnp.bfloat16),
            }
            banks[str(pos)] = _abstract(
                lambda w: build_bank(w, n_hi=nh, lo_bits=lo_bits), ew)
        bank_abs = banks
        # Serving never carries the dense experts — drop them (VER owns
        # residency), mirroring the quantized backends' materialize_banks.
        params_abs = jax.eval_shape(lambda p: _strip_experts(p, cfg), params_abs)
        params_sh = planner.tree_shardings(params_abs, "param")
    bank_sh = planner.tree_shardings(bank_abs, "param") if bank_abs else None

    cache_len = seq
    if cfg.attn is not None and cfg.attn.sliding_window is not None:
        cache_len = seq  # init_caches clamps per-position to the window
    caches_abs = _abstract(lambda: init_caches(cfg, batch, cache_len))
    caches_sh = planner.tree_shardings(caches_abs, "cache")

    if info["kind"] == "prefill":
        batch_abs = _token_inputs(cfg, batch, seq)
        batch_sh = planner.tree_shardings(batch_abs, "input")

        def prefill_step(params, bank, b, caches):
            return prefill(params, cfg, b, caches, bank=bank,
                           capacity_factor=capacity_factor)

        return DryrunSpec(
            name=f"{arch}×{shape}", step_fn=with_ctx(prefill_step),
            args=(params_abs, bank_abs, batch_abs, caches_abs),
            in_shardings=(params_sh, bank_sh, batch_sh, caches_sh),
            donate_argnums=(3,), cfg=cfg, kind="prefill",
            tokens_per_step=batch * seq, notes=notes)

    # decode
    tok_abs = jax.ShapeDtypeStruct((batch,), jnp.int32)
    tok_sh = planner.tree_shardings(tok_abs, "input")
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())

    def serve_step(params, bank, token, pos, caches):
        return decode_step(params, cfg, token, pos, caches, bank=bank,
                           capacity_factor=2.0)

    return DryrunSpec(
        name=f"{arch}×{shape}", step_fn=with_ctx(serve_step),
        args=(params_abs, bank_abs, tok_abs, pos_abs, caches_abs),
        in_shardings=(params_sh, bank_sh, tok_sh, pos_sh, caches_sh),
        donate_argnums=(4,), cfg=cfg, kind="decode",
        tokens_per_step=batch, notes=notes)


def _strip_experts(params, cfg: ArchConfig):
    sb = cfg.superblock_or_default()
    for pos, _ in enumerate(sb):
        if cfg.ffn_kind(pos) == "moe":
            params["blocks"][str(pos)]["moe"]["experts"] = None
    return params
