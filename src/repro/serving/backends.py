"""Pluggable expert-residency backends for the serving engine.

The paper's DynaExq controller is one point in a family of budget-constrained
residency strategies (static PTQ, offloading/prefetch, dense fp16). Each
strategy is a ``ResidencyBackend``: the engine owns requests, caches and the
jitted forward closures; the backend owns *where expert weights live* and
what moving them costs. All four backends run through literally the same
``InferenceEngine.step()`` loop, so the DynaExq-vs-offload comparison is
structural, not an artifact of two different serving loops.

Protocol (one backend instance per engine):

* ``materialize_banks(cfg, params, kv_bytes, budget=None)`` — build the
  device-resident weight tiers; returns the per-MoE-position bank mapping
  the engine passes into the jitted forward (``None`` ⇒ dense bf16 experts
  from ``params``). ``kv_bytes`` is the KV pool's own accounting (the
  engine's block math — no backend re-derives KV sizes); ``budget`` is the
  engine's shared ``BudgetTracker``: residency strategies that gate byte
  movement (DynaExq's hi tier) reserve through account-scoped views of it,
  so expert promotions and KV block admission contend for ONE HBM envelope.
* ``observe(counts, compute_s, prefill, row_valid)`` — per-forward
  router-trace hook; returns modeled *stall seconds* to charge to the
  step's critical path (non-zero only for demand-fetch strategies like
  offloading). ``counts`` values are either pre-masked (L, E) aggregates or
  row-resolved (L, R, E) arrays, in which case ``row_valid`` ((R,) bool)
  masks vacant/padding rows before they reach hotness or residency
  accounting — no backend ever sees phantom traffic.
* ``tick()`` — window boundary: run policies, publish completed transitions.
* ``device_bytes()`` — resident expert bytes under this strategy's budget.
* ``stats()`` — uniform serving stats: ``{ttft_s, tpot_s, stall_s,
  bytes_moved, promotions, demotions}`` (zeros where N/A), plus
  backend-specific extras.
* ``flush()`` — barrier on in-flight transitions (shutdown / tests).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Iterable, Optional, Protocol, runtime_checkable

import jax
import numpy as np

from repro.core import (ControllerConfig, DynaExqController, build_bank,
                        expert_hi_nbytes, expert_lo_nbytes, plan_budget)
from repro.core.budget import BudgetTracker
from repro.core.controller import EPCoordinator, RebalanceConfig
from repro.core.hotness import mask_row_counts
from repro.models.config import ArchConfig

GiB = 1 << 30

#: Keys every backend's ``stats()`` must return (zeros where N/A). The
#: speculative-decoding meters (``accept_rate``/``draft_tokens``/
#: ``verified_tokens``/``spec_rounds``) are part of the uniform schema so
#: every benchmark row is machine-comparable whether or not speculation ran;
#: the engine overwrites them with live values when its SpecDecoder is on.
#: Likewise the MoE dispatch gauges: ``active_experts`` (mean experts with
#: ≥1 routed token per layer-step) and ``dispatch_pad_ratio`` (fraction of
#: expert-GEMM rows that were padding under the configured layout) — the
#: engine fills them from its per-forward router counts.
#: The QoS-scheduler meters (``preemptions``/``resumes``/``shed_requests``/
#: ``downgraded``) join the schema the same way: zeros from every backend,
#: overwritten by the engine's live scheduler counters.
STAT_KEYS = ("ttft_s", "tpot_s", "stall_s", "bytes_moved",
             "promotions", "demotions",
             "accept_rate", "draft_tokens", "verified_tokens", "spec_rounds",
             "active_experts", "dispatch_pad_ratio",
             "preemptions", "resumes", "shed_requests", "downgraded")


def _param_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "dtype"))


@runtime_checkable
class ResidencyBackend(Protocol):
    """Structural interface the engine programs against (no isinstance /
    mode-string branching anywhere in the serving loop)."""

    name: str

    def materialize_banks(self, cfg: ArchConfig, params: Dict,
                          kv_bytes: int, budget=None) -> Optional[Dict]: ...

    def observe(self, counts: Dict, compute_s: float = 0.0,
                prefill: bool = False,
                row_valid: Optional[np.ndarray] = None) -> float: ...

    def tick(self) -> None: ...

    def device_bytes(self) -> int: ...

    def stats(self) -> Dict[str, float]: ...

    def flush(self) -> None: ...


class LRUSet:
    """O(1) LRU set over expert ids (OrderedDict: ``move_to_end`` on hit,
    ``popitem(last=False)`` on eviction). Replaces the earlier O(n)
    list-based LRU in the offload path."""

    def __init__(self, size: int, init: Optional[Iterable[int]] = None):
        self.size = size
        self._od: OrderedDict[int, None] = OrderedDict()
        if init is not None:
            for e in init:
                self.add(int(e))

    def __contains__(self, e: int) -> bool:
        return e in self._od

    def __len__(self) -> int:
        return len(self._od)

    def hit(self, e: int) -> bool:
        """Refresh ``e`` if cached; returns whether it was a hit."""
        if e in self._od:
            self._od.move_to_end(e)
            return True
        return False

    def add(self, e: int) -> None:
        """Insert ``e`` as most-recent, evicting the LRU entry on overflow."""
        self._od[e] = None
        self._od.move_to_end(e)
        while len(self._od) > self.size:
            self._od.popitem(last=False)

    def touch(self, e: int) -> bool:
        """Hit-or-insert; returns True on hit (classic LRU access)."""
        if self.hit(e):
            return True
        self.add(e)
        return False

    def order(self) -> list[int]:
        """Entries LRU-first (introspection/tests)."""
        return list(self._od)


class _BackendBase:
    """Shared accounting: latency aggregation (TTFT/TPOT as observed by the
    engine) and router-count accumulation (the uniform hotness signal)."""

    name = "base"

    def __init__(self):
        self._ttft: list[float] = []
        self._tpot: list[float] = []
        self._counts_sum: Dict[str, np.ndarray] = {}
        self.cfg: Optional[ArchConfig] = None
        self.budget = None                  # engine's shared BudgetTracker
        self.moe_positions: list[int] = []

    # -- lifecycle -------------------------------------------------------
    def materialize_banks(self, cfg: ArchConfig, params: Dict,
                          kv_bytes: int, budget=None) -> Optional[Dict]:
        self.cfg = cfg
        self.budget = budget
        sb = cfg.superblock_or_default()
        self.moe_positions = [p for p, _ in enumerate(sb)
                              if cfg.ffn_kind(p) == "moe"] if cfg.is_moe \
            else []
        return self._materialize(cfg, params, kv_bytes)

    def _materialize(self, cfg: ArchConfig, params: Dict,
                     kv_bytes: int) -> Optional[Dict]:
        return None

    # -- per-forward hook ------------------------------------------------
    def observe(self, counts: Dict, compute_s: float = 0.0,
                prefill: bool = False,
                row_valid: Optional[np.ndarray] = None) -> float:
        """Accumulate one forward's router counts and run residency
        accounting. Values may be (L, E) aggregates (accumulated as-is) or
        row-resolved (L, R, E), in which case ``row_valid`` masks vacant/
        padding rows before the sum (``core.hotness.mask_row_counts`` — the
        one scrub rule every residency strategy shares)."""
        cleaned: Dict[str, np.ndarray] = {}
        for k, c in counts.items():
            c = mask_row_counts(c, row_valid)
            cleaned[k] = c
            acc = self._counts_sum.get(k)
            self._counts_sum[k] = c.copy() if acc is None else acc + c
        stall = self._observe_residency(cleaned, compute_s)
        (self._ttft if prefill else self._tpot).append(compute_s + stall)
        return stall

    def _observe_residency(self, counts: Dict, compute_s: float) -> float:
        return 0.0

    def tick(self) -> None:
        pass

    def flush(self) -> None:
        pass

    # -- introspection ---------------------------------------------------
    def router_counts(self) -> Dict[str, np.ndarray]:
        """Accumulated router-selection counts per MoE position, (L, E)."""
        return dict(self._counts_sum)

    def device_bytes(self) -> int:
        raise NotImplementedError

    def stats(self) -> Dict[str, float]:
        out = {k: 0.0 for k in STAT_KEYS}
        if self._ttft:
            out["ttft_s"] = float(np.mean(self._ttft))
        if self._tpot:
            out["tpot_s"] = float(np.mean(self._tpot))
        out.update(self._residency_stats())
        return out

    def _residency_stats(self) -> Dict[str, float]:
        return {}


class Fp16Backend(_BackendBase):
    """Dense bf16 experts, fully device-resident — the quality/latency
    reference (and the compute substrate the offload model prices)."""

    name = "fp16"

    def __init__(self):
        super().__init__()
        self._dense_bytes = 0

    def _materialize(self, cfg, params, kv_bytes):
        self._dense_bytes = sum(
            _param_bytes(params["blocks"][str(p)]["moe"]["experts"])
            for p in self.moe_positions)
        return None        # forward uses the dense experts in params

    def device_bytes(self) -> int:
        return self._dense_bytes


class StaticPTQBackend(_BackendBase):
    """Uniform static PTQ (the paper's static baseline): every expert serves
    from the always-resident lo tier; no hi pool, no transfers, ever."""

    name = "static"

    def __init__(self, lo_bits: int = 4, group_size: int = 64):
        super().__init__()
        self.lo_bits = lo_bits
        self.group_size = group_size
        self.banks: Dict = {}
        self._lo_bytes = 0

    def _materialize(self, cfg, params, kv_bytes):
        for pos in self.moe_positions:
            experts = params["blocks"][str(pos)]["moe"]["experts"]
            shapes = {k: tuple(v.shape) for k, v in experts.items()}
            L, E = experts["w_gate"].shape[:2]
            self._lo_bytes += expert_lo_nbytes(
                shapes, self.lo_bits, self.group_size) * L * E
            self.banks[str(pos)] = build_bank(
                experts, n_hi=0, lo_bits=self.lo_bits,
                group_size=self.group_size)
            # Free the dense copies — the bank is the only residency now.
            params["blocks"][str(pos)]["moe"]["experts"] = None
        return self.banks

    def device_bytes(self) -> int:
        return self._lo_bytes


class DynaExqBackend(_BackendBase):
    """The paper's system: lo tier always resident + a budget-derived hi
    pool whose occupancy the online controller re-allocates from router
    traces. Promotions ride the migration stream (off the critical path) —
    ``observe`` only feeds hotness; ``tick`` runs the policy window.

    Expert parallelism (``ep_shards > 1``): every MoE position's hi-slot
    pool is split into per-shard slot ranges with per-shard budget accounts
    (shard j's promotions bill shard j's local HBM, never a neighbour's),
    and an ``EPCoordinator`` periodically rebalances expert *ownership*
    across shards from the globally-psum'd hotness (``tick`` drives its
    window alongside the per-position controllers)."""

    name = "dynaexq"

    def __init__(self, lo_bits: int = 4, hi_bits: int = 16,
                 group_size: int = 64,
                 n_hi_per_layer: Optional[int] = None,
                 hbm_gb: Optional[float] = None,
                 activation_slack_bytes: int = 64 << 20,
                 controller: Optional[ControllerConfig] = None,
                 ep_shards: int = 1,
                 rebalance: Optional[RebalanceConfig] = None):
        super().__init__()
        if ep_shards < 1:
            raise ValueError("ep_shards must be >= 1")
        self.lo_bits = lo_bits
        self.hi_bits = hi_bits
        self.group_size = group_size
        self.n_hi_per_layer = n_hi_per_layer
        self.hbm_gb = hbm_gb
        self.activation_slack_bytes = activation_slack_bytes
        self.controller_cfg = controller
        self.ep_shards = int(ep_shards)
        self.coordinator: Optional[EPCoordinator] = \
            EPCoordinator(self.ep_shards, rebalance) if ep_shards > 1 else None
        self.controllers: Dict[str, DynaExqController] = {}
        self.banks: Dict = {}

    def _materialize(self, cfg, params, kv_bytes):
        for pos in self.moe_positions:
            experts = params["blocks"][str(pos)]["moe"]["experts"]
            shapes = {k: tuple(v.shape) for k, v in experts.items()}
            hi_b = expert_hi_nbytes(shapes, hi_bits=self.hi_bits,
                                    group_size=self.group_size)
            lo_b = expert_lo_nbytes(shapes, self.lo_bits, self.group_size)
            L, E = experts["w_gate"].shape[:2]
            ep = self.ep_shards
            if ep > 1 and E % ep:
                raise ValueError(
                    f"num_experts={E} not divisible by ep_shards={ep}")
            if self.n_hi_per_layer is not None:
                n_hi = self.n_hi_per_layer
                if ep > 1 and n_hi % ep:
                    raise ValueError(
                        f"n_hi_per_layer={n_hi} not divisible by "
                        f"ep_shards={ep} (each shard owns n_hi/ep slots)")
            elif self.hbm_gb is not None:
                nonexp = _param_bytes({k: v for k, v in params.items()
                                       if k != "blocks"})
                plan = plan_budget(
                    m_total=int(self.hbm_gb * GiB),
                    m_fixed=nonexp + kv_bytes + self.activation_slack_bytes,
                    lo_bytes_total=lo_b * L * E,
                    hi_bytes_per_expert_layer=hi_b,
                    n_layers=L, num_experts=E, align=ep)
                n_hi = plan.n_hi_per_layer
            else:
                n_hi = max(1, E // 8)
                if ep > 1:
                    # round to a shard-divisible count (≥ one slot per shard)
                    n_hi = max(ep, n_hi // ep * ep)
            host_hi = {k: np.asarray(v) for k, v in experts.items()}
            bank = build_bank(experts, n_hi=n_hi, lo_bits=self.lo_bits,
                              group_size=self.group_size,
                              hi_bits=self.hi_bits)
            self.banks[str(pos)] = bank
            if n_hi > 0:
                # Under an engine-shared budget each position's hi tier is
                # an account-scoped view: its own cap is the classic
                # n_hi·L·hi_bytes pool, but every reservation also passes
                # through the ONE envelope KV blocks draw from — KV
                # pressure defers promotions, demotions free admission
                # headroom.
                tracker = None if self.budget is None else \
                    self.budget.view(f"hi:{pos}", cap=n_hi * L * hi_b)
                shard_trackers = None
                if ep > 1:
                    # One account per shard: a shard's promotions reserve
                    # against ITS slice of the pool (its local HBM), so a
                    # hot shard saturating its slots cannot starve — or
                    # borrow from — a neighbour's budget.
                    per_cap = (n_hi // ep) * L * hi_b
                    if self.budget is not None:
                        shard_trackers = [
                            self.budget.view(f"hi:{pos}:s{j}", cap=per_cap)
                            for j in range(ep)]
                    else:
                        shard_trackers = [BudgetTracker(per_cap)
                                          for _ in range(ep)]
                ctl = DynaExqController(
                    bank, host_hi, n_hi_per_layer=n_hi,
                    hi_bytes_per_expert=hi_b, cfg=self.controller_cfg,
                    tracker=tracker, ep_shards=ep,
                    shard_trackers=shard_trackers)
                self.controllers[str(pos)] = ctl
                if self.coordinator is not None:
                    # The moe params dict outlives the experts=None free
                    # below — the coordinator swaps its router leaf in
                    # place on migration.
                    self.coordinator.register(
                        ctl, params["blocks"][str(pos)]["moe"])
            params["blocks"][str(pos)]["moe"]["experts"] = None
        return self.banks

    def _observe_residency(self, counts, compute_s):
        for k, ctl in self.controllers.items():
            c = counts.get(k)
            if c is not None:
                ctl.observe(np.asarray(c))
        return 0.0

    def tick(self) -> None:
        for ctl in self.controllers.values():
            ctl.maybe_update()
        if self.coordinator is not None:
            self.coordinator.maybe_rebalance()

    def force_update(self) -> None:
        for ctl in self.controllers.values():
            ctl.update()

    def flush(self) -> None:
        for ctl in self.controllers.values():
            ctl.flush()

    def hi_sets(self) -> Dict[str, list]:
        out = {}
        for k, ctl in self.controllers.items():
            L = ctl.tm.slot_map_h.shape[0]
            out[k] = [sorted(ctl.tm.hi_set(l)) for l in range(L)]
        return out

    def device_bytes(self) -> int:
        total = 0
        for bank in self.banks.values():
            shapes = {n: tuple(q.shape) for n, q in bank.lo.items()}
            L, E = bank.slot_map.shape
            per_lo = expert_lo_nbytes(shapes, self.lo_bits, self.group_size)
            per_hi = expert_hi_nbytes(shapes, hi_bits=self.hi_bits,
                                      group_size=self.group_size)
            n_resident = int((np.asarray(bank.slot_owner) >= 0).sum())
            total += per_lo * L * E + n_resident * per_hi
        return total

    def _residency_stats(self):
        agg = {"stall_s": 0.0, "bytes_moved": 0.0,
               "promotions": 0.0, "demotions": 0.0, "deferred": 0.0}
        for ctl in self.controllers.values():
            agg["bytes_moved"] += ctl.tm.stats["bytes_moved"]
            agg["promotions"] += ctl.tm.stats["promoted"]
            agg["demotions"] += ctl.tm.stats["demoted"]
            agg["deferred"] += ctl.tm.stats["deferred"]
        if self.coordinator is not None:
            agg["migrations"] = float(self.coordinator.stats["migrations"])
            agg["bytes_moved"] += self.coordinator.stats["bytes_moved"]
        return agg


@dataclasses.dataclass
class OffloadConfig:
    cache_experts_per_layer: int = 16
    pcie_gbps: float = 16.0          # PCIe gen4 x16 — the paper's A6000
    prefetch: bool = True


class OffloadBackend(_BackendBase):
    """ExpertFlow-like offloading/prefetch baseline (paper §5.3 comparator).

    Experts live in host memory; the device keeps an LRU cache of
    ``cache_experts_per_layer`` experts per layer in bf16. Each forward the
    router's activated set is compared against the cache: misses must be
    fetched over PCIe *on the critical path* (minus whatever an optimistic
    prefetcher overlapped) — exactly the structural cost the paper's Fig. 1
    measures. The transfer cost is a deterministic model
    (bytes / pcie_gbps) layered on the measured compute time, so the
    DynaExq-vs-offload comparison reflects transfer volume, not CPU noise.

    Prefetch model: before each step the predictor prefetches the previous
    step's activated set (a strong next-step predictor for decode — routing
    is temporally correlated); prefetched bytes overlap with compute up to
    ``compute_s × pcie`` bytes per step, the rest spills into the stall.
    """

    name = "offload"

    def __init__(self, ocfg: Optional[OffloadConfig] = None):
        super().__init__()
        self.ocfg = ocfg if ocfg is not None else OffloadConfig()
        self.expert_bytes = 0
        self.n_moe_layers = 0
        self.lru: Dict[int, LRUSet] = {}
        self.prev_active: Dict[int, set] = {}
        self._acct = {"hits": 0, "misses": 0, "stall_s": 0.0,
                      "bytes_fetched": 0}

    def _materialize(self, cfg, params, kv_bytes):
        # Per-expert bf16 bytes (w_gate + w_up + w_down).
        self.expert_bytes = 3 * cfg.d_model * cfg.moe.d_ff_expert * 2
        self.n_moe_layers = len(self.moe_positions) * cfg.n_superblocks()
        self.lru = {l: LRUSet(self.ocfg.cache_experts_per_layer)
                    for l in range(self.n_moe_layers)}
        self.prev_active = {l: set() for l in range(self.n_moe_layers)}
        return None        # computes dense; residency is modeled

    def _observe_residency(self, counts, compute_s):
        activated: Dict[int, np.ndarray] = {}
        li = 0
        for pos in self.moe_positions:
            c = np.asarray(counts[str(pos)])       # (nsb, E)
            for sbi in range(c.shape[0]):
                activated[li] = np.nonzero(c[sbi] > 0)[0]
                li += 1
        miss_bytes = 0
        prefetched_bytes = 0
        for l, acts in activated.items():
            lru = self.lru[l]
            if self.ocfg.prefetch:
                for e in self.prev_active[l]:
                    if e not in lru:
                        prefetched_bytes += self.expert_bytes
                    lru.touch(int(e))
            for e in acts:
                if lru.touch(int(e)):
                    self._acct["hits"] += 1
                else:
                    self._acct["misses"] += 1
                    miss_bytes += self.expert_bytes
            self.prev_active[l] = set(int(x) for x in acts)
        pcie = self.ocfg.pcie_gbps * 1e9
        # Prefetches overlap with compute; anything beyond the overlap
        # window spills into the critical path with the demand misses.
        overlap_budget = compute_s * pcie
        spill = max(0.0, prefetched_bytes - overlap_budget)
        stall = (miss_bytes + spill) / pcie
        self._acct["stall_s"] += stall
        self._acct["bytes_fetched"] += miss_bytes + prefetched_bytes
        return stall

    def device_bytes(self) -> int:
        """Device-resident cache footprint under the offload budget."""
        return (self.n_moe_layers * self.ocfg.cache_experts_per_layer *
                self.expert_bytes)

    def _residency_stats(self):
        return {"stall_s": self._acct["stall_s"],
                "bytes_moved": float(self._acct["bytes_fetched"]),
                "hits": float(self._acct["hits"]),
                "misses": float(self._acct["misses"])}


BACKENDS = {
    "fp16": Fp16Backend,
    "static": StaticPTQBackend,
    "dynaexq": DynaExqBackend,
    "offload": OffloadBackend,
}


def make_backend(name: str, **kwargs) -> ResidencyBackend:
    """Registry factory: ``make_backend("dynaexq", n_hi_per_layer=2)``."""
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; "
                       f"one of {sorted(BACKENDS)}") from None
    return cls(**kwargs)
