"""Global cross-layer, cross-tier residency allocation (ROADMAP: GEMQ/DyMoE
direction).

The paper's top-n rule solves L independent per-layer knapsacks; this module
solves ONE. Every (layer-row, expert) cell competes for

* a global **hi budget** (``total_hi`` expert-slots across all rows — the
  same byte envelope the per-layer rule spreads uniformly), and
* optionally a global **lo-residency budget** (``lo_resident_total`` cells;
  everything below the cut lives in the host-DRAM tier and pays a modeled
  demand-fetch stall when routed).

Cells are ranked by *sensitivity-weighted hotness* (``value = hotness ×
sensitivity``, see ``quant.sensitivity``): a hot-but-robust expert can lose
its hi slot to a cooler-but-fragile one, and a hot layer can hold more hi
slots than a cold layer — the cross-layer reallocation the per-layer rule
cannot express. Feasibility is structural:

* ``sum(|hi_l|) <= total_hi`` and ``|hi_l| <= slots_per_layer`` (the
  physical per-row pool ceiling),
* the hi target is always a subset of the lo-resident target (the ladder is
  ordered: hi ⊆ lo ⊆ host),
* hysteresis (``margin``/``lo_margin``) mirrors the per-layer rule: a cell
  only displaces a current resident if its value clears the resident's by
  the margin, so near-tie oscillation produces zero transitions.

Host-side numpy over (rows, E) arrays — same O(L·E log) cost class as the
per-layer policy, far off the token critical path.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Set, Tuple

import numpy as np

Cell = Tuple[int, int]   # (row, expert) — row is a global layer index


@dataclasses.dataclass(frozen=True)
class AllocatorConfig:
    total_hi: int                 # global hi budget, in expert-slots
    slots_per_layer: int          # physical per-row hi pool ceiling
    margin: float = 0.0           # hysteresis on weighted value (hi tier)
    max_transitions: int = 0      # global per-window promotion cap (0 = inf)
    lo_resident_total: int = 0    # 0 = no host tier (all cells lo-resident)
    lo_margin: float = 0.0        # hysteresis at the lo ↔ host boundary

    def validate(self) -> None:
        if self.total_hi < 0 or self.slots_per_layer < 0:
            raise ValueError("hi budgets must be >= 0")
        if self.margin < 0 or self.lo_margin < 0:
            raise ValueError("margins must be >= 0")
        if self.lo_resident_total < 0:
            raise ValueError("lo_resident_total must be >= 0")


@dataclasses.dataclass
class TierAssignment:
    """One allocation window's output. ``promotions`` are ordered
    hottest-first and ``demotions`` coldest-first (the transition pipeline's
    admission order under rate limits); the lo lists are ``None`` when no
    host tier is configured."""
    hi: List[Set[int]]
    promotions: List[Cell]
    demotions: List[Cell]
    lo: Optional[List[Set[int]]] = None
    lo_promotions: Optional[List[Cell]] = None
    lo_demotions: Optional[List[Cell]] = None


class GlobalAllocator:
    """One knapsack over all (row, expert) cells, greedy by value with
    per-row ceilings — optimal for unit-size items under a cardinality
    budget, which is exactly what fixed-granularity expert slots are."""

    def __init__(self, cfg: AllocatorConfig):
        cfg.validate()
        self.cfg = cfg

    # -- internals --------------------------------------------------------
    @staticmethod
    def _order(value: np.ndarray) -> List[Cell]:
        R, E = value.shape
        flat = np.argsort(-value.reshape(-1), kind="stable")
        return [(int(i) // E, int(i) % E) for i in flat]

    @staticmethod
    def _caps(row_caps, R: int, default: int) -> np.ndarray:
        if row_caps is None:
            return np.full(R, default, np.int64)
        caps = np.asarray(row_caps, np.int64)
        if caps.shape != (R,):
            raise ValueError(f"row_caps shape {caps.shape} != ({R},)")
        return caps

    def _greedy(self, value: np.ndarray, K: int, caps: np.ndarray,
                pinned: Optional[Sequence[Set[int]]] = None
                ) -> List[Set[int]]:
        """Descending-value fill of K cells subject to per-row ceilings.
        ``pinned`` cells are seated first and count against K (they may
        overdraw it — the caller guarantees |pinned| <= K)."""
        R, E = value.shape
        target: List[Set[int]] = [set() for _ in range(R)]
        counts = np.zeros(R, np.int64)
        total = 0
        if pinned is not None:
            for r in range(R):
                for e in pinned[r]:
                    target[r].add(int(e))
                counts[r] = len(target[r])
            total = int(counts.sum())
        for r, e in self._order(value):
            if total >= K:
                break
            if e in target[r] or counts[r] >= caps[r]:
                continue
            target[r].add(e)
            counts[r] += 1
            total += 1
        return target

    def _hysteresis(self, value: np.ndarray, current: List[Set[int]],
                    target: List[Set[int]], margin: float,
                    caps: np.ndarray,
                    pinned: Optional[List[Set[int]]] = None) -> None:
        """Cancel churn: pair the strongest entrant with the weakest leaver;
        once a pair fails to clear ``margin``, cancel it and every weaker
        pair (the per-layer rule's swap loop, globalized). Mutates
        ``target`` in place. A cancel whose leaver cannot re-seat (its row
        was filled to the ceiling by stronger entrants) keeps the swap —
        feasibility beats stability on that edge."""
        entrants = sorted(
            ((r, e) for r in range(len(target)) for e in target[r]
             if e not in current[r]
             and not (pinned is not None and e in pinned[r])),
            key=lambda c: -value[c])
        leavers = sorted(
            ((r, e) for r in range(len(current)) for e in current[r]
             if e not in target[r]),
            key=lambda c: value[c])
        counts = np.array([len(t) for t in target], np.int64)
        cancelling = False
        for ent, lv in zip(entrants, leavers):
            if not cancelling and value[ent] > value[lv] + margin:
                continue           # clear winner — the swap stands
            cancelling = True
            re_, ee = ent
            rl, el = lv
            counts[re_] -= 1       # entrant steps back out…
            if counts[rl] < caps[rl]:
                target[re_].discard(ee)
                target[rl].add(el)  # …and the incumbent keeps its seat
                counts[rl] += 1
            else:
                counts[re_] += 1   # infeasible cancel: keep the swap

    # -- public -----------------------------------------------------------
    def allocate(self, value: np.ndarray,
                 current_hi: Sequence[Set[int]],
                 current_lo: Optional[Sequence[Set[int]]] = None,
                 row_caps=None) -> TierAssignment:
        """One window: ``value`` is the (rows, E) sensitivity-weighted
        hotness; ``current_hi`` (and ``current_lo`` when a host tier is on)
        are the published-or-pending residency sets. Rows from several MoE
        positions may be stacked — that is the point."""
        value = np.asarray(value, np.float64)
        R, E = value.shape
        if len(current_hi) != R:
            raise ValueError(f"{len(current_hi)} current sets != {R} rows")
        caps = self._caps(row_caps, R, min(self.cfg.slots_per_layer, E))
        current = [set(int(e) for e in s) for s in current_hi]

        K = self.cfg.total_hi
        target = self._greedy(value, K, caps)
        if any(current):
            self._hysteresis(value, current, target, self.cfg.margin, caps)
        promotions = sorted(
            ((r, e) for r in range(R) for e in target[r]
             if e not in current[r]), key=lambda c: -value[c])
        demotions = sorted(
            ((r, e) for r in range(R) for e in current[r]
             if e not in target[r]), key=lambda c: value[c])

        if self.cfg.max_transitions:
            k = self.cfg.max_transitions
            promotions = promotions[:k]
            n_cur = sum(len(s) for s in current)
            overflow = max(0, n_cur + len(promotions) - K)
            demotions = demotions[:max(overflow, min(len(demotions), k))]
            target = [set(s) for s in current]
            for r, e in demotions:
                target[r].discard(e)
            for r, e in promotions:
                target[r].add(e)
            # Ceiling fix-up: a trimmed demotion list may leave a row over
            # its physical pool — force-demote its coldest members.
            for r in range(R):
                while len(target[r]) > caps[r]:
                    coldest = min(target[r], key=lambda e: value[r, e])
                    target[r].discard(coldest)
                    if (r, coldest) not in demotions:
                        demotions.append((r, coldest))
                    promotions = [c for c in promotions if c != (r, coldest)]

        lo = lo_promos = lo_demos = None
        if self.cfg.lo_resident_total:
            K_lo = max(self.cfg.lo_resident_total,
                       sum(len(s) for s in target))
            cur_lo = [set(int(e) for e in s) for s in current_lo] \
                if current_lo is not None else [set(range(E))
                                               for _ in range(R)]
            full = np.full(R, E, np.int64)
            lo = self._greedy(value, K_lo, full, pinned=target)
            if any(cur_lo):
                self._hysteresis(value, cur_lo, lo, self.cfg.lo_margin,
                                 full, pinned=target)
            # The ladder is ordered: hi residency implies lo residency.
            for r in range(R):
                lo[r] |= target[r]
            lo_promos = sorted(
                ((r, e) for r in range(R) for e in lo[r]
                 if e not in cur_lo[r]), key=lambda c: -value[c])
            lo_demos = sorted(
                ((r, e) for r in range(R) for e in cur_lo[r]
                 if e not in lo[r]), key=lambda c: value[c])

        return TierAssignment(hi=target, promotions=promotions,
                              demotions=demotions, lo=lo,
                              lo_promotions=lo_promos,
                              lo_demotions=lo_demos)
