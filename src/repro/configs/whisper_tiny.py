"""Whisper-tiny — encoder-decoder ASR backbone; conv/mel frontend is a STUB
(input_specs supplies precomputed frame embeddings). [arXiv:2212.04356]

Deviation noted in DESIGN.md: positions use RoPE instead of Whisper's learned
embeddings to stay shape-generic across the assigned input shapes.
"""
from repro.models.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,               # decoder layers
    d_model=384,
    vocab_size=51865,
    d_ff=1536,
    attn=AttnConfig(n_heads=6, n_kv_heads=6, head_dim=64,
                    rope_theta=10000.0),
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_seq=1500,         # 30 s of audio at 50 Hz after the conv stub
    norm_eps=1e-5,
    max_seq_len=448,
    source="arXiv:2212.04356 (Whisper)",
)
