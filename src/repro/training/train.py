"""Training loop: cross-entropy + MoE load-balance aux loss, AdamW, remat'd
scan forward. ``make_train_step`` returns the jittable step used by both the
CPU examples and the multi-pod dry-run (same function, different shardings).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import forward_train
from repro.models.config import ArchConfig
from repro.training.adamw import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    capacity_factor: float = 2.0
    remat: bool = True
    z_loss: float = 1e-4
    # Gradient-accumulation microbatches: divides peak activation memory by
    # ~microbatches at the cost of re-gathering FSDP-sharded params per
    # microbatch (§Perf trade-off, measured in EXPERIMENTS.md).
    microbatches: int = 1


def loss_fn(params, cfg: ArchConfig, batch: Dict, tcfg: TrainConfig):
    logits, aux = forward_train(params, cfg, batch,
                                capacity_factor=tcfg.capacity_factor,
                                remat=tcfg.remat)
    labels = batch["labels"]
    V = logits.shape[-1]
    if logits.shape[1] != labels.shape[1]:
        # VLM: image-prefix positions carry no labels.
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    # Sharding-friendly CE: never gathers the vocab axis. The label logit is
    # an iota-masked reduction (fuses; no one-hot materialization, no
    # take_along_axis gather that would force a vocab all-gather under SPMD).
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)                        # (B, S)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    label_logit = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits32, 0.0), axis=-1)
    nll = lse - label_logit
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    # z-loss stabilizes the router-facing logits scale.
    zl = tcfg.z_loss * jnp.mean(lse ** 2)
    total = ce + aux["aux_loss"] + zl
    return total, {"ce": ce, "aux": aux["aux_loss"], "z": zl,
                   "counts": aux["counts"]}


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig = TrainConfig()):
    nmb = tcfg.microbatches

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, tcfg), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if nmb == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            from repro.models.model import _scan
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(nmb, x.shape[0] // nmb, *x.shape[1:]),
                batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_body(carry, mb):
                g_acc, l_acc = carry
                (loss, _m), grads = grads_of(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, l_acc + loss), None

            (g32, loss), _ = _scan(mb_body, (zeros, jnp.float32(0)), mbs)
            grads = jax.tree_util.tree_map(
                lambda g, p: (g / nmb).astype(p.dtype), g32, params)
            loss = loss / nmb
            metrics = {"ce": loss}
        params, opt_state, opt_metrics = adamw_update(
            tcfg.optimizer, params, grads, opt_state)
        metrics = dict(metrics)
        metrics.pop("counts", None)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics
    return train_step


def train_loop(cfg: ArchConfig, params, batches, tcfg: TrainConfig = TrainConfig(),
               log_every: int = 20, log=print):
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    opt_state = adamw_init(params)
    history = []
    t0 = time.perf_counter()
    for i, batch in enumerate(batches):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i < 3:
            m = {k: float(v) for k, v in m.items()}
            m["step"] = i
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            log(f"step {i:4d}  loss {m['loss']:.4f}  ce {m['ce']:.4f}  "
                f"aux {m['aux']:.4f}  gnorm {m['gnorm']:.2f}")
    return params, opt_state, history


def eval_perplexity(cfg: ArchConfig, params, batches,
                    capacity_factor: float = 4.0, bank=None) -> float:
    """Held-out perplexity; ``bank`` switches the MoE layers to a quantized
    (static or DynaExq) expert bank — the quality-benchmark hook."""
    from repro.models import prefill, init_caches  # noqa
    total_nll, total_tok = 0.0, 0

    @jax.jit
    def batch_nll(params, batch, the_bank):
        logits, _ = forward_train(params, cfg,
                                  {k: v for k, v in batch.items()
                                   if k != "labels"},
                                  capacity_factor=capacity_factor,
                                  remat=False) if the_bank is None else \
            _forward_with_bank(params, cfg, batch, the_bank, capacity_factor)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return jnp.sum(nll), nll.size

    for batch in batches:
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        nll, n = batch_nll(params, batch, bank)
        total_nll += float(nll)
        total_tok += int(n)
    return float(jnp.exp(total_nll / total_tok))


def _forward_with_bank(params, cfg, batch, bank, capacity_factor):
    """Full-sequence forward through the serving (bank) path: prefill
    without caring about the caches, returning per-position logits."""
    from repro.models.model import (_embed_inputs, _lm_logits, _block_step)
    sb = cfg.superblock_or_default()
    x = _embed_inputs(params, cfg, batch)
    B, S, d = x.shape
    from repro.models import moe as X
    cap = X.moe_capacity(B * S, cfg.moe, capacity_factor) if cfg.is_moe else 0

    def sb_body(x, xs):
        bp, bank_sliced = xs
        for pos, kind in enumerate(sb):
            x, counts, _ = _train_block_with_bank(bp[str(pos)], cfg, pos, kind,
                                                  x, cap, bank_sliced)
        return x, None

    x, _ = jax.lax.scan(sb_body, x, (params["blocks"], bank))
    return _lm_logits(params, cfg, x), None


def _train_block_with_bank(bp, cfg, pos, kind, x, cap, bank):
    from repro.models.model import _block_train
    return _block_train(bp, cfg, pos, kind, x, cap, bank, None)
