"""Synthetic LM data pipeline.

A deterministic, learnable sequence task: tokens follow a sparse first-order
Markov chain over a Zipf-weighted vocabulary (each token has a small set of
likely successors). A model must learn the transition table, so train loss
decreases measurably within a few hundred steps — giving the quality
benchmarks a *real* trained model to quantize. Workload conditioning reuses
the serving request generator's per-workload vocab slices so routing skew
and shift emerge naturally.
"""
from __future__ import annotations

import numpy as np


class SyntheticLMTask:
    def __init__(self, vocab_size: int, branching: int = 4, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        # successor table: token → `branching` likely next tokens
        self.table = rng.integers(0, vocab_size, size=(vocab_size, branching))
        self.start_probs = self._zipf(vocab_size)
        self.branching = branching

    @staticmethod
    def _zipf(n, s=1.1):
        p = 1.0 / np.arange(1, n + 1) ** s
        return p / p.sum()

    def sample(self, batch: int, length: int, seed: int,
               noise: float = 0.1) -> np.ndarray:
        rng = np.random.default_rng(seed)
        toks = np.empty((batch, length), np.int32)
        cur = rng.choice(self.vocab, size=batch, p=self.start_probs)
        toks[:, 0] = cur
        for t in range(1, length):
            nxt = self.table[cur, rng.integers(0, self.branching, size=batch)]
            rand = rng.integers(0, self.vocab, size=batch)
            use_rand = rng.random(batch) < noise
            cur = np.where(use_rand, rand, nxt).astype(np.int32)
            toks[:, t] = cur
        return toks

    def batches(self, batch: int, length: int, n_steps: int, seed: int = 0):
        for i in range(n_steps):
            toks = self.sample(batch, length, seed=seed + i)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
