"""Expert-parallel scaling: tokens/s, bytes-moved/token, rebalance count.

The structural claim (ISSUE 7 / DESIGN §EP): under expert parallelism each
shard exchanges a fixed per-destination payload — ``2·(n−1)·S·d`` elements
per MoE layer, out and back, with ``S`` the static all-to-all row budget
(``ep_payload_rows``) — while the replicated baseline psums the full
activation, ``2·(n−1)/n·T·d`` elements per shard. Per token the EP exchange
is **batch-independent** (``S`` is capped by per-destination capacity), so
from 4 shards up it moves strictly fewer bytes per token than the psum; at
2 shards the capacity slice is still wide enough that it legitimately
loses. Both models are reported per shard count alongside the measured
layer throughput on a forced host-device mesh, plus the hotness
rebalancer's migration count under a canned skew.

Each shard count runs in a subprocess (jax pins the device count at first
init). ``BENCH_SMOKE=1`` shrinks the timing loop. Rows land in
``experiments/BENCH_dist.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import BENCH_SMOKE

D_MODEL = 256
N_TOKENS = 512
N_EXPERTS = 16
BYTES_EL = 2                       # bf16 payload
SHARD_COUNTS = (1, 2, 4, 8)
ITERS = 3 if BENCH_SMOKE else 20
JSON_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "BENCH_dist.json")

SCRIPT = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.models.config import MoEConfig
from repro.models import moe as M
from repro.launch.dist import dist_ctx, ep_context
from repro.launch.mesh import make_ep_mesh

n, iters, d, T = %(n)d, %(iters)d, %(d)d, %(T)d
cfg = MoEConfig(num_experts=%(E)d, top_k=2, d_ff_expert=512,
                n_shared_experts=0, capacity_factor=1.25,
                norm_topk_prob=True)
params = M.init_moe(jax.random.PRNGKey(0), d, cfg)
dense = dict(params["experts"])
x = jax.random.normal(jax.random.PRNGKey(1), (T, d), jnp.bfloat16)
cap = M.moe_capacity(T, cfg)

jf = jax.jit(lambda p, b, xx: M.moe_apply(p, b, xx, cfg, cap,
                                          dispatch="ragged", gemm="jnp"))
if n > 1:
    ctx = ep_context(make_ep_mesh(n))
    def call():
        with dist_ctx(ctx):
            return jf(params, dense, x)
else:
    def call():
        return jf(params, dense, x)
y, _ = call()
y.block_until_ready()                          # compile outside the timing
t0 = time.perf_counter()
for _ in range(iters):
    y, _ = call()
y.block_until_ready()
wall = time.perf_counter() - t0
S = M.ep_payload_rows(T, cfg.top_k, cfg.num_experts // n, cap, n) \
    if n > 1 else 0
print("RESULT " + json.dumps({"wall_s": wall, "capacity": cap, "S": S,
                              "tokens_per_s": T * iters / wall}))
"""


def _time_shards(n):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    src = SCRIPT % dict(n=n, iters=ITERS, d=D_MODEL, T=N_TOKENS, E=N_EXPERTS)
    r = subprocess.run([sys.executable, "-c", src], env=env,
                       capture_output=True, text=True, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(f"ep_scaling subprocess n={n} failed:\n"
                           f"{r.stderr[-3000:]}")
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def _rebalance_count(n):
    """Exercise the EP coordinator (host-side, no mesh) under a canned
    two-hot-experts-on-one-shard skew; returns migrations admitted."""
    import jax
    import jax.numpy as jnp

    from repro.core import (ControllerConfig, DynaExqController, build_bank,
                            expert_hi_nbytes)
    from repro.core.controller import EPCoordinator, RebalanceConfig

    w = {"w": jax.random.normal(jax.random.PRNGKey(0),
                                (1, N_EXPERTS, 64, 32), jnp.float32)
         .astype(jnp.bfloat16)}
    bank = build_bank(w, n_hi=0, lo_bits=4)
    host = {k: np.asarray(v) for k, v in w.items()}
    hib = expert_hi_nbytes({k: v.shape for k, v in w.items()})
    ctl = DynaExqController(bank, host, n_hi_per_layer=0,
                            hi_bytes_per_expert=hib,
                            cfg=ControllerConfig(update_interval_s=1e9),
                            ep_shards=n)
    coord = EPCoordinator(n, RebalanceConfig(interval_s=1e9))
    coord.register(ctl, {"router": jnp.zeros((1, 16, N_EXPERTS),
                                             jnp.float32)})
    ctl.hotness.counts[:, 0] += 100
    ctl.hotness.counts[:, 1] += 100
    return coord.maybe_rebalance(force=True)


def run(report):
    results = {"smoke": BENCH_SMOKE, "d_model": D_MODEL,
               "n_tokens": N_TOKENS, "n_experts": N_EXPERTS,
               "iters": ITERS, "shards": {}}
    for n in SHARD_COUNTS:
        row = _time_shards(n)
        if n > 1:
            # per-shard interconnect models, bytes per (global) token
            row["bytes_per_token_ep"] = (2 * (n - 1) * row["S"] * D_MODEL *
                                         BYTES_EL / N_TOKENS)
            row["bytes_per_token_replicated"] = (2 * (n - 1) / n * D_MODEL *
                                                 BYTES_EL)
            row["rebalance_migrations"] = _rebalance_count(n)
        else:
            row["bytes_per_token_ep"] = 0.0
            row["bytes_per_token_replicated"] = 0.0
            row["rebalance_migrations"] = 0
        results["shards"][str(n)] = row
        report(f"ep_scaling/tokens_per_s/{n}shard",
               1e6 * row["wall_s"] / ITERS, round(row["tokens_per_s"], 1))
        report(f"ep_scaling/bytes_per_token/{n}shard", 0.0,
               round(row["bytes_per_token_ep"], 1))
    # The claim that makes EP worth serving: at 4+ shards the all-to-all
    # moves strictly fewer bytes/token per shard than the replicated psum.
    for n in (4, 8):
        row = results["shards"][str(n)]
        if not row["bytes_per_token_ep"] < row["bytes_per_token_replicated"]:
            raise AssertionError(
                f"EP exchange at {n} shards moved "
                f"{row['bytes_per_token_ep']:.0f} B/token, not below the "
                f"replicated {row['bytes_per_token_replicated']:.0f} — "
                "payload sizing regressed")
        if row["rebalance_migrations"] < 1:
            raise AssertionError(
                f"rebalancer admitted no migration at {n} shards under a "
                "canned skew — coordinator policy regressed")
    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    with open(JSON_OUT, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.normpath(JSON_OUT)}")
