from repro.serving.backends import (BACKENDS, DynaExqBackend, Fp16Backend,
                                    LRUSet, OffloadBackend, OffloadConfig,
                                    ResidencyBackend, STAT_KEYS,
                                    StaticPTQBackend, make_backend)
from repro.serving.engine import (EngineConfig, InferenceEngine,
                                  RequestHandle, RequestState)
from repro.serving.requests import (Request, RequestStream, WORKLOADS,
                                    make_prompts, mixed_stream)

__all__ = [
    "BACKENDS", "DynaExqBackend", "EngineConfig", "Fp16Backend",
    "InferenceEngine", "LRUSet", "OffloadBackend", "OffloadConfig",
    "Request", "RequestHandle", "RequestState", "RequestStream",
    "ResidencyBackend", "STAT_KEYS", "StaticPTQBackend", "WORKLOADS",
    "make_backend", "make_prompts", "mixed_stream",
]
