"""DynaExq core — the paper's contribution: online, budget-constrained
precision allocation for MoE serving (hotness → top-n policy → VER +
non-blocking transitions under a hard HBM budget)."""
from repro.core.allocator import (AllocatorConfig, GlobalAllocator,
                                  TierAssignment)
from repro.core.budget import (BudgetTracker, BudgetView, BudgetPlan,
                               HierarchyPlan, UNBOUNDED, plan_budget,
                               plan_hierarchy, BudgetExceeded)
from repro.core.controller import (ControllerConfig, DynaExqController,
                                   EPCoordinator, RebalanceConfig)
from repro.core.hotness import HotnessEstimator, mask_row_counts
from repro.core.policy import PolicyConfig, select_hi_set
from repro.core.pools import SlotPool
from repro.core.transitions import TransitionManager
from repro.core.ver import (
    ExpertBankQ, Residency, build_bank, build_bank_empty, expert_hi_nbytes,
    expert_lo_nbytes, publish, unpublish, write_hi_slot, write_lo_expert,
)

__all__ = [
    "AllocatorConfig", "GlobalAllocator", "TierAssignment",
    "BudgetTracker", "BudgetView", "BudgetPlan", "HierarchyPlan",
    "UNBOUNDED", "plan_budget", "plan_hierarchy", "BudgetExceeded",
    "ControllerConfig", "DynaExqController", "EPCoordinator",
    "RebalanceConfig", "HotnessEstimator", "mask_row_counts",
    "PolicyConfig", "select_hi_set", "SlotPool", "TransitionManager",
    "ExpertBankQ", "Residency", "build_bank", "build_bank_empty",
    "expert_hi_nbytes", "expert_lo_nbytes", "publish", "unpublish",
    "write_hi_slot", "write_lo_expert",
]
