"""Unified model builder covering all six assigned families.

A model is a stack of repeating *super-blocks* (one layer-kind pattern, e.g.
jamba = 7×mamba + 1×attn with MoE FFN on odd positions). Parameters for each
super-block position are stacked over the number of super-blocks so the whole
stack runs under one ``lax.scan`` — keeping the lowered HLO small enough to
compile 40 (arch × shape) × 2 meshes on this container.

Three entry points:
* ``forward_train``  — full-sequence causal forward (training / quality eval)
* ``prefill``        — full forward writing KV/SSM caches, last-token logits;
  ``lengths=`` turns it into a padded, masked prefill (per-row true lengths,
  logits gathered at ``lengths - 1``) so the serving engine can batch
  variable-length prompts into a handful of length buckets
* ``decode_step``    — ONE token against the caches (the serving hot path);
  ``row_valid=`` masks vacant continuous-batching rows out of MoE dispatch
  and router counts

Both serving entry points accept ``per_row_counts=True`` to return router
counts per ROW ((nsb, B, E)) instead of aggregated — the per-request routing
telemetry the engine attributes to request handles and the residency
backends use to keep phantom traffic out of hotness.

MoE layers accept an optional DynaExq ``ExpertBankQ`` override (serving in
mixed precision); without it they use the dense bf16 experts in ``params``.
Every MoE layer emits its router-selection counts — the hotness signal.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models import mlp as M
from repro.models import moe as X
from repro.models import ssm as S
from repro.models.layers import KVCache
from repro.models.ssm import MambaCache

PyTree = Any

# Roofline instrumentation: when True, layer scans fully unroll so XLA's
# cost_analysis (which counts while-loop bodies once) sees every iteration.
# Enabled only by the dry-run's reduced-depth variant compiles.
_SCAN_UNROLL = False


@contextlib.contextmanager
def unrolled_scans():
    global _SCAN_UNROLL
    prev = _SCAN_UNROLL
    _SCAN_UNROLL = True
    try:
        yield
    finally:
        _SCAN_UNROLL = prev


def _scan(body, carry, xs, length=None):
    return jax.lax.scan(body, carry, xs, length=length,
                        unroll=True if _SCAN_UNROLL else 1)


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, kind: str, ffn: str) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict = {"norm1": L.init_rmsnorm(cfg.d_model),
               "norm2": L.init_rmsnorm(cfg.d_model)}
    if kind == "attn":
        p["attn"] = L.init_attention(ks[0], cfg.d_model, cfg.attn)
    elif kind == "mamba":
        p["mamba"] = S.init_mamba(ks[0], cfg.d_model, cfg.ssm)
    else:
        raise ValueError(kind)
    if ffn == "moe":
        p["moe"] = X.init_moe(ks[1], cfg.d_model, cfg.moe)
    elif cfg.d_ff:
        p["mlp"] = M.init_swiglu(ks[1], cfg.d_model, cfg.d_ff)
    if cfg.is_encoder_decoder and kind == "attn":
        p["norm_cross"] = L.init_rmsnorm(cfg.d_model)
        p["cross"] = L.init_cross_attention(ks[2], cfg.d_model, cfg.attn)
    return p


def _init_enc_block(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 2)
    return {"norm1": L.init_rmsnorm(cfg.d_model),
            "attn": L.init_attention(ks[0], cfg.d_model, cfg.attn),
            "norm2": L.init_rmsnorm(cfg.d_model),
            "mlp": M.init_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff or 4 * cfg.d_model)}


def init_params(key, cfg: ArchConfig) -> Dict:
    sb = cfg.superblock_or_default()
    nsb = cfg.n_superblocks()
    keys = jax.random.split(key, 4 + len(sb))
    params: Dict = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(jnp.bfloat16),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "blocks": {},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size), jnp.float32)
            * cfg.d_model ** -0.5).astype(jnp.bfloat16)
    for pos, kind in enumerate(sb):
        ffn = cfg.ffn_kind(pos)
        pos_keys = jax.random.split(keys[4 + pos], nsb)
        params["blocks"][str(pos)] = jax.vmap(
            lambda k: _init_block(k, cfg, kind, ffn))(pos_keys)
    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(keys[2], cfg.n_encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: _init_enc_block(k, cfg))(enc_keys)
        params["enc_final_norm"] = L.init_rmsnorm(cfg.d_model)
    return params


# --------------------------------------------------------------------------
# Caches
# --------------------------------------------------------------------------

class DecodeCaches(NamedTuple):
    """Per super-block-position stacked caches + (audio) cross-attn KV."""
    blocks: Dict[str, Any]       # pos → KVCache | MambaCache (leading nsb)
    cross: Optional[Dict[str, jax.Array]]  # {'k','v'}: (nsb, B, Senc, Hkv, hd)


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16,
                positions: Optional[list] = None) -> DecodeCaches:
    """``positions``: optional subset of super-block position keys (str) to
    build caches for — e.g. only the mamba positions when the attention KV
    lives in a shared paged pool (allocating dense rows to throw away would
    waste device memory on every admission)."""
    sb = cfg.superblock_or_default()
    nsb = cfg.n_superblocks()
    blocks = {}
    for pos, kind in enumerate(sb):
        if positions is not None and str(pos) not in positions:
            continue
        if kind == "attn":
            cap = max_len if cfg.attn.sliding_window is None \
                else min(max_len, cfg.attn.sliding_window)
            c = L.init_kv_cache(batch, cap, cfg.attn, dtype)
        else:
            c = S.init_mamba_cache(batch, cfg.d_model, cfg.ssm, dtype)
        blocks[str(pos)] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (nsb,) + a.shape).copy(), c)
    cross = None
    if cfg.is_encoder_decoder:
        shape = (nsb, batch, cfg.encoder_seq, cfg.attn.n_kv_heads,
                 cfg.attn.head_dim)
        cross = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    return DecodeCaches(blocks=blocks, cross=cross)


def attn_logical_capacity(cfg: ArchConfig, max_len: int,
                          block_tokens: int) -> int:
    """Per-sequence logical KV capacity under paging: the dense capacity
    (``max_len``, or the sliding window) rounded UP to a whole number of
    blocks. Extra padded slots are never valid, so attention results match
    the dense cache exactly."""
    cap = max_len if cfg.attn.sliding_window is None \
        else min(max_len, cfg.attn.sliding_window)
    return -(-cap // block_tokens) * block_tokens


def init_paged_caches(cfg: ArchConfig, batch: int, max_len: int,
                      block_tokens: int, n_blocks: int,
                      dtype=jnp.bfloat16) -> DecodeCaches:
    """Decode caches for the paged engine: attention positions hold ONE
    shared (nsb, N, Hkv, bt, hd) physical block pool (batch-independent —
    requests lease blocks out of it via block tables), while mamba
    positions keep their per-slot recurrent state rows (O(1) per slot, so
    paging them buys nothing)."""
    if cfg.is_encoder_decoder:
        raise NotImplementedError("paged caches are decoder-only")
    sb = cfg.superblock_or_default()
    nsb = cfg.n_superblocks()
    blocks = {}
    for pos, kind in enumerate(sb):
        if kind == "attn":
            c = L.init_paged_kv_cache(n_blocks, block_tokens, cfg.attn,
                                      dtype)
        else:
            c = S.init_mamba_cache(batch, cfg.d_model, cfg.ssm, dtype)
        blocks[str(pos)] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (nsb,) + a.shape).copy(), c)
    return DecodeCaches(blocks=blocks, cross=None)


# --------------------------------------------------------------------------
# Block application
# --------------------------------------------------------------------------

def _apply_ffn(bp: Dict, cfg: ArchConfig, pos: int, x2d: jax.Array,
               capacity: int, bank, token_valid=None, n_rows=None,
               row_capacity=None, moe_dispatch=None):
    """x2d: (T, d) → (y, MoEAux | None)."""
    ffn = cfg.ffn_kind(pos)
    if ffn == "moe":
        b = bank[str(pos)] if bank is not None else bp["moe"]["experts"]
        y, aux = X.moe_apply(bp["moe"], b, x2d, cfg.moe, capacity,
                             token_valid=token_valid, n_rows=n_rows,
                             row_capacity=row_capacity,
                             dispatch=moe_dispatch)
        return y, aux
    if "mlp" in bp:
        return M.swiglu(bp["mlp"], x2d), None
    return jnp.zeros_like(x2d), None


def _block_train(bp: Dict, cfg: ArchConfig, pos: int, kind: str, x: jax.Array,
                 capacity: int, bank, enc_out: Optional[jax.Array]):
    B, Sq, d = x.shape
    h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        attn_out = L.attention_full(bp["attn"], cfg.attn, h)
        if cfg.is_encoder_decoder:
            x = x + attn_out
            hc = L.rmsnorm(bp["norm_cross"], x, cfg.norm_eps)
            ek, ev = L.encode_cross_kv(bp["cross"], cfg.attn, enc_out)
            attn_out = L.cross_attention(bp["cross"], cfg.attn, hc, ek, ev)
    else:
        attn_out, _ = S.ssd_forward(bp["mamba"], cfg.ssm, cfg.d_model, h)
    x = x + attn_out
    h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
    y, aux = _apply_ffn(bp, cfg, pos, h.reshape(B * Sq, d), capacity, bank)
    counts = aux.counts if aux is not None else None
    aux_loss = aux.aux_loss if aux is not None else jnp.float32(0)
    return x + y.reshape(B, Sq, d), counts, aux_loss


def _block_step(bp: Dict, cfg: ArchConfig, pos: int, kind: str, x: jax.Array,
                cache, pos_idx, capacity: int, bank,
                cross_kv, prefill: bool, lengths=None, token_valid=None,
                n_rows=None, paged: Optional[Dict] = None,
                row_capacity=None, moe_dispatch=None):
    """Shared prefill/decode body. x: (B, S, d) (S=1 for decode).

    ``lengths``/``token_valid``/``n_rows`` carry the per-row validity
    signal: masked cache writes for padded prefill, masked MoE dispatch,
    and optional per-row router counts (see ``prefill``/``decode_step``).
    ``paged`` switches attention positions to the block-table path
    (gather/scatter against the shared ``PagedKVCache`` pool): a dict with
    ``table`` (B, nb) and either ``write_blk``/``write_off`` (decode) or
    ``start``/``has_prefix`` (prefill). Mamba positions are unaffected —
    their per-slot state is not paged. ``row_capacity``/``moe_dispatch``
    select the MoE drop rule and token layout (see ``moe.moe_apply``).
    Returns (x, cache, counts) where counts is (E,) or (n_rows, E)."""
    B, Sq, d = x.shape
    h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        if paged is not None:
            if prefill:
                # paged["lengths"] are TOTAL prompt lengths (prefix + the
                # suffix being computed); the ``lengths`` param carries the
                # suffix lengths for the non-paged (mamba) positions.
                attn_out, cache = L.attention_prefill_paged(
                    bp["attn"], cfg.attn, h, cache, paged["table"],
                    paged["start"], paged["lengths"],
                    has_prefix=paged["has_prefix"])
            else:
                attn_out, cache = L.attention_decode_paged(
                    bp["attn"], cfg.attn, h, pos_idx, cache,
                    paged["table"], paged["write_blk"], paged["write_off"])
        elif prefill:
            attn_out, cache = L.attention_prefill(bp["attn"], cfg.attn, h,
                                                  cache, lengths=lengths)
        else:
            attn_out, cache = L.attention_decode(bp["attn"], cfg.attn, h,
                                                 pos_idx, cache)
        if cfg.is_encoder_decoder:
            x = x + attn_out
            hc = L.rmsnorm(bp["norm_cross"], x, cfg.norm_eps)
            attn_out = L.cross_attention(bp["cross"], cfg.attn, hc,
                                         cross_kv["k"], cross_kv["v"])
    else:
        if prefill:
            attn_out, cache = S.ssd_forward(bp["mamba"], cfg.ssm,
                                            cfg.d_model, h, lengths=lengths)
        else:
            attn_out, cache = S.ssd_decode_step(bp["mamba"], cfg.ssm,
                                                cfg.d_model, h, cache)
    x = x + attn_out
    h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
    # Per-row capacity needs the row count even when per-row counts were
    # not requested; the counts selection below still keys on the caller's
    # ``n_rows`` so the emitted telemetry shape is unchanged.
    n_rows_ffn = n_rows if n_rows is not None \
        else (B if row_capacity is not None else None)
    y, aux = _apply_ffn(bp, cfg, pos, h.reshape(B * Sq, d), capacity, bank,
                        token_valid=token_valid, n_rows=n_rows_ffn,
                        row_capacity=row_capacity, moe_dispatch=moe_dispatch)
    if aux is None:
        counts = None
    elif n_rows is not None and aux.row_counts is not None:
        counts = aux.row_counts
    else:
        # Per-row counts unavailable (shard_map expert parallelism) — fall
        # back to the aggregated (E,) counts rather than dropping the
        # hotness signal entirely. Consumers must branch on ndim.
        counts = aux.counts
    return x + y.reshape(B, Sq, d), cache, counts


# --------------------------------------------------------------------------
# Encoder (audio)
# --------------------------------------------------------------------------

def encode(params: Dict, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, Senc, d) stub frontend output → encoder hidden states."""
    def body(x, bp):
        h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
        x = x + L.attention_full(bp["attn"], cfg.attn, h, causal=False)
        h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
        return x + M.gelu_mlp(bp["mlp"], h), None
    x, _ = _scan(body, frames, params["encoder"])
    return L.rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)


# --------------------------------------------------------------------------
# Embedding / head
# --------------------------------------------------------------------------

def _embed_inputs(params: Dict, cfg: ArchConfig, batch: Dict) -> jax.Array:
    x = params["embed"][batch["tokens"]]  # (B, S, d)
    if cfg.family == "vlm" and "image_embeds" in batch:
        x = jnp.concatenate(
            [batch["image_embeds"].astype(x.dtype), x], axis=1)
    return x


def _lm_logits(params: Dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head).astype(jnp.float32)


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------

def forward_train(params: Dict, cfg: ArchConfig, batch: Dict,
                  capacity_factor: Optional[float] = None,
                  remat: bool = True):
    """Full causal forward. Returns (logits (B,S,V) f32, aux dict)."""
    sb = cfg.superblock_or_default()
    x = _embed_inputs(params, cfg, batch)
    B, Stot, d = x.shape
    cap = X.moe_capacity(B * Stot, cfg.moe, capacity_factor) if cfg.is_moe else 0
    enc_out = encode(params, cfg, batch["audio_embeds"]) \
        if cfg.is_encoder_decoder else None

    def sb_body(carry, bp_sliced):
        x, aux_sum = carry
        counts_out = {}
        for pos, kind in enumerate(sb):
            x, counts, aux = _block_train(bp_sliced[str(pos)], cfg, pos, kind,
                                          x, cap, None, enc_out)
            aux_sum = aux_sum + aux
            if counts is not None:
                counts_out[str(pos)] = counts
        return (x, aux_sum), counts_out

    body = jax.checkpoint(sb_body) if remat else sb_body
    (x, aux_sum), counts = _scan(body, (x, jnp.float32(0)),
                                        params["blocks"])
    logits = _lm_logits(params, cfg, x)
    return logits, {"aux_loss": aux_sum, "counts": counts}


def prefill(params: Dict, cfg: ArchConfig, batch: Dict, caches: DecodeCaches,
            bank=None, capacity_factor: Optional[float] = None,
            lengths: Optional[jax.Array] = None,
            per_row_counts: bool = False,
            row_capacity: Optional[int] = None,
            moe_dispatch: Optional[str] = None):
    """Full forward writing caches. Returns (last-token logits (B,V),
    caches, counts).

    ``lengths`` ((B,) int32) enables padded, masked prefill: each row's true
    length within the (right-padded) batch. Logits are gathered at
    ``lengths - 1`` per row, padded positions are excluded from MoE dispatch
    and every router count, attention/SSM cache writes stop at each row's
    last real token, and a ``lengths == 0`` row is fully inert (a batch-pad
    row). Padding must be on the right; causal masking then keeps it out of
    every valid position's attention for free.

    ``per_row_counts=True`` returns counts values of shape (nsb, B, E)
    (per-row routing telemetry) instead of the aggregated (nsb, E).
    ``row_capacity``/``moe_dispatch``: MoE drop rule and token layout
    (``moe.moe_apply``); ``row_capacity`` requires per-row counts.
    """
    sb = cfg.superblock_or_default()
    x = _embed_inputs(params, cfg, batch)
    B, Stot, d = x.shape
    cap = X.moe_capacity(B * Stot, cfg.moe, capacity_factor) if cfg.is_moe else 0
    token_valid = None
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
        token_valid = (jnp.arange(Stot)[None, :] <
                       lengths[:, None]).reshape(-1)
    n_rows = B if per_row_counts else None

    cross = caches.cross
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, batch["audio_embeds"])

        def fill_cross(bp_sliced):
            out = {}
            for pos, kind in enumerate(sb):
                if kind == "attn":
                    k, v = L.encode_cross_kv(bp_sliced[str(pos)]["cross"],
                                             cfg.attn, enc_out)
                    out = {"k": k, "v": v}
            return out
        cross = jax.vmap(fill_cross)(params["blocks"])

    def sb_body(x, xs):
        if bank is not None:
            bp_sliced, cache_sliced, cross_sliced, bank_sliced = xs
        else:
            bp_sliced, cache_sliced, cross_sliced = xs
            bank_sliced = None
        counts_out, new_caches = {}, {}
        for pos, kind in enumerate(sb):
            x, c, counts = _block_step(bp_sliced[str(pos)], cfg, pos, kind, x,
                                       cache_sliced[str(pos)], None, cap,
                                       bank_sliced, cross_sliced,
                                       prefill=True, lengths=lengths,
                                       token_valid=token_valid,
                                       n_rows=n_rows,
                                       row_capacity=row_capacity,
                                       moe_dispatch=moe_dispatch)
            new_caches[str(pos)] = c
            if counts is not None:
                counts_out[str(pos)] = counts
        return x, (new_caches, counts_out)

    xs = (params["blocks"], caches.blocks, cross)
    if bank is not None:
        xs = xs + (bank,)
    x, (new_blocks, counts) = _scan(sb_body, x, xs)
    if lengths is None:
        x_last = x[:, -1:, :]
    else:
        last = jnp.clip(lengths - 1, 0, Stot - 1)
        x_last = x[jnp.arange(B), last][:, None, :]
    logits = _lm_logits(params, cfg, x_last)[:, 0]
    return logits, DecodeCaches(blocks=new_blocks, cross=cross), counts


def decode_step(params: Dict, cfg: ArchConfig, token: jax.Array,
                pos_idx: jax.Array, caches: DecodeCaches, bank=None,
                capacity_factor: float = 2.0,
                row_valid: Optional[jax.Array] = None,
                per_row_counts: bool = False,
                row_capacity: Optional[int] = None,
                moe_dispatch: Optional[str] = None):
    """One-token decode. token: (B,) int32; pos_idx: scalar int32 position,
    or a (B,) int32 vector of per-sequence positions (continuous batching —
    each KV-cache slot advances at its own request's offset).
    Returns (logits (B,V), caches, counts).

    ``row_valid`` ((B,) bool) marks which rows carry real requests: invalid
    (vacant continuous-batching) rows are dropped from MoE dispatch,
    capacity and all router counts, so their replayed tokens cannot
    contaminate hotness or offload accounting. Their logits are garbage and
    must not be read. ``per_row_counts=True`` returns counts values shaped
    (nsb, B, E) instead of the aggregated (nsb, E). ``row_capacity``
    normalizes MoE drops per row; ``moe_dispatch`` picks the token layout
    — ``"ragged"`` routes every MoE layer of this step through the
    padding-free compacted dispatch + fused mixed-precision kernel."""
    sb = cfg.superblock_or_default()
    x = params["embed"][token][:, None, :]  # (B, 1, d)
    B = x.shape[0]
    cap = X.moe_capacity(B, cfg.moe, capacity_factor) if cfg.is_moe else 0
    token_valid = None if row_valid is None \
        else jnp.asarray(row_valid, bool).reshape(-1)
    n_rows = B if per_row_counts else None

    def sb_body(x, xs):
        if bank is not None:
            bp_sliced, cache_sliced, cross_sliced, bank_sliced = xs
        else:
            bp_sliced, cache_sliced, cross_sliced = xs
            bank_sliced = None
        counts_out, new_caches = {}, {}
        for pos, kind in enumerate(sb):
            x, c, counts = _block_step(bp_sliced[str(pos)], cfg, pos, kind, x,
                                       cache_sliced[str(pos)], pos_idx, cap,
                                       bank_sliced, cross_sliced,
                                       prefill=False,
                                       token_valid=token_valid,
                                       n_rows=n_rows,
                                       row_capacity=row_capacity,
                                       moe_dispatch=moe_dispatch)
            new_caches[str(pos)] = c
            if counts is not None:
                counts_out[str(pos)] = counts
        return x, (new_caches, counts_out)

    xs = (params["blocks"], caches.blocks, caches.cross)
    if bank is not None:
        xs = xs + (bank,)
    x, (new_blocks, counts) = _scan(sb_body, x, xs)
    logits = _lm_logits(params, cfg, x)[:, 0]
    return logits, DecodeCaches(blocks=new_blocks, cross=caches.cross), counts


# --------------------------------------------------------------------------
# Paged entry points (block-table KV, see repro.serving.kvpool)
# --------------------------------------------------------------------------

def prefill_paged(params: Dict, cfg: ArchConfig, batch: Dict,
                  caches: DecodeCaches, block_table: jax.Array,
                  start: jax.Array, lengths: jax.Array, bank=None,
                  capacity_factor: Optional[float] = None,
                  per_row_counts: bool = False, has_prefix: bool = False,
                  row_capacity: Optional[int] = None,
                  moe_dispatch: Optional[str] = None):
    """Masked prefill of prompt SUFFIXES into the paged KV pool.

    ``batch["tokens"]``: (R, S) rows holding tokens ``start[r]`` ..
    ``lengths[r]-1`` right-padded to the bucket width S; ``lengths`` are
    TOTAL prompt lengths, so ``lengths - start`` are the per-row suffix
    lengths (0 ⇒ inert batch-pad row). ``block_table``: (R, nb) physical
    block ids (the engine pre-resolves allocation and copy-on-write).
    ``has_prefix=True`` (static) additionally attends each suffix over its
    row's cached prefix blocks — the prefix-sharing fast path that skips
    recomputing trie-hit tokens entirely. Prefix skips are only valid for
    attention-state stacks: rows of stacks with mamba positions must have
    ``start == 0`` (their recurrent state cannot be leased from a cache).

    Returns (suffix-last-token logits (R, V), caches, counts); attention
    leaves of ``caches`` are the UPDATED shared pools."""
    sb = cfg.superblock_or_default()
    if cfg.is_encoder_decoder:
        raise NotImplementedError("paged prefill is decoder-only")
    x = _embed_inputs(params, cfg, batch)
    R, Stot, d = x.shape
    cap = X.moe_capacity(R * Stot, cfg.moe, capacity_factor) if cfg.is_moe \
        else 0
    start = jnp.asarray(start, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    suffix_lens = lengths - start
    token_valid = (jnp.arange(Stot)[None, :] <
                   suffix_lens[:, None]).reshape(-1)
    n_rows = R if per_row_counts else None
    paged = {"table": block_table, "start": start, "lengths": lengths,
             "has_prefix": has_prefix}

    def sb_body(x, xs):
        if bank is not None:
            bp_sliced, cache_sliced, bank_sliced = xs
        else:
            bp_sliced, cache_sliced = xs
            bank_sliced = None
        counts_out, new_caches = {}, {}
        for pos, kind in enumerate(sb):
            x, c, counts = _block_step(bp_sliced[str(pos)], cfg, pos, kind, x,
                                       cache_sliced[str(pos)], None, cap,
                                       bank_sliced, None,
                                       prefill=True, lengths=suffix_lens,
                                       token_valid=token_valid,
                                       n_rows=n_rows, paged=paged,
                                       row_capacity=row_capacity,
                                       moe_dispatch=moe_dispatch)
            new_caches[str(pos)] = c
            if counts is not None:
                counts_out[str(pos)] = counts
        return x, (new_caches, counts_out)

    xs = (params["blocks"], caches.blocks)
    if bank is not None:
        xs = xs + (bank,)
    x, (new_blocks, counts) = _scan(sb_body, x, xs)
    last = jnp.clip(suffix_lens - 1, 0, Stot - 1)
    x_last = x[jnp.arange(R), last][:, None, :]
    logits = _lm_logits(params, cfg, x_last)[:, 0]
    return logits, DecodeCaches(blocks=new_blocks, cross=None), counts


def decode_step_paged(params: Dict, cfg: ArchConfig, token: jax.Array,
                      pos_idx: jax.Array, caches: DecodeCaches,
                      block_table: jax.Array, write_blk: jax.Array,
                      write_off: jax.Array, bank=None,
                      capacity_factor: float = 2.0,
                      row_valid: Optional[jax.Array] = None,
                      per_row_counts: bool = False,
                      row_capacity: Optional[int] = None,
                      moe_dispatch: Optional[str] = None):
    """One-token decode against the paged KV pool: ``decode_step`` with the
    attention cache addressed through per-row block tables. ``write_blk``/
    ``write_off`` ((B,) int32) name each row's pre-resolved physical write
    target (vacant rows point at the trash block). Semantics otherwise
    identical to ``decode_step`` — the gathered logical view equals the
    dense per-slot cache bit for bit."""
    sb = cfg.superblock_or_default()
    x = params["embed"][token][:, None, :]  # (B, 1, d)
    B = x.shape[0]
    cap = X.moe_capacity(B, cfg.moe, capacity_factor) if cfg.is_moe else 0
    token_valid = None if row_valid is None \
        else jnp.asarray(row_valid, bool).reshape(-1)
    n_rows = B if per_row_counts else None
    paged = {"table": block_table, "write_blk": write_blk,
             "write_off": write_off}

    def sb_body(x, xs):
        if bank is not None:
            bp_sliced, cache_sliced, bank_sliced = xs
        else:
            bp_sliced, cache_sliced = xs
            bank_sliced = None
        counts_out, new_caches = {}, {}
        for pos, kind in enumerate(sb):
            x, c, counts = _block_step(bp_sliced[str(pos)], cfg, pos, kind, x,
                                       cache_sliced[str(pos)], pos_idx, cap,
                                       bank_sliced, None,
                                       prefill=False,
                                       token_valid=token_valid,
                                       n_rows=n_rows, paged=paged,
                                       row_capacity=row_capacity,
                                       moe_dispatch=moe_dispatch)
            new_caches[str(pos)] = c
            if counts is not None:
                counts_out[str(pos)] = counts
        return x, (new_caches, counts_out)

    xs = (params["blocks"], caches.blocks)
    if bank is not None:
        xs = xs + (bank,)
    x, (new_blocks, counts) = _scan(sb_body, x, xs)
    logits = _lm_logits(params, cfg, x)[:, 0]
    return logits, DecodeCaches(blocks=new_blocks, cross=None), counts


# --------------------------------------------------------------------------
# Speculative decoding entry points (multi-token draft / verify)
# --------------------------------------------------------------------------
#
# Both run S chained single-token decode steps under ONE ``lax.scan`` — one
# device dispatch advances every row by S positions. Each scan iteration IS
# ``decode_step``/``decode_step_paged``, so every per-position computation
# (attention reduction order, MoE capacity = moe_capacity(B), masked cache
# writes) is identical to the engine's sequential decode — token parity with
# the non-speculative path holds by construction, the same way the paged
# attention shares ``_attend_cache`` with the dense path. (A width-S fused
# verify forward — the arithmetic-intensity win on real accelerators — is a
# kernel follow-up; it would trade this bit-parity for throughput.)

def _mamba_position_keys(cfg: ArchConfig) -> tuple:
    sb = cfg.superblock_or_default()
    return tuple(str(p) for p, k in enumerate(sb) if k != "attn")


def spec_draft(params: Dict, cfg: ArchConfig, token: jax.Array,
               pos: jax.Array, caches: DecodeCaches, row_valid: jax.Array,
               bank=None, capacity_factor: float = 2.0,
               paged: Optional[Dict] = None,
               row_capacity: Optional[int] = None,
               moe_dispatch: Optional[str] = None):
    """Draft ``S = row_valid.shape[0]`` greedy tokens per row by chaining
    decode steps (each step's argmax feeds the next step's embedding).

    ``token``: (B,) the last emitted token per row; ``pos``: (B,) the first
    write position; ``row_valid``: (S, B) per-STEP validity (a row past its
    own draft depth is masked out of MoE dispatch and counts but still rides
    for shape stability). ``paged``: ``{"table": (B, nb), "write_blk"/
    "write_off": (S, B)}`` pre-resolved physical write lanes (the engine
    routes beyond-depth and vacant lanes to the trash block).

    Passing an all-lo ``bank`` (every ``slot_owner`` = -1) turns the
    always-resident low-precision fallback tier into the draft model — no
    extra weights exist, the lo tier IS the speculator; under
    ``moe_dispatch="ragged"`` each draft step runs the same padding-free
    fused kernel as the target decode (the slot derivation reads the
    disowned handles, so every tile streams lo — no separate all-lo GEMM
    path). Returns ``(drafted (S, B) int32, caches)``; counts are not
    emitted (draft traffic must never feed hotness)."""
    S = row_valid.shape[0]

    def body(carry, xs):
        tok, c = carry
        if paged is not None:
            j, rv, wb, wo = xs
            logits, c, _ = decode_step_paged(
                params, cfg, tok, pos + j, c, paged["table"], wb, wo,
                bank=bank, capacity_factor=capacity_factor, row_valid=rv,
                row_capacity=row_capacity, moe_dispatch=moe_dispatch)
        else:
            j, rv = xs
            logits, c, _ = decode_step(
                params, cfg, tok, pos + j, c, bank=bank,
                capacity_factor=capacity_factor, row_valid=rv,
                row_capacity=row_capacity, moe_dispatch=moe_dispatch)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return (nxt, c), nxt

    xs = (jnp.arange(S, dtype=jnp.int32), row_valid)
    if paged is not None:
        xs = xs + (paged["write_blk"], paged["write_off"])
    (_, caches), drafted = jax.lax.scan(body, (token, caches), xs)
    return drafted, caches


def spec_verify(params: Dict, cfg: ArchConfig, tokens: jax.Array,
                pos: jax.Array, caches: DecodeCaches, row_valid: jax.Array,
                bank=None, capacity_factor: float = 2.0,
                paged: Optional[Dict] = None,
                row_capacity: Optional[int] = None,
                moe_dispatch: Optional[str] = None):
    """Verify ``S`` positions in one dispatch: chained decode steps over the
    given tokens (row r, step j consumes ``tokens[j, r]`` at position
    ``pos[r] + j``) under the TARGET (mixed-precision) bank.

    Returns ``(logits (S, B, V), caches, counts, ssm_states)``:

    * ``logits[j]`` is the next-token distribution after consuming
      ``tokens[:j+1]`` — position j's draft is judged against
      ``logits[j-1]`` and ``logits[a]`` supplies the bonus token;
    * ``counts`` values are per-step stacked ((S, nsb, B, E)) so the engine
      can keep REJECTED positions out of the hotness signal;
    * ``ssm_states`` maps each mamba position to its per-step stacked cache
      ((S, nsb, B, ...)) — rejection rolls a row's recurrent state back to
      exactly the last accepted step, no recompute."""
    mkeys = _mamba_position_keys(cfg)

    def body(c, xs):
        if paged is not None:
            tok, j, rv, wb, wo = xs
            logits, c, counts = decode_step_paged(
                params, cfg, tok, pos + j, c, paged["table"], wb, wo,
                bank=bank, capacity_factor=capacity_factor, row_valid=rv,
                per_row_counts=True, row_capacity=row_capacity,
                moe_dispatch=moe_dispatch)
        else:
            tok, j, rv = xs
            logits, c, counts = decode_step(
                params, cfg, tok, pos + j, c, bank=bank,
                capacity_factor=capacity_factor, row_valid=rv,
                per_row_counts=True, row_capacity=row_capacity,
                moe_dispatch=moe_dispatch)
        ssm = {p: c.blocks[p] for p in mkeys}
        return c, (logits, counts, ssm)

    S = tokens.shape[0]
    xs = (tokens, jnp.arange(S, dtype=jnp.int32), row_valid)
    if paged is not None:
        xs = xs + (paged["write_blk"], paged["write_off"])
    caches, (logits, counts, ssm) = jax.lax.scan(body, caches, xs)
    return logits, caches, counts, ssm


