"""MoE serving engine with DynaExq mixed-precision residency.

Modes:
* ``fp16``    — dense bf16 experts (quality/latency reference)
* ``static``  — uniform static PTQ (paper's static baseline): lo tier only
* ``dynaexq`` — lo tier + budget-derived hi pool driven by the online
                controller (the paper's system)

The engine owns the jitted prefill/decode closures, the per-MoE-position
expert banks + controllers, and the serving loop instrumentation (TTFT,
TPOP, router-trace observation, window updates).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ControllerConfig, DynaExqController, build_bank,
                        expert_hi_nbytes, expert_lo_nbytes, plan_budget)
from repro.models import (decode_step, init_caches, prefill)
from repro.models.config import ArchConfig

GiB = 1 << 30


@dataclasses.dataclass
class ServeConfig:
    mode: str = "dynaexq"            # dynaexq | static | fp16
    lo_bits: int = 4
    hi_bits: int = 16
    group_size: int = 64
    hbm_gb: Optional[float] = None   # derive n_hi from a device envelope
    n_hi_per_layer: Optional[int] = None  # or set it directly
    max_len: int = 512
    capacity_factor: float = 2.0
    controller: ControllerConfig = dataclasses.field(
        default_factory=ControllerConfig)
    activation_slack_bytes: int = 64 << 20


def _param_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "dtype"))


class MoEServer:
    def __init__(self, cfg: ArchConfig, params: Dict, scfg: ServeConfig,
                 batch: int):
        self.cfg = cfg
        self.scfg = scfg
        self.batch = batch
        sb = cfg.superblock_or_default()
        self.moe_positions = [p for p, _ in enumerate(sb)
                              if cfg.ffn_kind(p) == "moe"] if cfg.is_moe else []
        self.controllers: Dict[str, DynaExqController] = {}
        self.banks = None
        self.params = params
        self.stats = {"steps": 0, "prefills": 0}

        if scfg.mode != "fp16" and self.moe_positions:
            self._build_banks()

        self._jit_prefill = jax.jit(
            lambda p, b, c, banks: prefill(
                p, cfg, b, c, bank=banks,
                capacity_factor=scfg.capacity_factor))
        self._jit_decode = jax.jit(
            lambda p, t, i, c, banks: decode_step(
                p, cfg, t, i, c, bank=banks,
                capacity_factor=scfg.capacity_factor))
        self.caches = None
        self.pos = 0
        self._counts_last: Dict = {}

    # ------------------------------------------------------------------
    def _build_banks(self):
        cfg, scfg = self.cfg, self.scfg
        banks = {}
        for pos in self.moe_positions:
            experts = self.params["blocks"][str(pos)]["moe"]["experts"]
            shapes = {k: tuple(v.shape) for k, v in experts.items()}
            hi_b = expert_hi_nbytes(shapes, hi_bits=scfg.hi_bits,
                                    group_size=scfg.group_size)
            lo_b = expert_lo_nbytes(shapes, scfg.lo_bits, scfg.group_size)
            L = experts["w_gate"].shape[0]
            E = experts["w_gate"].shape[1]
            n_hi = 0
            if scfg.mode == "dynaexq":
                if scfg.n_hi_per_layer is not None:
                    n_hi = scfg.n_hi_per_layer
                elif scfg.hbm_gb is not None:
                    nonexp = _param_bytes({k: v for k, v in self.params.items()
                                           if k != "blocks"})
                    kv_b = self._kv_bytes()
                    plan = plan_budget(
                        m_total=int(scfg.hbm_gb * GiB),
                        m_fixed=nonexp + kv_b + scfg.activation_slack_bytes,
                        lo_bytes_total=lo_b * L * E,
                        hi_bytes_per_expert_layer=hi_b,
                        n_layers=L, num_experts=E)
                    n_hi = plan.n_hi_per_layer
                else:
                    n_hi = max(1, E // 8)
            host_hi = {k: np.asarray(v) for k, v in experts.items()}
            bank = build_bank(experts, n_hi=n_hi, lo_bits=scfg.lo_bits,
                              group_size=scfg.group_size,
                              hi_bits=scfg.hi_bits)
            banks[str(pos)] = bank
            if scfg.mode == "dynaexq" and n_hi > 0:
                self.controllers[str(pos)] = DynaExqController(
                    bank, host_hi, n_hi_per_layer=n_hi,
                    hi_bytes_per_expert=hi_b, cfg=scfg.controller)
            # Free the dense copies — the bank is now the only residency.
            self.params["blocks"][str(pos)]["moe"]["experts"] = None
        self.banks = banks

    def _kv_bytes(self) -> int:
        cfg = self.cfg
        if cfg.attn is None:
            return 0
        sb = cfg.superblock_or_default()
        n_attn = sum(1 for k in sb if k == "attn") * cfg.n_superblocks()
        cap = self.scfg.max_len if cfg.attn.sliding_window is None else \
            min(self.scfg.max_len, cfg.attn.sliding_window)
        return (2 * self.batch * cap * cfg.attn.n_kv_heads *
                cfg.attn.head_dim * 2 * n_attn)

    def _current_banks(self):
        if self.banks is None:
            return None
        out = {}
        for pos in self.moe_positions:
            k = str(pos)
            out[k] = self.controllers[k].bank if k in self.controllers \
                else self.banks[k]
        return out

    # ------------------------------------------------------------------
    def start(self, batch: Dict) -> tuple[jax.Array, float]:
        """Prefill. Returns (last-token logits, wall seconds)."""
        extra = batch["tokens"].shape[1] + self.cfg.num_image_tokens
        self.caches = init_caches(self.cfg, self.batch,
                                  max(self.scfg.max_len, extra))
        t0 = time.perf_counter()
        logits, self.caches, counts = self._jit_prefill(
            self.params, batch, self.caches, self._current_banks())
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.pos = extra
        self._observe(counts)
        self.stats["prefills"] += 1
        return logits, dt

    def step(self, tokens: jax.Array) -> tuple[jax.Array, float]:
        """One decode step for the whole batch."""
        t0 = time.perf_counter()
        logits, self.caches, counts = self._jit_decode(
            self.params, tokens, jnp.int32(self.pos), self.caches,
            self._current_banks())
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.pos += 1
        self._observe(counts)
        self.stats["steps"] += 1
        return logits, dt

    def generate(self, batch: Dict, n_tokens: int):
        """Greedy generation; returns (tokens, ttft_s, per_token_s list)."""
        logits, ttft = self.start(batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out, times = [tok], []
        for _ in range(n_tokens - 1):
            logits, dt = self.step(tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(tok)
            times.append(dt)
        return jnp.stack(out, 1), ttft, times

    # ------------------------------------------------------------------
    def _observe(self, counts: Dict) -> None:
        self._counts_last = counts
        if not self.controllers:
            return
        for k, ctl in self.controllers.items():
            c = counts.get(k)
            if c is not None:
                ctl.observe(np.asarray(c))
            ctl.maybe_update()

    def force_update(self) -> None:
        for ctl in self.controllers.values():
            ctl.update()

    def flush(self) -> None:
        for ctl in self.controllers.values():
            ctl.flush()

    # Introspection for benchmarks/tests -------------------------------
    def hi_sets(self) -> Dict[str, list]:
        out = {}
        for k, ctl in self.controllers.items():
            L = ctl.tm.slot_map_h.shape[0]
            out[k] = [sorted(ctl.tm.hi_set(l)) for l in range(L)]
        return out

    def expert_device_bytes(self) -> int:
        """Resident expert bytes under the budget model (lo + hi tiers)."""
        if self.banks is None:
            total = 0
            for pos in self.moe_positions:
                total += _param_bytes(
                    self.params["blocks"][str(pos)]["moe"]["experts"])
            return total
        total = 0
        for k, bank in self.banks.items():
            # bank.lo[n].shape is the logical dense shape (L, E, K, N).
            shapes = {n: tuple(q.shape) for n, q in bank.lo.items()}
            L, E = bank.slot_map.shape
            per_lo = expert_lo_nbytes(shapes, self.scfg.lo_bits,
                                      self.scfg.group_size)   # one expert-layer
            per_hi = expert_hi_nbytes(shapes, hi_bits=self.scfg.hi_bits,
                                      group_size=self.scfg.group_size)
            n_resident = int((np.asarray(bank.slot_owner) >= 0).sum())
            total += per_lo * L * E + n_resident * per_hi
        return total
