"""Observability layer: flight-recorder tracing, metrics, trace cost model.

``Observability`` bundles the two live components the serving engine
threads through the stack:

* ``tracer`` — a ``FlightRecorder`` (bounded typed-event ring buffer,
  Chrome trace-event export) whose clock the engine rebinds to its own
  ``_now()`` so virtual-clock replays trace deterministically;
* ``metrics`` — a ``MetricsRegistry`` (counters/gauges/histograms,
  Prometheus text exposition, optional JSONL per-step sink).

Both are optional and independently disableable; a ``None`` observability
object (the default everywhere) keeps every instrumentation site a pointer
check — the decode hot path is untouched.

``repro.obs.costmodel`` replays a recorded trace offline into measured
bytes/token and validates ``launch/roofline.py``'s analytic model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry)
from repro.obs.trace import (FlightRecorder, TraceEvent,   # noqa: F401
                             load_chrome_trace)


@dataclasses.dataclass
class ObsConfig:
    trace: bool = True               # flight recorder on?
    trace_capacity: int = 1 << 16    # ring-buffer events
    metrics: bool = True             # metrics registry on?
    metrics_jsonl: Optional[str] = None   # per-step JSONL sink path
    sample_every: int = 1            # metrics sampling cadence (steps)


class Observability:
    """The engine-facing bundle: construct once, pass to
    ``InferenceEngine(..., obs=...)``."""

    def __init__(self, cfg: Optional[ObsConfig] = None):
        self.cfg = cfg if cfg is not None else ObsConfig()
        self.tracer: Optional[FlightRecorder] = \
            FlightRecorder(self.cfg.trace_capacity) if self.cfg.trace \
            else None
        self.metrics: Optional[MetricsRegistry] = \
            MetricsRegistry(self.cfg.metrics_jsonl) if self.cfg.metrics \
            else None

    def save_trace(self, path: str) -> None:
        if self.tracer is None:
            raise ValueError("tracing disabled (ObsConfig.trace=False)")
        self.tracer.save(path)

    def summary(self) -> Dict:
        """Shutdown one-liner material: promotion publish percentiles and
        the roofline residual (from the live recorder) plus the metrics
        snapshot."""
        out: Dict = {}
        if self.tracer is not None:
            from repro.obs import costmodel
            out.update(costmodel.report(self.tracer))
            out["trace_events"] = len(self.tracer)
            out["trace_dropped"] = self.tracer.dropped
        if self.metrics is not None:
            out["metrics"] = self.metrics.snapshot()
        return out

    def close(self) -> None:
        if self.metrics is not None:
            self.metrics.close()


__all__ = [
    "Counter", "FlightRecorder", "Gauge", "Histogram", "MetricsRegistry",
    "ObsConfig", "Observability", "TraceEvent", "load_chrome_trace",
]
