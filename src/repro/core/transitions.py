"""Non-blocking transition pipeline (paper §3.4), JAX realization.

Queues + admission control + publish-then-switch:

* ``request_promotion/request_demotion`` enqueue candidates (from the policy).
* ``drain()`` processes demotions first (reclaiming capacity enlarges the
  feasible set — the paper's eviction priority), then admits promotions that
  pass BOTH gates: the byte budget (``BudgetTracker.try_reserve``) and the
  per-window migration-rate limit (bounded interference).
* An admitted promotion allocates a slot from the layer's ``SlotPool`` and
  issues the hi-weight copy (``write_hi_slot``). JAX dispatch is async — this
  is the migration-stream analogue: the copy is independent of the in-flight
  serve step because the slot is unpublished.
* ``publish_ready()`` — called at a window boundary — publishes completed
  copies by writing ``slot_map``/``slot_owner``. A copy is "complete" when
  its result array is ready (the CUDA-event analogue).

The forward pass never observes a partially-materialized version: ``slot_map``
only ever points at slots whose copies completed.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.budget import BudgetTracker
from repro.core.pools import ShardedSlotPool
from repro.core.ver import ExpertBankQ, Residency, write_hi_slot
from repro.fault.inject import TransferFault
from repro.fault.retry import RetryExhausted, RetryPolicy, retry_call


@dataclasses.dataclass
class PendingPromotion:
    layer: int
    expert: int
    slot: int
    nbytes: int
    # THIS copy's result arrays (one per bank leaf). Readiness must be
    # probed on these — ``bank.hi`` is overwritten by every later
    # ``_issue_copy``, so peeking the bank would let an older promotion
    # publish on a newer copy's completion (and vice versa).
    arrays: tuple = ()
    # Flight-recorder lifecycle: async-span correlation id and the engine-
    # clock issue timestamp (publish latency = publish ts − issue ts).
    seq: int = 0
    issue_ts: float = 0.0
    # Fault state: an injected DMA stall keeps the copy "in flight" until
    # the clock passes ``stall_until``; a corrupt payload is caught by the
    # publish-time integrity check and never published; ``cancelled`` makes
    # the refund idempotent no matter which path cancels first.
    stall_until: float = 0.0
    corrupt: bool = False
    cancelled: bool = False


class TransitionManager:
    def __init__(self, bank: ExpertBankQ,
                 host_hi: Dict[str, np.ndarray],
                 tracker: BudgetTracker,
                 hi_bytes_per_expert: int,
                 migration_bytes_per_window: int = 0,
                 n_shards: int = 1,
                 shard_trackers: Optional[Sequence[BudgetTracker]] = None):
        """``host_hi``: name → (L, E, K, N) host copies of the hi tier (the
        paper's pre-packed pinned-host source). ``migration_bytes_per_window``
        0 = unlimited. Under expert parallelism (``n_shards > 1``) the hi
        pool's slot dim is sharded: expert ``e`` lives on shard
        ``e // (E/n_shards)`` and may only occupy that shard's slots;
        ``shard_trackers`` (one per shard) price each shard's hi slots
        against its LOCAL HBM — without them all shards bill ``tracker``."""
        self.bank = bank
        self.host_hi = host_hi
        self.tracker = tracker
        self.hi_bytes = hi_bytes_per_expert
        self.rate_limit = migration_bytes_per_window
        L, n_hi = bank.slot_owner.shape
        E = bank.num_experts
        if n_shards > 1 and E % n_shards:
            raise ValueError(f"num_experts={E} not divisible by n_shards={n_shards}")
        if shard_trackers is not None and len(shard_trackers) != n_shards:
            raise ValueError("need one shard tracker per shard")
        self.n_shards = n_shards
        self.e_per_shard = E // n_shards
        self.shard_trackers = list(shard_trackers) if shard_trackers else None
        self.pools = [ShardedSlotPool(n_hi, n_shards) for _ in range(L)]
        self.state = np.full((L, bank.num_experts), Residency.RESIDENT_LO.value,
                             np.int8)
        self.update_q: deque[tuple[int, int]] = deque()
        self.evict_q: deque[tuple[int, int]] = deque()
        self._pending: List[PendingPromotion] = []
        # Host mirrors of the published device maps (authoritative copies —
        # reading device arrays back every window would sync the stream).
        self.slot_map_h = np.asarray(bank.slot_map).copy()
        self.slot_owner_h = np.asarray(bank.slot_owner).copy()
        # One per-window transfer meter shared by promotion admission AND
        # EP ownership migrations (relabeling bytes) — both ride the same
        # interconnect, so they contend for the same budget. ``drain()``
        # opens a fresh window; migrations spend whatever the window's
        # promotions left.
        self._window_used = 0
        self.stats = {"promoted": 0, "demoted": 0, "deferred": 0,
                      "bytes_moved": 0, "retries": 0, "fault_cancels": 0}
        # Observability (attached by the backend, None by default): every
        # hook below guards on ``tracer is not None`` — with observability
        # off the pipeline allocates nothing extra.
        self.tracer = None                  # repro.obs.trace.FlightRecorder
        self.publish_hist = None            # metrics Histogram (publish lat)
        # Fault tolerance (same pointer-check discipline as obs). ``clock``
        # is rebound to the engine clock so promotion ages — the watchdog's
        # input — ride the virtual clock under replay.
        self.injector = None                # repro.fault.inject.FaultInjector
        self.retry = RetryPolicy()
        self.clock = time.monotonic
        self.fail_cb = None                 # controller failure-decay hook
        # Sum of reservations issued but neither published nor cancelled.
        # ``check_invariants`` pins this to the open promotion spans —
        # the exactly-once-refund audit.
        self.inflight_bytes = 0

    # -- shard plumbing ---------------------------------------------------
    def shard_of_expert(self, expert: int) -> int:
        return expert // self.e_per_shard

    def _tracker_for(self, shard: int) -> BudgetTracker:
        return self.shard_trackers[shard] if self.shard_trackers else self.tracker

    # -- queue side ------------------------------------------------------
    def request_promotion(self, layer: int, expert: int) -> None:
        if self.state[layer, expert] == Residency.RESIDENT_LO.value:
            self.state[layer, expert] = Residency.PROMOTING.value
            self.update_q.append((layer, expert))
            if self.tracer is not None:
                self.tracer.instant("promo_request", cat="residency",
                                    layer=layer, expert=expert)

    def request_demotion(self, layer: int, expert: int) -> None:
        if self.state[layer, expert] == Residency.RESIDENT_HI.value:
            self.state[layer, expert] = Residency.DEMOTING.value
            self.evict_q.append((layer, expert))
            if self.tracer is not None:
                self.tracer.instant("demo_request", cat="residency",
                                    layer=layer, expert=expert)

    def try_consume_window(self, nbytes: int) -> bool:
        """Charge ``nbytes`` against the current window's transfer budget
        (always succeeds when no rate limit is configured). The EP
        coordinator prices its relabeling bytes here, so rebalancing and
        promotions genuinely contend for one per-window budget."""
        if not self.rate_limit:
            return True
        if self._window_used + nbytes > self.rate_limit:
            return False
        self._window_used += nbytes
        return True

    # -- worker side -----------------------------------------------------
    def drain(self) -> None:
        """Process evictions, then admit promotions under both gates.
        Opens a fresh transfer window: promotions spend first, and any
        coordinator migrations until the next drain spend the remainder."""
        while self.evict_q:
            l, e = self.evict_q.popleft()
            if self.state[l, e] != Residency.DEMOTING.value:
                continue
            self._demote(l, e)
        self._window_used = 0
        deferred = deque()
        while self.update_q:
            l, e = self.update_q.popleft()
            if self.state[l, e] != Residency.PROMOTING.value:
                continue
            if self.rate_limit and \
                    self._window_used + self.hi_bytes > self.rate_limit:
                deferred.append((l, e))
                continue
            shard = self.shard_of_expert(e)
            if (self.pools[l].n_free_in(shard) == 0
                    or not self._tracker_for(shard).try_reserve(self.hi_bytes)):
                deferred.append((l, e))   # backpressure: stay queued
                self.stats["deferred"] += 1
                if self.tracer is not None:
                    self.tracer.instant("promo_deferred", cat="residency",
                                        layer=l, expert=e)
                continue
            slot = self.pools[l].alloc(e, shard)
            if self._issue_copy(l, e, slot):
                self._window_used += self.hi_bytes
        self.update_q = deferred

    def _issue_copy(self, layer: int, expert: int, slot: int) -> bool:
        """Async hi-weight copy into the (unpublished) pool slot. When the
        host side is a ``HostExpertStore`` (duck-typed via ``ensure_hi``),
        the expert's host rows are materialized first — on a streaming cold
        start that is the lazy read from the checkpoint shard.

        Fault path: injected ``promo_copy`` failures are retried under
        ``self.retry``; if the copy (or the host-side load underneath it)
        exhausts its retries, the admission is aborted — slot freed,
        reservation refunded, expert back to RESIDENT_LO, controller
        notified via ``fail_cb`` — and the expert keeps serving lo.
        Returns True iff the copy was issued."""
        fault = [None]

        def attempt():
            if self.injector is not None:
                f = self.injector.fire("promo_copy", layer=layer,
                                       expert=expert)
                if f is not None:
                    if f.kind == "fail":
                        raise TransferFault("promo_copy", seq=f.seq)
                    fault[0] = f        # stall / corrupt ride the copy
            ensure = getattr(self.host_hi, "ensure_hi", None)
            if ensure is not None:
                ensure(layer, expert)
            new_hi = {}
            for name, leaf in self.bank.hi.items():
                w = jnp.asarray(self.host_hi[name][layer, expert]).astype(
                    leaf.dtype)
                new_hi[name] = write_hi_slot(leaf, jnp.int32(layer),
                                             jnp.int32(slot), w)
            return new_hi

        seed = self.injector.seed if self.injector is not None else 0
        try:
            new_hi, retries, _ = retry_call(
                attempt, self.retry, seed=seed, key=(layer << 16) | expert,
                site="promo_copy", tracer=self.tracer)
        except (RetryExhausted, TransferFault) as e:
            self._abort_issue(layer, expert, slot, e)
            return False
        if retries:
            self.stats["retries"] += retries
        self.bank.hi = new_hi  # dispatched, not yet waited on
        p = PendingPromotion(layer, expert, slot, self.hi_bytes,
                             arrays=tuple(new_hi.values()))
        p.issue_ts = self.clock()
        f = fault[0]
        if f is not None:
            if f.kind == "stall":
                p.stall_until = p.issue_ts + f.stall_s
            elif f.kind == "corrupt":
                p.corrupt = True
        if self.tracer is not None:
            # Lifecycle span: opens at copy issue, closes at publish (or
            # cancellation) — per-phase timestamps on the engine clock.
            p.seq = self.tracer.next_id()
            self.tracer.async_begin("promotion", p.seq, cat="residency",
                                    layer=layer, expert=expert, slot=slot,
                                    bytes=self.hi_bytes)
        self._pending.append(p)
        self.inflight_bytes += self.hi_bytes
        self.stats["bytes_moved"] += self.hi_bytes
        return True

    def _abort_issue(self, layer: int, expert: int, slot: int,
                     err: Exception) -> None:
        """Unwind an admission whose copy never issued: the slot and the
        reservation go back, the expert stays lo, and the controller's
        failure-decay penalty keeps a flapping expert from livelocking the
        promotion budget."""
        self.pools[layer].free(slot)
        self._tracker_for(self.pools[layer].shard_of(slot)).release(
            self.hi_bytes)
        self.state[layer, expert] = Residency.RESIDENT_LO.value
        self.stats["fault_cancels"] += 1
        if self.fail_cb is not None:
            self.fail_cb(layer, expert)
        if self.tracer is not None:
            self.tracer.instant("fault_cancel", cat="fault", layer=layer,
                                expert=expert, site="promo_copy",
                                reason=type(err).__name__)

    def _demote(self, layer: int, expert: int) -> None:
        """Publish-then-reclaim: redirect the handle to lo, then free."""
        slot = int(self.slot_map_h[layer, expert])
        self.slot_map_h[layer, expert] = -1
        if slot >= 0:
            self.slot_owner_h[layer, slot] = -1
            self.pools[layer].free(slot)
            self._tracker_for(self.pools[layer].shard_of(slot)).release(
                self.hi_bytes)
        self.state[layer, expert] = Residency.RESIDENT_LO.value
        self.stats["demoted"] += 1
        if self.tracer is not None:
            self.tracer.instant("demotion", cat="residency", layer=layer,
                                expert=expert, slot=slot)

    def publish_ready(self, wait: bool = False) -> int:
        """Publish completed copies (window boundary). ``wait=True`` blocks on
        all in-flight copies (used at shutdown / in tests). Each pending
        promotion is probed on ITS OWN result arrays (``p.arrays``), never
        on the bank's current leaves — the bank only reflects the most
        recently issued copy."""
        if not self._pending:
            self._flush_maps()
            return 0
        still = []
        published = 0
        for p in self._pending:
            if not wait and p.stall_until > self.clock():
                # Injected DMA stall: the copy is "still on the wire" until
                # the deadline passes (the watchdog may cancel it first).
                still.append(p)
                continue
            ready = wait or all(_is_ready(a) for a in p.arrays)
            if ready and wait:
                for a in p.arrays:
                    jax.block_until_ready(a)
            if not ready:
                still.append(p)
                continue
            if self.state[p.layer, p.expert] == Residency.PROMOTING.value:
                if p.corrupt:
                    # Modeled publish-time integrity check: the copy landed
                    # but its payload is bad — cancel instead of publishing,
                    # so the forward never sees the corrupt version.
                    self._cancel_pending(p, "corrupt")
                    continue
                self.slot_map_h[p.layer, p.expert] = p.slot
                self.slot_owner_h[p.layer, p.slot] = p.expert
                self.state[p.layer, p.expert] = Residency.RESIDENT_HI.value
                self.inflight_bytes -= p.nbytes
                published += 1
                self.stats["promoted"] += 1
                if self.tracer is not None:
                    # ``published=1`` certifies the publish-then-switch
                    # discipline: this span only closes published after its
                    # own result arrays probed ready — no forward can have
                    # observed a half-materialized expert.
                    self.tracer.async_end("promotion", p.seq,
                                          cat="residency", published=1)
                    if self.publish_hist is not None:
                        self.publish_hist.observe(
                            self.tracer.clock() - p.issue_ts)
            else:
                # Demoted while promoting — reclaim without publishing.
                self._cancel_pending(p, "demoted", fault=False)
        self._pending = still
        self._flush_maps()
        return published

    def _cancel_pending(self, p: PendingPromotion, reason: str,
                        fault: bool = True) -> None:
        """Cancel an in-flight promotion through the async-span cancel path.
        Idempotent: the slot frees and the reservation refunds exactly once
        no matter how many paths (publish, watchdog, demote) race to cancel."""
        if p.cancelled:
            return
        p.cancelled = True
        self.pools[p.layer].free(p.slot)
        self._tracker_for(self.pools[p.layer].shard_of(p.slot)).release(
            p.nbytes)
        self.state[p.layer, p.expert] = Residency.RESIDENT_LO.value
        self.inflight_bytes -= p.nbytes
        if fault:
            self.stats["fault_cancels"] += 1
            if self.fail_cb is not None:
                self.fail_cb(p.layer, p.expert)
        if self.tracer is not None:
            self.tracer.async_end("promotion", p.seq, cat="residency",
                                  published=0, reason=reason)

    def cancel_stuck(self, now: float, deadline_s: float) -> int:
        """Watchdog hook: cancel promotions in flight longer than
        ``deadline_s`` (engine-clock age since issue). The expert keeps
        serving lo and the controller re-candidates it next window."""
        n = 0
        still = []
        for p in self._pending:
            age = now - p.issue_ts
            if age > deadline_s:
                if self.tracer is not None:
                    self.tracer.instant("promo_timeout", cat="fault",
                                        layer=p.layer, expert=p.expert,
                                        age_s=round(age, 6))
                self._cancel_pending(p, "timeout")
                n += 1
            else:
                still.append(p)
        self._pending = still
        return n

    def refund_window(self, nbytes: int) -> None:
        """Return bytes charged via ``try_consume_window`` for a transfer
        that was subsequently aborted (e.g. an EP migration that rolled
        back) — the window budget should only price transfers that landed."""
        if self.rate_limit:
            self._window_used = max(0, self._window_used - nbytes)

    def pending_ages(self, now: float) -> List[tuple]:
        """(layer, expert, age_s) for every in-flight promotion — the
        stall-diagnostic snapshot's view of the transfer plane."""
        return [(p.layer, p.expert, round(now - p.issue_ts, 6))
                for p in self._pending]

    def _flush_maps(self) -> None:
        """Push the host-side handle table to the device arrays (tiny)."""
        self.bank.slot_map = jnp.asarray(self.slot_map_h)
        self.bank.slot_owner = jnp.asarray(self.slot_owner_h)

    # -- introspection ----------------------------------------------------
    def hi_set(self, layer: int) -> set[int]:
        return {int(e) for e in np.nonzero(self.slot_map_h[layer] >= 0)[0]}

    def pending_experts(self, layer: int) -> set[int]:
        """Experts with an in-flight (issued, unpublished) promotion on
        ``layer`` — the policy must treat these as already hi."""
        return {int(p.expert) for p in self._pending if p.layer == layer}

    def check_invariants(self) -> None:
        """VER invariants (tested property-based): every published handle
        resolves to a slot owned by that expert; budget counts match."""
        L, E = self.slot_map_h.shape
        n_used = 0
        used_shard = np.zeros(self.n_shards, np.int64)
        for l in range(L):
            for e in range(E):
                s = self.slot_map_h[l, e]
                if s >= 0:
                    assert self.slot_owner_h[l, s] == e, (l, e, s)
                    # sharded placement: expert's slot lives on its shard
                    assert self.pools[l].shard_of(s) == self.shard_of_expert(e), \
                        (l, e, s)
                    n_used += 1
                    used_shard[self.shard_of_expert(e)] += 1
        owners = (self.slot_owner_h >= 0).sum()
        assert owners == n_used, (owners, n_used)
        in_flight = len(self._pending)
        # Exactly-once refund audit: bytes reserved-but-unpublished must
        # equal the sum of OPEN promotion spans — a double refund (or a
        # leaked reservation) after an injected fault breaks this first.
        open_bytes = sum(p.nbytes for p in self._pending)
        assert self.inflight_bytes == open_bytes, \
            (self.inflight_bytes, open_bytes)
        assert not any(p.cancelled for p in self._pending)
        for p in self._pending:
            used_shard[self.pools[p.layer].shard_of(p.slot)] += 1
        if self.shard_trackers:
            for j, trk in enumerate(self.shard_trackers):
                assert trk.used == used_shard[j] * self.hi_bytes, \
                    (j, trk.used, used_shard[j])
        else:
            assert self.tracker.used == (n_used + in_flight) * self.hi_bytes, \
                (self.tracker.used, n_used, in_flight)


def _is_ready(arr) -> bool:
    try:
        return arr.is_ready()
    except AttributeError:
        jax.block_until_ready(arr)
        return True
