"""Property + unit tests for the global cross-layer knapsack allocator."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.allocator import AllocatorConfig, GlobalAllocator


def _alloc(total_hi, slots, margin=0.0, max_transitions=0,
           lo_total=0, lo_margin=0.0):
    return GlobalAllocator(AllocatorConfig(
        total_hi=total_hi, slots_per_layer=slots, margin=margin,
        max_transitions=max_transitions, lo_resident_total=lo_total,
        lo_margin=lo_margin))


def _rand_state(rng, R, E, n_cur):
    value = rng.random((R, E)) * 10
    current = [set() for _ in range(R)]
    for _ in range(n_cur):
        current[int(rng.integers(R))].add(int(rng.integers(E)))
    return value, current


# -- feasibility ------------------------------------------------------------

@settings(max_examples=40)
@given(seed=st.integers(0, 10_000), R=st.integers(1, 5),
       E=st.integers(2, 8), total=st.integers(0, 16),
       slots=st.integers(1, 6), margin=st.floats(0.0, 2.0),
       max_tr=st.integers(0, 4))
def test_budget_feasibility(seed, R, E, total, slots, margin, max_tr):
    """Whatever the traffic and starting state, the plan never exceeds the
    global slot budget or any row's physical pool ceiling, and applying the
    promotion/demotion lists to `current` reproduces the target exactly."""
    rng = np.random.default_rng(seed)
    value, current = _rand_state(rng, R, E, n_cur=min(total, R * 2))
    # Feasible starting state: rows never hold more than their ceiling.
    cap = min(slots, E)
    current = [set(sorted(s)[:cap]) for s in current]
    asn = _alloc(total, slots, margin, max_tr).allocate(value, current)
    assert sum(len(s) for s in asn.hi) <= total
    for r in range(R):
        assert len(asn.hi[r]) <= cap
    rebuilt = [set(s) for s in current]
    for r, e in asn.demotions:
        rebuilt[r].discard(e)
    for r, e in asn.promotions:
        rebuilt[r].add(e)
    assert rebuilt == asn.hi


@settings(max_examples=40)
@given(seed=st.integers(0, 10_000), R=st.integers(1, 4),
       E=st.integers(2, 6), total=st.integers(1, 8),
       lo_total=st.integers(1, 20))
def test_ladder_order_hi_subset_of_lo(seed, R, E, total, lo_total):
    """hi ⊆ lo always: a hi-resident expert is never demoted to host."""
    rng = np.random.default_rng(seed)
    value, current = _rand_state(rng, R, E, n_cur=total)
    cur_lo = [set(range(E)) for _ in range(R)]
    asn = _alloc(total, slots=E, lo_total=lo_total).allocate(
        value, current, cur_lo)
    assert asn.lo is not None
    for r in range(R):
        assert asn.hi[r] <= asn.lo[r]
    hi_cells = {(r, e) for r in range(R) for e in asn.hi[r]}
    assert not hi_cells & set(asn.lo_demotions)


# -- hotness monotonicity ---------------------------------------------------

@settings(max_examples=40)
@given(seed=st.integers(0, 10_000), R=st.integers(1, 4),
       E=st.integers(2, 8), total=st.integers(1, 12),
       slots=st.integers(1, 6))
def test_hotness_monotone_within_row(seed, R, E, total, slots):
    """Fresh allocation (no incumbents): within any row, every selected
    cell is at least as valuable as every unselected cell — the row ceiling
    can cap a row's count but never invert its ranking."""
    rng = np.random.default_rng(seed)
    value = rng.random((R, E)) * 10
    asn = _alloc(total, slots).allocate(value, [set() for _ in range(R)])
    for r in range(R):
        outside = [value[r, e] for e in range(E) if e not in asn.hi[r]]
        if asn.hi[r] and outside:
            assert min(value[r, e] for e in asn.hi[r]) >= max(outside) - 1e-12


def test_cross_layer_reallocation():
    """The point of the global knapsack: a hot row takes more slots than a
    cold one at the same total budget — inexpressible per-layer (top-n with
    n_hi=1 per row would pin one slot each)."""
    value = np.array([[10.0, 9.0, 0.0, 0.0],
                      [0.1, 0.1, 0.1, 0.1]])
    asn = _alloc(total_hi=2, slots=2).allocate(value, [set(), set()])
    assert asn.hi[0] == {0, 1}
    assert asn.hi[1] == set()


# -- hysteresis -------------------------------------------------------------

@settings(max_examples=30)
@given(seed=st.integers(0, 10_000), R=st.integers(1, 4),
       E=st.integers(2, 8), total=st.integers(1, 10))
def test_hysteresis_no_thrash(seed, R, E, total):
    """Near-tie oscillation produces ZERO transitions: re-allocating with
    value perturbations strictly inside the margin keeps the incumbent set
    untouched."""
    rng = np.random.default_rng(seed)
    value = rng.random((R, E)) * 10
    margin = 1.0
    allocator = _alloc(total, slots=E, margin=margin)
    first = allocator.allocate(value, [set() for _ in range(R)])
    jitter = rng.uniform(-margin / 4, margin / 4, size=value.shape)
    again = allocator.allocate(value + jitter, first.hi)
    assert again.promotions == []
    assert again.demotions == []
    assert again.hi == first.hi


def test_margin_clearing_swap_goes_through():
    """A genuinely hotter entrant (clears the margin) still displaces the
    coldest incumbent — hysteresis damps ties, it does not freeze."""
    value = np.array([[5.0, 1.0, 0.0]])
    allocator = _alloc(total_hi=1, slots=1, margin=1.0)
    asn = allocator.allocate(value, [{2}])
    assert asn.hi == [{0}]
    assert asn.promotions == [(0, 0)] and asn.demotions == [(0, 2)]


# -- rate limiting ----------------------------------------------------------

def test_max_transitions_truncates_globally():
    """The per-window cap truncates the plan hottest-first while keeping it
    budget- and ceiling-feasible."""
    R, E, total = 3, 4, 3
    value = np.zeros((R, E))
    value[0] = [9, 8, 7, 6]            # row 0 suddenly red hot
    current = [set(), {0, 1}, {2}]     # 3 slots held elsewhere
    asn = _alloc(total, slots=3, max_transitions=1).allocate(value, current)
    assert len(asn.promotions) <= 1
    assert asn.promotions == [(0, 0)]  # hottest promotion admitted first
    assert sum(len(s) for s in asn.hi) <= total
    for r in range(R):
        assert len(asn.hi[r]) <= 3


def test_lo_quota_and_host_demotion():
    """With a lo-residency quota below the cell count, exactly the quota's
    coldest complement is demoted to host — and lo promotions/demotions
    reproduce the target from the current set."""
    value = np.array([[4.0, 3.0, 2.0, 1.0],
                      [8.0, 7.0, 6.0, 5.0]])
    cur_lo = [set(range(4)), set(range(4))]
    asn = _alloc(total_hi=1, slots=1, lo_total=5).allocate(
        value, [set(), set()], cur_lo)
    assert sum(len(s) for s in asn.lo) == 5
    rebuilt = [set(s) for s in cur_lo]
    for r, e in asn.lo_demotions:
        rebuilt[r].discard(e)
    for r, e in asn.lo_promotions:
        rebuilt[r].add(e)
    assert rebuilt == asn.lo
    # The 3 coldest cells overall went to host.
    demoted = set(asn.lo_demotions)
    assert demoted == {(0, 1), (0, 2), (0, 3)}


def test_config_validation():
    with pytest.raises(ValueError):
        AllocatorConfig(total_hi=-1, slots_per_layer=1).validate()
    with pytest.raises(ValueError):
        AllocatorConfig(total_hi=1, slots_per_layer=1,
                        margin=-0.5).validate()
    with pytest.raises(ValueError):
        GlobalAllocator(AllocatorConfig(total_hi=1, slots_per_layer=1)) \
            .allocate(np.zeros((2, 3)), [set()])
