"""Pallas TPU kernel: single-token flash attention over a long KV cache.

The decode_32k / long_500k hot spot: one query row per (batch, head) against
S cached keys. Online-softmax accumulation over KV tiles keeps the working
set at O(bs·hd) VMEM regardless of S; GQA is handled in the BlockSpec index
map (q head → kv head), so kv tiles are fetched once per kv head group.

Grid: (B, H, S/bs), S innermost/sequential with running (m, l, acc) scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _fd_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
               m_ref, l_ref, acc_ref, *, ns, scale):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (1, hd) via block
    k = k_ref[0, :, 0].astype(jnp.float32)              # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)              # (bs, hd)
    logits = (q @ k.T) * scale                          # (1, bs)
    logits = jnp.where(valid_ref[0][None, :], logits, -jnp.inf)

    m_prev = m_ref[...]                                 # (1, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    # All-masked tiles keep m at -inf; exp(-inf - -inf) is nan — guard.
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
    p = jnp.exp(logits - m_new)                         # (1, bs), 0 where -inf
    p = jnp.where(jnp.isfinite(logits), p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v         # (1, hd)
    m_ref[...] = m_new

    @pl.when(s == ns - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom)[0].astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, valid: jax.Array,
                 *, bs: int = 512, interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k/v: (B, S, Hkv, hd); valid: (B, S) bool → (B, H, hd)."""
    B, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    bs = min(bs, S)
    if S % bs:
        raise ValueError(f"S={S} not tileable by bs={bs}")
    ns = S // bs
    grid = (B, H, ns)
    return pl.pallas_call(
        functools.partial(_fd_kernel, ns=ns, scale=hd ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h // rep, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h // rep, 0)),
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, s: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[_vmem((1, 1), jnp.float32),
                        _vmem((1, 1), jnp.float32),
                        _vmem((1, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, valid)
