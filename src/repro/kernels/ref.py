"""Pure-jnp oracles for every Pallas kernel (the allclose targets) plus the
group-blocked quantized GEMM expressions the serving path dispatches to on
backends without Pallas support (see ``repro.kernels.ops``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.qtensor import unpack_bits, unpack_codes_int8


def dequant_ref(packed: jax.Array, scales: jax.Array, bits: int,
                group: int) -> jax.Array:
    """packed: (..., K//epb, N) uint8; scales: (..., K//g, N) → (..., K, N) f32."""
    epb = 8 // bits
    *lead, kp, n = packed.shape
    k = kp * epb
    u = unpack_bits(packed, bits, k)
    q = u - (1 << (bits - 1))
    qf = q.reshape(*lead, k // group, group, n).astype(jnp.float32)
    return (qf * scales[..., :, None, :].astype(jnp.float32)).reshape(*lead, k, n)


def quant_matmul_ref(x: jax.Array, packed: jax.Array, scales: jax.Array,
                     bits: int, group: int) -> jax.Array:
    """x: (M, K) × quantized (K, N) → (M, N) f32-accumulated, x.dtype out."""
    w = dequant_ref(packed, scales, bits, group)
    return jnp.dot(x.astype(jnp.float32), w).astype(x.dtype)


def grouped_quant_matmul_ref(xg: jax.Array, packed: jax.Array,
                             scales: jax.Array, bits: int,
                             group: int) -> jax.Array:
    """xg: (E, C, K) × quantized (E, K, N) → (E, C, N)."""
    w = dequant_ref(packed, scales, bits, group)
    return jnp.einsum("eck,ekn->ecn", xg.astype(jnp.float32), w).astype(xg.dtype)


def grouped_lo_gemm_jnp(xg: jax.Array, packed: jax.Array, scales: jax.Array,
                        bits: int, group: int) -> jax.Array:
    """Group-blocked quantized GEMM, jnp expression: xg (B, C, K) × int codes
    (B, K, N) with per-(group, N) scales applied AFTER the per-group partial
    matmuls — the dequantized (K, N) weight matrix is never materialized.
    This is the jnp re-expression of the Pallas fused quant-matmul
    (``kernels.quant_matmul``); the two are collapsed behind ONE dispatcher
    (``ops.grouped_lo_matmul``) and bit-parity-tested against each other.
    The leading dim is any batch (experts in the padded MoE path, row tiles
    in the ragged path)."""
    B, C, K = xg.shape
    codes = unpack_codes_int8(packed, bits)          # (B, K, N) int8
    N = codes.shape[-1]
    G = K // group
    # (b, g) merge into ONE batch dim (multi-batch-dim bf16 dots are not
    # universally supported by backends).
    xr = xg.reshape(B, C, G, group).transpose(0, 2, 1, 3) \
        .reshape(B * G, C, group)
    qr = codes.reshape(B * G, group, N).astype(xg.dtype)
    part = jnp.einsum("bcd,bdn->bcn", xr, qr,
                      preferred_element_type=jnp.float32)
    part = part.reshape(B, G, C, N).transpose(0, 2, 1, 3)    # (B, C, G, N)
    out = jnp.einsum("ecgn,egn->ecn", part,
                     scales.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.astype(xg.dtype)


def ragged_quant_ffn_ref(xs: jax.Array, tile_eid: jax.Array,
                         tile_slot: jax.Array,
                         gate_packed, gate_scales, up_packed, up_scales,
                         down_packed, down_scales,
                         hi_gate=None, hi_up=None, hi_down=None, *,
                         bits: int, group: int, bm: int) -> jax.Array:
    """jnp oracle for the ragged mixed-precision expert FFN: ``xs`` is the
    (R = Tt·bm, K) bm-aligned compacted activation buffer, ``tile_eid`` the
    (Tt,) expert id per row tile and ``tile_slot`` its hi-pool slot (−1 ⇒
    lo tier). Each tile computes SwiGLU with either its expert's lo-tier
    group-blocked quantized weights or its hi-slot bf16 weights — the same
    per-row math (and therefore the same bits on a given backend) as the
    padded ``_quant_expert_ffn`` path, just laid out raggedly."""
    Tt = tile_eid.shape[0]
    K = xs.shape[1]
    xt = xs.reshape(Tt, bm, K)
    g1 = grouped_lo_gemm_jnp(xt, gate_packed[tile_eid],
                             gate_scales[tile_eid], bits, group)
    up = grouped_lo_gemm_jnp(xt, up_packed[tile_eid],
                             up_scales[tile_eid], bits, group)
    h = jax.nn.silu(g1.astype(jnp.float32)).astype(xt.dtype) * up
    y = grouped_lo_gemm_jnp(h, down_packed[tile_eid],
                            down_scales[tile_eid], bits, group)
    if hi_gate is not None and hi_gate.shape[0] > 0:
        safe = jnp.clip(tile_slot, 0, hi_gate.shape[0] - 1)
        hh = jax.nn.silu(
            jnp.einsum("tbd,tdf->tbf", xt, hi_gate[safe])
            .astype(jnp.float32)).astype(xt.dtype)
        hh = hh * jnp.einsum("tbd,tdf->tbf", xt, hi_up[safe])
        yh = jnp.einsum("tbf,tfd->tbd", hh, hi_down[safe])
        y = jnp.where((tile_slot >= 0)[:, None, None], yh, y)
    return y.reshape(Tt * bm, y.shape[-1])


def ragged_dense_ffn_ref(xs: jax.Array, tile_eid: jax.Array,
                         w_gate: jax.Array, w_up: jax.Array,
                         w_down: jax.Array, *, bm: int) -> jax.Array:
    """jnp oracle for the ragged DENSE expert FFN (fp16/offload banks with
    no quantized tier): same bm-aligned layout and tile→expert map as
    ``ragged_quant_ffn_ref``, but every tile reads its expert's dense
    weights — inactive experts still never stream. Per-tile math matches
    the padded dense body (and the quant path's hi overlay) einsum for
    einsum, so the two layouts stay bit-identical per token."""
    Tt = tile_eid.shape[0]
    K = xs.shape[1]
    xt = xs.reshape(Tt, bm, K)
    h = jax.nn.silu(jnp.einsum("tbd,tdf->tbf", xt, w_gate[tile_eid])
                    .astype(jnp.float32)).astype(xt.dtype)
    h = h * jnp.einsum("tbd,tdf->tbf", xt, w_up[tile_eid])
    y = jnp.einsum("tbf,tfd->tbd", h, w_down[tile_eid])
    return y.reshape(Tt * bm, y.shape[-1])


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array) -> jax.Array:
    """q: (B, H, hd); k/v: (B, S, Hkv, hd); valid: (B, S) bool → (B, H, hd)."""
    B, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)
