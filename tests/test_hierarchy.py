"""Residency-ladder integration tests: global cross-layer allocation, the
host-DRAM third tier, streaming cold start, and their serving-stack wiring."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import ControllerConfig
from repro.core.budget import BudgetExceeded, plan_hierarchy
from repro.core.controller import RebalanceConfig
from repro.core.hotness import HotnessEstimator
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           STAT_KEYS, Scheduler, SchedulerConfig,
                           load_streaming_params, make_backend,
                           make_prompts, save_expert_shards)
from repro.serving.hoststore import FetchModel


def _clone(params):
    return jax.tree_util.tree_map(lambda x: x, params)


def _engine(cfg, params, backend, **ecfg_kw):
    ecfg_kw.setdefault("max_slots", 2)
    ecfg_kw.setdefault("max_len", 48)
    return InferenceEngine(cfg, params, backend, EngineConfig(**ecfg_kw))


def _dynaexq(**kw):
    kw.setdefault("lo_bits", 4)
    kw.setdefault("n_hi_per_layer", 2)
    kw.setdefault("controller", ControllerConfig(update_interval_s=0.0))
    return make_backend("dynaexq", **kw)


# -- tentpole: global cross-layer allocation --------------------------------

def test_global_cross_layer_beats_per_layer(serving_setup):
    """The acceptance case: under layer-skewed traffic the global allocator
    concentrates hi slots on the hot layer — an assignment the per-layer
    top-n rule structurally cannot express (it pins each layer to n_hi)."""
    cfg, params = serving_setup
    counts = np.zeros((2, cfg.moe.num_experts))
    counts[0] = [40, 30, 20, 10]      # layer 0 red hot, layer 1 silent
    sets = {}
    for global_alloc in (True, False):
        be = _dynaexq(global_alloc=global_alloc)
        eng = _engine(cfg, _clone(params), be)
        ctl = be.controllers["0"]
        ctl.observe(counts)
        be.force_update()
        be.flush()
        ctl.tm.check_invariants()
        sets[global_alloc] = be.hi_sets()["0"]
        # Same slot budget spent either way.
        assert sum(len(s) for s in sets[global_alloc]) == 4
        del eng
    assert sets[True][0] == [0, 1, 2, 3]   # whole budget on the hot layer
    assert sets[True][1] == []
    assert all(len(s) == 2 for s in sets[False])   # per-layer: pinned


def test_global_default_and_ep_exclusion(serving_setup):
    """Global allocation is the single-shard default; expert parallelism
    falls back to per-layer (shard-local slots) and rejects an explicit
    global request."""
    assert _dynaexq().global_alloc is True
    assert _dynaexq(ep_shards=2).global_alloc is False
    with pytest.raises(ValueError):
        _dynaexq(ep_shards=2, global_alloc=True)
    with pytest.raises(ValueError):
        _dynaexq(ep_shards=2, lo_resident_total=4)


def test_sensitivity_bends_allocation(serving_setup):
    """A fragile expert (high quantization sensitivity) wins a hi slot from
    an equally-hot robust one."""
    cfg, params = serving_setup
    E = cfg.moe.num_experts
    sens = np.ones((2, E))
    sens[1, 3] = 40.0                  # expert (1, 3) is fragile
    be = _dynaexq(sensitivity={"0": sens})
    _engine(cfg, _clone(params), be)
    counts = np.ones((2, E))           # perfectly uniform traffic
    be.controllers["0"].observe(counts)
    be.force_update()
    be.flush()
    assert 3 in be.hi_sets()["0"][1]


# -- host-DRAM third tier ---------------------------------------------------

def test_host_tier_quota_and_demand_stall(serving_setup):
    cfg, params = serving_setup
    E = cfg.moe.num_experts
    be = _dynaexq(lo_resident_total=5,
                  fetch=FetchModel(gbps=1.0))
    eng = _engine(cfg, _clone(params), be)
    counts = np.zeros((2, E))
    counts[0] = [40, 30, 20, 10]
    counts[1] = [4, 3, 2, 1]
    be.controllers["0"].observe(counts)
    be.force_update()
    be.flush()
    store = be.stores["0"]
    store.check_invariants()
    # Exactly the quota stays device-lo-resident; the rest went to host.
    assert int(store.lo_resident.sum()) == 5
    # Ladder order: every hi resident is lo-resident.
    for l in range(2):
        for e in be.hi_sets()["0"][l]:
            assert store.lo_resident[l, e]
    # Routing a host-resident expert pays a modeled demand-fetch stall.
    host_cell = np.argwhere(~store.lo_resident)[0]
    demand = np.zeros((2, E))
    demand[host_cell[0], host_cell[1]] = 3
    stall = be.observe({"0": demand}, compute_s=0.0)
    assert stall > 0
    st = be.stats()
    assert st["host_fetches"] >= 1
    assert st["lo_resident_frac"] < 1.0
    assert set(STAT_KEYS) <= set(st)
    # Modeled footprint shrinks with the quota (same traffic, same hi
    # residency — only the lo tier differs).
    full = _dynaexq()
    _engine(cfg, _clone(params), full)
    full.controllers["0"].observe(counts)
    full.force_update()
    full.flush()
    assert be.device_bytes() < full.device_bytes()
    del eng


def test_randomized_ladder_interleaving(serving_setup):
    """Randomized promote/demote/host-evict interleavings: after every
    window the VER handle table, the store masks, and the ladder ordering
    (hi ⊆ lo-resident, resident count == quota) all hold."""
    cfg, params = serving_setup
    E = cfg.moe.num_experts
    quota = 6
    be = _dynaexq(lo_resident_total=quota,
                  controller=ControllerConfig(update_interval_s=0.0,
                                              margin=0.5))
    _engine(cfg, _clone(params), be)
    ctl = be.controllers["0"]
    store = be.stores["0"]
    rng = np.random.default_rng(7)
    for round_ in range(25):
        counts = rng.integers(0, 50, size=(2, E)) * \
            rng.integers(0, 2, size=(2, E))
        ctl.observe(counts)
        be.observe({"0": rng.integers(0, 3, size=(2, E))})
        be.force_update()
        if round_ % 3 == 0:
            be.flush()
        ctl.tm.check_invariants()
        store.check_invariants()
        hi = be.hi_sets()["0"]
        for l in range(2):
            for e in hi[l]:
                assert store.lo_resident[l, e], (round_, l, e)
        assert int(store.lo_resident.sum()) == quota
    be.flush()
    assert be.stats()["promotions"] > 0


# -- streaming cold start ---------------------------------------------------

@pytest.fixture(scope="module")
def shard_dir(tmp_path_factory, serving_setup):
    cfg, params = serving_setup
    d = tmp_path_factory.mktemp("shards")
    save_expert_shards(str(d), _clone(params), [0], lo_bits=4)
    return str(d)


def test_streaming_token_parity(serving_setup, shard_dir):
    """Frozen-policy temp-0 parity: an engine that streamed its lo tier
    from checkpoint shards emits token-for-token what the fully
    materialized engine does — staged rows are bit-identical to
    build_bank's."""
    cfg, params = serving_setup
    frozen = ControllerConfig(update_interval_s=1e9)
    prompts = make_prompts("text", cfg.vocab_size, 2, 16)
    eng_a = _engine(cfg, _clone(params), _dynaexq(controller=frozen))
    out_a, _, _ = eng_a.generate({"tokens": prompts}, 6)
    eng_b = _engine(cfg, load_streaming_params(shard_dir),
                    _dynaexq(controller=frozen, stream=shard_dir,
                             stream_experts_per_tick=3))
    assert not eng_b.backend.serving_ready()
    out_b, _, _ = eng_b.generate({"tokens": prompts}, 6)
    assert eng_b.backend.serving_ready()
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))


def test_streaming_warmed_parity(serving_setup, shard_dir):
    """With identical traffic, the streamed and materialized engines reach
    identical hi sets AND identical tokens — hi shards (f32 on disk) cast
    back to the exact bf16 the dense checkpoint held."""
    cfg, params = serving_setup
    frozen = ControllerConfig(update_interval_s=1e9)
    counts = np.zeros((2, cfg.moe.num_experts))
    counts[0] = [40, 30, 20, 10]
    prompts = make_prompts("text", cfg.vocab_size, 2, 16)
    outs, his = [], []
    for stream in (None, shard_dir):
        p = load_streaming_params(shard_dir) if stream else _clone(params)
        be = _dynaexq(controller=frozen, stream=stream)
        eng = _engine(cfg, p, be)
        be.flush()                       # finish the cold-start pump
        be.controllers["0"].observe(counts)
        be.force_update()
        be.flush()
        his.append(be.hi_sets())
        out, _, _ = eng.generate({"tokens": prompts}, 6)
        outs.append(np.asarray(out))
    assert his[0] == his[1]
    assert sum(len(s) for s in his[1]["0"]) == 4
    np.testing.assert_array_equal(outs[0], outs[1])


def test_cold_start_gating(serving_setup, shard_dir):
    """While streaming, the engine queues but never runs a forward (no
    request may observe a partially materialized expert); readiness grows
    monotonically; queued work drains once the lo tier completes."""
    cfg, _ = serving_setup
    be = _dynaexq(controller=ControllerConfig(update_interval_s=1e9),
                  stream=shard_dir, stream_experts_per_tick=2)
    eng = _engine(cfg, load_streaming_params(shard_dir), be)
    prompts = make_prompts("text", cfg.vocab_size, 1, 8)
    h = eng.submit(Request(tokens=prompts[0], max_new_tokens=4))
    last_frac, steps = 0.0, 0
    while not be.serving_ready():
        assert all(s is None for s in eng.slots)
        assert eng.step() == []
        frac = be.ready_frac()
        assert frac >= last_frac
        last_frac = frac
        steps += 1
        assert steps < 100
    for store in be.stores.values():
        store.check_invariants()
        assert store.lo_complete
    assert eng.load_snapshot()["residency_ready_frac"] == 1.0
    eng.drain()
    assert len(h.tokens) == 4
    st = be.stats()
    assert st["residency_ready_frac"] == 1.0


def test_scheduler_sheds_during_cold_start():
    s = Scheduler(SchedulerConfig(shed_policy="downgrade",
                                  shed_min_ready_frac=0.9))
    warm = {"queue_depth": 0.0, "est_wait_s": 0.0,
            "budget_headroom_frac": 1.0}
    assert s.overloaded({**warm, "residency_ready_frac": 0.5})
    assert not s.overloaded({**warm, "residency_ready_frac": 0.95})
    assert not s.overloaded(warm)      # absent signal = warm engine
    with pytest.raises(ValueError):
        SchedulerConfig(shed_min_ready_frac=1.5).validate()


# -- satellites -------------------------------------------------------------

def test_migration_rate_limit_shared_with_promotions(serving_setup):
    """EP relabeling draws from the SAME per-window transfer budget as
    promotions: a starved window defers migrations (counted), an open one
    admits them."""
    cfg, params = serving_setup
    counts = np.zeros((2, cfg.moe.num_experts))
    counts[:] = [100, 50, 1, 0]        # shard 0 holds all the heat
    reb = RebalanceConfig(interval_s=0.0, skew_threshold=1.1,
                          max_migrations_per_window=4)
    migrated = {}
    for limit in (1, 0):               # 1 byte/window vs unlimited
        be = _dynaexq(ep_shards=2,
                      controller=ControllerConfig(
                          update_interval_s=0.0,
                          migration_bytes_per_window=limit),
                      rebalance=dataclasses.replace(reb))
        be.materialize_banks(cfg, _clone(params), kv_bytes=0)
        ctl = be.controllers["0"]
        ctl.observe(counts)
        ctl.update()
        migrated[limit] = be.coordinator.rebalance()
        if limit == 1:
            assert be.coordinator.stats["deferred_migrations"] > 0
        ctl.tm.check_invariants()
        be.stores["0"].check_invariants()
    assert migrated[1] == 0
    assert migrated[0] > 0


def test_hotness_save_restore_roundtrip(tmp_path):
    h = HotnessEstimator(2, 4, alpha=0.5)
    h.observe(np.arange(8).reshape(2, 4))
    h.fold()
    h.observe(np.ones((2, 4)))
    p = str(tmp_path / "hot.npz")
    h.save(p)
    h2 = HotnessEstimator(2, 4)
    h2.load(p)
    np.testing.assert_array_equal(h2.scores, h.scores)
    np.testing.assert_array_equal(h2.counts, h.counts)
    assert h2.intervals == h.intervals
    with pytest.raises(ValueError):
        HotnessEstimator(3, 4).load(p)


def test_backend_hotness_persistence(serving_setup, tmp_path):
    """save_hotness → a new backend constructed with the same prefix opens
    with the previous run's traffic as its prior."""
    cfg, params = serving_setup
    prefix = str(tmp_path / "hotness")
    be = _dynaexq(hotness_path=prefix)
    _engine(cfg, _clone(params), be)
    counts = np.zeros((2, cfg.moe.num_experts))
    counts[0, 1] = 99
    be.controllers["0"].observe(counts)
    be.controllers["0"].hotness.fold()
    be.save_hotness()
    be2 = _dynaexq(hotness_path=prefix)
    _engine(cfg, _clone(params), be2)
    np.testing.assert_array_equal(
        be2.controllers["0"].hotness.scores,
        be.controllers["0"].hotness.scores)
    assert be2._host_acct["hotness_restored"] == 1


def test_plan_hierarchy_budget_split():
    plan = plan_hierarchy(m_total=1000, m_fixed=100,
                          lo_bytes_per_expert_layer=10,
                          hi_bytes_per_expert_layer=100,
                          n_layers=2, num_experts=4)
    assert plan.lo_resident_total == 8 and plan.total_hi == 8
    partial = plan_hierarchy(m_total=150, m_fixed=100,
                             lo_bytes_per_expert_layer=10,
                             hi_bytes_per_expert_layer=100,
                             n_layers=2, num_experts=4)
    assert partial.lo_resident_total == 5 and partial.total_hi == 0
    with pytest.raises(BudgetExceeded):
        plan_hierarchy(m_total=105, m_fixed=100,
                       lo_bytes_per_expert_layer=10,
                       hi_bytes_per_expert_layer=100,
                       n_layers=2, num_experts=4)


def test_offload_uniform_stats(engine_factory):
    """The absorbed offload baseline reports through the uniform schema:
    bytes_moved (renamed from bytes_fetched) and host_fetches (= misses)."""
    eng = engine_factory("offload")
    prompts = make_prompts("text", eng.cfg.vocab_size, 2, 16)
    eng.generate({"tokens": prompts}, 4)
    st = eng.backend.stats()
    assert set(STAT_KEYS) <= set(st)
    assert st["host_fetches"] == st["misses"]
    assert st["bytes_moved"] > 0
