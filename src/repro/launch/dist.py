"""Distribution context: lets model code (notably the MoE layer) opt into
shard_map expert parallelism when a mesh is active, while staying pure jnp on
a single device.

GSPMD auto-sharding handles every dense layer well, but MoE dispatch is
data-dependent (sort/scatter by expert id): the partitioner cannot shard a
global argsort and replicates the (tokens×top_k, d_model) gather — a ~68 GB
buffer at train_4k scale. The production formulation makes dispatch LOCAL:
each data shard routes its own tokens, each model shard computes only its
E/16 experts, and partial outputs reduce with one psum over 'model' per MoE
layer. ``dist_ctx`` carries the mesh + axis names into the model layers.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

_STATE = threading.local()


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: object
    dp_axes: Tuple[str, ...]      # ('pod', 'data') or ('data',)
    model_axis: str = "model"
    tokens_dp_sharded: bool = True   # False for batch-1 long-context decode

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]


def get_dist() -> Optional[DistContext]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def dist_ctx(ctx: Optional[DistContext]):
    prev = get_dist()
    _STATE.ctx = ctx
    try:
        yield
    finally:
        _STATE.ctx = prev
