"""Paper Fig. 10 (and Fig. 1's motivation): TTFT vs prompt length. Longer
prompts densify expert activation; offloading pays transfer stalls that grow
with the activated set, DynaExq and static PTQ do not. All baselines run as
backends behind the same InferenceEngine."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_backend, clone, trained_model
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           make_prompts)


def _measure_ttft(kind, cfg, params, bs, toks):
    eng = InferenceEngine(cfg, clone(params), bench_backend(kind),
                          EngineConfig(max_slots=bs, max_len=256))
    handles = [eng.submit(Request(tokens=toks[b], max_new_tokens=1))
               for b in range(bs)]
    eng.drain()
    return float(np.mean([h.ttft_s for h in handles]))


def _measure_mixed(kind, cfg, params, lens):
    """Mixed-length batch (one request per length): bucketed admission pays
    O(#buckets) prefill compiles where the per-length path paid one each."""
    eng = InferenceEngine(cfg, clone(params), bench_backend(kind),
                          EngineConfig(max_slots=4, max_len=256))
    handles = [eng.submit(Request(
        tokens=make_prompts("text", cfg.vocab_size, 1, ln, seed=ln)[0],
        max_new_tokens=1)) for ln in lens]
    eng.drain()
    return (float(np.mean([h.ttft_s for h in handles])),
            len(eng.prefill_shapes), len(eng.buckets))


def run(report):
    cfg, params, task = trained_model()
    bs = 4
    for plen in (16, 64, 192):
        toks = np.asarray(task.sample(bs, plen, seed=plen))
        row = {}
        for kind in ("static", "dynaexq", "offload"):
            _measure_ttft(kind, cfg, params, bs, toks)   # warm-up compile
            ttft = _measure_ttft(kind, cfg, params, bs, toks)
            row[kind] = ttft
            report(f"prompt_scaling/ttft/{kind}/len{plen}", ttft * 1e6,
                   round(ttft, 4))
        report(f"prompt_scaling/offload_overhead_x/len{plen}", 0.0,
               round(row["offload"] / row["static"], 2))

    # Mixed-length workload: 8 distinct lengths through ONE engine. (The
    # compile-count regression guard lives in serving_perf / the tier-1
    # tests; here the shape count is reported for the figure only.)
    lens = (9, 14, 22, 37, 55, 90, 130, 200)
    for kind in ("static", "dynaexq"):
        _measure_mixed(kind, cfg, params, lens)          # warm-up compile
        ttft, n_shapes, _n_buckets = _measure_mixed(kind, cfg, params, lens)
        report(f"prompt_scaling/ttft/{kind}/mixed", ttft * 1e6,
               round(ttft, 4))
        report(f"prompt_scaling/prefill_compiles/{kind}/mixed", 0.0,
               n_shapes)
