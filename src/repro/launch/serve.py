"""Serving launcher.

On this CPU container it runs the reduced configs end to end (the full
configs are exercised by the dry-run); on a real TPU slice the same command
serves the full config under the production mesh:

    python -m repro.launch.serve --arch granite-moe-1b-a400m \
        --backend dynaexq --batch 4 --prompt-len 32 --new-tokens 16 [--full]
"""
import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core import ControllerConfig
from repro.launch.dist import ep_context
from repro.launch.mesh import make_ep_mesh
from repro.models import init_params
from repro.serving import (BACKENDS, EngineConfig, InferenceEngine,
                           OffloadConfig, Request, SamplingParams,
                           SchedulerConfig, load_streaming_params,
                           make_backend, make_prompts, save_expert_shards)


def build_backend(args):
    """CLI name → ResidencyBackend construction (builder code — the engine
    itself is backend-agnostic)."""
    if args.backend == "dynaexq":
        return make_backend(
            "dynaexq", lo_bits=args.lo_bits,
            n_hi_per_layer=None if args.hbm_gb else args.n_hi,
            hbm_gb=args.hbm_gb,
            controller=ControllerConfig(update_interval_s=0.25),
            ep_shards=args.ep_shards,
            global_alloc=False if args.per_layer_alloc else None,
            sensitivity=args.sensitivity,
            lo_resident_total=args.lo_resident_total,
            hotness_path=args.hotness_path,
            stream=args.stream_from,
            fault=_fault_plan(args))
    if args.backend == "static":
        return make_backend("static", lo_bits=args.lo_bits)
    if args.backend == "offload":
        return make_backend("offload", ocfg=OffloadConfig(
            cache_experts_per_layer=args.n_hi * 2))
    return make_backend(args.backend)


def _fault_plan(args):
    """``--fault-plan`` (JSON string or path) → FaultPlan, with
    ``--fault-seed`` overriding the plan's seed when given."""
    if not getattr(args, "fault_plan", None):
        return None
    from repro.fault import FaultPlan
    return FaultPlan.parse(args.fault_plan, seed=args.fault_seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m", choices=ARCH_IDS)
    ap.add_argument("--backend", "--mode", dest="backend", default="dynaexq",
                    choices=sorted(BACKENDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--lo-bits", type=int, default=4, choices=[2, 4, 8])
    ap.add_argument("--n-hi", type=int, default=2)
    ap.add_argument("--hbm-gb", type=float, default=None,
                    help="derive n_hi from a device envelope instead")
    ap.add_argument("--full", action="store_true",
                    help="full (assigned) config — needs a real accelerator")
    ap.add_argument("--workload", default="text")
    ap.add_argument("--dense-kv", action="store_true",
                    help="dense per-slot KV rows instead of the paged pool")
    ap.add_argument("--block-tokens", type=int, default=16,
                    help="KV positions per paged block")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable trie-based cross-request prefix reuse")
    ap.add_argument("--hbm-budget-gb", type=float, default=None,
                    help="unified envelope shared by KV blocks and the "
                         "expert hi tier (promotion backpressure under KV "
                         "pressure)")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=["padded", "ragged"],
                    help="MoE token layout: padded (E,C,d) reference vs "
                         "ragged compacted dispatch + fused mixed-precision "
                         "kernel (default: ragged on TPU, padded on CPU)")
    ap.add_argument("--row-capacity", action="store_true",
                    help="normalize MoE capacity drops per request row "
                         "(batch-shape-independent token drops)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max speculative draft depth per round (drafts on "
                         "the all-lo expert tier, verifies against the "
                         "mixed-precision banks)")
    ap.add_argument("--no-spec", action="store_true",
                    help="disable speculative decoding (one token per step)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=None,
                    help="sample only from the k most probable tokens")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = full vocab)")
    ap.add_argument("--seed", type=int, default=0,
                    help="per-request sampling seed base (request b uses "
                         "seed+b)")
    ap.add_argument("--qos-default", default="standard",
                    choices=["batch", "standard", "premium"],
                    help="QoS class for requests that carry none (batch "
                         "decodes on the all-lo banks, premium keeps the "
                         "hi tier + speculative bursts)")
    ap.add_argument("--shed-policy", default="none",
                    choices=["none", "downgrade", "reject"],
                    help="overload response: downgrade batch/standard "
                         "execution to the lo tier, or also reject "
                         "batch-tier submissions outright")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="split prompts longer than this many tokens into "
                         "chunked prefills interleaved with decode "
                         "(0 = single-shot; rounded down to a "
                         "block-aligned prefill bucket)")
    ap.add_argument("--per-layer-alloc", action="store_true",
                    help="use the paper's per-layer top-n policy instead "
                         "of the default global cross-layer knapsack "
                         "allocator (dynaexq, single-shard)")
    ap.add_argument("--sensitivity", default=None,
                    help=".npz of per-expert quantization sensitivity "
                         "(quant.sensitivity.save_sensitivity) — weights "
                         "the global allocator's hotness ranking")
    ap.add_argument("--lo-resident-total", type=int, default=None,
                    help="enable the host-DRAM third tier: only this many "
                         "(layer, expert) cells stay device-lo-resident; "
                         "the rest pay a modeled demand-fetch stall when "
                         "routed")
    ap.add_argument("--hotness-path", default=None,
                    help="prefix for hotness snapshots: restored at "
                         "startup (warm allocator prior + hottest-first "
                         "streaming) and saved after the run")
    ap.add_argument("--stream-from", default=None,
                    help="expert-sharded checkpoint dir (save_expert_"
                         "shards): stream the lo tier in at startup and "
                         "serve before the model fully materializes")
    ap.add_argument("--save-shards", default=None,
                    help="write the expert-sharded serving checkpoint to "
                         "this dir and exit (streaming cold-start source)")
    ap.add_argument("--trace-out", default=None,
                    help="write the flight-recorder trace here as Chrome "
                         "trace-event JSON (open in Perfetto / "
                         "chrome://tracing)")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="append per-step metric samples to this JSONL file")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable the observability layer entirely (no "
                         "tracer, no metrics, no shutdown summary)")
    ap.add_argument("--fault-plan", default=None,
                    help="fault-injection plan for chaos runs: a JSON "
                         "string or a path to one, e.g. "
                         '\'{"seed": 7, "rules": [{"site": "host_lo", '
                         '"prob": 0.1}]}\' (dynaexq backend only)')
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="override the fault plan's Philox seed")
    ap.add_argument("--promo-deadline-s", type=float, default=None,
                    help="watchdog: cancel promotions still unpublished "
                         "after this many seconds (refund + keep serving "
                         "lo)")
    ap.add_argument("--ep-shards", type=int, default=1,
                    help="expert-parallel serving over this many devices: "
                         "tokens and experts shard over the model axis, MoE "
                         "layers run the ragged all-to-all pipeline, and "
                         "the dynaexq hi pool splits into per-shard slot "
                         "ranges with per-shard budgets (requires "
                         "num_experts and --n-hi divisible by the shard "
                         "count; 1 = single-device)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    spec_k = 0 if args.no_spec else max(0, args.spec_k)
    dist = None
    if args.ep_shards > 1:
        if args.ep_shards > jax.device_count():
            raise SystemExit(
                f"--ep-shards {args.ep_shards} > visible devices "
                f"{jax.device_count()} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count=N to emulate)")
        dist = ep_context(make_ep_mesh(args.ep_shards))
    print(f"[serve] {cfg.name} backend={args.backend} "
          f"devices={jax.device_count()} spec_k={spec_k} "
          f"ep_shards={args.ep_shards}")
    if args.stream_from:
        # Streaming cold start: only the base (non-expert) params load
        # synchronously; the lo tier backfills behind the engine.
        params = load_streaming_params(args.stream_from)
    else:
        params = init_params(jax.random.PRNGKey(0), cfg)
    if args.save_shards:
        positions = [p for p, _ in enumerate(cfg.superblock_or_default())
                     if cfg.ffn_kind(p) == "moe"] if cfg.is_moe else []
        save_expert_shards(args.save_shards, params, positions,
                           lo_bits=args.lo_bits)
        print(f"[serve] expert-sharded checkpoint -> {args.save_shards}")
        return
    obs = None
    if not args.no_obs:
        from repro.obs import Observability, ObsConfig
        obs = Observability(ObsConfig(metrics_jsonl=args.metrics_jsonl))
    engine = InferenceEngine(
        cfg, params, build_backend(args),
        EngineConfig(max_slots=args.batch,
                     max_len=args.prompt_len + args.new_tokens + 8,
                     paged=not args.dense_kv,
                     block_tokens=args.block_tokens,
                     prefix_sharing=not args.no_prefix_sharing,
                     hbm_budget_bytes=None if args.hbm_budget_gb is None
                     else int(args.hbm_budget_gb * (1 << 30)),
                     spec_k=spec_k,
                     moe_dispatch=args.moe_dispatch,
                     row_capacity_norm=args.row_capacity,
                     promo_deadline_s=args.promo_deadline_s,
                     scheduler=SchedulerConfig(
                         qos_default=args.qos_default,
                         shed_policy=args.shed_policy,
                         prefill_chunk=args.prefill_chunk)),
        dist=dist, obs=obs)
    toks = make_prompts(args.workload, cfg.vocab_size,
                        args.batch, args.prompt_len)
    use_sampling = (args.temperature > 0 or args.top_k is not None or
                    args.top_p < 1.0)
    t0 = time.perf_counter()
    handles = [engine.submit(Request(
        tokens=toks[b], max_new_tokens=args.new_tokens,
        sampling=SamplingParams(temperature=args.temperature,
                                top_k=args.top_k, top_p=args.top_p,
                                seed=args.seed + b)
        if use_sampling else None))
               for b in range(args.batch)]
    engine.drain()
    engine.flush()
    wall = time.perf_counter() - t0
    tput = sum(len(h.tokens) for h in handles) / wall
    st = engine.stats()
    print(f"[serve] TTFT {st['ttft_s']*1e3:.1f} ms  TPOT "
          f"{st['tpot_s']*1e3:.1f} ms  throughput {tput:.2f} tok/s")
    print(f"[serve] moe dispatch={engine.moe_dispatch}: "
          f"active_experts {st.get('active_experts', 0.0):.1f}"
          f"/{cfg.moe.num_experts if cfg.is_moe else 0}  "
          f"pad_ratio {st.get('dispatch_pad_ratio', 0.0):.2f}")
    if spec_k:
        row_rounds = max(1.0, st.get("spec_row_rounds", 0.0))
        print(f"[serve] spec: accept_rate {st['accept_rate']:.2f}  "
              f"tokens/row-round {st['verified_tokens']/row_rounds:.2f} "
              f"(1.0 = no speculation; {st['draft_tokens']:.0f} drafted "
              f"over {st['spec_rounds']:.0f} rounds)")
    print(f"[serve] resident expert bytes: {engine.device_bytes():,}")
    if obs is None:
        # No obs layer: fall back to the raw uniform stats dump.
        print(f"[serve] uniform stats: "
              f"{ {k: round(float(v), 4) for k, v in st.items()} }")
    else:
        summ = obs.summary()
        roof, prom = summ["roofline"], summ["promotions"]
        resid = max((abs(b["rel_residual"]) for b in roof["buckets"]),
                    default=0.0)
        stall = sum(h.stall_exposure_s for h in handles)
        print(f"[serve] obs: {summ['trace_events']} events "
              f"({summ['trace_dropped']} dropped)  "
              f"promotions {prom['n_published']} published / "
              f"{prom['n_cancelled']} cancelled "
              f"publish p95 {prom['publish_latency_p95_s']*1e3:.1f} ms  "
              f"bytes/token residual max {resid:.3f} "
              f"over {roof['n_steps']} decode steps  "
              f"stall exposure {stall*1e3:.1f} ms  "
              f"shed {st.get('shed_requests', 0.0):.0f}")
        if args.trace_out:
            obs.save_trace(args.trace_out)
            print(f"[serve] trace -> {args.trace_out}")
        obs.close()
    if args.hotness_path and hasattr(engine.backend, "save_hotness"):
        engine.backend.save_hotness()
        print(f"[serve] hotness snapshot -> {args.hotness_path}_p*.npz")


if __name__ == "__main__":
    main()
