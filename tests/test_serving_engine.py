"""Serving engine: four residency backends behind one request-level loop —
budget accounting, online adaptation, and the offload transfer model.
Engines come from the shared ``engine_factory`` fixture (tests/conftest.py),
so every suite exercises the same canonical backend settings."""
import numpy as np
import pytest

from repro.serving import OffloadConfig, make_prompts
from repro.serving.requests import WORKLOADS


@pytest.fixture()
def prompts(serving_setup):
    cfg, _ = serving_setup
    return np.asarray(make_prompts("text", cfg.vocab_size, 4, 24))


@pytest.mark.parametrize("name", ["fp16", "static", "dynaexq"])
def test_backends_generate(engine_factory, prompts, name):
    eng = engine_factory(name)
    out, ttft, times = eng.generate({"tokens": prompts}, 5)
    eng.flush()
    assert out.shape == (4, 5)
    assert ttft > 0 and len(times) == 4
    assert not np.isnan(np.asarray(out, np.float32)).any()


def test_footprint_ordering(engine_factory, prompts):
    """static < dynaexq < fp16 expert bytes — the budget story of Table 4."""
    sizes = {}
    for name in ["fp16", "static", "dynaexq"]:
        eng = engine_factory(name)
        if name == "dynaexq":
            eng.generate({"tokens": prompts}, 4)
            eng.flush()
        sizes[name] = eng.device_bytes()
    assert sizes["static"] < sizes["dynaexq"] < sizes["fp16"]


def test_dynaexq_promotes_under_skew(engine_factory, prompts):
    eng = engine_factory("dynaexq")
    eng.generate({"tokens": prompts}, 6)
    eng.flush()
    hi = eng.backend.hi_sets()["0"]
    # Budget-full residency: the global allocator spends the whole slot
    # budget (n_hi × L) but may skew slots toward hot layers — only the
    # TOTAL is pinned (the per-layer rule would pin each layer to n_hi).
    assert sum(len(s) for s in hi) == 2 * len(hi)
    ctl = eng.backend.controllers["0"]
    ctl.tm.check_invariants()
    assert ctl.tm.stats["promoted"] >= 2 * len(hi)  # n_hi × layers at least


def test_budget_derived_n_hi(serving_setup, engine_factory):
    """hbm_gb envelope → plan_budget path derives n_hi (paper's budget
    init)."""
    cfg, _ = serving_setup
    eng = engine_factory("dynaexq", n_hi_per_layer=None, hbm_gb=0.05,
                         activation_slack_bytes=1 << 20)
    ctl = eng.backend.controllers.get("0")
    if ctl is not None:
        assert 0 < ctl.policy.n_hi <= cfg.moe.num_experts


def test_offload_backend_accounts_transfers(engine_factory, prompts):
    eng = engine_factory("offload",
                         ocfg=OffloadConfig(cache_experts_per_layer=2,
                                            pcie_gbps=16.0))
    out, ttft, times = eng.generate({"tokens": prompts}, 5)
    st = eng.backend.stats()
    assert st["misses"] > 0 and st["bytes_moved"] > 0
    assert st["stall_s"] > 0
    # stall must equal modeled bytes/bw within the prefetch-overlap slack
    assert st["stall_s"] <= st["bytes_moved"] / 16e9 + 1e-6


def test_offload_cache_larger_means_fewer_misses(engine_factory, prompts):
    misses = {}
    for c in (1, 4):
        eng = engine_factory("offload",
                             ocfg=OffloadConfig(cache_experts_per_layer=c,
                                                prefetch=False))
        eng.generate({"tokens": prompts}, 5)
        misses[c] = eng.backend.stats()["misses"]
    assert misses[4] <= misses[1]


def test_workload_token_distributions_disjoint():
    """Different workloads draw from (mostly) disjoint vocab slices —
    the mechanism behind Fig. 2's hot-set shift."""
    sets = []
    for w in WORKLOADS:
        toks = make_prompts(w, 3000, 8, 128, seed=1)
        sets.append(set(np.asarray(toks).reshape(-1).tolist()))
    assert not (sets[0] & sets[1])
    assert not (sets[1] & sets[2])
