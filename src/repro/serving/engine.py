"""Request-level MoE serving engine with pluggable expert residency and a
paged, prefix-shared KV cache.

The unit of work is a **request**, not a batch: ``submit(request)`` returns a
handle, ``step()`` advances every in-flight request — by one sampled token,
or by a whole accepted draft burst when self-speculative decoding is on
(``EngineConfig(spec_k > 0)``, see ``repro.serving.spec``) — and ``drain()``
runs until the queue empties. Tokens are drawn host-side by each request's
own ``SamplingParams`` (``repro.serving.sampler``; greedy default is exact
argmax). The engine implements continuous batching over a fixed pool of
``max_slots`` batch rows:

* **admission** — queued requests are batched into a padded, masked prefill:
  prompt lengths round up a small geometric bucket ladder
  (``bucket_base``·2^i, capped at ``max_len``), up to ``prefill_rows``
  same-bucket requests prefill in ONE forward (per-row true lengths mask
  padding out of attention-cache writes, MoE dispatch and router counts).
  XLA therefore compiles at most one prefill executable per bucket
  — O(#buckets), not O(#distinct prompt lengths) — and admission cost
  amortizes over the batch at high arrival rates;
* **decode** — one jitted step advances *all* occupied slots together, with
  a per-slot position vector (each request decodes at its own offset) and a
  per-slot validity mask: vacant slots still ride along for shape stability
  but are masked out of MoE dispatch and every router count;
* **eviction/refill** — a finished request frees its slot at the end of the
  step; the next ``step()`` admits queued work into it mid-stream.

KV residency (``paged=True``, the default) is a **block pool**
(``repro.serving.kvpool``): attention caches live as fixed-size physical
blocks leased to requests through per-slot block tables, with a token-prefix
trie (``repro.serving.prefix``) mapping shared prompt prefixes (system
prompts, few-shot headers) onto the SAME physical blocks — admission adopts
trie hits and prefills only the suffix, skipping recompute entirely; decode
appends lazily and copy-on-writes shared blocks on divergence. KV block
bytes are reserved from the same ``BudgetTracker`` the expert hi-tier
promotes against, so KV admission and DynaExq promotions genuinely contend
for one HBM envelope (``hbm_budget_bytes``): KV pressure defers promotions,
demotions free headroom for admission. ``paged=False`` keeps the dense
per-slot rows — the parity reference. (Parity caveat: with a TIGHT MoE
``capacity_factor`` the router may drop overflow tokens, and the drop set
is a function of the compute batch — prefix skipping changes that batch,
exactly like batching itself does. Token-identity between the shared and
dense paths is therefore guaranteed for drop-free capacity settings.)

Where expert weights live — dense fp16, static PTQ, DynaExq mixed precision,
or host-offloaded with an LRU device cache — is entirely the
``ResidencyBackend``'s business (see ``repro.serving.backends``). The engine
calls exactly the backend protocol: ``materialize_banks`` at build time
(receiving the POOL's byte accounting and the shared budget),
``observe(counts, compute_s, prefill, row_valid)`` after every forward with
per-row (slot-resolved) router counts plus the row-validity mask — so no
backend ever accounts phantom traffic from padding or vacant slots — and
``tick()`` at step boundaries. There is no mode switch and no per-backend
branch anywhere in this loop.

Per-request routing telemetry falls out of the same signal: every
``RequestHandle`` accumulates its own row's expert counts
(``handle.expert_counts``: MoE position → (nsb, E)), attributing router
traffic to the request that caused it (prefix-skipped tokens are attributed
to the request that originally computed them).

``generate(batch, n_tokens)`` survives as a thin compat shim over
submit + drain for the whole-batch callers (benchmarks, launchers).
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import itertools
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.budget import UNBOUNDED, BudgetTracker
from repro.kernels import ops as kops
from repro.models import (attn_logical_capacity, decode_step,
                          decode_step_paged, init_caches, init_paged_caches,
                          prefill, prefill_paged)
from repro.models.config import ArchConfig
from repro.models.moe import RAGGED_BM, moe_capacity
from repro.models.model import DecodeCaches
from repro.serving.backends import ResidencyBackend
from repro.serving.kvpool import KVBlockPool, KVLease
from repro.serving.prefix import PrefixTrie
from repro.serving.requests import Request
from repro.serving.sampler import RequestSampler


# Module-level jitted entry points with the (frozen, hashable) ArchConfig as
# a static argument: the XLA compile cache is keyed on the function identity,
# so every engine built for the same config shares compilations — a warm-up
# engine genuinely warms the measured one (benchmarks rely on this).

@functools.partial(jax.jit, static_argnames=("cfg", "capacity_factor",
                                             "moe_dispatch", "row_capacity"))
def _prefill_jit(params, batch, caches, banks, lengths, *, cfg,
                 capacity_factor, moe_dispatch=None, row_capacity=None):
    return prefill(params, cfg, batch, caches, bank=banks,
                   capacity_factor=capacity_factor, lengths=lengths,
                   per_row_counts=True, moe_dispatch=moe_dispatch,
                   row_capacity=row_capacity)


@functools.partial(jax.jit, static_argnames=("cfg", "capacity_factor",
                                             "moe_dispatch", "row_capacity"))
def _decode_jit(params, token, pos, caches, banks, row_valid, *, cfg,
                capacity_factor, moe_dispatch=None, row_capacity=None):
    return decode_step(params, cfg, token, pos, caches, bank=banks,
                       capacity_factor=capacity_factor, row_valid=row_valid,
                       per_row_counts=True, moe_dispatch=moe_dispatch,
                       row_capacity=row_capacity)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "capacity_factor", "has_prefix",
                                    "moe_dispatch", "row_capacity"),
                   donate_argnums=(2,))
def _prefill_paged_jit(params, batch, caches, banks, table, start, lengths,
                       *, cfg, capacity_factor, has_prefix,
                       moe_dispatch=None, row_capacity=None):
    return prefill_paged(params, cfg, batch, caches, table, start, lengths,
                         bank=banks, capacity_factor=capacity_factor,
                         per_row_counts=True, has_prefix=has_prefix,
                         moe_dispatch=moe_dispatch, row_capacity=row_capacity)


@functools.partial(jax.jit, static_argnames=("cfg", "capacity_factor",
                                             "moe_dispatch", "row_capacity"),
                   donate_argnums=(3,))
def _decode_paged_jit(params, token, pos, caches, banks, row_valid, table,
                      write_blk, write_off, *, cfg, capacity_factor,
                      moe_dispatch=None, row_capacity=None):
    return decode_step_paged(params, cfg, token, pos, caches, table,
                             write_blk, write_off, bank=banks,
                             capacity_factor=capacity_factor,
                             row_valid=row_valid, per_row_counts=True,
                             moe_dispatch=moe_dispatch,
                             row_capacity=row_capacity)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(pool, rows, slots):
    """Write the first ``len(slots)`` prefilled rows of a bucket cache into
    the batch rows named by ``slots``. The pool is donated so XLA updates
    the (large) cache buffers in place."""
    n = slots.shape[0]
    return jax.tree_util.tree_map(
        lambda m, o: m.at[:, slots].set(o[:, :n]), pool, rows)


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_blocks(pools, src, dst):
    """Batched physical block copies (COW resolution): block ``src[i]`` →
    ``dst[i]`` in every attention pool leaf ((nsb, N, ...)). Sources are
    all gathered before any scatter, so same-step chains (A→B while A is
    reallocated as another copy's destination) read pre-step contents.
    Padding lanes are trash→trash self-copies."""
    return jax.tree_util.tree_map(
        lambda a: a.at[:, dst].set(a[:, src]), pools)


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 4               # concurrent requests (batch rows)
    max_len: int = 512               # per-slot sequence budget
    capacity_factor: float = 2.0
    pad_token_id: int = 0            # fed to never-yet-occupied decode rows
    bucket_base: int = 32            # smallest prefill length bucket
    # Rows per batched prefill (compile-time constant so the prefill compile
    # count stays O(#buckets)); None → min(4, max_slots).
    prefill_rows: Optional[int] = None
    # ---- paged KV pool ------------------------------------------------
    paged: bool = True               # block-pool KV (False = dense rows)
    block_tokens: int = 16           # cache positions per physical block
    # Physical blocks in the pool; None → exactly enough for max_slots full
    # sequences plus the trash block (sharing then only ADDS headroom).
    kv_blocks: Optional[int] = None
    prefix_sharing: bool = True      # trie-based cross-request prefix reuse
    # Unified HBM envelope shared by KV block reservations and the expert
    # hi tier (None = unbounded: per-subsystem caps still apply).
    hbm_budget_bytes: Optional[int] = None
    # ---- self-speculative decoding -----------------------------------
    # Max draft depth per round (0 = off). Drafting runs decode with the
    # backend's all-lo expert banks (no extra weights); every verify round
    # emits 1..spec_k+1 tokens. Token-identical to spec-off at
    # temperature=0 under drop-free MoE capacity (see serving.spec).
    spec_k: int = 0
    # Adapt the per-round draft depth from an acceptance-rate EMA over a
    # power-of-two ladder (False = always draft spec_k).
    spec_adaptive: bool = True
    # ---- MoE dispatch ------------------------------------------------
    # Token layout for every MoE layer of the serving forwards: "padded"
    # (fixed-capacity (E, C, d) scatter, reference), "ragged" (compacted
    # activations + fused mixed-precision kernel — only active experts'
    # weights stream), or None → kernels.ops.moe_dispatch_default()
    # (ragged on TPU, padded on CPU; REPRO_MOE_DISPATCH overrides).
    # Resolved ONCE at engine construction.
    moe_dispatch: Optional[str] = None
    # Per-row MoE capacity normalization: the drop rule under tight
    # capacity_factor becomes per-request-row (see moe._row_capacity_keep),
    # so whether a token's assignment drops no longer depends on which
    # other requests share the compute batch — prefix sharing and
    # spec-verify token identity then hold even in drop regimes.
    row_capacity_norm: bool = False


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"


class RequestHandle:
    """Mutable per-request view returned by ``submit``."""

    def __init__(self, rid: int, request: Request):
        self.id = rid
        self.request = request
        self.state = RequestState.QUEUED
        self.slot: Optional[int] = None
        self.tokens: List[int] = []      # generated tokens
        # Per-request sampling state (counter-based PRNG keyed by the
        # request's seed; greedy when the request carries no params).
        self.sampler = RequestSampler(request.sampling)
        self._eos_scanned = 0            # tokens already checked for EOS
        # Per-REQUEST speculative acceptance EMA: draft depth adapts from
        # this request's own history only, so its burst boundaries (and
        # therefore its PRNG stream consumption) never depend on which
        # other requests share the batch — bit-reproducibility survives
        # adaptive speculation.
        self.spec_ema = 0.75
        self.submit_s: float = 0.0       # perf_counter at submit
        self.stall_at_submit: float = 0.0  # engine stall-clock at submit
        self.ttft_s: float = 0.0         # submit → first token (incl. queue)
        self.step_times: List[float] = []
        self.lease: Optional[KVLease] = None   # paged-mode KV block lease
        self.prefix_hit_tokens: int = 0  # prompt tokens served from the trie
        # Per-request routing telemetry: MoE position → (nsb, E) int64
        # router selections attributed to THIS request's row (prompt tokens
        # at prefill + one per decode step). Populated at admission.
        self.expert_counts: Optional[Dict[str, np.ndarray]] = None

    @property
    def workload(self) -> str:
        return self.request.workload

    def token_array(self) -> np.ndarray:
        return np.asarray(self.tokens, np.int32)

    def __repr__(self):
        return (f"RequestHandle(id={self.id}, state={self.state.value}, "
                f"slot={self.slot}, n_generated={len(self.tokens)})")


class InferenceEngine:
    """Continuous-batching serving loop over a ``ResidencyBackend``."""

    def __init__(self, cfg: ArchConfig, params: Dict,
                 backend: ResidencyBackend,
                 ecfg: Optional[EngineConfig] = None):
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "InferenceEngine serves decoder-only stacks; encoder-decoder "
                "architectures go through the batch prefill/decode entry "
                "points in repro.models directly.")
        self.cfg = cfg
        self.params = params
        self.backend = backend
        self.ecfg = ecfg if ecfg is not None else EngineConfig()

        n = self.ecfg.max_slots
        sb = cfg.superblock_or_default()
        self._attn_pos = [str(p) for p, k in enumerate(sb) if k == "attn"]
        self._mamba_pos = [str(p) for p, k in enumerate(sb) if k != "attn"]

        # ---- unified HBM envelope + paged KV pool ----------------------
        # The pool is the single source of truth for KV bytes: both modes
        # size KV from the same block math, and in paged mode every block
        # is reserved against the shared budget the expert hi tier also
        # draws from (see repro.core.budget).
        cap = self.ecfg.hbm_budget_bytes
        self.budget = BudgetTracker(UNBOUNDED if cap is None else cap)
        self.pool: Optional[KVBlockPool] = None
        self.trie: Optional[PrefixTrie] = None
        self._bt = self.ecfg.block_tokens
        if self._attn_pos:
            self._C_attn = self.ecfg.max_len \
                if cfg.attn.sliding_window is None \
                else min(self.ecfg.max_len, cfg.attn.sliding_window)
            self._C_pad = attn_logical_capacity(cfg, self.ecfg.max_len,
                                                self._bt)
            self._nb_per_slot = self._C_pad // self._bt
        else:
            self._C_attn = self._C_pad = self._nb_per_slot = 0
        n_blocks = self.ecfg.kv_blocks if self.ecfg.kv_blocks is not None \
            else 1 + n * self._nb_per_slot
        block_bytes = self._block_bytes()
        if self.ecfg.paged and self._attn_pos:
            if self._nb_per_slot > n_blocks - 1:
                raise ValueError(
                    f"kv_blocks={n_blocks} cannot hold even one sequence "
                    f"({self._nb_per_slot} logical blocks + the trash "
                    f"block); raise kv_blocks or shrink max_len")
            self.pool = KVBlockPool(n_blocks, self._bt, block_bytes,
                                    budget=self.budget.view("kv"),
                                    reclaim=self._reclaim_blocks)
            # Prefix skipping needs leasable sequence state; recurrent
            # (mamba) positions cannot be restored from a cache, so mixed
            # stacks run the pool without the trie.
            if self.ecfg.prefix_sharing and not self._mamba_pos:
                self.trie = PrefixTrie(self.pool)
        # KV bytes reported to the backend = what is actually allocated:
        # the pool's capacity (trash + rounding included) in paged mode,
        # the dense per-slot rows otherwise.
        if self.pool is not None:
            kv_bytes = self.pool.capacity_bytes
        elif self._attn_pos:
            kv_bytes = (block_bytes // self._bt) * n * self._C_attn
        else:
            kv_bytes = 0

        self.banks = backend.materialize_banks(cfg, params, kv_bytes,
                                               budget=self.budget)
        # MoE dispatch layout + per-row capacity normalization, resolved
        # ONCE here (env changes after construction cannot disagree with
        # already-compiled executables). The decode row cap is static; the
        # prefill cap depends on the length bucket and rides per call.
        self.moe_dispatch = self.ecfg.moe_dispatch \
            if self.ecfg.moe_dispatch is not None \
            else kops.moe_dispatch_default()
        if self.moe_dispatch not in ("padded", "ragged"):
            raise ValueError(f"moe_dispatch={self.moe_dispatch!r}; "
                             f"one of padded|ragged")
        norm = self.ecfg.row_capacity_norm and cfg.is_moe
        self._row_cap_decode = moe_capacity(
            1, cfg.moe, self.ecfg.capacity_factor) if norm else None
        self._row_cap_norm = norm
        self._jit_prefill = functools.partial(
            _prefill_jit, cfg=cfg,
            capacity_factor=self.ecfg.capacity_factor,
            moe_dispatch=self.moe_dispatch)
        self._jit_decode = functools.partial(
            _decode_jit, cfg=cfg,
            capacity_factor=self.ecfg.capacity_factor,
            moe_dispatch=self.moe_dispatch,
            row_capacity=self._row_cap_decode)
        self._jit_prefill_paged = functools.partial(
            _prefill_paged_jit, cfg=cfg,
            capacity_factor=self.ecfg.capacity_factor,
            moe_dispatch=self.moe_dispatch)
        self._jit_decode_paged = functools.partial(
            _decode_paged_jit, cfg=cfg,
            capacity_factor=self.ecfg.capacity_factor,
            moe_dispatch=self.moe_dispatch,
            row_capacity=self._row_cap_decode)
        self._jit_scatter = _scatter_rows
        # Dispatch-efficiency gauges (host mirror of MoEAux telemetry).
        self._disp_active_sum = 0.0
        self._disp_pad_sum = 0.0
        self._disp_layers = 0

        if self.pool is not None:
            self.caches = init_paged_caches(cfg, n, self.ecfg.max_len,
                                            self._bt, self.pool.n_blocks)
        else:
            self.caches = init_caches(cfg, n, self.ecfg.max_len)
        self.slots: List[Optional[RequestHandle]] = [None] * n
        self.pos = np.zeros(n, np.int32)        # next write position per slot
        self.tokens = np.full(n, self.ecfg.pad_token_id, np.int32)
        self.queue: deque[RequestHandle] = deque()
        self.last_counts: Dict = {}             # (nsb, E) counts, last forward
        self.last_row_counts: Dict = {}         # (nsb, R, E), last forward
        self.decode_times: List[float] = []     # per-step latency incl. stall
        # Per-TOKEN decode latency accounting: a speculative round's
        # dispatch latency amortizes over every token the round emits, so
        # tpot stays time-per-OUTPUT-token whether or not speculation runs.
        self._tpot_sum = 0.0                    # Σ row-rounds × latency
        self._tpot_tokens = 0                   # decode-emitted tokens
        self.ttfts: List[float] = []            # per-request submit→first-tok
        # Cumulative modeled stall seconds (backend-returned, never slept):
        # a virtual clock running alongside perf_counter, so queue-inclusive
        # latencies charge the stalls of work that ran ahead of a request.
        self._stall_clock = 0.0
        self._ids = itertools.count()
        self.counters = {"steps": 0, "prefills": 0, "admitted": 0,
                         "finished": 0, "prefill_tokens": 0,
                         "prefix_hit_tokens": 0, "kv_cow_copies": 0}
        # ---- length-bucket ladder -----------------------------------
        # SSD prefill requires sequence length divisible by the chunk size,
        # so for stacks with mamba layers every bucket is a chunk multiple.
        self._seq_mult = cfg.ssm.chunk if self._mamba_pos else 1
        m = self._seq_mult
        cap = (self.ecfg.max_len // m) * m
        if cap <= 0:
            raise ValueError(
                f"max_len={self.ecfg.max_len} below the SSD chunk multiple "
                f"{m}; no prefill bucket fits")
        base = max(1, -(-self.ecfg.bucket_base // m) * m)
        ladder: List[int] = []
        v = base
        while v < cap:
            ladder.append(v)
            v *= 2
        ladder.append(cap)
        self.buckets = tuple(ladder)            # ascending, last == cap
        self._max_prompt = cap
        self._prefill_rows = self.ecfg.prefill_rows \
            if self.ecfg.prefill_rows is not None else min(4, n)
        self.prefill_shapes: set = set()        # (rows, bucket) traced
        # ---- self-speculative decoding ------------------------------
        self._spec = None
        if self.ecfg.spec_k > 0:
            from repro.serving.spec import SpecDecoder
            self._spec = SpecDecoder(self)

    # ------------------------------------------------------------------
    def _row_cap_prefill(self, bucket: int) -> Optional[int]:
        """Per-row MoE capacity for a prefill at this length bucket (None
        when normalization is off). Bucket-derived so it is a static compile
        constant per bucket and depends only on the request's own length —
        never on which rows share the batch."""
        if not self._row_cap_norm:
            return None
        return moe_capacity(bucket, self.cfg.moe, self.ecfg.capacity_factor)

    def _note_dispatch(self, counts_np: Dict) -> None:
        """Host mirror of the MoEAux dispatch telemetry: per-layer active
        expert counts and the pad ratio of the layout actually configured
        (padding rows of the (E, C) buffer, or intra-tile slack of the
        bm-aligned ragged layout) — the uniform ``active_experts`` /
        ``dispatch_pad_ratio`` gauges in ``stats()``."""
        if not self.cfg.is_moe or not counts_np:
            return
        E = self.cfg.moe.num_experts
        if self._row_cap_decode is not None:
            C = self.ecfg.max_slots * self._row_cap_decode
        else:
            C = moe_capacity(self.ecfg.max_slots, self.cfg.moe,
                             self.ecfg.capacity_factor)
        for v in counts_np.values():
            v = np.asarray(v)
            if v.ndim == 4:                       # (W, nsb, B, E) spec steps
                per = v.sum(axis=2).reshape(-1, E)
            elif v.ndim == 3:                     # (nsb, B, E) per-row
                per = v.sum(axis=1).reshape(-1, E)
            else:                                 # (nsb, E) aggregated
                per = v.reshape(-1, E)
            per = per.astype(np.float64)
            routed = per.sum(axis=1)
            live = routed > 0
            if not live.any():
                continue
            per = per[live]
            routed = routed[live]
            active = (per > 0).sum(axis=1)
            if self.moe_dispatch == "ragged":
                tiles = np.ceil(per / RAGGED_BM).sum(axis=1)
                pad = 1.0 - routed / np.maximum(tiles * RAGGED_BM, 1.0)
            else:
                kept = np.minimum(per, C).sum(axis=1)
                pad = 1.0 - kept / max(E * C, 1)
            self._disp_active_sum += float(active.sum())
            self._disp_pad_sum += float(pad.sum())
            self._disp_layers += int(active.shape[0])

    def _block_bytes(self) -> int:
        """Bytes of ONE physical block across every attention layer of the
        stack (k+v, bf16). The pool's block math is the only KV size
        accounting in the system."""
        cfg = self.cfg
        if not self._attn_pos:
            return 0
        n_attn = len(self._attn_pos) * cfg.n_superblocks()
        return (2 * self._bt * cfg.attn.n_kv_heads * cfg.attn.head_dim *
                2 * n_attn)

    def _reclaim_blocks(self, need: int) -> int:
        return self.trie.evict(need) if self.trie is not None else 0

    def _quota_blocks(self, plen: int, start: int, max_new: int) -> int:
        """Worst-case physical blocks a request can ever allocate.

        Full attention (positions only grow): exactly the logical blocks
        from the (block-aligned) prefix hit ``start`` to the sequence cap —
        adopted prefix blocks and registered chunks are never rewritten, so
        they can never COW. Sliding-window rings can wrap a write onto ANY
        logical block: one allocation per logical block (lazy append or COW
        of an adopted block) plus one per trie-registrable prompt chunk (a
        block this lease computes, shares, then COWs on a later wrap)."""
        seq_cap = min(self.ecfg.max_len, plen + max_new)
        if self.cfg.attn.sliding_window is None:
            return -(-seq_cap // self._bt) - start // self._bt
        n_write = -(-min(self._C_pad, seq_cap) // self._bt)
        n_reg = plen // self._bt \
            if (self.trie is not None and plen <= self._C_attn) else 0
        return n_write + n_reg

    # ------------------------------------------------------------------
    def submit(self, request: Request) -> RequestHandle:
        """Queue a request; it is admitted on a later ``step()`` as soon as
        a cache slot frees up. Returns immediately with a handle.

        The prompt must fit the largest prefill bucket (``max_len`` rounded
        down to the engine's sequence multiple). A generation budget that
        overruns the slot is fine — common for eos-bounded requests — the
        request is truncated at the sequence capacity (finishes with fewer
        than ``max_new_tokens`` tokens)."""
        plen = int(np.asarray(request.tokens).shape[-1])
        if plen > self._max_prompt:
            raise ValueError(
                f"prompt of {plen} tokens exceeds the largest prefill "
                f"bucket {self._max_prompt} (max_len={self.ecfg.max_len})")
        if request.sampling is not None:
            # Malformed sampling params fail at the door, not mid-decode.
            request.sampling.validate()
        if self.pool is not None:
            # Loud infeasibility instead of an unbounded queue spin: a
            # request whose worst-case KV quota (no prefix hits) plus the
            # trash block can NEVER fit the envelope — or whose live block
            # footprint exceeds the pool's physical blocks — would block
            # the queue head forever.
            worst = ((1 + self._quota_blocks(plen, 0, request.max_new_tokens))
                     * self.pool.block_bytes)
            if worst > self.budget.cap:
                raise ValueError(
                    f"request needs {worst} bytes of KV worst-case but the "
                    f"HBM envelope caps at {self.budget.cap}; raise "
                    f"hbm_budget_bytes or shorten the request")
        handle = RequestHandle(next(self._ids), request)
        handle.submit_s = time.perf_counter()
        handle.stall_at_submit = self._stall_clock
        self.queue.append(handle)
        return handle

    def _bucket_len(self, plen: int) -> int:
        """Smallest ladder bucket that fits ``plen`` tokens."""
        for b in self.buckets:
            if b >= plen:
                return b
        raise ValueError(f"prompt of {plen} tokens exceeds the largest "
                         f"bucket {self.buckets[-1]}")

    @staticmethod
    def _prompt_len(handle: RequestHandle) -> int:
        return int(np.asarray(handle.request.tokens).reshape(-1).shape[0])

    # -- paged-mode helpers --------------------------------------------
    def _apply_copies(self, cows: List[Tuple[int, int]]) -> None:
        """Run the batched (src, dst) block copies on-device; lane count
        padded to a power of two (trash self-copies) to bound compiles."""
        if not cows:
            return
        n = 1 << max(0, len(cows) - 1).bit_length()
        src = np.zeros(n, np.int32)
        dst = np.zeros(n, np.int32)
        for i, (s, d) in enumerate(cows):
            src[i], dst[i] = s, d
        attn_sub = {p: self.caches.blocks[p] for p in self._attn_pos}
        new_sub = _copy_blocks(attn_sub, jnp.asarray(src), jnp.asarray(dst))
        self.caches = DecodeCaches(
            blocks={**self.caches.blocks, **new_sub}, cross=None)
        self.counters["kv_cow_copies"] += len(cows)

    def _block_tables(self) -> np.ndarray:
        """(max_slots, nb) physical block table rows (vacant rows -1)."""
        nb = max(1, self._nb_per_slot)
        out = np.full((self.ecfg.max_slots, nb), -1, np.int32)
        for i, h in enumerate(self.slots):
            if h is not None and h.lease is not None:
                out[i] = h.lease.table
        return out

    def _ensure_write(self, lease: KVLease, pos: int,
                      cows: List[Tuple[int, int]]) -> Tuple[int, int]:
        """Resolve the physical (block, offset) for a write at absolute
        position ``pos``, collecting any COW obligation."""
        s = pos % self._C_pad
        phys, cow = lease.ensure(s // self._bt)
        if cow >= 0:
            cows.append((cow, phys))
        return phys, s % self._bt

    # ------------------------------------------------------------------
    def _admit(self, finished: List[RequestHandle]) -> None:
        """Fill free slots from the queue with batched, length-bucketed
        masked prefills: the queue head picks the bucket, same-bucket
        requests behind it join (up to ``prefill_rows`` and the free-slot
        count), the batch right-pads to (prefill_rows, bucket), and each
        prefilled row scatters into its slot of the batched caches. Batch
        rows beyond the group are ``lengths == 0`` pads, so every prefill
        compiles at one of O(#buckets) shapes.

        In paged mode the bucket is chosen by the SUFFIX length (prompt
        minus trie-hit prefix) and admission additionally passes the KV
        quota gate: a request whose worst-case block bytes do not fit the
        shared budget waits in the queue — expert demotions or finishing
        requests free the headroom that admits it. (Stacks without
        attention positions have no KV to page and always take the dense
        path.)"""
        if self.pool is not None:
            self._admit_paged(finished)
        else:
            self._admit_dense(finished)

    def _admit_dense(self, finished: List[RequestHandle]) -> None:
        while self.queue:
            free = [i for i, h in enumerate(self.slots) if h is None]
            if not free:
                return
            R = self._prefill_rows
            limit = min(len(free), R)
            head = self.queue.popleft()
            bucket = self._bucket_len(self._prompt_len(head))
            group = [head]
            skipped: List[RequestHandle] = []
            while self.queue and len(group) < limit:
                h = self.queue.popleft()
                if self._bucket_len(self._prompt_len(h)) == bucket:
                    group.append(h)
                else:
                    skipped.append(h)
            self.queue.extendleft(reversed(skipped))

            G = len(group)
            lengths = np.zeros(R, np.int32)
            batch_toks = np.full((R, bucket), self.ecfg.pad_token_id,
                                 np.int32)
            for r, h in enumerate(group):
                p = np.asarray(h.request.tokens, np.int32).reshape(-1)
                lengths[r] = p.shape[0]
                batch_toks[r, :p.shape[0]] = p
            row_caches = init_caches(self.cfg, R, self.ecfg.max_len)
            t0 = time.perf_counter()
            logits, row_caches, counts = self._jit_prefill(
                self.params, {"tokens": jnp.asarray(batch_toks)},
                row_caches, self.banks, jnp.asarray(lengths),
                row_capacity=self._row_cap_prefill(bucket))
            logits.block_until_ready()
            dt = time.perf_counter() - t0
            self.prefill_shapes.add((R, bucket))
            slots_arr = np.asarray(free[:G], np.int32)
            # Scatter the prefilled rows into their slots' batch rows.
            self.caches = DecodeCaches(
                blocks=self._jit_scatter(self.caches.blocks,
                                         row_caches.blocks,
                                         jnp.asarray(slots_arr)),
                cross=None)
            self._post_prefill(group, slots_arr, lengths, counts, dt,
                               logits,
                               [int(x) for x in lengths[:G]], finished)

    def _admit_paged(self, finished: List[RequestHandle]) -> None:
        while self.queue:
            free = [i for i, h in enumerate(self.slots) if h is None]
            if not free:
                return
            R = self._prefill_rows
            limit = min(len(free), R)
            group: List[Tuple[RequestHandle, KVLease, int]] = []
            skipped: List[RequestHandle] = []
            bucket = None
            while self.queue and len(group) < limit:
                h = self.queue.popleft()
                plen = self._prompt_len(h)
                toks = np.asarray(h.request.tokens, np.int32).reshape(-1)
                hits: List[int] = []
                if self.trie is not None:
                    max_hit = min((plen - 1) // self._bt, self._nb_per_slot)
                    hits = self.trie.match(toks, max_blocks=max_hit)
                    # Pin the hits NOW: the quota reservation below may
                    # reclaim trie-exclusive blocks under byte pressure,
                    # and a bare match() holds no reference.
                    for blk in hits:
                        self.pool.retain(blk)
                start = len(hits) * self._bt
                b = self._bucket_len(plen - start)
                if bucket is None:
                    bucket = b
                elif b != bucket:
                    for blk in hits:
                        self.pool.release(blk)
                    skipped.append(h)
                    continue
                # Physical headroom: live lease footprints are bounded by
                # nb_per_slot each (release-before-alloc keeps COW from
                # pinning extras), so admission defers when an UNDERSIZED
                # pool (explicit kv_blocks) cannot physically host one more
                # sequence alongside the running ones — instead of crashing
                # a mid-stream alloc. Default sizing never defers here.
                running = sum(s is not None for s in self.slots) + len(group)
                if (running + 1) * self._nb_per_slot > self.pool.n_blocks - 1:
                    for blk in hits:
                        self.pool.release(blk)
                    skipped.append(h)
                    if not group:
                        break       # wait for a running request to finish
                    continue
                quota = self._quota_blocks(plen, start,
                                           h.request.max_new_tokens)
                if not self.pool.try_reserve_quota(quota):
                    # Shared-envelope backpressure: the request waits for
                    # expert demotions / finishing requests to free bytes.
                    for blk in hits:
                        self.pool.release(blk)
                    skipped.append(h)
                    if not group:
                        break       # head blocked — retry next step
                    continue
                lease = KVLease(self.pool, self._nb_per_slot, quota)
                if hits:
                    lease.adopt_prefix(hits, retained=True)
                    h.prefix_hit_tokens = start
                group.append((h, lease, start))
            self.queue.extendleft(reversed(skipped))
            if not group:
                return
            G = len(group)
            nb = max(1, self._nb_per_slot)
            lengths = np.zeros(R, np.int32)       # TOTAL prompt lengths
            starts = np.zeros(R, np.int32)
            tables = np.full((R, nb), -1, np.int32)
            batch_toks = np.full((R, bucket), self.ecfg.pad_token_id,
                                 np.int32)
            cows: List[Tuple[int, int]] = []
            for r, (h, lease, start) in enumerate(group):
                toks = np.asarray(h.request.tokens, np.int32).reshape(-1)
                plen = toks.shape[0]
                lengths[r], starts[r] = plen, start
                batch_toks[r, :plen - start] = toks[start:]
                # Resolve every block the suffix will write (ring wrap
                # included): fresh allocation or COW of shared blocks.
                # O(#blocks), not O(#tokens): the written ring-slot span is
                # contiguous modulo C_pad.
                if plen - start >= self._C_pad:
                    write_blocks = range(self._nb_per_slot)
                else:
                    s0 = start % self._C_pad
                    s1 = (plen - 1) % self._C_pad
                    if s0 <= s1:
                        write_blocks = range(s0 // self._bt,
                                             s1 // self._bt + 1)
                    else:                    # wrapped once past the ring end
                        write_blocks = sorted(
                            set(range(0, s1 // self._bt + 1)) |
                            set(range(s0 // self._bt, self._nb_per_slot)))
                for j in write_blocks:
                    phys, cow = lease.ensure(j)
                    if cow >= 0:
                        cows.append((cow, phys))
                tables[r] = lease.table
            self._apply_copies(cows)
            has_prefix = bool((starts > 0).any())
            mamba_rows = init_caches(self.cfg, R, self.ecfg.max_len,
                                     positions=self._mamba_pos).blocks \
                if self._mamba_pos else {}
            call_caches = DecodeCaches(blocks={
                **{p: self.caches.blocks[p] for p in self._attn_pos},
                **mamba_rows}, cross=None)
            t0 = time.perf_counter()
            logits, new_caches, counts = self._jit_prefill_paged(
                self.params, {"tokens": jnp.asarray(batch_toks)},
                call_caches, self.banks, jnp.asarray(tables),
                jnp.asarray(starts), jnp.asarray(lengths),
                has_prefix=has_prefix,
                row_capacity=self._row_cap_prefill(bucket))
            logits.block_until_ready()
            dt = time.perf_counter() - t0
            self.prefill_shapes.add((R, bucket))
            slots_arr = np.asarray(free[:G], np.int32)
            blocks = {p: new_caches.blocks[p] for p in self._attn_pos}
            if self._mamba_pos:
                mamba_new = self._jit_scatter(
                    {p: self.caches.blocks[p] for p in self._mamba_pos},
                    {p: new_caches.blocks[p] for p in self._mamba_pos},
                    jnp.asarray(slots_arr))
                blocks.update(mamba_new)
            self.caches = DecodeCaches(blocks=blocks, cross=None)
            # Register newly computed prompt chunks for future sharing (only
            # prompts that fit the logical cache wholly — ring overwrites
            # would otherwise leave stale chunks in the trie).
            for (h, lease, start) in group:
                plen = self._prompt_len(h)
                if self.trie is not None and plen <= self._C_attn:
                    toks = np.asarray(h.request.tokens,
                                      np.int32).reshape(-1)
                    chain = [int(lease.table[j])
                             for j in range(plen // self._bt)]
                    self.trie.insert(toks, chain)
            for (h, lease, _) in group:
                h.lease = lease
            self._post_prefill([h for h, _, _ in group], slots_arr, lengths,
                               counts, dt, logits,
                               [int(lengths[r] - starts[r])
                                for r in range(G)], finished)

    def _post_prefill(self, group: List[RequestHandle],
                      slots_arr: np.ndarray, lengths: np.ndarray, counts,
                      dt: float, logits,
                      computed: List[int],
                      finished: List[RequestHandle]) -> None:
        """Shared post-prefill bookkeeping: counts → backend, TTFT, slot
        assignment, telemetry. ``logits`` ((R, V) f32, device) are the
        last-token logits each row's sampler draws its FIRST token from
        (emission index 0); an all-greedy group ships only the device
        argmax to host. ``computed[r]`` is the number of prompt tokens this
        prefill actually computed for row r (suffix length in paged mode —
        the prefix-share saving shows up here)."""
        R = self._prefill_rows
        G = len(group)
        amax = np.asarray(jnp.argmax(logits, -1), np.int32)
        samp = self._gather_sampling_rows(
            logits, [r for r, h in enumerate(group)
                     if not h.sampler.greedy])
        counts_np = {k: np.asarray(v) for k, v in counts.items()}
        self.last_row_counts = counts_np
        self.last_counts = {k: v.sum(axis=1) if v.ndim == 3 else v
                            for k, v in counts_np.items()}
        row_valid = np.zeros(R, bool)
        row_valid[:G] = True
        stall = self.backend.observe(counts_np, dt, prefill=True,
                                     row_valid=row_valid)
        self._stall_clock += stall
        for r, handle in enumerate(group):
            slot = int(slots_arr[r])
            tok = int(amax[r]) if r not in samp else \
                handle.sampler.next_token(samp[r], 0)
            handle.tokens.append(tok)
            # Serving TTFT: submit → first token. Wall clock covers
            # queue wait and the prefills admitted ahead of it; the
            # stall-clock delta charges every MODELED stall since submit
            # (predecessors' demand misses and this forward's own) that
            # wall time never slept. The backend's own ttft_s tracks
            # per-prefill latency.
            handle.ttft_s = (time.perf_counter() - handle.submit_s +
                             self._stall_clock - handle.stall_at_submit)
            self.ttfts.append(handle.ttft_s)
            handle.state = RequestState.RUNNING
            handle.slot = slot
            # Per-request attribution needs row-resolved counts; under
            # shard_map expert parallelism only aggregates exist.
            handle.expert_counts = {
                k: v[:, r].astype(np.int64)
                for k, v in counts_np.items() if v.ndim == 3}
            self.slots[slot] = handle
            self.pos[slot] = int(lengths[r])
            self.tokens[slot] = tok
            self.counters["admitted"] += 1
            self.counters["prefill_tokens"] += computed[r]
            self.counters["prefix_hit_tokens"] += handle.prefix_hit_tokens
            if self._done(handle):
                self._finish(handle, finished)
        self.counters["prefills"] += 1

    @staticmethod
    def _gather_sampling_rows(logits, rows: List[int]) -> Dict[int,
                                                               np.ndarray]:
        """Ship the (·, V) f32 logits of only the given batch rows to host
        (device-side gather first): row index → (V,) np array."""
        if not rows:
            return {}
        sub = np.asarray(logits[jnp.asarray(rows, jnp.int32)])
        return {i: sub[j] for j, i in enumerate(rows)}

    def _done(self, handle: RequestHandle) -> bool:
        req = handle.request
        if req.eos_token_id is not None:
            # A speculative verify step can accept a burst with EOS in the
            # MIDDLE: scan every not-yet-checked token (not just the tail)
            # and truncate the output at the first occurrence.
            toks = handle.tokens
            for t in range(handle._eos_scanned, len(toks)):
                if toks[t] == req.eos_token_id:
                    del toks[t + 1:]
                    handle._eos_scanned = len(toks)
                    return True
            handle._eos_scanned = len(toks)
        if len(handle.tokens) >= req.max_new_tokens:
            return True
        # Out of sequence budget: the slot's cache row is full.
        return int(self.pos[handle.slot]) >= self.ecfg.max_len

    def _finish(self, handle: RequestHandle,
                finished: List[RequestHandle]) -> None:
        handle.state = RequestState.FINISHED
        self.slots[handle.slot] = None
        if handle.lease is not None:
            # Release block refs + unspent quota; trie-registered blocks
            # keep the trie's own reference and stay warm for future hits.
            handle.lease.close()
        # The vacated row keeps replaying its last token through the batched
        # decode (shape stability), but row_valid masks it out of MoE
        # dispatch and every router count — vacancy is invisible to hotness
        # and residency accounting.
        self.counters["finished"] += 1
        finished.append(handle)

    # ------------------------------------------------------------------
    def step(self) -> List[RequestHandle]:
        """One engine step: admit queued requests into free slots, then
        advance every running request — by one token on the plain path, by
        a whole accepted burst (1..spec_k+1 tokens) when speculative
        decoding is on. Returns the handles that finished this step."""
        finished: List[RequestHandle] = []
        self._admit(finished)
        active = [(i, h) for i, h in enumerate(self.slots) if h is not None]
        if active:
            # The speculative round falls back to the single-token step
            # when no row has draft headroom (e.g. one token remaining).
            if self._spec is None or not self._spec.round(active, finished):
                self._decode_one(active, finished)
        self.backend.tick()
        return finished

    def _decode_one(self, active, finished: List[RequestHandle]) -> None:
        """Advance every active row by exactly one sampled token."""
        row_valid = np.asarray([h is not None for h in self.slots], bool)
        t0 = time.perf_counter()
        if self.pool is not None:
            n = self.ecfg.max_slots
            wblk = np.zeros(n, np.int32)     # vacant rows → trash block
            woff = np.zeros(n, np.int32)
            cows: List[Tuple[int, int]] = []
            for i, h in active:
                wblk[i], woff[i] = self._ensure_write(
                    h.lease, int(self.pos[i]), cows)
            self._apply_copies(cows)
            logits, self.caches, counts = self._jit_decode_paged(
                self.params, jnp.asarray(self.tokens),
                jnp.asarray(self.pos), self.caches, self.banks,
                jnp.asarray(row_valid),
                jnp.asarray(self._block_tables()),
                jnp.asarray(wblk), jnp.asarray(woff))
        else:
            logits, self.caches, counts = self._jit_decode(
                self.params, jnp.asarray(self.tokens),
                jnp.asarray(self.pos), self.caches, self.banks,
                jnp.asarray(row_valid))
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        counts_np = {k: np.asarray(v) for k, v in counts.items()}
        self.last_row_counts = counts_np
        self.last_counts = {k: v.sum(axis=1) if v.ndim == 3 else v
                            for k, v in counts_np.items()}
        self._note_dispatch(counts_np)
        stall = self.backend.observe(counts_np, dt, prefill=False,
                                     row_valid=row_valid)
        self._stall_clock += stall
        latency = dt + stall
        self.decode_times.append(latency)
        self._tpot_sum += latency * len(active)
        self._tpot_tokens += len(active)
        # Greedy fast path: only the (B,) device argmax crosses to host;
        # full (·, V) logits rows ship only for requests that sample
        # (device-gathered, so greedy neighbors stay off the transfer).
        amax = np.asarray(jnp.argmax(logits, -1), np.int32)
        samp = self._gather_sampling_rows(
            logits, [i for i, h in active if not h.sampler.greedy])
        for i, handle in active:
            tok = int(amax[i]) if i not in samp else \
                handle.sampler.next_token(samp[i], len(handle.tokens))
            handle.tokens.append(tok)
            handle.step_times.append(latency)
            for k, v in counts_np.items():
                if v.ndim == 3 and k in handle.expert_counts:
                    handle.expert_counts[k] += v[:, i]
            self.tokens[i] = tok
            self.pos[i] += 1
            if self._done(handle):
                self._finish(handle, finished)
        self.counters["steps"] += 1

    def drain(self) -> List[RequestHandle]:
        """Run ``step()`` until no request is queued or running; returns the
        handles finished during the drain, in completion order.

        A queued request blocked on the shared HBM envelope normally waits
        for in-flight work (finishing requests, expert demotions) to free
        bytes. If the engine goes fully idle and hundreds of consecutive
        steps (each of which ticks the backend, so pending transitions and
        demotions do get their chance) admit nothing, no future step can
        change anything — raise instead of busy-spinning forever."""
        done: List[RequestHandle] = []
        stalled = 0
        while self.queue or any(h is not None for h in self.slots):
            before = len(self.queue)
            done.extend(self.step())
            stalled = self._check_admission_stall(stalled, before)
        return done

    def _check_admission_stall(self, stalled: int, queue_before: int) -> int:
        """Post-step progress accounting for the serving loops: bump (and
        eventually trip) the stall counter when the engine sits fully idle
        with queued work it could not admit."""
        idle = not any(h is not None for h in self.slots)
        if self.queue and idle and len(self.queue) == queue_before:
            stalled += 1
            if stalled > 256:
                raise RuntimeError(
                    f"admission stalled: {len(self.queue)} queued "
                    f"request(s) cannot reserve KV under the shared "
                    f"HBM envelope and no in-flight work remains to "
                    f"free bytes (envelope used "
                    f"{self.budget.used}/{self.budget.cap})")
            return stalled
        return 0

    def replay(self, stream, realtime: bool = True,
               virtual_step_s: float = 2e-3) -> List[RequestHandle]:
        """Serve an arrival-timed request stream (e.g. ``RequestStream``).

        ``realtime=True`` (benchmarks): each request is submitted once the
        wall clock — measured from replay start — passes its ``arrival_s``
        offset, so queueing delay and TTFT reflect the offered load. When
        the engine goes idle before the next arrival it skips ahead instead
        of spinning.

        ``realtime=False`` (CI / tests): a **virtual clock** replaces
        ``perf_counter`` — it advances ``virtual_step_s`` per engine step
        and fast-forwards across idle gaps — so the interleaving of
        arrivals with admissions (and therefore every generated token) is
        fully deterministic, machine speed be damned.

        Returns handles in arrival order; all are FINISHED on return."""
        requests = list(stream)
        handles: List[RequestHandle] = []
        i = 0
        now = 0.0
        stalled = 0
        t0 = time.perf_counter()
        while i < len(requests) or self.queue or \
                any(h is not None for h in self.slots):
            if realtime:
                now = time.perf_counter() - t0
            while i < len(requests) and requests[i].arrival_s <= now:
                handles.append(self.submit(requests[i]))
                i += 1
            if i < len(requests) and not self.queue and \
                    all(h is None for h in self.slots):
                # Idle gap until the next arrival — fast-forward.
                if not realtime:
                    now = requests[i].arrival_s
                handles.append(self.submit(requests[i]))
                i += 1
            before = len(self.queue)
            self.step()
            if i >= len(requests):
                # All arrivals in: the same dead-admission detection as
                # drain() (a permanently envelope-blocked head would
                # otherwise spin this loop forever).
                stalled = self._check_admission_stall(stalled, before)
            if not realtime:
                now += virtual_step_s
        return handles

    def flush(self) -> None:
        """Barrier on the backend's in-flight residency transitions."""
        self.backend.flush()

    # ------------------------------------------------------------------
    def generate(self, batch: Dict, n_tokens: int, sampling=None):
        """Whole-batch compat shim over submit + drain.

        ``batch``: ``{"tokens": (B, S)}`` with B ≤ ``max_slots``.
        ``sampling``: optional ``SamplingParams`` applied to every row
        (default greedy — bit-identical to the pre-sampler shim); validated
        at ``submit`` like any request. Returns ``(tokens (B, n_tokens),
        ttft_s, per_step_s)`` token-for-token identical to driving
        submit/step/drain directly.
        Token-only: multimodal batches (``image_embeds``/``audio_embeds``)
        are not supported by the request path and are rejected loudly.
        """
        extra = set(batch) - {"tokens"}
        if extra:
            raise NotImplementedError(
                f"InferenceEngine serves token-only requests; unsupported "
                f"batch keys: {sorted(extra)}. Use repro.models.prefill/"
                f"decode_step directly for multimodal batches.")
        toks = np.asarray(batch["tokens"])
        B = toks.shape[0]
        if B > self.ecfg.max_slots:
            raise ValueError(f"batch {B} > max_slots={self.ecfg.max_slots}")
        if toks.shape[1] + n_tokens - 1 > self.ecfg.max_len:
            # The shim stacks a dense (B, n_tokens) grid — truncation would
            # break it, so the whole batch must fit the slot budget.
            raise ValueError(
                f"{toks.shape[1]}-token prompts + {n_tokens} new tokens "
                f"exceed max_len={self.ecfg.max_len}")
        handles = [self.submit(Request(tokens=toks[i],
                                       max_new_tokens=n_tokens,
                                       sampling=sampling))
                   for i in range(B)]
        n_before = len(self.decode_times)
        self.drain()
        out = jnp.asarray(np.stack([h.token_array() for h in handles], 0))
        ttft = float(np.mean([h.ttft_s for h in handles]))
        return out, ttft, self.decode_times[n_before:]

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Backend's uniform serving stats merged with engine counters.
        ``ttft_s`` is the request-level submit→first-token mean (queue wait
        included); the backend's per-prefill latency stays available via
        ``backend.stats()``. Paged engines add the KV-pool gauges:
        ``kv_blocks_in_use`` / ``kv_bytes_in_use`` (pool accounting, quota
        included) and the prefix-sharing meters ``prefix_hit_tokens`` /
        ``prefill_tokens`` (prompt tokens served from the trie vs actually
        computed)."""
        out = dict(self.backend.stats())
        if self.ttfts:
            out["ttft_s"] = float(np.mean(self.ttfts))
        if self._tpot_tokens:
            # Time per OUTPUT token: a speculative round's latency spreads
            # over every token it emitted (the backend's own tpot_s stays
            # per-forward — per-dispatch latency).
            out["tpot_s"] = self._tpot_sum / self._tpot_tokens
        out.update({k: float(v) for k, v in self.counters.items()})
        out["prefill_compiles"] = float(len(self.prefill_shapes))
        if self._disp_layers:
            out["active_experts"] = self._disp_active_sum / self._disp_layers
            out["dispatch_pad_ratio"] = self._disp_pad_sum / \
                self._disp_layers
        if self._spec is not None:
            out.update(self._spec.stats())
        if self.pool is not None:
            out["kv_blocks_in_use"] = float(self.pool.blocks_in_use)
            out["kv_bytes_in_use"] = float(self.pool.bytes_in_use)
            if self.trie is not None:
                out["prefix_trie_nodes"] = float(self.trie.n_nodes)
        else:
            out["kv_blocks_in_use"] = 0.0
            out["kv_bytes_in_use"] = 0.0
        return out

    def device_bytes(self) -> int:
        return self.backend.device_bytes()
