"""H2O-Danube3-4B — dense llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""
from repro.models.config import ArchConfig, AttnConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    vocab_size=32000,
    d_ff=10240,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=120,
                    rope_theta=10000.0, sliding_window=4096),
    norm_eps=1e-5,
    max_seq_len=524288,  # SWA ⇒ long-context decode is native
    source="arXiv:2401.16818 (H2O-Danube); SWA per model card",
)
