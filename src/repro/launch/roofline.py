"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step per chip:

    compute    = HLO_FLOPs / (chips × peak)          peak = 197 TFLOP/s bf16
    memory     = HLO_bytes / (chips × hbm_bw)        hbm  = 819 GB/s
    collective = collective_bytes / (chips × links)  link = 50 GB/s/link ICI

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are NOT
in cost_analysis — we parse the optimized HLO text and sum the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (a per-chip measure, since post-SPMD HLO shapes are
per-device).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12         # bf16 per chip, TPU v5e
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link (~1 effective link per axis)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[16,2048,768]{2,1,0} all-gather(...)"  (also matches tuple elems)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


_CONVERT_RE = re.compile(r"= (\w+)\[([\d,]*)\]\S* convert\(")

# When a convert is elided (fused on TPU), we also save reading its input:
# input bytes = out_elems × src_size; src inferred from the usual CPU
# legalization pairs (bf16→f32 for dots, f32→bf16 results).
_CONVERT_SRC_BYTES = {"f32": 2, "bf16": 4, "u32": 1, "s32": 1, "s8": 1}


_COMP_HEADER_RE = re.compile(r"^(%?[\w.\-]+)\s*(?:\([^)]*\))?\s*.*->.*\{\s*$")


def convert_bytes(hlo_text: str) -> int:
    """Bytes attributable to TOP-LEVEL dtype converts in the optimized HLO
    (converts inside fusion bodies never touch HBM and are skipped).

    XLA:CPU legalizes bf16 dots by upcasting operands to f32 and the SPMD
    partitioner's masked fallbacks run in f32 — on TPU these are native (MXU
    bf16 inputs) or fused. Subtracting convert traffic gives the
    TPU-faithful memory term; both raw and adjusted values are reported."""
    total = 0
    in_fusion = False
    for line in hlo_text.splitlines():
        h = _COMP_HEADER_RE.match(line.strip())
        if h and line.rstrip().endswith("{"):
            in_fusion = "fused" in h.group(1)
            continue
        if in_fusion:
            continue
        m = _CONVERT_RE.search(line)
        if not m:
            continue
        dt, dims = m.group(1), m.group(2)
        out_b = _shape_bytes(dt, dims)
        if not out_b:
            continue
        elems = out_b // max(_DTYPE_BYTES.get(dt, 1), 1)
        total += out_b + elems * _CONVERT_SRC_BYTES.get(dt, 0)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result shape appears before "opname(", e.g. "%x = bf16[..] all-gather("
        for kind in _COLLECTIVES:
            if f" {kind}(" in s or s.startswith(f"{kind}("):
                if f"%{kind}" in s or f"= {kind}" in s or f" {kind}(" in s:
                    lhs = s.split(f" {kind}(")[0]
                    total = sum(_shape_bytes(m.group(1), m.group(2))
                                for m in _SHAPE_RE.finditer(lhs))
                    out[kind] += total
                    counts[kind] += 1
                break
    out["_counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                # per-chip HLO flops
    hbm_bytes: float            # per-chip bytes accessed
    coll_bytes: float           # per-chip collective bytes
    coll_detail: Dict[str, int]
    chips: int
    model_flops: float          # 6·N·D (train) or 2·N_active·D (inference)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> dict:
        return {
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "bottleneck": self.bottleneck,
            "hlo_gflops_per_chip": round(self.flops / 1e9, 2),
            "hbm_gb_per_chip": round(self.hbm_bytes / 1e9, 3),
            "coll_mb_per_chip": round(self.coll_bytes / 1e6, 3),
            "model_gflops_total": round(self.model_flops / 1e9, 2),
            "useful_flops_ratio": round(self.useful_flops_ratio, 4),
        }


def model_flops(cfg, kind: str, tokens: int) -> float:
    """6·N·D for training, 2·N_active·D for inference forward."""
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    if kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens


def expected_active_experts(tokens: float, num_experts: int,
                            top_k: int) -> float:
    """Coupon-collector expectation: distinct experts activated by
    ``tokens`` independent top-k draws under a uniform router —
    ``E · (1 − (1 − k/E)^T)``. Real (skewed, temporally correlated) routing
    activates fewer; the gap is exactly what the trace-driven cost model
    (``repro.obs.costmodel``) measures as a residual."""
    if tokens <= 0:
        return 0.0
    E = float(num_experts)
    return E * (1.0 - (1.0 - top_k / E) ** tokens)


def predict_moe_bytes_per_token(tokens: float, layers: int, num_experts: int,
                                top_k: int, lo_bytes: int, hi_bytes: int,
                                published_hi: int = 0,
                                dispatch: str = "ragged") -> float:
    """Analytic expert-weight HBM traffic of ONE MoE forward, per routed
    token — the prediction the flight-recorder replay validates.

    ``layers`` is the number of MoE layer-steps in the forward (all
    positions × superblocks); ``published_hi`` the total published hi cells
    across those layers. ``padded`` streams every layer's full lo tier plus
    every published hi slot regardless of routing; ``ragged`` streams only
    the expected active experts at their resident tier (hi cells assumed
    uniformly spread, i.e. hit proportionally to their population)."""
    if tokens <= 0 or layers <= 0:
        return 0.0
    if dispatch == "padded":
        total = layers * num_experts * lo_bytes + published_hi * hi_bytes
        return total / tokens
    act = expected_active_experts(tokens, num_experts, top_k)
    hi_frac = published_hi / float(layers * num_experts)
    act_hi = act * hi_frac
    act_lo = act - act_hi
    return layers * (act_lo * lo_bytes + act_hi * hi_bytes) / tokens


def analyze(compiled, hlo_text: str, cfg, kind: str, tokens: int,
            chips: int) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):   # older API returned [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    # bytes accessed: sum the explicit operand/output accounting if present.
    hbm = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    detail = {k: v for k, v in coll.items() if k != "_counts"}
    total_coll = float(sum(detail.values()))
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=total_coll,
                    coll_detail=coll, chips=chips,
                    model_flops=model_flops(cfg, kind, tokens))
