"""Quantization substrate: pack/unpack inverses, dequant error bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.quant import (QuantizedTensor, bits_per_element, dequantize,
                         pack_bits, quantize, quantized_nbytes, unpack_bits)
from repro.quant.ptq import quantize_tree, dequantize_tree


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_pack_unpack_roundtrip(bits):
    rng = np.random.default_rng(0)
    k, n = 64, 16
    u = rng.integers(0, 2 ** bits, size=(3, k, n)).astype(np.uint8)
    packed = pack_bits(jnp.asarray(u), bits)
    out = unpack_bits(packed, bits, k)
    np.testing.assert_array_equal(np.asarray(out), u)


@settings(max_examples=25, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]),
       k=st.sampled_from([32, 64, 128]),
       n=st.sampled_from([8, 24]),
       seed=st.integers(0, 2 ** 16))
def test_dequant_error_bound(bits, k, n, seed):
    """Symmetric RTN error is bounded by half a quantization step per group."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n), jnp.float32)
    g = 32
    qt = quantize(w, bits=bits, group_size=g)
    wd = np.asarray(dequantize(qt, jnp.float32))
    wn = np.asarray(w)
    qmax = 2 ** (bits - 1) - 1
    absmax = np.abs(wn.reshape(k // g, g, n)).max(1, keepdims=True)
    step = absmax / qmax
    err = np.abs(wn.reshape(k // g, g, n) - wd.reshape(k // g, g, n))
    # bf16 scales add a relative rounding term.
    assert (err <= step / 2 + absmax * 8e-3 + 1e-6).all()


def test_quantized_nbytes_compression():
    shape = (4, 256, 128)
    full = int(np.prod(shape)) * 2
    for bits, factor in [(8, 2.2), (4, 4.2), (2, 8.0)]:
        q = quantized_nbytes(shape, bits, 64)
        assert q < full / factor + full / 16  # packed + scales overhead


def test_dequant_survives_leading_axis_slicing():
    """lax.scan slices the layer axis off bank leaves — dequant must key off
    array shapes, not stored metadata."""
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 2, 64, 16), jnp.float32)
    qt = quantize(w, bits=4, group_size=32)
    sliced = jax.tree_util.tree_map(lambda a: a[1], qt)
    out = dequantize(sliced, jnp.float32)
    want = dequantize(qt, jnp.float32)[1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


def test_quantize_tree_scoping():
    params = {
        "blocks": {"w_big": jnp.ones((256, 256), jnp.bfloat16),
                   "norm": {"scale": jnp.ones((256,), jnp.bfloat16)},
                   "router": jnp.ones((256, 8), jnp.float32)},
        "embed": jnp.ones((512, 64), jnp.bfloat16),
    }
    qt = quantize_tree(params, bits=4, group_size=64, min_size=1024)
    assert isinstance(qt["blocks"]["w_big"], QuantizedTensor)
    assert not isinstance(qt["blocks"]["norm"]["scale"], QuantizedTensor)
    assert not isinstance(qt["blocks"]["router"], QuantizedTensor)
    assert not isinstance(qt["embed"], QuantizedTensor)  # name-skipped
    dq = dequantize_tree(qt)
    assert dq["blocks"]["w_big"].shape == (256, 256)


def test_bits_validation():
    with pytest.raises(ValueError):
        bits_per_element(3)
    with pytest.raises(ValueError):
        quantize(jnp.ones((64, 8)), bits=4, group_size=48)  # 48 % epb ok, 64 % 48 != 0
