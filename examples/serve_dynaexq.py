"""End-to-end serving driver (the paper's deployment story):

  1. train a ~small MoE for a few hundred steps on the synthetic LM task,
  2. prepare DynaExq weight tiers (int2 lo / bf16 hi) under a device budget,
  3. serve a SHIFTING request stream (text → math → code) through the
     continuous-batching InferenceEngine,
  4. watch the controller re-allocate the hi-precision budget online and
     compare footprint/stats against static PTQ at the same engine loop.

    PYTHONPATH=src python examples/serve_dynaexq.py [--steps 200]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.core import ControllerConfig
from repro.models import init_params
from repro.serving import (EngineConfig, InferenceEngine, RequestStream,
                           make_backend)
from repro.serving.requests import WORKLOADS
from repro.training import SyntheticLMTask, TrainConfig, train_loop
from repro.training.adamw import AdamWConfig


def build_engine(cfg, params, kind):
    if kind == "dynaexq":
        backend = make_backend(
            "dynaexq", lo_bits=2, n_hi_per_layer=2,
            controller=ControllerConfig(update_interval_s=0.0,
                                        alpha=0.6, margin=0.5))
    else:
        backend = make_backend("static", lo_bits=2)
    return InferenceEngine(
        cfg, jax.tree_util.tree_map(lambda x: x, params), backend,
        EngineConfig(max_slots=4, max_len=128))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = get_config("granite-moe-1b-a400m", reduced=True)
    cfg = dataclasses.replace(
        cfg, n_layers=4,
        moe=dataclasses.replace(cfg.moe, num_experts=8, top_k=2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    task = SyntheticLMTask(cfg.vocab_size, seed=0)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=2e-3, total_steps=args.steps))
    print(f"=== training {args.steps} steps ===")
    params, _, _ = train_loop(cfg, params, task.batches(16, 65, args.steps),
                              tcfg, log_every=50)

    print("=== serving a shifting request stream ===")
    dyn = build_engine(cfg, params, "dynaexq")
    stat = build_engine(cfg, params, "static")
    for phase, workload in enumerate(WORKLOADS):
        stream = RequestStream(cfg.vocab_size, phases=[(workload, 12)],
                               prompt_len=48, prompt_len_jitter=8,
                               max_new_tokens=6, seed=phase * 10)
        for req in stream:
            dyn.submit(req)
            stat.submit(req)
        dyn.drain()
        stat.drain()
        dyn.flush()
        print(f"phase {phase} ({workload:5s}): hi-sets layer0..3 = "
              f"{dyn.backend.hi_sets()['0']}")
    print("dynaexq stats:", {k: round(v, 4)
                             for k, v in dyn.stats().items()})
    print("static  stats:", {k: round(v, 4)
                             for k, v in stat.stats().items()})
    print(f"expert bytes: dynaexq={dyn.device_bytes():,}  "
          f"static={stat.device_bytes():,}")
    print("(hi sets follow the workload: promotions+demotions above zero,\n"
          " budget invariant held by construction — see tests/)")


if __name__ == "__main__":
    main()
