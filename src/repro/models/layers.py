"""Core layers: norms, RoPE, GQA attention (full / sliding-window / cross)
with prefill + single-token decode against a KV cache.

All functions are pure; parameters are dict pytrees created by the matching
``init_*`` functions. Compute dtype is bf16 with f32 softmax/norm accumulation.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import AttnConfig

Param = dict


def _init(key, shape, scale=None, dtype=jnp.bfloat16):
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_rmsnorm(d: int) -> Param:
    return {"scale": jnp.ones((d,), jnp.bfloat16)}


def rmsnorm(p: Param, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"]


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

class KVCache(NamedTuple):
    """KV cache, laid out (B, Hkv, C, hd) — head-major so the decode GQA dot
    is a SINGLE-batch-dim matmul after a free (B·Hkv) reshape. (The seq-major
    layout forced XLA to upcast the full cache to f32 every layer: the
    multi-batch-dim bf16 dot is unsupported and the GQA grouping put (b, g)
    in the batch dims.) Full cache: ``capacity == max_len``; sliding window
    uses a ring buffer of ``capacity == window`` slots addressed
    ``pos % window`` on axis 2."""
    k: jax.Array  # (B, Hkv, C, hd)
    v: jax.Array  # (B, Hkv, C, hd)

    @property
    def capacity(self) -> int:
        return self.k.shape[2]


def init_kv_cache(batch: int, capacity: int, cfg: AttnConfig,
                  dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, cfg.n_kv_heads, capacity, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


class PagedKVCache(NamedTuple):
    """Physical KV block pool, laid out (N, Hkv, bt, hd): ``N`` fixed-size
    blocks of ``bt`` cache positions each, shared by every request. A
    request's logical cache is named by a *block table* row ((nb,) int32 of
    physical block ids, -1 = unallocated): gathering the table recovers the
    exact (Hkv, nb·bt, hd) head-major view the dense ``KVCache`` stores per
    batch row, so both layouts run the same attention math. Block 0 is the
    pool's trash block (vacant-row writes land there; see
    ``repro.serving.kvpool``)."""
    k: jax.Array  # (N, Hkv, bt, hd)
    v: jax.Array  # (N, Hkv, bt, hd)

    @property
    def n_blocks(self) -> int:
        return self.k.shape[0]

    @property
    def block_tokens(self) -> int:
        return self.k.shape[2]


def init_paged_kv_cache(n_blocks: int, block_tokens: int, cfg: AttnConfig,
                        dtype=jnp.bfloat16) -> PagedKVCache:
    shape = (n_blocks, cfg.n_kv_heads, block_tokens, cfg.head_dim)
    return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def paged_view(cache: PagedKVCache, table: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    """Gather-by-block-table: (B, nb) table → (B, Hkv, nb·bt, hd) logical
    K/V views (slot order = logical cache order). Unallocated (-1) entries
    read the trash block; callers mask those slots out of attention."""
    B, nb = table.shape
    idx = jnp.clip(table, 0)
    k = cache.k[idx]                           # (B, nb, Hkv, bt, hd)
    v = cache.v[idx]
    Hkv, bt, hd = k.shape[2], k.shape[3], k.shape[4]
    k = jnp.moveaxis(k, 2, 1).reshape(B, Hkv, nb * bt, hd)
    v = jnp.moveaxis(v, 2, 1).reshape(B, Hkv, nb * bt, hd)
    return k, v


def init_attention(key, d_model: int, cfg: AttnConfig) -> Param:
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d_model, cfg.q_dim)),
        "wk": _init(ks[1], (d_model, cfg.kv_dim)),
        "wv": _init(ks[2], (d_model, cfg.kv_dim)),
        "wo": _init(ks[3], (cfg.q_dim, d_model)),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(cfg.head_dim)
        p["k_norm"] = init_rmsnorm(cfg.head_dim)
    return p


def _project_qkv(p: Param, cfg: AttnConfig, x: jax.Array, positions):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, n_heads):
    """q: (B,Sq,H,hd)  k,v: (B,Skv,Hkv,hd)  mask: (B|1, Sq, Skv) bool.

    Grouped-GQA form: K/V are never head-repeated (materializing the repeat
    forced an extra full-cache copy per layer at decode), and the QK einsum
    accumulates bf16*bf16->f32 on the MXU instead of upcasting K/V."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    rep = n_heads // Hkv
    qg = q.reshape(B, Sq, Hkv, rep, hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32) * scale
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)
    return out.reshape(B, Sq, H * hd)


def _seq_parallel_constraint(q, k, v, n_heads):
    """Sequence parallelism for architectures whose head count does not
    divide the model axis (24H, 56H, 6H vs 16): attention params stay
    replicated (FSDP handles their storage) and each model rank computes ALL
    heads for S/16 of the query positions. K/V are gathered per layer — a
    268 MB-scale all-gather instead of the TB-scale all-reduces (or
    replication fallbacks) that contraction / uneven head sharding caused."""
    try:
        from repro.launch.dist import get_dist
    except ImportError:  # pragma: no cover
        return q, k, v
    ctx = get_dist()
    if ctx is None or n_heads % ctx.model_size == 0:
        return q, k, v
    if q.shape[1] % ctx.model_size:
        return q, k, v
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = ctx.dp_axes if ctx.tokens_dp_sharded else None
    qs = NamedSharding(ctx.mesh, P(dp, "model", None, None))
    kvs = NamedSharding(ctx.mesh, P(dp, None, None, None))
    return (jax.lax.with_sharding_constraint(q, qs),
            jax.lax.with_sharding_constraint(k, kvs),
            jax.lax.with_sharding_constraint(v, kvs))


def attention_full(p: Param, cfg: AttnConfig, x: jax.Array,
                   causal: bool = True,
                   positions: Optional[jax.Array] = None) -> jax.Array:
    """Training / encoder attention over a full sequence (no cache)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    q, k, v = _seq_parallel_constraint(q, k, v, cfg.n_heads)
    if causal and S > PREFILL_CHUNK_THRESHOLD and S % PREFILL_CHUNK == 0:
        out = _sdpa_chunked(q, k, v, cfg.n_heads, cfg.sliding_window,
                            PREFILL_CHUNK)
        return out @ p["wo"]
    qpos = positions[..., :, None]
    kpos = positions[..., None, :]
    if causal:
        mask = kpos <= qpos
        if cfg.sliding_window is not None:
            mask &= kpos > qpos - cfg.sliding_window
    else:
        mask = jnp.ones((1, S, S), bool)
    mask = jnp.broadcast_to(mask, (B, S, S)) if mask.shape[0] != B else mask
    out = _sdpa(q, k, v, mask, cfg.n_heads)
    return out @ p["wo"]


def _sdpa_chunked(q, k, v, n_heads, sliding_window, chunk: int):
    """Causal attention via a q-chunk scan — never materializes (S, S)
    logits; per-chunk working set is (B, H, chunk, S). Used for long
    prefill (and train at long S)."""
    B, S, H, hd = q.shape[0], q.shape[1], q.shape[2], q.shape[3]
    Hkv = k.shape[2]
    rep = n_heads // Hkv
    scale = hd ** -0.5
    nq = S // chunk
    qc = q.reshape(B, nq, chunk, Hkv, rep, hd)
    kpos = jnp.arange(S)

    def body(_, qi_i):
        qi, i = qi_i                      # (B, chunk, Hkv, rep, hd), scalar
        qpos = i * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qi, k).astype(jnp.float32) * scale
        m = kpos[None, :] <= qpos[:, None]
        if sliding_window is not None:
            m &= kpos[None, :] > qpos[:, None] - sliding_window
        logits = jnp.where(m[None, None, None, :, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(qi.dtype)
        return None, jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)

    from repro.models import model as _model_mod
    _, out = jax.lax.scan(body, None,
                          (jnp.moveaxis(qc, 1, 0), jnp.arange(nq)),
                          unroll=True if _model_mod._SCAN_UNROLL else 1)
    out = jnp.moveaxis(out, 0, 1)          # (B, nq, chunk, Hkv, rep, hd)
    return out.reshape(B, S, H * hd)


PREFILL_CHUNK_THRESHOLD = 2048
PREFILL_CHUNK = 256


def attention_prefill(p: Param, cfg: AttnConfig, x: jax.Array,
                      cache: KVCache, lengths: Optional[jax.Array] = None
                      ) -> tuple[jax.Array, KVCache]:
    """Causal prefill writing the cache. Sequence starts at position 0.

    For a sliding-window ring cache (capacity < S) only the last ``capacity``
    keys land in the cache, which is exactly the window semantics.

    ``lengths`` ((B,) int32) marks each row's true prompt length for padded
    (length-bucketed) prefill. Causal masking already keeps end-of-row
    padding out of every valid position's attention; what needs care is the
    cache write: ring slot i must hold each ROW's largest real position
    p < length with p % C == i (not the batch tail, which for a short row
    in a long bucket is pure padding), and slots no real position maps to
    keep their previous contents. Attention outputs at padded positions are
    garbage and must not be read.
    """
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    if S > PREFILL_CHUNK_THRESHOLD and S % PREFILL_CHUNK == 0:
        out = _sdpa_chunked(q, k, v, cfg.n_heads, cfg.sliding_window,
                            PREFILL_CHUNK)
    else:
        qpos = positions[..., :, None]
        kpos = positions[..., None, :]
        mask = kpos <= qpos
        if cfg.sliding_window is not None:
            mask &= kpos > qpos - cfg.sliding_window
        mask = jnp.broadcast_to(mask, (B, S, S))
        out = _sdpa(q, k, v, mask, cfg.n_heads)

    C = cache.capacity
    kc = k.transpose(0, 2, 1, 3)       # (B, Hkv, S, hd) — cache layout
    vc = v.transpose(0, 2, 1, 3)
    if lengths is not None:
        # Per-row masked write (full and ring caches alike): slot i takes
        # the row's largest real position p < length with p % C == i; slots
        # with no real owner keep their previous contents.
        last = lengths[:, None] - 1 - \
            jnp.mod(lengths[:, None] - 1 - jnp.arange(C)[None, :], C)  # (B,C)
        has_owner = (last >= 0) & (lengths[:, None] > 0)
        src = jnp.clip(last, 0, S - 1)[:, None, :, None]
        gk = jnp.take_along_axis(kc, src, axis=2)        # (B, Hkv, C, hd)
        gv = jnp.take_along_axis(vc, src, axis=2)
        keep = has_owner[:, None, :, None]
        new_k = jnp.where(keep, gk, cache.k)
        new_v = jnp.where(keep, gv, cache.v)
    elif C >= S:
        new_k = jax.lax.dynamic_update_slice(cache.k, kc, (0, 0, 0, 0))
        new_v = jax.lax.dynamic_update_slice(cache.v, vc, (0, 0, 0, 0))
    else:  # ring buffer: keep last C positions, slot = pos % C
        tail_k, tail_v = kc[:, :, S - C:], vc[:, :, S - C:]
        slots = (jnp.arange(S - C, S)) % C
        new_k = cache.k.at[:, :, slots].set(tail_k)
        new_v = cache.v.at[:, :, slots].set(tail_v)
    return out @ p["wo"], KVCache(new_k, new_v)


def _attend_cache(q: jax.Array, k_all: jax.Array, v_all: jax.Array,
                  pos_b: jax.Array, cfg: AttnConfig) -> jax.Array:
    """Single-token attention over a written cache view. ``q``: (B,1,H,hd);
    ``k_all``/``v_all``: (B, Hkv, C, hd) head-major views (dense rows or
    block-table gathers — same math either way); ``pos_b``: (B,) positions
    just written. Slot i of a row's view holds the largest position
    p <= pos with p % C == i (full cache ⇒ slot == position)."""
    B, C = q.shape[0], k_all.shape[2]
    idx = jnp.arange(C)[None, :]
    if cfg.sliding_window is None:
        valid = idx <= pos_b[:, None]                             # (B, C)
    else:
        # slot i holds the largest position p' <= pos with p' % C == i.
        slot_pos = pos_b[:, None] - jnp.mod(pos_b[:, None] - idx, C)
        valid = (slot_pos >= 0) & \
            (slot_pos > pos_b[:, None] - cfg.sliding_window)

    H, hd = cfg.n_heads, cfg.head_dim
    Hkv = cfg.n_kv_heads
    rep = H // Hkv
    # q head order H = g·rep + r matches the (B,1,H,hd) projection reshape.
    qg = q.reshape(B, Hkv, rep, hd).reshape(B * Hkv, rep, hd)
    kf = k_all.reshape(B * Hkv, C, hd)
    vf = v_all.reshape(B * Hkv, C, hd)
    # bf16 dot (TPU MXU accumulates f32 natively; requesting f32 out here
    # makes the CPU lowering convert the ENTIRE cache to f32 every layer,
    # which would poison the roofline bytes and the real TPU layout alike).
    logits = jnp.einsum("brd,bkd->brk", qg, kf).astype(jnp.float32) * hd ** -0.5
    # valid (B, C) → rows of the (B·Hkv) flattened batch, b-major like kf.
    logits = jnp.where(jnp.repeat(valid, Hkv, axis=0)[:, None, :],
                       logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("brk,bkd->brd", probs, vf)        # (B·Hkv, rep, hd)
    return out.reshape(B, 1, H * hd)


def attention_decode(p: Param, cfg: AttnConfig, x: jax.Array, pos: jax.Array,
                     cache: KVCache) -> tuple[jax.Array, KVCache]:
    """One-token decode. ``x``: (B, 1, d); ``pos``: scalar int32 or (B,)
    int32 vector of per-sequence positions (continuous batching: every slot
    tracks its own request's position). Works for both full and ring caches.
    All dots are single-batch-dim bf16 matmuls on the head-major cache (see
    KVCache)."""
    B = x.shape[0]
    C = cache.capacity
    pos = jnp.asarray(pos, jnp.int32)
    pos_b = jnp.broadcast_to(pos, (B,)) if pos.ndim == 0 else pos  # (B,)
    positions = pos_b[:, None]                                    # (B, 1)
    q, k, v = _project_qkv(p, cfg, x, positions)       # k/v: (B, 1, Hkv, hd)
    slot = jnp.mod(pos_b, C)                                      # (B,)
    # Masked update instead of dynamic_update_slice: SPMD partitions a
    # dynamic-index DUS over the sharded seq axis through an f32 masked
    # fallback (measured ~4x the bytes); the explicit where-mask stays bf16
    # and costs exactly one cache read+write.
    slot_mask = (jnp.arange(C)[None, :] == slot[:, None])[:, None, :, None]
    new_k = jnp.where(slot_mask, k.transpose(0, 2, 1, 3), cache.k)
    new_v = jnp.where(slot_mask, v.transpose(0, 2, 1, 3), cache.v)
    out = _attend_cache(q, new_k, new_v, pos_b, cfg)
    return out @ p["wo"], KVCache(new_k, new_v)


def attention_decode_paged(p: Param, cfg: AttnConfig, x: jax.Array,
                           pos: jax.Array, cache: PagedKVCache,
                           table: jax.Array, write_blk: jax.Array,
                           write_off: jax.Array
                           ) -> tuple[jax.Array, PagedKVCache]:
    """One-token decode against the paged pool. ``table``: (B, nb) block
    tables; ``write_blk``/``write_off``: (B,) physical block + in-block
    offset for each row's write (COW already resolved host-side — the
    engine routes vacant rows to the trash block). After the scatter the
    gathered logical view equals the dense cache row bit for bit, so decode
    shares ``_attend_cache`` with the contiguous path."""
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    pos_b = jnp.broadcast_to(pos, (B,)) if pos.ndim == 0 else pos
    q, k, v = _project_qkv(p, cfg, x, pos_b[:, None])
    new_k = cache.k.at[write_blk, :, write_off].set(k[:, 0])
    new_v = cache.v.at[write_blk, :, write_off].set(v[:, 0])
    cache = PagedKVCache(new_k, new_v)
    k_all, v_all = paged_view(cache, table)
    out = _attend_cache(q, k_all, v_all, pos_b, cfg)
    return out @ p["wo"], cache


def attention_prefill_paged(p: Param, cfg: AttnConfig, x: jax.Array,
                            cache: PagedKVCache, table: jax.Array,
                            start: jax.Array, lengths: jax.Array,
                            has_prefix: bool = False
                            ) -> tuple[jax.Array, PagedKVCache]:
    """Masked prefill of a prompt SUFFIX into pool blocks.

    ``x``: (B, S, d) embeds of tokens ``start[b] .. lengths[b]-1`` (right-
    padded to the bucket S); ``start``: (B,) int32 prefix-hit offsets (0 =
    whole prompt); ``lengths``: (B,) TOTAL prompt lengths. ``table``:
    (B, nb) block tables covering logical slots 0..nb·bt — for a prefix hit
    the leading entries alias trie-shared blocks whose contents were written
    by an earlier request (any block this call writes was COWed or freshly
    allocated by the engine first).

    Writes use the same per-slot last-owner rule as the dense masked
    prefill (ring wrap included), restricted to positions >= start so
    shared prefix slots are never touched. With ``has_prefix`` the suffix
    queries additionally attend over the gathered prefix K/V (read before
    the write), giving exact continuation semantics without recomputing a
    single prefix token. Outputs at padded positions are garbage and must
    not be read."""
    B, S, _ = x.shape
    bt = cache.block_tokens
    C = table.shape[1] * bt
    start = jnp.asarray(start, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    positions = start[:, None] + jnp.arange(S)[None, :]           # (B, S)
    q, k, v = _project_qkv(p, cfg, x, positions)
    if has_prefix:
        # Prefix view BEFORE the suffix write (the data dependency keeps
        # the gather ordered ahead of the scatter inside one jit).
        pk, pv = paged_view(cache, table)                # (B, Hkv, C, hd)

    # ---- scatter suffix K/V: slot s takes the row's largest real position
    # p in [start, length) with p % C == s; all other lanes hit the trash
    # block (never a live one).
    idx = jnp.arange(C)[None, :]
    last = lengths[:, None] - 1 - jnp.mod(lengths[:, None] - 1 - idx, C)
    own = (last >= start[:, None]) & (lengths[:, None] > start[:, None])
    src = jnp.clip(last - start[:, None], 0, S - 1)
    kc = k.transpose(0, 2, 1, 3)                          # (B, Hkv, S, hd)
    vc = v.transpose(0, 2, 1, 3)
    gk = jnp.take_along_axis(kc, src[:, None, :, None], axis=2)
    gv = jnp.take_along_axis(vc, src[:, None, :, None], axis=2)
    blk = jnp.take_along_axis(table, jnp.broadcast_to(idx // bt, (B, C)),
                              axis=1)
    phys = jnp.where(own, jnp.clip(blk, 0), 0)
    offs = jnp.broadcast_to(idx % bt, (B, C))
    new_k = cache.k.at[phys, :, offs].set(gk.transpose(0, 2, 1, 3))
    new_v = cache.v.at[phys, :, offs].set(gv.transpose(0, 2, 1, 3))

    # ---- suffix queries over [cached prefix ⊕ suffix] ------------------
    qpos = positions[:, :, None]                          # (B, S, 1)
    kpos = positions[:, None, :]                          # (B, 1, S)
    mask = (kpos <= qpos) & (kpos < lengths[:, None, None])
    if cfg.sliding_window is not None:
        mask = mask & (kpos > qpos - cfg.sliding_window)
    if has_prefix:
        # Slot s of the pre-write view holds prefix position
        # p_s = largest p < start with p % C == s (ring and full alike).
        ppos = start[:, None] - 1 - jnp.mod(start[:, None] - 1 - idx, C)
        pmask = jnp.broadcast_to((ppos >= 0)[:, None, :], (B, S, C))
        if cfg.sliding_window is not None:
            pmask = pmask & (ppos[:, None, :] > qpos - cfg.sliding_window)
        k_cat = jnp.concatenate([pk.transpose(0, 2, 1, 3), k], axis=1)
        v_cat = jnp.concatenate([pv.transpose(0, 2, 1, 3), v], axis=1)
        mask = jnp.concatenate([pmask, jnp.broadcast_to(mask, (B, S, S))],
                               axis=-1)
        out = _sdpa(q, k_cat, v_cat, mask, cfg.n_heads)
    else:
        out = _sdpa(q, k, v, jnp.broadcast_to(mask, (B, S, S)), cfg.n_heads)
    return out @ p["wo"], PagedKVCache(new_k, new_v)


# --------------------------------------------------------------------------
# Cross attention (encoder-decoder)
# --------------------------------------------------------------------------

def init_cross_attention(key, d_model: int, cfg: AttnConfig) -> Param:
    p = init_attention(key, d_model, cfg)
    return p


def cross_attention(p: Param, cfg: AttnConfig, x: jax.Array,
                    enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """x: (B, Sq, d); enc_k/enc_v: (B, Senc, Hkv, hd) precomputed."""
    B, Sq, _ = x.shape
    q = (x @ p["wq"]).reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
    mask = jnp.ones((B, Sq, enc_k.shape[1]), bool)
    out = _sdpa(q, enc_k, enc_v, mask, cfg.n_heads)
    return out @ p["wo"]


def encode_cross_kv(p: Param, cfg: AttnConfig, enc_out: jax.Array):
    B, Senc, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, Senc, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(B, Senc, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = rmsnorm(p["k_norm"], k)
    return k, v
