"""AdamW with decoupled weight decay and a cosine schedule with warmup.

f32 moments regardless of param dtype (bf16 params update through an f32
master-view computed on the fly — adequate at this scale and halves optimizer
memory vs. keeping a separate master copy).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 2e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    warmup_steps: int = 10
    total_steps: int = 300
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    lr = _schedule(cfg, state["step"])

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step)
        vh = v / (1 - cfg.b2 ** step)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"gnorm": gnorm, "lr": lr}
