"""Mixture-of-Experts layer with capacity-based dispatch and a pluggable
expert bank (dense bf16 for training, DynaExq mixed-precision for serving).

Two dispatch layouts, selected per call (``dispatch=``, default from
``kernels.ops.moe_dispatch_default``):

* **padded** (reference): sort the token→expert assignments, scatter into a
  fixed-capacity (E, C, d) buffer, run the batched expert GEMM over ALL E
  experts, combine with the router gates. Simple, shardable, and the
  bit-parity oracle — but at decode batch sizes most of (E, C) is padding,
  so every step pays the weight-read bytes of every expert.
* **ragged** (serving decode hot path): sort + compact into a (Tt·bm, d)
  buffer whose per-expert segments are aligned to the row tile ``bm``, and
  hand per-tile expert/slot maps to ONE fused mixed-precision kernel
  (``kernels.ops.ragged_quant_ffn_op``). Only experts that received tokens
  this step stream their weights, and each streams its *resident tier only*
  (hi bf16 slot or packed lo codes dequantized in VMEM) — the bytes/token
  the lo tier was built to save are actually saved.

Execution regimes:

* Single device (tests, CPU serving, benchmarks): both layouts available.
* Distributed (dry-run / launcher, via ``repro.launch.dist``): the padded
  body runs inside ``shard_map`` — each data shard routes its own tokens,
  each model shard computes only its local E/n experts
  (``e_offset``/``e_local``), and the partial token outputs reduce with a
  single psum over the model axis. This is the formulation GSPMD cannot
  derive on its own (data-dependent sort/scatter) and the reason dispatch is
  explicit here. (Ragged is single-device for now; the sharded mesh keeps
  the padded body.)

Per-(layer, expert) selection counts — the hotness signal the DynaExq
scheduler consumes (paper §3.5) — fall out of dispatch for free, as do the
dispatch-efficiency gauges (``MoEAux.active_experts`` /
``dispatch_pad_ratio``) the serving stats surface.
"""
from __future__ import annotations

import os
from typing import Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.ver import ExpertBankQ
from repro.kernels import ops as kops
from repro.models.config import MoEConfig
from repro.models.layers import _init
from repro.models.mlp import init_swiglu, swiglu
from repro.quant.qtensor import dequantize

#: Row-tile height of the ragged layout: each active expert's token segment
#: is padded up to a multiple of this (the ONLY padding the ragged path
#: pays). 8 matches the f32 sublane on TPU and keeps CPU tests cheap.
RAGGED_BM = int(os.environ.get("REPRO_MOE_RAGGED_BM", "8"))


class MoEAux(NamedTuple):
    counts: jax.Array     # (E,) int32 — router selections this call
    aux_loss: jax.Array   # scalar f32 — load-balance loss
    dropped: jax.Array    # scalar f32 — fraction of assignments dropped
    # (R, E) int32 — selections segment-summed per row (request/slot), only
    # when ``moe_apply(..., n_rows=R)`` asks for it. Rows whose tokens are
    # all masked by ``token_valid`` contribute zeros, which is what lets the
    # serving engine keep vacant continuous-batching slots and prompt
    # padding out of the hotness signal.
    row_counts: Optional[jax.Array] = None
    # Dispatch-efficiency telemetry (None on the sharded path): number of
    # experts that received ≥1 assignment this call, and the fraction of
    # GEMM rows that were padding — (E·C − kept)/(E·C) for the padded
    # layout, (Tt·bm − routed)/(Tt·bm) for the ragged layout.
    active_experts: Optional[jax.Array] = None
    dispatch_pad_ratio: Optional[jax.Array] = None


def init_moe(key, d_model: int, cfg: MoEConfig) -> Dict:
    ks = jax.random.split(key, 5)
    E, f = cfg.num_experts, cfg.d_ff_expert
    p = {
        "router": _init(ks[0], (d_model, E), scale=d_model ** -0.5,
                        dtype=jnp.float32),
        "experts": {
            "w_gate": _init(ks[1], (E, d_model, f)),
            "w_up": _init(ks[2], (E, d_model, f)),
            "w_down": _init(ks[3], (E, f, d_model)),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = init_swiglu(ks[4], d_model,
                                  cfg.d_ff_shared * cfg.n_shared_experts)
    return p


def effective_expert_weights(bank: Union[Dict, ExpertBankQ],
                             e_offset: int = 0,
                             e_local: Optional[int] = None,
                             slot_lo: int = 0,
                             n_slot_local: Optional[int] = None
                             ) -> Dict[str, jax.Array]:
    """Materialize per-expert weights (E_local, K, N) in bf16.

    Dense bank: identity. DynaExq bank: dequantize the lo tier then scatter
    the published hi versions over their owners — experts whose stable handle
    points at a hi slot compute with hi weights, the rest with lo. Under
    expert parallelism the bank leaves arrive pre-sliced to the local expert
    (and hi-slot) ranges; ``slot_owner`` stays global, so owners are shifted
    by ``e_offset`` and out-of-range owners drop out of the scatter.
    (The Pallas serving kernel performs the same selection in-kernel without
    materializing; this jnp path is the oracle + dry-run path.)
    """
    if isinstance(bank, ExpertBankQ):
        owner = bank.slot_owner            # (n_hi,) global, after scan slicing
        E = bank.slot_map.shape[-1]
        e_local = e_local if e_local is not None else E
        if n_slot_local is not None:
            owner = jax.lax.dynamic_slice_in_dim(owner, slot_lo, n_slot_local)
        owner = owner - e_offset
        safe_owner = jnp.where((owner >= 0) & (owner < e_local),
                               owner, e_local)          # OOB ⇒ dropped
        out = {}
        for name, qt in bank.lo.items():
            w = dequantize(qt)             # (E_local, K, N)
            out[name] = w.at[safe_owner].set(bank.hi[name], mode="drop")
        return out
    return bank


def route(router_w: jax.Array, x: jax.Array, cfg: MoEConfig):
    """x: (T, d) → gates (T, k), idx (T, k), probs (T, E)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk_prob:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, idx, probs


def _sort_routing(idx: jax.Array, e_local: int):
    """Shared dispatch prologue — the ONE place the assignment order, the
    per-expert counts and positions, and therefore the padded↔ragged
    bit-identity contract are defined. idx: (T, k) local expert ids with
    ``e_local`` as the out-of-range sentinel. Returns ``(order, sorted_eid,
    counts (e_local,), pos_in_e, tok)`` over the stable sort-by-expert of
    the flattened assignments."""
    k = idx.shape[1]
    fidx = idx.reshape(-1)                                   # (T*k,)
    order = jnp.argsort(fidx, stable=True)
    sorted_eid = fidx[order]
    counts_all = jnp.bincount(fidx, length=e_local + 1)
    counts = counts_all[:e_local]
    starts = jnp.cumsum(counts_all) - counts_all
    pos_in_e = jnp.arange(fidx.shape[0], dtype=jnp.int32) - \
        starts[sorted_eid]
    tok = order // k                                         # source token
    return order, sorted_eid, counts, pos_in_e, tok


def _keep_mask(sorted_eid: jax.Array, pos_in_e: jax.Array, tok: jax.Array,
               e_local: int, capacity: int, row_capacity: Optional[int],
               n_rows: Optional[int], n_tokens: int) -> jax.Array:
    """The ONE drop rule both layouts share: global per-expert capacity, or
    the per-row normalization when ``row_capacity`` is set."""
    if row_capacity is None:
        return (pos_in_e < capacity) & (sorted_eid < e_local)
    return _row_capacity_keep(sorted_eid, tok, e_local, n_rows, n_tokens,
                              row_capacity) & (sorted_eid < e_local)


def _row_capacity_keep(sorted_eid: jax.Array, tok: jax.Array, e_local: int,
                       n_rows: int, n_tokens: int,
                       row_capacity: int) -> jax.Array:
    """Per-row drop rule: an assignment survives iff its rank among ITS OWN
    row's assignments to the same expert is < ``row_capacity``. Whether a
    token's assignment drops then depends only on that row's routing —
    never on which other rows share the compute batch (the batch-shape
    independence prefix sharing and spec-verify token-identity need in drop
    regimes). Assumes ``sorted_eid``/``tok`` come from the stable
    sort-by-expert (same-(expert, row) entries are contiguous and in token
    order)."""
    tpr = n_tokens // n_rows
    rid = tok // tpr
    key = jnp.where(sorted_eid < e_local, sorted_eid * n_rows + rid,
                    e_local * n_rows)
    cnt = jnp.zeros((e_local * n_rows + 1,), jnp.int32).at[key].add(1)
    kstart = jnp.cumsum(cnt) - cnt
    pos_re = jnp.arange(key.shape[0], dtype=jnp.int32) - kstart[key]
    return pos_re < row_capacity


def dispatch_compute(bank, x: jax.Array, idx: jax.Array, gates: jax.Array,
                     e_local: int, capacity: int, e_offset: int = 0,
                     n_slot_local: Optional[int] = None, slot_lo: int = 0,
                     ff_axis=None, row_capacity: Optional[int] = None,
                     n_rows: Optional[int] = None, gemm: Optional[str] = None):
    """Padded sort-scatter dispatch + batched expert GEMM + gated combine.

    x: (T, d); idx: (T, k) LOCAL expert ids with ``e_local`` as the
    out-of-range sentinel; gates: (T, k) with zeros on sentinel entries.
    ``row_capacity`` (with ``n_rows``) switches the drop rule from the
    global per-expert capacity to the per-row rule (see
    ``_row_capacity_keep``); ``capacity`` must then be the physical bound
    the caller derived (``n_rows · row_capacity`` makes overflow
    impossible). Returns (y (T, d), counts (e_local,), dropped scalar).
    """
    T, d = x.shape
    order, sorted_eid, counts, pos_in_e, tok = _sort_routing(idx, e_local)
    valid = _keep_mask(sorted_eid, pos_in_e, tok, e_local, capacity,
                       row_capacity, n_rows, T)
    if row_capacity is None:
        scat_pos = pos_in_e
    else:
        # Scatter by rank among KEPT assignments of the expert so the
        # physical buffer only ever holds survivors.
        kept_i = valid.astype(jnp.int32)
        inc = jnp.cumsum(kept_i)
        kept_e = jnp.zeros((e_local + 1,), jnp.int32) \
            .at[sorted_eid].add(kept_i)
        kstart = jnp.cumsum(kept_e) - kept_e
        scat_pos = jnp.where(valid, inc - 1 - kstart[sorted_eid], capacity)

    xg = jnp.zeros((e_local, capacity, d), x.dtype)
    xg = xg.at[sorted_eid, scat_pos].set(x[tok], mode="drop")

    if isinstance(bank, ExpertBankQ):
        yg = _quant_expert_ffn(bank, xg, e_offset=e_offset, e_local=e_local,
                               slot_lo=slot_lo, n_slot_local=n_slot_local,
                               ff_axis=ff_axis, gemm=gemm)
    else:
        w = bank
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, w["w_gate"])
                        .astype(jnp.float32)).astype(x.dtype)
        h = h * jnp.einsum("ecd,edf->ecf", xg, w["w_up"])
        yg = jnp.einsum("ecf,efd->ecd", h, w["w_down"])

    pos_safe = jnp.minimum(scat_pos, capacity - 1)
    eid_safe = jnp.minimum(sorted_eid, e_local - 1)
    y_sorted = yg[eid_safe, pos_safe]
    gate_sorted = gates.reshape(-1)[order].astype(x.dtype)
    contrib = jnp.where(valid[:, None], y_sorted * gate_sorted[:, None], 0)
    # yg's output-feature dim may be data-sliced under 2-D expert sharding.
    y = jnp.zeros((T, yg.shape[-1]), x.dtype).at[tok].add(contrib)

    routed = jnp.sum(jnp.where(sorted_eid < e_local, 1.0, 0.0))
    kept = jnp.sum(jnp.where(valid, 1.0, 0.0))
    dropped = 1.0 - kept / jnp.maximum(routed, 1.0)
    return y, counts.astype(jnp.int32), dropped


def _quant_expert_ffn(bank: ExpertBankQ, xg: jax.Array, e_offset=0,
                      e_local: Optional[int] = None, slot_lo: int = 0,
                      n_slot_local: Optional[int] = None,
                      ff_axis=None, gemm: Optional[str] = None) -> jax.Array:
    """SwiGLU expert FFN on the lo tier (group-blocked quantized GEMMs via
    the ``kernels.ops.grouped_lo_matmul`` dispatcher — jnp expression or
    Pallas kernel, one math) with the published hi-precision experts
    overlaid: hi slots compute in bf16 and their outputs replace the lo
    outputs of the experts they own — numerically identical to swapping the
    weights, without materializing per-expert dense weights."""
    E_, C, d = xg.shape
    lo = bank.lo
    g1 = kops.grouped_lo_matmul(xg, lo["w_gate"].packed, lo["w_gate"].scales,
                                lo["w_gate"].bits, lo["w_gate"].group_size,
                                backend=gemm)
    up = kops.grouped_lo_matmul(xg, lo["w_up"].packed, lo["w_up"].scales,
                                lo["w_up"].bits, lo["w_up"].group_size,
                                backend=gemm)
    h = (jax.nn.silu(g1.astype(jnp.float32)).astype(xg.dtype) * up)
    if ff_axis is not None:
        # 2-D expert sharding for token-replicated decode (batch-1 long
        # context): gate/up are FF-sliced over the otherwise-idle data axis,
        # so each rank dequantized/read only F/|data| of every expert. The
        # activations are tiny at decode — gathering h costs ~100 KB.
        h = jax.lax.all_gather(h, ff_axis, axis=2, tiled=True)
    y = kops.grouped_lo_matmul(h, lo["w_down"].packed, lo["w_down"].scales,
                               lo["w_down"].bits, lo["w_down"].group_size,
                               backend=gemm)

    owner = bank.slot_owner
    if n_slot_local is not None:
        owner = jax.lax.dynamic_slice_in_dim(owner, slot_lo, n_slot_local)
    hi = bank.hi
    n_slots = owner.shape[0]
    if n_slots == 0:
        return y
    owner_l = owner - e_offset
    valid = (owner_l >= 0) & (owner_l < E_)
    safe = jnp.where(valid, owner_l, 0)
    xh = xg[safe]                                     # (n_hi, C, d)
    hh = jax.nn.silu(jnp.einsum("scd,sdf->scf", xh, hi["w_gate"])
                     .astype(jnp.float32)).astype(xg.dtype)
    hh = hh * jnp.einsum("scd,sdf->scf", xh, hi["w_up"])
    if ff_axis is not None:
        hh = jax.lax.all_gather(hh, ff_axis, axis=2, tiled=True)
    yh = jnp.einsum("scf,sfd->scd", hh, hi["w_down"])
    sentinel = jnp.where(valid, owner_l, E_)          # OOB ⇒ dropped
    return y.at[sentinel].set(yh, mode="drop")


def ragged_tile_map(counts: jax.Array, bm: int, n_assign: int):
    """bm-aligned ragged layout over per-expert assignment ``counts``
    ((E,) int32; ``n_assign`` = static total assignment budget T·k).

    Returns ``(astart (E,), tile_eid (Tt,), n_tiles scalar)``: expert e's
    segment starts at compact row ``astart[e]``; row tile t computes with
    expert ``tile_eid[t]``. Experts with zero tokens never appear in the
    live prefix ``tile_eid[:n_tiles]`` — their weights are never streamed.
    Σ ceil(c_e/bm) tiles ≤ n_assign//bm + #active, so the static tile
    budget Tt covers every routing; tail tiles (t ≥ n_tiles) repeat the
    last active expert — no fresh weight DMA, and their garbage rows are
    never gathered back."""
    e_local = counts.shape[0]
    aligned = ((counts + bm - 1) // bm) * bm
    astart = jnp.cumsum(aligned) - aligned
    ntile = aligned // bm
    cum_t = jnp.cumsum(ntile)
    n_tiles = cum_t[-1]
    Tt = n_assign // bm + min(e_local, n_assign) + 1
    t_range = jnp.arange(Tt, dtype=jnp.int32)
    tile_eid = jnp.searchsorted(cum_t, t_range, side="right") \
        .astype(jnp.int32)
    e_last = jnp.maximum(
        jnp.max(jnp.where(counts > 0, jnp.arange(e_local), -1)), 0)
    tile_eid = jnp.clip(jnp.where(t_range < n_tiles, tile_eid, e_last),
                        0, e_local - 1)
    return astart, tile_eid, n_tiles


def _dispatch_ragged(bank: ExpertBankQ, x: jax.Array, idx: jax.Array,
                     gates: jax.Array, e_local: int, capacity: int,
                     row_capacity: Optional[int] = None,
                     n_rows: Optional[int] = None,
                     gemm: Optional[str] = None):
    """Padding-free ragged dispatch + ONE fused mixed-precision kernel.

    Same routing contract as ``dispatch_compute`` (idx sorted stably by
    expert, identical drop rule, identical gate-weighted combine — the two
    layouts are bit-identical per token on a given backend), but tokens
    compact into a (Tt·bm, d) buffer whose per-expert segments are aligned
    to the row tile ``RAGGED_BM`` instead of scattering into (E, C, d).
    The tile→expert map visits only experts that received tokens this
    step; per tile the kernel streams the expert's resident tier only (hi
    slot derived from ``slot_owner`` — the same stable handles the padded
    overlay scatters through, so an all-lo draft bank stays all-lo here
    too). Dropped-by-capacity assignments still occupy compact rows (the
    layout depends only on routing) but are zeroed at combine, exactly
    like the padded path never computing them.

    Returns (y (T, D), counts (E,), dropped, pad_ratio)."""
    T, d = x.shape
    Tk = T * idx.shape[1]
    bm = RAGGED_BM
    order, sorted_eid, counts, pos_in_e, tok = _sort_routing(idx, e_local)
    kept = _keep_mask(sorted_eid, pos_in_e, tok, e_local, capacity,
                      row_capacity, n_rows, T)
    astart, tile_eid, n_tiles = ragged_tile_map(counts, bm, Tk)
    R = tile_eid.shape[0] * bm
    safe_e = jnp.minimum(sorted_eid, e_local - 1)
    rowpos = jnp.where(sorted_eid < e_local,
                       astart[safe_e] + pos_in_e, R)        # sentinel → drop
    xs = jnp.zeros((R, d), x.dtype).at[rowpos].set(x[tok], mode="drop")

    # Stable handles: expert → hi slot derived from slot_owner (NOT
    # slot_map), matching the padded overlay's semantics — a draft bank
    # that disowns every slot is all-lo under both layouts.
    owner = bank.slot_owner                                  # (n_hi,)
    n_hi = owner.shape[0]
    if n_hi > 0:
        eff_map = jnp.full((e_local + 1,), -1, jnp.int32).at[
            jnp.where(owner >= 0, owner, e_local)].set(
            jnp.arange(n_hi, dtype=jnp.int32), mode="drop")[:e_local]
        tile_slot = eff_map[tile_eid]
    else:
        tile_slot = jnp.full_like(tile_eid, -1)

    y_rows = kops.ragged_quant_ffn_op(
        xs, tile_eid, tile_slot, bank.lo, bank.hi if n_hi else None,
        bits=bank.lo["w_gate"].bits, group=bank.lo["w_gate"].group_size,
        bm=bm, backend=gemm)

    y_asn = y_rows[jnp.minimum(rowpos, R - 1)]
    gate_sorted = gates.reshape(-1)[order].astype(x.dtype)
    contrib = jnp.where(kept[:, None], y_asn * gate_sorted[:, None], 0)
    y = jnp.zeros((T, y_rows.shape[-1]), x.dtype).at[tok].add(contrib)

    routed = jnp.sum(jnp.where(sorted_eid < e_local, 1.0, 0.0))
    kept_f = jnp.sum(jnp.where(kept, 1.0, 0.0))
    dropped = 1.0 - kept_f / jnp.maximum(routed, 1.0)
    pad_ratio = 1.0 - routed / jnp.maximum(n_tiles * bm, 1).astype(jnp.float32)
    return y, counts.astype(jnp.int32), dropped, pad_ratio


def _moe_local(params: Dict, bank, x: jax.Array, cfg: MoEConfig,
               capacity: int, e_offset, e_local: int,
               slot_lo=0, n_slot_local: Optional[int] = None, ff_axis=None,
               token_valid: Optional[jax.Array] = None,
               n_rows: Optional[int] = None,
               row_capacity: Optional[int] = None,
               dispatch: Optional[str] = None, gemm: Optional[str] = None):
    """Route + dispatch for one shard (e_offset may be traced).

    ``token_valid`` ((T,) bool) drops masked tokens from dispatch entirely:
    they route to the sentinel expert (zero output, no capacity consumed)
    and vanish from every count — the per-row validity signal prefill
    padding and vacant decode slots ride in on. ``n_rows`` additionally
    returns (n_rows, E) counts segment-summed over T/n_rows-token rows.
    ``row_capacity`` switches the drop rule to the per-row normalization
    (see ``_row_capacity_keep``); ``dispatch``/``gemm`` select the token
    layout and GEMM backend (see ``kernels.ops``).
    """
    E, k = cfg.num_experts, cfg.top_k
    T = x.shape[0]
    gates, idx, probs = route(params["router"], x, cfg)
    sel = (idx >= e_offset) & (idx < e_offset + e_local)
    if token_valid is not None:
        sel = sel & token_valid[:, None]
    idx_l = jnp.where(sel, idx - e_offset, e_local)          # sentinel
    gates_l = jnp.where(sel, gates, 0.0)
    if row_capacity is not None:
        if n_rows is None:
            raise ValueError("row_capacity needs n_rows")
        # Physical capacity covering the per-row rule's worst case (all
        # surviving assignments on one expert) — overflow-free, so drops
        # come from the row rule alone.
        capacity = n_rows * row_capacity
    # Ragged layout: single-device quantized serving path only — sharded
    # meshes (traced e_offset / sliced slots / FF-split experts) and the
    # dense training bank keep the padded reference body.
    use_ragged = (dispatch == "ragged" and isinstance(bank, ExpertBankQ)
                  and isinstance(e_offset, int) and e_offset == 0
                  and n_slot_local is None and ff_axis is None)
    if use_ragged:
        y, counts_l, dropped, pad_ratio = _dispatch_ragged(
            bank, x, idx_l, gates_l, e_local, capacity,
            row_capacity=row_capacity, n_rows=n_rows, gemm=gemm)
    else:
        y, counts_l, dropped = dispatch_compute(
            bank, x, idx_l, gates_l, e_local, capacity,
            e_offset=e_offset, slot_lo=slot_lo, n_slot_local=n_slot_local,
            ff_axis=ff_axis, row_capacity=row_capacity, n_rows=n_rows,
            gemm=gemm)
        kept_rows = jnp.sum(jnp.clip(counts_l, 0, capacity))
        pad_ratio = 1.0 - kept_rows.astype(jnp.float32) / \
            jnp.float32(max(e_local * capacity, 1))
    active_experts = jnp.sum((counts_l > 0).astype(jnp.int32))

    # Load-balance aux on the full (replicated) router distribution,
    # restricted to valid tokens so padding cannot skew the balance target.
    if token_valid is None:
        full_idx = jnp.clip(idx.reshape(-1), 0, E)
        n_assign = x.shape[0] * k
        mean_prob = jnp.mean(probs, axis=0)
    else:
        full_idx = jnp.where(token_valid[:, None], jnp.clip(idx, 0, E),
                             E).reshape(-1)
        n_assign = jnp.maximum(jnp.sum(token_valid), 1) * k
        tv = token_valid[:, None].astype(jnp.float32)
        mean_prob = jnp.sum(probs * tv, axis=0) / \
            jnp.maximum(jnp.sum(tv), 1.0)
    full_counts = jnp.zeros((E + 1,), jnp.int32).at[full_idx].add(1)[:E]
    frac_routed = full_counts.astype(jnp.float32) / jnp.maximum(n_assign, 1)
    aux_loss = cfg.router_aux_coef * E * jnp.sum(frac_routed * mean_prob)

    row_counts = None
    if n_rows is not None:
        # Segment-sum the valid assignments per row: row r covers tokens
        # [r·T/R, (r+1)·T/R). Uses GLOBAL expert ids (telemetry is shard-
        # agnostic); masked/out-of-shard assignments fall into the E bucket.
        tpr = T // n_rows
        rid = jnp.arange(T, dtype=jnp.int32) // tpr
        eid = jnp.where(sel, idx, E)
        row_counts = jnp.zeros((n_rows, E + 1), jnp.int32).at[
            jnp.broadcast_to(rid[:, None], (T, k)), eid].add(1)[:, :E]
    return y, counts_l, full_counts.astype(jnp.int32), aux_loss, dropped, \
        row_counts, active_experts, pad_ratio


def moe_apply(params: Dict, bank: Union[Dict, ExpertBankQ], x: jax.Array,
              cfg: MoEConfig, capacity: int,
              token_valid: Optional[jax.Array] = None,
              n_rows: Optional[int] = None,
              row_capacity: Optional[int] = None,
              dispatch: Optional[str] = None,
              gemm: Optional[str] = None) -> tuple[jax.Array, MoEAux]:
    """Single-device path. params: {'router', ['shared']}; x: (T, d).

    ``token_valid``/``n_rows``: see ``_moe_local`` — masked tokens are
    excluded from dispatch, capacity and every count; ``n_rows`` requests
    per-row (R, E) counts in ``MoEAux.row_counts``. ``row_capacity``
    normalizes the drop rule per row (batch-shape-independent drops;
    requires ``n_rows``). ``dispatch`` ∈ {padded, ragged} picks the token
    layout (None → ``kernels.ops.moe_dispatch_default()``); ``gemm`` ∈
    {jnp, pallas} the quantized-GEMM backend.
    """
    dist = _get_dist()
    if dist is not None:
        return _moe_apply_sharded(params, bank, x, cfg, capacity, dist,
                                  token_valid=token_valid)
    if dispatch is None:
        dispatch = kops.moe_dispatch_default()
    y, counts, _full, aux_loss, dropped, row_counts, active, padr = \
        _moe_local(params, bank, x, cfg, capacity, 0, cfg.num_experts,
                   token_valid=token_valid, n_rows=n_rows,
                   row_capacity=row_capacity, dispatch=dispatch, gemm=gemm)
    if "shared" in params:
        y = y + swiglu(params["shared"], x)
    return y, MoEAux(counts=counts, aux_loss=aux_loss, dropped=dropped,
                     row_counts=row_counts, active_experts=active,
                     dispatch_pad_ratio=padr)


def _get_dist():
    try:
        from repro.launch.dist import get_dist
        return get_dist()
    except ImportError:  # pragma: no cover
        return None


def _moe_apply_sharded(params, bank, x, cfg: MoEConfig, capacity, dist,
                       token_valid=None):
    """shard_map expert parallelism (see module docstring).

    ``token_valid`` shards alongside ``x`` and masks dispatch exactly like
    the single-device path. Per-row counts are not produced here (rows are
    dp-sharded; the serving engine is single-device) — ``row_counts`` stays
    ``None``.

    The bank is decomposed into plain dicts around the shard_map boundary
    (PartitionSpec trees must structurally match the args; custom pytree
    metadata like QuantizedTensor's logical shape changes under slicing)."""
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

    import inspect
    # jax ≥ 0.6 renamed check_rep → check_vma; support both.
    check_kw = "check_vma" if "check_vma" in \
        inspect.signature(shard_map).parameters else "check_rep"

    mesh = dist.mesh
    mn = dist.model_size
    E = cfg.num_experts
    if E % mn:
        # Cannot expert-shard — run replicated (noted by the planner).
        y, counts, _f, aux, dropped, _rc, _a, _p = _moe_local(
            params, bank, x, cfg, capacity, 0, E, token_valid=token_valid)
        if "shared" in params:
            y = y + swiglu(params["shared"], x)
        return y, MoEAux(counts, aux, dropped)
    e_local = E // mn
    is_q = isinstance(bank, ExpertBankQ)
    n_hi = bank.n_hi if is_q else 0
    hi_shard = n_hi > 0 and n_hi % mn == 0
    nh_local = n_hi // mn if hi_shard else None

    dp_n = 1
    for a in dist.dp_axes:
        dp_n *= mesh.shape[a]
    # capacity was computed for global T and global E; the local shard keeps
    # the same per-expert expectation: T_loc·k·cf / E = capacity / dp_n.
    cap_local = max(8, (capacity // dp_n + 7) // 8 * 8) \
        if dist.tokens_dp_sharded else capacity

    # FF-slice over the idle data axis when tokens are replicated (batch-1
    # long-context decode) and every sliced dim divides: 2-D expert sharding.
    dp1 = dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0]
    ff_axis = None
    if is_q and not dist.tokens_dp_sharded and dp_n > 1:
        f_dim = bank.lo["w_gate"].packed.shape[-1]
        d_dim = bank.lo["w_down"].packed.shape[-1]
        if f_dim % dp_n == 0 and d_dim % dp_n == 0:
            ff_axis = dp1

    # ---- flatten bank to plain dicts + spec trees -----------------------
    eshard = P("model")          # prefix spec: shard dim 0 (E / n_hi)
    repl = P()
    if is_q:
        flat = {f"lo_packed.{n}": qt.packed for n, qt in bank.lo.items()}
        flat.update({f"lo_scales.{n}": qt.scales for n, qt in bank.lo.items()})
        flat.update({f"hi.{n}": a for n, a in bank.hi.items()})
        flat["slot_owner"] = bank.slot_owner
        flat["slot_map"] = bank.slot_map
        meta = {n: (qt.bits, qt.group_size) for n, qt in bank.lo.items()}

        def spec_of(k):
            he = eshard if hi_shard else repl
            if k.startswith("slot"):
                return repl
            base = eshard if k.startswith("lo_") else he
            if ff_axis is not None:   # slice the last (F or D-out) dim
                return P(*(tuple(base) + (None,) * (2 - len(tuple(base))) + (dp1,)))
            return base
        bank_spec = {k: spec_of(k) for k in flat}
    else:
        flat = dict(bank)
        meta = None
        bank_spec = {k: eshard for k in flat}

    def rebuild(flat_l):
        if not is_q:
            return flat_l
        lo = {n: QuantizedTensorLike(flat_l[f"lo_packed.{n}"],
                                     flat_l[f"lo_scales.{n}"], *meta[n])
              for n in bank.lo}
        return ExpertBankQ(lo=lo, hi={n: flat_l[f"hi.{n}"] for n in bank.hi},
                           slot_owner=flat_l["slot_owner"],
                           slot_map=flat_l["slot_map"])

    params_spec = jax.tree_util.tree_map(lambda _: repl, params)
    x_spec = P(dist.dp_axes) if dist.tokens_dp_sharded else repl
    tv_spec = None if token_valid is None else x_spec

    def body(params_l, flat_l, x_l, tv_l):
        j = jax.lax.axis_index(dist.model_axis)
        e_off = j * e_local
        slot_lo = (j * nh_local) if hi_shard else 0
        y, counts_l, _full, aux, dropped, _rc, _a, _p = _moe_local(
            params_l, rebuild(flat_l), x_l, cfg, cap_local, e_off, e_local,
            slot_lo=slot_lo, n_slot_local=nh_local, ff_axis=ff_axis,
            token_valid=tv_l)
        y = jax.lax.psum(y, dist.model_axis)
        if ff_axis is not None:   # y is D-sliced over data: gather (tiny)
            y = jax.lax.all_gather(y, ff_axis, axis=1, tiled=True)
        if "shared" in params_l:
            y = y + swiglu(params_l["shared"], x_l)
        # Global hotness counts: place the local expert slice, reduce over
        # model (expert partition) and data (token partition).
        counts = jnp.zeros((cfg.num_experts,), jnp.int32)
        counts = jax.lax.dynamic_update_slice(counts, counts_l, (e_off,))
        counts = jax.lax.psum(counts, dist.model_axis)
        if dist.tokens_dp_sharded and dist.dp_axes:
            counts = jax.lax.psum(counts, dist.dp_axes)
            aux = jax.lax.pmean(aux, dist.dp_axes)
            dropped = jax.lax.pmean(dropped, dist.dp_axes)
        dropped = jax.lax.pmean(dropped, dist.model_axis)
        return y, counts, aux, dropped

    y, counts, aux, dropped = shard_map(
        body, mesh=mesh,
        in_specs=(params_spec, bank_spec, x_spec, tv_spec),
        out_specs=(x_spec, repl, repl, repl),
        **{check_kw: False},
    )(params, flat, x, token_valid)
    return y, MoEAux(counts=counts, aux_loss=aux, dropped=dropped)


class QuantizedTensorLike(NamedTuple):
    """Local-shard view of a QuantizedTensor inside shard_map (plain tuple:
    no global-shape metadata to go stale)."""
    packed: jax.Array
    scales: jax.Array
    bits: int
    group_size: int


def moe_capacity(n_tokens: int, cfg: MoEConfig, factor: float | None = None) -> int:
    f = factor if factor is not None else cfg.capacity_factor
    cap = int(n_tokens * cfg.top_k * f / cfg.num_experts) + 1
    # Round up to a multiple of 8 for friendlier tiling/sharding.
    return max(8, (cap + 7) // 8 * 8)
