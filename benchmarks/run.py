# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

    PYTHONPATH=src python -m benchmarks.run [--only PREFIX]

Each module reproduces one paper artifact (see DESIGN.md §8):
  activation_ratio → Tables 1–2   workload_shift → Fig 2
  demotion_curve   → Fig 3        quality        → Table 4
  serving_perf     → Figs 6–9     prompt_scaling → Fig 10
  kernels_bench    → (ours) Pallas kernel roofline check
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    rows = []

    def report(name, us_per_call, derived):
        rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.1f},{derived}")

    from benchmarks import (activation_ratio, demotion_curve, ep_scaling,
                            fault_tolerance, hierarchy, kernels_bench,
                            kv_reuse, obs_overhead, prompt_scaling, quality,
                            serving_perf, serving_sim, slo_serving,
                            spec_decode, workload_shift)
    suites = [
        ("activation_ratio", activation_ratio.run),
        ("workload_shift", workload_shift.run),
        ("demotion_curve", demotion_curve.run),
        ("quality", quality.run),
        ("serving_sim", serving_sim.run),
        ("serving_perf", serving_perf.run),
        ("slo_serving", slo_serving.run),
        ("kv_reuse", kv_reuse.run),
        ("ep_scaling", ep_scaling.run),
        ("hierarchy", hierarchy.run),
        ("fault_tolerance", fault_tolerance.run),
        ("spec_decode", spec_decode.run),
        ("obs_overhead", obs_overhead.run),
        ("prompt_scaling", prompt_scaling.run),
        ("kernels", kernels_bench.run),
        ("kernels_roofline", kernels_bench.run_roofline),
        ("kernels_flash", kernels_bench.run_flash),
    ]
    failures = 0
    print("name,us_per_call,derived")
    for name, fn in suites:
        if args.only and not name.startswith(args.only):
            continue
        t0 = time.perf_counter()
        try:
            fn(report)
            print(f"# {name}: done in {time.perf_counter() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name}: FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
