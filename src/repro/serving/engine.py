"""Request-level MoE serving engine with pluggable expert residency.

The unit of work is a **request**, not a batch: ``submit(request)`` returns a
handle, ``step()`` advances every in-flight request by one token, ``drain()``
runs until the queue empties. The engine implements continuous batching over
a fixed pool of ``max_slots`` KV-cache slots:

* **admission** — queued requests are batched into a padded, masked prefill:
  prompt lengths round up a small geometric bucket ladder
  (``bucket_base``·2^i, capped at ``max_len``), up to ``prefill_rows``
  same-bucket requests prefill in ONE forward (per-row true lengths mask
  padding out of attention-cache writes, MoE dispatch and router counts),
  and each row's KV/SSM state is scattered into its slot of the batched
  caches. XLA therefore compiles at most one prefill executable per bucket
  — O(#buckets), not O(#distinct prompt lengths) — and admission cost
  amortizes over the batch at high arrival rates;
* **decode** — one jitted step advances *all* occupied slots together, with
  a per-slot position vector (each request decodes at its own offset) and a
  per-slot validity mask: vacant slots still ride along for shape stability
  but are masked out of MoE dispatch and every router count;
* **eviction/refill** — a finished request frees its slot at the end of the
  step; the next ``step()`` admits queued work into it mid-stream.

Where expert weights live — dense fp16, static PTQ, DynaExq mixed precision,
or host-offloaded with an LRU device cache — is entirely the
``ResidencyBackend``'s business (see ``repro.serving.backends``). The engine
calls exactly the backend protocol: ``materialize_banks`` at build time,
``observe(counts, compute_s, prefill, row_valid)`` after every forward with
per-row (slot-resolved) router counts plus the row-validity mask — so no
backend ever accounts phantom traffic from padding or vacant slots — and
``tick()`` at step boundaries. There is no mode switch and no per-backend
branch anywhere in this loop.

Per-request routing telemetry falls out of the same signal: every
``RequestHandle`` accumulates its own row's expert counts
(``handle.expert_counts``: MoE position → (nsb, E)), attributing router
traffic to the request that caused it.

``generate(batch, n_tokens)`` survives as a thin compat shim over
submit + drain for the whole-batch callers (benchmarks, launchers).
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import itertools
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_caches, prefill
from repro.models.config import ArchConfig
from repro.models.model import DecodeCaches
from repro.serving.backends import ResidencyBackend
from repro.serving.requests import Request


# Module-level jitted entry points with the (frozen, hashable) ArchConfig as
# a static argument: the XLA compile cache is keyed on the function identity,
# so every engine built for the same config shares compilations — a warm-up
# engine genuinely warms the measured one (benchmarks rely on this).

@functools.partial(jax.jit, static_argnames=("cfg", "capacity_factor"))
def _prefill_jit(params, batch, caches, banks, lengths, *, cfg,
                 capacity_factor):
    return prefill(params, cfg, batch, caches, bank=banks,
                   capacity_factor=capacity_factor, lengths=lengths,
                   per_row_counts=True)


@functools.partial(jax.jit, static_argnames=("cfg", "capacity_factor"))
def _decode_jit(params, token, pos, caches, banks, row_valid, *, cfg,
                capacity_factor):
    return decode_step(params, cfg, token, pos, caches, bank=banks,
                       capacity_factor=capacity_factor, row_valid=row_valid,
                       per_row_counts=True)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(pool, rows, slots):
    """Write the first ``len(slots)`` prefilled rows of a bucket cache into
    the batch rows named by ``slots``. The pool is donated so XLA updates
    the (large) cache buffers in place."""
    n = slots.shape[0]
    return jax.tree_util.tree_map(
        lambda m, o: m.at[:, slots].set(o[:, :n]), pool, rows)


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 4               # concurrent requests (batch rows)
    max_len: int = 512               # per-slot sequence budget
    capacity_factor: float = 2.0
    pad_token_id: int = 0            # fed to never-yet-occupied decode rows
    bucket_base: int = 32            # smallest prefill length bucket
    # Rows per batched prefill (compile-time constant so the prefill compile
    # count stays O(#buckets)); None → min(4, max_slots).
    prefill_rows: Optional[int] = None


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"


class RequestHandle:
    """Mutable per-request view returned by ``submit``."""

    def __init__(self, rid: int, request: Request):
        self.id = rid
        self.request = request
        self.state = RequestState.QUEUED
        self.slot: Optional[int] = None
        self.tokens: List[int] = []      # generated tokens (greedy)
        self.submit_s: float = 0.0       # perf_counter at submit
        self.stall_at_submit: float = 0.0  # engine stall-clock at submit
        self.ttft_s: float = 0.0         # submit → first token (incl. queue)
        self.step_times: List[float] = []
        # Per-request routing telemetry: MoE position → (nsb, E) int64
        # router selections attributed to THIS request's row (prompt tokens
        # at prefill + one per decode step). Populated at admission.
        self.expert_counts: Optional[Dict[str, np.ndarray]] = None

    @property
    def workload(self) -> str:
        return self.request.workload

    def token_array(self) -> np.ndarray:
        return np.asarray(self.tokens, np.int32)

    def __repr__(self):
        return (f"RequestHandle(id={self.id}, state={self.state.value}, "
                f"slot={self.slot}, n_generated={len(self.tokens)})")


class InferenceEngine:
    """Continuous-batching serving loop over a ``ResidencyBackend``."""

    def __init__(self, cfg: ArchConfig, params: Dict,
                 backend: ResidencyBackend,
                 ecfg: Optional[EngineConfig] = None):
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "InferenceEngine serves decoder-only stacks; encoder-decoder "
                "architectures go through the batch prefill/decode entry "
                "points in repro.models directly.")
        self.cfg = cfg
        self.params = params
        self.backend = backend
        self.ecfg = ecfg if ecfg is not None else EngineConfig()

        self.banks = backend.materialize_banks(cfg, params, self._kv_bytes())
        self._jit_prefill = functools.partial(
            _prefill_jit, cfg=cfg,
            capacity_factor=self.ecfg.capacity_factor)
        self._jit_decode = functools.partial(
            _decode_jit, cfg=cfg,
            capacity_factor=self.ecfg.capacity_factor)
        self._jit_scatter = _scatter_rows

        n = self.ecfg.max_slots
        self.caches = init_caches(cfg, n, self.ecfg.max_len)
        self.slots: List[Optional[RequestHandle]] = [None] * n
        self.pos = np.zeros(n, np.int32)        # next write position per slot
        self.tokens = np.full(n, self.ecfg.pad_token_id, np.int32)
        self.queue: deque[RequestHandle] = deque()
        self.last_counts: Dict = {}             # (nsb, E) counts, last forward
        self.last_row_counts: Dict = {}         # (nsb, R, E), last forward
        self.decode_times: List[float] = []     # per-step latency incl. stall
        self.ttfts: List[float] = []            # per-request submit→first-tok
        # Cumulative modeled stall seconds (backend-returned, never slept):
        # a virtual clock running alongside perf_counter, so queue-inclusive
        # latencies charge the stalls of work that ran ahead of a request.
        self._stall_clock = 0.0
        self._ids = itertools.count()
        self.counters = {"steps": 0, "prefills": 0, "admitted": 0,
                         "finished": 0}
        # ---- length-bucket ladder -----------------------------------
        # SSD prefill requires sequence length divisible by the chunk size,
        # so for stacks with mamba layers every bucket is a chunk multiple.
        sb = cfg.superblock_or_default()
        self._seq_mult = cfg.ssm.chunk if "mamba" in sb else 1
        m = self._seq_mult
        cap = (self.ecfg.max_len // m) * m
        if cap <= 0:
            raise ValueError(
                f"max_len={self.ecfg.max_len} below the SSD chunk multiple "
                f"{m}; no prefill bucket fits")
        base = max(1, -(-self.ecfg.bucket_base // m) * m)
        ladder: List[int] = []
        v = base
        while v < cap:
            ladder.append(v)
            v *= 2
        ladder.append(cap)
        self.buckets = tuple(ladder)            # ascending, last == cap
        self._max_prompt = cap
        self._prefill_rows = self.ecfg.prefill_rows \
            if self.ecfg.prefill_rows is not None else min(4, n)
        self.prefill_shapes: set = set()        # (rows, bucket) traced

    # ------------------------------------------------------------------
    def _kv_bytes(self) -> int:
        cfg = self.cfg
        if cfg.attn is None:
            return 0
        sb = cfg.superblock_or_default()
        n_attn = sum(1 for k in sb if k == "attn") * cfg.n_superblocks()
        cap = self.ecfg.max_len if cfg.attn.sliding_window is None else \
            min(self.ecfg.max_len, cfg.attn.sliding_window)
        return (2 * self.ecfg.max_slots * cap * cfg.attn.n_kv_heads *
                cfg.attn.head_dim * 2 * n_attn)

    # ------------------------------------------------------------------
    def submit(self, request: Request) -> RequestHandle:
        """Queue a request; it is admitted on a later ``step()`` as soon as
        a cache slot frees up. Returns immediately with a handle.

        The prompt must fit the largest prefill bucket (``max_len`` rounded
        down to the engine's sequence multiple). A generation budget that
        overruns the slot is fine — common for eos-bounded requests — the
        request is truncated at the sequence capacity (finishes with fewer
        than ``max_new_tokens`` tokens)."""
        plen = int(np.asarray(request.tokens).shape[-1])
        if plen > self._max_prompt:
            raise ValueError(
                f"prompt of {plen} tokens exceeds the largest prefill "
                f"bucket {self._max_prompt} (max_len={self.ecfg.max_len})")
        handle = RequestHandle(next(self._ids), request)
        handle.submit_s = time.perf_counter()
        handle.stall_at_submit = self._stall_clock
        self.queue.append(handle)
        return handle

    def _bucket_len(self, plen: int) -> int:
        """Smallest ladder bucket that fits ``plen`` tokens."""
        for b in self.buckets:
            if b >= plen:
                return b
        raise ValueError(f"prompt of {plen} tokens exceeds the largest "
                         f"bucket {self.buckets[-1]}")

    @staticmethod
    def _prompt_len(handle: RequestHandle) -> int:
        return int(np.asarray(handle.request.tokens).reshape(-1).shape[0])

    def _admit(self, finished: List[RequestHandle]) -> None:
        """Fill free slots from the queue with batched, length-bucketed
        masked prefills: the queue head picks the bucket, same-bucket
        requests behind it join (up to ``prefill_rows`` and the free-slot
        count), the batch right-pads to (prefill_rows, bucket), and each
        prefilled row scatters into its slot of the batched caches. Batch
        rows beyond the group are ``lengths == 0`` pads, so every prefill
        compiles at one of O(#buckets) shapes."""
        while self.queue:
            free = [i for i, h in enumerate(self.slots) if h is None]
            if not free:
                return
            R = self._prefill_rows
            limit = min(len(free), R)
            head = self.queue.popleft()
            bucket = self._bucket_len(self._prompt_len(head))
            group = [head]
            skipped: List[RequestHandle] = []
            while self.queue and len(group) < limit:
                h = self.queue.popleft()
                if self._bucket_len(self._prompt_len(h)) == bucket:
                    group.append(h)
                else:
                    skipped.append(h)
            self.queue.extendleft(reversed(skipped))

            G = len(group)
            lengths = np.zeros(R, np.int32)
            batch_toks = np.full((R, bucket), self.ecfg.pad_token_id,
                                 np.int32)
            for r, h in enumerate(group):
                p = np.asarray(h.request.tokens, np.int32).reshape(-1)
                lengths[r] = p.shape[0]
                batch_toks[r, :p.shape[0]] = p
            row_caches = init_caches(self.cfg, R, self.ecfg.max_len)
            t0 = time.perf_counter()
            logits, row_caches, counts = self._jit_prefill(
                self.params, {"tokens": jnp.asarray(batch_toks)},
                row_caches, self.banks, jnp.asarray(lengths))
            logits.block_until_ready()
            dt = time.perf_counter() - t0
            self.prefill_shapes.add((R, bucket))
            counts_np = {k: np.asarray(v) for k, v in counts.items()}
            self.last_row_counts = counts_np
            self.last_counts = {k: v.sum(axis=1) if v.ndim == 3 else v
                                for k, v in counts_np.items()}
            row_valid = np.zeros(R, bool)
            row_valid[:G] = True
            stall = self.backend.observe(counts_np, dt, prefill=True,
                                         row_valid=row_valid)
            # Scatter the prefilled rows into their slots' batch rows.
            slots_arr = np.asarray(free[:G], np.int32)
            self.caches = DecodeCaches(
                blocks=self._jit_scatter(self.caches.blocks,
                                         row_caches.blocks,
                                         jnp.asarray(slots_arr)),
                cross=None)
            self._stall_clock += stall
            first = np.asarray(jnp.argmax(logits, -1), np.int32)
            for r, handle in enumerate(group):
                slot = int(slots_arr[r])
                tok = int(first[r])
                handle.tokens.append(tok)
                # Serving TTFT: submit → first token. Wall clock covers
                # queue wait and the prefills admitted ahead of it; the
                # stall-clock delta charges every MODELED stall since submit
                # (predecessors' demand misses and this forward's own) that
                # wall time never slept. The backend's own ttft_s tracks
                # per-prefill latency.
                handle.ttft_s = (time.perf_counter() - handle.submit_s +
                                 self._stall_clock - handle.stall_at_submit)
                self.ttfts.append(handle.ttft_s)
                handle.state = RequestState.RUNNING
                handle.slot = slot
                # Per-request attribution needs row-resolved counts; under
                # shard_map expert parallelism only aggregates exist.
                handle.expert_counts = {
                    k: v[:, r].astype(np.int64)
                    for k, v in counts_np.items() if v.ndim == 3}
                self.slots[slot] = handle
                self.pos[slot] = int(lengths[r])
                self.tokens[slot] = tok
                self.counters["admitted"] += 1
                if self._done(handle):
                    self._finish(handle, finished)
            self.counters["prefills"] += 1

    def _done(self, handle: RequestHandle) -> bool:
        req = handle.request
        if len(handle.tokens) >= req.max_new_tokens:
            return True
        if req.eos_token_id is not None and \
                handle.tokens[-1] == req.eos_token_id:
            return True
        # Out of sequence budget: the slot's cache row is full.
        return int(self.pos[handle.slot]) >= self.ecfg.max_len

    def _finish(self, handle: RequestHandle,
                finished: List[RequestHandle]) -> None:
        handle.state = RequestState.FINISHED
        self.slots[handle.slot] = None
        # The vacated row keeps replaying its last token through the batched
        # decode (shape stability), but row_valid masks it out of MoE
        # dispatch and every router count — vacancy is invisible to hotness
        # and residency accounting.
        self.counters["finished"] += 1
        finished.append(handle)

    # ------------------------------------------------------------------
    def step(self) -> List[RequestHandle]:
        """One engine step: admit queued requests into free slots, then
        advance every running request by one token. Returns the handles
        that finished during this step."""
        finished: List[RequestHandle] = []
        self._admit(finished)
        active = [(i, h) for i, h in enumerate(self.slots) if h is not None]
        if active:
            row_valid = np.asarray([h is not None for h in self.slots], bool)
            t0 = time.perf_counter()
            logits, self.caches, counts = self._jit_decode(
                self.params, jnp.asarray(self.tokens),
                jnp.asarray(self.pos), self.caches, self.banks,
                jnp.asarray(row_valid))
            logits.block_until_ready()
            dt = time.perf_counter() - t0
            counts_np = {k: np.asarray(v) for k, v in counts.items()}
            self.last_row_counts = counts_np
            self.last_counts = {k: v.sum(axis=1) if v.ndim == 3 else v
                                for k, v in counts_np.items()}
            stall = self.backend.observe(counts_np, dt, prefill=False,
                                         row_valid=row_valid)
            self._stall_clock += stall
            latency = dt + stall
            self.decode_times.append(latency)
            next_tokens = np.asarray(jnp.argmax(logits, -1), np.int32)
            for i, handle in active:
                tok = int(next_tokens[i])
                handle.tokens.append(tok)
                handle.step_times.append(latency)
                for k, v in counts_np.items():
                    if v.ndim == 3 and k in handle.expert_counts:
                        handle.expert_counts[k] += v[:, i]
                self.tokens[i] = tok
                self.pos[i] += 1
                if self._done(handle):
                    self._finish(handle, finished)
            self.counters["steps"] += 1
        self.backend.tick()
        return finished

    def drain(self) -> List[RequestHandle]:
        """Run ``step()`` until no request is queued or running; returns the
        handles finished during the drain, in completion order."""
        done: List[RequestHandle] = []
        while self.queue or any(h is not None for h in self.slots):
            done.extend(self.step())
        return done

    def replay(self, stream) -> List[RequestHandle]:
        """Serve an arrival-timed request stream (e.g. ``RequestStream``):
        each request is submitted once the wall clock — measured from replay
        start — passes its ``arrival_s`` offset, so queueing delay and TTFT
        reflect the offered load. When the engine goes idle before the next
        arrival it skips ahead instead of spinning. Returns handles in
        arrival order; all are FINISHED on return."""
        requests = list(stream)
        handles: List[RequestHandle] = []
        t0 = time.perf_counter()
        i = 0
        while i < len(requests) or self.queue or \
                any(h is not None for h in self.slots):
            now = time.perf_counter() - t0
            while i < len(requests) and requests[i].arrival_s <= now:
                handles.append(self.submit(requests[i]))
                i += 1
            if i < len(requests) and not self.queue and \
                    all(h is None for h in self.slots):
                # Idle gap until the next arrival — fast-forward.
                handles.append(self.submit(requests[i]))
                i += 1
            self.step()
        return handles

    def flush(self) -> None:
        """Barrier on the backend's in-flight residency transitions."""
        self.backend.flush()

    # ------------------------------------------------------------------
    def generate(self, batch: Dict, n_tokens: int):
        """Whole-batch compat shim over submit + drain.

        ``batch``: ``{"tokens": (B, S)}`` with B ≤ ``max_slots``. Greedy
        generation; returns ``(tokens (B, n_tokens), ttft_s, per_step_s)``
        token-for-token identical to driving submit/step/drain directly.
        Token-only: multimodal batches (``image_embeds``/``audio_embeds``)
        are not supported by the request path and are rejected loudly.
        """
        extra = set(batch) - {"tokens"}
        if extra:
            raise NotImplementedError(
                f"InferenceEngine serves token-only requests; unsupported "
                f"batch keys: {sorted(extra)}. Use repro.models.prefill/"
                f"decode_step directly for multimodal batches.")
        toks = np.asarray(batch["tokens"])
        B = toks.shape[0]
        if B > self.ecfg.max_slots:
            raise ValueError(f"batch {B} > max_slots={self.ecfg.max_slots}")
        if toks.shape[1] + n_tokens - 1 > self.ecfg.max_len:
            # The shim stacks a dense (B, n_tokens) grid — truncation would
            # break it, so the whole batch must fit the slot budget.
            raise ValueError(
                f"{toks.shape[1]}-token prompts + {n_tokens} new tokens "
                f"exceed max_len={self.ecfg.max_len}")
        handles = [self.submit(Request(tokens=toks[i],
                                       max_new_tokens=n_tokens))
                   for i in range(B)]
        n_before = len(self.decode_times)
        self.drain()
        out = jnp.asarray(np.stack([h.token_array() for h in handles], 0))
        ttft = float(np.mean([h.ttft_s for h in handles]))
        return out, ttft, self.decode_times[n_before:]

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Backend's uniform serving stats merged with engine counters.
        ``ttft_s`` is the request-level submit→first-token mean (queue wait
        included); the backend's per-prefill latency stays available via
        ``backend.stats()``."""
        out = dict(self.backend.stats())
        if self.ttfts:
            out["ttft_s"] = float(np.mean(self.ttfts))
        out.update({k: float(v) for k, v in self.counters.items()})
        out["prefill_compiles"] = float(len(self.prefill_shapes))
        return out

    def device_bytes(self) -> int:
        return self.backend.device_bytes()
