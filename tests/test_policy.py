"""Budget-feasible top-n selection + hysteresis (paper §3.5) — property tests."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.policy import PolicyConfig, select_hi_set


@settings(max_examples=100, deadline=None)
@given(e=st.integers(2, 64), n_hi=st.integers(0, 16),
       margin=st.floats(0, 10), seed=st.integers(0, 2 ** 16),
       cur_size=st.integers(0, 16))
def test_budget_never_exceeded(e, n_hi, margin, seed, cur_size):
    rng = np.random.default_rng(seed)
    scores = rng.random(e) * 100
    current = set(rng.choice(e, size=min(cur_size, min(n_hi, e)),
                             replace=False).tolist())
    cfg = PolicyConfig(n_hi=n_hi, margin=margin)
    target, promos, demos = select_hi_set(scores, current, cfg)
    assert len(target) <= min(n_hi, e)                 # (C1) budget feasible
    assert target == (current - set(demos)) | set(promos)
    assert not (set(promos) & current)
    assert set(demos) <= current


@settings(max_examples=50, deadline=None)
@given(e=st.integers(4, 32), seed=st.integers(0, 2 ** 16))
def test_fills_capacity_from_empty(e, seed):
    rng = np.random.default_rng(seed)
    scores = rng.random(e)
    n = e // 2
    target, promos, _ = select_hi_set(scores, set(), PolicyConfig(n_hi=n))
    assert len(target) == n
    # hottest expert always selected
    assert int(np.argmax(scores)) in target


def test_hysteresis_prevents_churn_on_ties():
    """Near-tie scores must not swap members (C3 stability)."""
    scores = np.array([10.0, 10.1, 9.95, 1.0])
    cfg = PolicyConfig(n_hi=2, margin=0.5)
    current = {0, 2}          # scores 10.0 and 9.95; outsider 1 has 10.1
    target, promos, demos = select_hi_set(scores, current, cfg)
    assert target == current and not promos and not demos
    # without margin the swap happens
    t2, p2, d2 = select_hi_set(scores, current, PolicyConfig(n_hi=2, margin=0.0))
    assert 1 in t2 and 2 not in t2


def test_clear_winner_overcomes_hysteresis():
    scores = np.array([10.0, 50.0, 9.0, 1.0])
    target, promos, demos = select_hi_set(
        scores, {0, 2}, PolicyConfig(n_hi=2, margin=5.0))
    assert 1 in target and demos == [2]   # coldest demoted first


def test_capacity_shrink_demotes_coldest():
    scores = np.array([5.0, 4.0, 3.0, 2.0])
    target, _, demos = select_hi_set(scores, {0, 1, 2}, PolicyConfig(n_hi=2))
    assert target == {0, 1} and 2 in demos


def test_transition_rate_limit():
    scores = np.array([0.0, 0.0, 10.0, 11.0, 12.0, 13.0])
    cfg = PolicyConfig(n_hi=2, max_transitions_per_layer=1)
    target, promos, demos = select_hi_set(scores, {0, 1}, cfg)
    assert len(promos) == 1 and promos[0] == 5   # hottest first
    assert len(target) <= 2
