"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Implements the chunked SSD algorithm for train/prefill (quadratic within a
chunk, linear recurrence across chunks via ``lax.scan``) and the O(1)
recurrent step for decode. Used standalone (mamba2-130m) and inside the
Jamba hybrid super-block.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import SSMConfig
from repro.models.layers import _init


class MambaCache(NamedTuple):
    conv: jax.Array   # (B, d_conv, conv_dim) rolling window of conv inputs
    state: jax.Array  # (B, H, P, N) SSM state


def conv_dim(cfg: SSMConfig, d_model: int) -> int:
    return cfg.d_inner(d_model) + 2 * cfg.n_groups * cfg.d_state


def init_mamba(key, d_model: int, cfg: SSMConfig) -> Dict:
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    cdim = conv_dim(cfg, d_model)
    d_in_proj = 2 * di + 2 * cfg.n_groups * cfg.d_state + nh
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _init(ks[0], (d_model, d_in_proj)),
        "conv_w": _init(ks[1], (cfg.d_conv, cdim), scale=cfg.d_conv ** -0.5),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm": {"scale": jnp.ones((di,), jnp.bfloat16)},
        "out_proj": _init(ks[3], (di, d_model)),
    }


def init_mamba_cache(batch: int, d_model: int, cfg: SSMConfig,
                     dtype=jnp.bfloat16) -> MambaCache:
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    return MambaCache(
        conv=jnp.zeros((batch, cfg.d_conv, conv_dim(cfg, d_model)), dtype),
        state=jnp.zeros((batch, nh, cfg.head_dim, cfg.d_state), jnp.float32),
    )


def _gated_rmsnorm(p, y: jax.Array, z: jax.Array, eps: float = 1e-6):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps)).astype(y.dtype) * p["scale"]


def _split_proj(zxbcdt, d_model: int, cfg: SSMConfig):
    di = cfg.d_inner(d_model)
    gn = cfg.n_groups * cfg.d_state
    nh = cfg.n_heads(d_model)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:di + di + 2 * gn + nh]
    return z, xBC, dt


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., L) → (..., L, L) with out[i, j] = sum_{j<t<=i} a_t (−inf above
    the diagonal)."""
    L = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_forward(params: Dict, cfg: SSMConfig, d_model: int, x: jax.Array,
                init_state: jax.Array | None = None,
                lengths: jax.Array | None = None):
    """Full-sequence SSD. x: (B, S, d_model) → (y: (B, S, d_model),
    final MambaCache).

    ``lengths`` ((B,) int32) marks each row's true length for padded
    (length-bucketed) prefill: positions >= length get ``dt = 0`` so they
    neither advance nor decay the SSM state (the returned state is exactly
    the state after the last REAL token), and the conv cache window is
    gathered per row around its own last real input instead of the batch
    tail. Outputs at padded positions are garbage and must not be read.
    """
    B, S, _ = x.shape
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    P, N, G = cfg.head_dim, cfg.d_state, cfg.n_groups
    Q = cfg.chunk
    if S % Q:
        raise ValueError(f"seq {S} not divisible by SSD chunk {Q}")

    zxbcdt = x @ params["in_proj"]
    z, xBC, dt_raw = _split_proj(zxbcdt, d_model, cfg)

    # Causal depthwise conv over the sequence.
    K = params["conv_w"].shape[0]
    pad = jnp.zeros((B, K - 1, xBC.shape[-1]), xBC.dtype)
    xBC_pad = jnp.concatenate([pad, xBC], axis=1)
    # Conv cache = last K raw inputs (decode shifts one off before
    # appending); with per-row lengths, "last" means the window ending at
    # each row's final real token: padded index (length-1) + k holds raw
    # position length-K+k (the leading K-1 zeros cover short rows).
    if lengths is None:
        conv_tail = xBC_pad[:, S - 1: S + K - 1]
    else:
        tidx = jnp.clip(lengths[:, None] - 1, 0, S - 1) + \
            jnp.arange(K, dtype=jnp.int32)[None, :]          # (B, K)
        conv_tail = jnp.take_along_axis(xBC_pad, tidx[..., None], axis=1)
        # A lengths==0 (batch-pad) row is fully inert: keep its conv window
        # at the zero init, not the pad token's projected input.
        conv_tail = conv_tail * (lengths > 0)[:, None, None]
    windows = jnp.stack([xBC_pad[:, i:i + S] for i in range(K)], axis=2)
    xBC = jnp.einsum("bskc,kc->bsc", windows, params["conv_w"].astype(xBC.dtype))
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)

    xh = xBC[..., :di].reshape(B, S, nh, P)
    Bm = xBC[..., di:di + G * N].reshape(B, S, G, N)
    Cm = xBC[..., di + G * N:].reshape(B, S, G, N)
    hpg = nh // G
    Bm = jnp.repeat(Bm, hpg, axis=2)  # (B, S, H, N)
    Cm = jnp.repeat(Cm, hpg, axis=2)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    if lengths is not None:
        # dt=0 at padded positions ⇒ zero input contribution AND unit decay
        # (dA = dt·A = 0, exp(0) = 1): the state passes through unchanged.
        pos_valid = jnp.arange(S)[None, :] < lengths[:, None]             # (B,S)
        dt = jnp.where(pos_valid[..., None], dt, 0.0)
    A = -jnp.exp(params["A_log"])                                          # (H,)
    dA = dt * A                                                            # (B,S,H)

    nc = S // Q
    def chunked(t, shape):
        return t.reshape(B, nc, Q, *shape)
    xh_c = chunked(xh.astype(jnp.float32), (nh, P))
    B_c = chunked(Bm.astype(jnp.float32), (nh, N))
    C_c = chunked(Cm.astype(jnp.float32), (nh, N))
    dt_c = chunked(dt, (nh,))
    dA_c = chunked(dA, (nh,))

    Acum = jnp.cumsum(dA_c, axis=2)                         # (B,nc,Q,H)
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA_c, -1, 2)))      # (B,nc,H,Q,Q)

    xdt = xh_c * dt_c[..., None]                            # (B,nc,Q,H,P)
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp",
                        C_c, B_c, Lmat, xdt)

    decay_states = jnp.exp(Acum[:, :, -1:, :] - Acum)       # (B,nc,Q,H)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn",
                        B_c, decay_states * dt_c, xh_c)     # (B,nc,H,P,N)
    chunk_decay = jnp.exp(Acum[:, :, -1, :])                # (B,nc,H)

    def scan_fn(carry, xs):
        st_in, cd = xs                                      # (B,H,P,N), (B,H)
        new = carry * cd[..., None, None] + st_in
        return new, carry                                   # emit state BEFORE chunk

    init = init_state if init_state is not None else jnp.zeros((B, nh, P, N), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (B,nc,H,P,N)

    state_decay = jnp.exp(Acum)                             # (B,nc,Q,H)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", C_c, prev_states, state_decay)

    y = (y_diag + y_off).reshape(B, S, nh, P)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = _gated_rmsnorm(params["gate_norm"], y, z)
    out = y @ params["out_proj"]

    cache = MambaCache(conv=conv_tail.astype(jnp.bfloat16), state=final_state)
    return out, cache


def ssd_decode_step(params: Dict, cfg: SSMConfig, d_model: int, x: jax.Array,
                    cache: MambaCache):
    """One-token recurrence. x: (B, 1, d_model) → (y (B,1,d_model), cache)."""
    B = x.shape[0]
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    P, N, G = cfg.head_dim, cfg.d_state, cfg.n_groups

    zxbcdt = x[:, 0] @ params["in_proj"]
    z, xBC, dt_raw = _split_proj(zxbcdt, d_model, cfg)

    conv = jnp.concatenate([cache.conv[:, 1:], xBC[:, None, :].astype(cache.conv.dtype)], axis=1)
    xBC = jnp.einsum("bkc,kc->bc", conv, params["conv_w"].astype(conv.dtype))
    xBC = jax.nn.silu(xBC.astype(jnp.float32))

    xh = xBC[..., :di].reshape(B, nh, P)
    Bm = xBC[..., di:di + G * N].reshape(B, G, N)
    Cm = xBC[..., di + G * N:].reshape(B, G, N)
    hpg = nh // G
    Bm = jnp.repeat(Bm, hpg, axis=1)
    Cm = jnp.repeat(Cm, hpg, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                                    # (B,H)

    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt, Bm, xh)
    state = cache.state * dA[..., None, None] + dBx
    y = jnp.einsum("bhn,bhpn->bhp", Cm, state)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, di).astype(x.dtype)
    y = _gated_rmsnorm(params["gate_norm"], y, z)
    out = (y @ params["out_proj"])[:, None, :]
    return out, MambaCache(conv=conv, state=state)
