"""Incremental decode == full forward, for every cache type (KV full, KV
ring/sliding-window, Mamba recurrent state, enc-dec cross-attn, VLM prefix)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (decode_step, forward_train, init_caches, init_params,
                          prefill)
from repro.models.frontend import audio_frame_embeddings, image_patch_embeddings

CASES = ["granite-moe-1b-a400m", "mamba2-130m", "jamba-v0_1-52b",
         "h2o-danube-3-4b", "llava-next-34b", "whisper-tiny",
         "qwen3-moe-80b-a3b"]


@pytest.mark.parametrize("arch", CASES)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S, n_dec = 2, 16, 4
    toks = jax.random.randint(key, (B, S + n_dec), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["audio_embeds"] = audio_frame_embeddings(key, cfg, B)
    if cfg.family == "vlm":
        batch["image_embeds"] = image_patch_embeddings(key, cfg, B)

    # full forward over S + n_dec positions (chunk-divisible for SSD: pad)
    pad = 0
    if cfg.ssm is not None:
        chunk = cfg.ssm.chunk
        total = S + n_dec
        pad = (-total) % chunk
    toks_full = jnp.pad(toks, ((0, 0), (0, pad)))
    full_logits, _ = forward_train(params, cfg, {**batch, "tokens": toks_full},
                                   capacity_factor=8.0)
    img = cfg.num_image_tokens if cfg.family == "vlm" else 0

    caches = init_caches(cfg, B, 64 + img)
    lg, caches, _ = prefill(params, cfg,
                            {**batch, "tokens": toks[:, :S]}, caches,
                            capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full_logits[:, S - 1 + img]),
                               rtol=6e-2, atol=6e-1)
    pos = S + img
    for i in range(n_dec):
        lg, caches, _ = decode_step(params, cfg, toks[:, S + i],
                                    jnp.int32(pos + i), caches,
                                    capacity_factor=8.0)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, S + i + img]),
                                   rtol=6e-2, atol=6e-1)


def test_sliding_window_ring_cache_consistency():
    """Decode far past the window: ring cache must equal full forward with
    the same window mask."""
    cfg = get_config("h2o-danube-3-4b", reduced=True)   # window 64 reduced
    assert cfg.attn.sliding_window == 64
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    B, total = 2, 96                                    # > window
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab_size)
    full_logits, _ = forward_train(params, cfg, {"tokens": toks})
    S = 80
    caches = init_caches(cfg, B, 64)                    # ring of 64 slots
    lg, caches, _ = prefill(params, cfg, {"tokens": toks[:, :S]}, caches)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, S - 1]),
                               rtol=6e-2, atol=6e-1)
    for i in range(S, total - 1):
        lg, caches, _ = decode_step(params, cfg, toks[:, i], jnp.int32(i),
                                    caches)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, i]),
                                   rtol=6e-2, atol=6e-1)
