"""Paper Figs 6–9: TTFT, TPOP, end-to-end latency, throughput vs batch size
for static PTQ / DynaExq / ExpertFlow-style offloading, under the same
device-memory budget.

Compute is measured on CPU; the host↔device transfer costs (the quantity the
paper's comparison is actually about) use the deterministic PCIe model, so
the ordering reflects transfer volume on/off the critical path. DynaExq's
background promotions are charged to the migration stream (off critical
path), offloading's demand misses to the step latency (on critical path) —
the paper's structural distinction."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import clone, trained_model
from benchmarks.hw import PCIE_GBPS
from repro.core import ControllerConfig
from repro.serving import (MoEServer, OffloadConfig, OffloadServer,
                           ServeConfig)

N_NEW = 8
PROMPT = 48


def _run_engine(kind, cfg, params, bs, toks):
    if kind == "offload":
        srv = OffloadServer(cfg, clone(params),
                            OffloadConfig(cache_experts_per_layer=2,
                                          pcie_gbps=PCIE_GBPS),
                            batch=bs, max_len=96)
        out, ttft, times = srv.generate({"tokens": toks}, N_NEW)
        return ttft, times, srv.stats["stall_s"]
    mode = "static" if kind == "static" else "dynaexq"
    srv = MoEServer(cfg, clone(params),
                    ServeConfig(mode=mode, lo_bits=4, n_hi_per_layer=2,
                                max_len=96,
                                controller=ControllerConfig(
                                    update_interval_s=0.05,
                                    migration_bytes_per_window=1 << 20)),
                    batch=bs)
    out, ttft, times = srv.generate({"tokens": toks}, N_NEW)
    # DynaExq promotions ride the migration stream: NOT added to latency,
    # but reported (bounded interference).
    moved = sum(c.tm.stats["bytes_moved"] for c in srv.controllers.values())
    return ttft, times, moved / (PCIE_GBPS * 1e9)


def run(report):
    cfg, params, task = trained_model()
    for bs in (1, 4, 8):
        toks = jnp.asarray(task.sample(bs, PROMPT, seed=bs))
        rows = {}
        for kind in ("static", "dynaexq", "offload"):
            # warm-up compile out of the timing
            _run_engine(kind, cfg, params, bs, toks)
            ttft, times, bg = _run_engine(kind, cfg, params, bs, toks)
            tpop = float(np.mean(times))
            p99 = float(np.percentile(times, 99))
            e2e = ttft + float(np.sum(times))
            tput = bs * (N_NEW) / e2e
            rows[kind] = (ttft, tpop, e2e, tput)
            report(f"serving/ttft/{kind}/bs{bs}", ttft * 1e6, round(ttft, 4))
            report(f"serving/tpop/{kind}/bs{bs}", tpop * 1e6, round(p99, 4))
            report(f"serving/e2e/{kind}/bs{bs}", e2e * 1e6, round(e2e, 4))
            report(f"serving/throughput_tps/{kind}/bs{bs}", 0.0,
                   round(tput, 2))
        report(f"serving/dynaexq_vs_offload_tput_x/bs{bs}", 0.0,
               round(rows["dynaexq"][3] / rows["offload"][3], 2))
