"""Paged KV-cache pool: block accounting for the serving engine.

The engine's KV memory is one preallocated device pool of fixed-size blocks
(``block_tokens`` cache positions each, across every attention layer of the
stack at once — one physical block id addresses the same block index in all
(position, superblock) pools). This module is the HOST-side half of the
subsystem: a constant-time free list, per-block refcounts, copy-on-write
resolution, and byte accounting against the engine's ``BudgetTracker``
(see ``repro.core.budget``), so KV admission and expert hi-tier promotions
draw from one envelope. The DEVICE half (the physical arrays and the
gather-by-block-table attention) lives in ``repro.models.layers`` /
``repro.kernels.flash_decode``.

Admission control is quota-based, the paper's feasibility-by-construction
style: a request reserves its worst-case block count up front
(``try_reserve_quota``); every later allocation — lazy appends during
decode, COW copies when a shared block diverges — draws from that quota and
therefore can never fail mid-request. Physical bytes stay reserved for as
long as a block is referenced by ANY lease or by the prefix trie; freeing
the last reference returns both the block and its bytes.

Block 0 is the **trash block**: permanently allocated, never leased. Vacant
continuous-batching rows (and masked write lanes) scatter into it so the
jitted forwards keep static shapes without corrupting live blocks.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

TRASH_BLOCK = 0


class KVBlockPool:
    """Free list + refcounts + budget ledger over ``n_blocks`` KV blocks."""

    def __init__(self, n_blocks: int, block_tokens: int, block_bytes: int,
                 budget=None, reclaim: Optional[Callable[[int], int]] = None):
        """``budget``: optional BudgetTracker/BudgetView charged
        ``block_bytes`` per in-use block and per outstanding quota block.
        ``reclaim(need)``: callback invoked when the free list runs dry —
        typically the prefix trie's evictor — returning how many blocks it
        freed back into this pool."""
        if n_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (trash + one usable)")
        if block_tokens < 1:
            raise ValueError("block_tokens must be >= 1")
        self.n_blocks = int(n_blocks)
        self.block_tokens = int(block_tokens)
        self.block_bytes = int(block_bytes)
        self.budget = budget
        self.reclaim = reclaim
        self.refcount = np.zeros(self.n_blocks, np.int64)
        self.refcount[TRASH_BLOCK] = 1          # never leased, never freed
        self._free: List[int] = list(range(self.n_blocks - 1, 0, -1))
        self.quota_blocks = 0                   # pre-reserved, not yet alloc'd
        self.stats = {"allocs": 0, "frees": 0, "cow": 0, "retains": 0,
                      "reclaimed": 0, "quota_denied": 0, "unwinds": 0}
        if self.budget is not None and \
                not self.budget.try_reserve(self.block_bytes):
            raise MemoryError("KV pool: budget cannot cover the trash block")

    # -- introspection ---------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        """Leased/shared blocks (excluding the trash block)."""
        return self.n_blocks - 1 - len(self._free)

    @property
    def capacity_bytes(self) -> int:
        return self.n_blocks * self.block_bytes

    @property
    def bytes_in_use(self) -> int:
        """Bytes currently reserved: live blocks + outstanding quota +
        trash."""
        return (self.blocks_in_use + self.quota_blocks + 1) * self.block_bytes

    # -- quota (admission control) ---------------------------------------
    def try_reserve_quota(self, n_blocks: int) -> bool:
        """Reserve bytes for ``n_blocks`` worst-case future allocations.
        This is the admission gate: a granted quota guarantees every later
        ``alloc``/COW for the request succeeds. Under byte pressure the
        prefix cache yields first: blocks held only by the trie are
        reclaimed (freeing their bytes) before admission is refused."""
        need = n_blocks * self.block_bytes
        if self.budget is not None and not self.budget.try_reserve(need):
            if self.reclaim is not None:
                short = -(-max(0, need - self.budget.free)
                          // self.block_bytes)
                self.reclaim(short)
            if not self.budget.try_reserve(need):
                self.stats["quota_denied"] += 1
                return False
        self.quota_blocks += n_blocks
        return True

    def release_quota(self, n_blocks: int) -> None:
        if n_blocks > self.quota_blocks:
            raise RuntimeError("released more quota than reserved")
        self.quota_blocks -= n_blocks
        if self.budget is not None:
            self.budget.release(n_blocks * self.block_bytes)

    # -- block lifecycle -------------------------------------------------
    def alloc(self) -> int:
        """Pop a free block, transferring one quota block's bytes onto it.
        The caller must hold quota (see ``KVLease``)."""
        if self.quota_blocks <= 0:
            raise RuntimeError("alloc without quota — admission control bug")
        if not self._free and self.reclaim is not None:
            self.reclaim(1)
        if not self._free:
            raise RuntimeError(
                "KV pool exhausted with quota outstanding — sizing bug "
                f"(n_blocks={self.n_blocks})")
        blk = self._free.pop()
        self.refcount[blk] = 1
        self.quota_blocks -= 1                  # bytes move quota → block
        self.stats["allocs"] += 1
        return blk

    def retain(self, blk: int) -> None:
        """Add a reference (prefix hit / trie registration)."""
        if blk == TRASH_BLOCK or self.refcount[blk] <= 0:
            raise RuntimeError(f"retain of dead block {blk}")
        self.refcount[blk] += 1
        self.stats["retains"] += 1

    def release(self, blk: int) -> bool:
        """Drop one reference; returns True when the block was freed (its
        bytes return to the budget)."""
        if blk == TRASH_BLOCK:
            raise RuntimeError("release of the trash block")
        if self.refcount[blk] <= 0:
            raise RuntimeError(f"double free of block {blk}")
        self.refcount[blk] -= 1
        if self.refcount[blk] == 0:
            self._free.append(blk)
            if self.budget is not None:
                self.budget.release(self.block_bytes)
            self.stats["frees"] += 1
            return True
        return False

    def check_invariants(self) -> None:
        assert self.refcount[TRASH_BLOCK] == 1
        assert (self.refcount >= 0).all()
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "free list duplicates"
        for blk in range(1, self.n_blocks):
            assert (self.refcount[blk] == 0) == (blk in free_set), blk
        assert self.quota_blocks >= 0
        if self.budget is not None:
            assert self.budget.used == self.bytes_in_use, \
                (self.budget.used, self.bytes_in_use)


class KVLease:
    """One request's view of the pool: a logical-block → physical-block
    table plus the quota that funds its future allocations.

    ``ensure(j)`` is the single write-side entry point: it returns the
    physical block that logical block ``j`` may be WRITTEN through, resolving
    lazily-unallocated blocks (fresh alloc) and shared blocks (copy-on-write:
    a fresh alloc plus a ``(src, dst)`` device-copy obligation the engine
    batches before the forward).
    """

    def __init__(self, pool: KVBlockPool, n_logical: int, quota_blocks: int):
        self.pool = pool
        self.table = np.full(n_logical, -1, np.int32)
        self.quota = quota_blocks              # lease's share of pool quota
        self.closed = False

    def adopt_prefix(self, blocks: Sequence[int],
                     retained: bool = False) -> None:
        """Map a trie hit: share ``blocks`` as logical blocks 0..len-1.
        ``retained=True`` when the caller already holds the references
        (pinned before a reclaim-capable operation, e.g. the quota
        reservation) — the lease takes ownership of them."""
        for j, blk in enumerate(blocks):
            if self.table[j] != -1:
                raise RuntimeError("adopt over an occupied logical block")
            if not retained:
                self.pool.retain(int(blk))
            self.table[j] = int(blk)

    def _alloc(self) -> int:
        if self.quota <= 0:
            raise RuntimeError("lease quota exhausted — quota sizing bug")
        blk = self.pool.alloc()
        self.quota -= 1
        return blk

    def ensure(self, j: int) -> Tuple[int, int]:
        """Make logical block ``j`` privately writable. Returns
        ``(phys, cow_src)`` where ``cow_src`` is -1 (no copy needed) or the
        physical block whose contents must be copied into ``phys`` before
        the next write."""
        blk = int(self.table[j])
        if blk >= 0 and self.pool.refcount[blk] == 1:
            return blk, -1
        cow_src = -1
        if blk >= 0:                            # shared → copy-on-write
            # Release OUR reference before allocating: if the only other
            # holder is the prefix trie, the allocator may reclaim (evict)
            # this very block and hand it straight back — then the "copy"
            # degenerates to keeping the now-private block, which is
            # exactly right. Allocating first would pin the block behind
            # our own refcount and could exhaust a correctly-sized pool.
            cow_src = blk
            self.pool.release(blk)
            self.pool.stats["cow"] += 1
        new = self._alloc()
        self.table[j] = new
        if new == cow_src:
            cow_src = -1                        # self-copy is a no-op
        return new, cow_src

    def unwind(self, j: int) -> None:
        """Give logical block ``j`` back (speculative-rewind path): the
        block held only REJECTED positions, so it returns to the pool and
        its bytes move back onto this lease's quota — a later write at the
        same position re-allocates without a new admission decision.

        COW-safety: only privately-owned blocks may unwind. A block the
        rewind would release back while shared (adopted prefix, trie
        registration) was never allocated BY the burst in the first place —
        the engine only unwinds blocks it saw ``ensure`` freshly allocate,
        and those always carry exactly one reference."""
        blk = int(self.table[j])
        if blk < 0:
            raise RuntimeError(f"unwind of unallocated logical block {j}")
        if self.pool.refcount[blk] != 1:
            raise RuntimeError(
                f"unwind of shared block {blk} (refcount "
                f"{int(self.pool.refcount[blk])}) — only burst-fresh "
                f"private blocks may rewind")
        self.pool.release(blk)                  # frees the block's bytes
        self.table[j] = -1
        # Re-fund the quota with the bytes the release just returned; this
        # cannot fail — the budget has at least block_bytes free now.
        if self.pool.budget is not None and \
                not self.pool.budget.try_reserve(self.pool.block_bytes):
            raise RuntimeError("unwind could not re-reserve quota bytes")
        self.pool.quota_blocks += 1
        self.quota += 1
        self.pool.stats["unwinds"] += 1

    def blocks(self) -> List[int]:
        return [int(b) for b in self.table if b >= 0]

    def close(self) -> None:
        """Release every reference and the unspent quota."""
        if self.closed:
            return
        for j, blk in enumerate(self.table):
            if blk >= 0:
                self.pool.release(int(blk))
                self.table[j] = -1
        if self.quota:
            self.pool.release_quota(self.quota)
            self.quota = 0
        self.closed = True
