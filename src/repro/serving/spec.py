"""Self-speculative decoding on the low-precision fallback tier.

DynaExq keeps an always-resident lo-precision copy of every expert — the
fallback the hi pool demotes onto. That tier is also a free draft model:
running the whole MoE with **all-lo expert banks** is exactly the cheap
approximate forward speculative decoding needs, so quantization buys
throughput, not just footprint. No draft weights are materialized anywhere:
the draft bank reuses the target ``ExpertBankQ`` buffers with every
``slot_owner`` pointed at -1 (lo fallback), which keeps the same pytree
structure and therefore reuses the already-compiled decode executables.

One speculative round per engine step:

1. **draft** — ``k`` greedy tokens per row from ONE dispatch
   (``models.spec_draft``: chained decode steps under a ``lax.scan``) with
   the all-lo banks;
2. **verify** — all ``k+1`` positions in ONE multi-token dispatch
   (``models.spec_verify``) against the mixed-precision banks. Each verify
   position runs the *decode-step math itself* (same attention reduction,
   same per-step MoE capacity), so a verified prefix is bit-identical to
   what the non-speculative engine would have computed — token parity by
   construction, the same way paged attention shares ``_attend_cache`` with
   the dense path;
3. **accept** — standard rejection sampling against each request's
   ``SamplingParams`` (greedy draft ⇒ accept probability ``p(d)``, residual
   ``p`` with ``d`` removed), so the output distribution provably matches
   the target model; ``temperature == 0`` degenerates to exact
   argmax-agreement and the emitted tokens equal the non-speculative
   greedy path's;
4. **rewind** — rejected positions roll back: per-lease write positions
   retreat, paged blocks that only ever held rejected positions return to
   the pool (``KVLease.unwind``, COW-safe), sliding-window ring slots
   restore their pre-burst contents from a snapshot, and mamba recurrent
   state rolls back to the last accepted step via the per-step states the
   verify scan stacked (snapshot/restore around the draft burst keeps
   mixed mamba+attention stacks exact).

Hotness hygiene: ONLY verify-pass router counts for ACCEPTED steps reach
``backend.observe`` — draft traffic and rejected positions never distort
promotion decisions.

Draft depth adapts from each REQUEST's own acceptance-rate EMA over a
power-of-two ladder (compiles stay O(log k_max), the bucket idiom admission
uses). Row-local adaptation is a determinism guarantee, not just a tuning
choice: a request's burst boundaries — and therefore which counter-keyed
PRNG draws its sampled decode consumes — depend only on its own history,
never on which neighbors share the batch.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ver import ExpertBankQ
from repro.models import spec_draft, spec_verify
from repro.models.model import DecodeCaches
from repro.serving.sampler import (STREAM_ACCEPT, STREAM_BONUS,
                                   STREAM_RESIDUAL, RequestSampler,
                                   categorical, sampling_probs)


# Module-level jits with the frozen ArchConfig static, like the engine's
# decode wrappers: every engine for the same config shares compilations.
# None of these donate their cache operands — the round holds live
# references (the SSM snapshot aliases the pre-draft caches, the engine's
# ``self.caches`` still points at them until the round commits), and a
# donated buffer dies even while referenced.

@functools.partial(jax.jit, static_argnames=("cfg", "capacity_factor",
                                             "moe_dispatch", "row_capacity"))
def _draft_jit(params, token, pos, caches, banks, row_valid, *, cfg,
               capacity_factor, moe_dispatch=None, row_capacity=None):
    return spec_draft(params, cfg, token, pos, caches, row_valid, bank=banks,
                      capacity_factor=capacity_factor,
                      moe_dispatch=moe_dispatch, row_capacity=row_capacity)


@functools.partial(jax.jit, static_argnames=("cfg", "capacity_factor",
                                             "moe_dispatch", "row_capacity"))
def _draft_paged_jit(params, token, pos, caches, banks, row_valid, table,
                     wblk, woff, *, cfg, capacity_factor,
                     moe_dispatch=None, row_capacity=None):
    return spec_draft(params, cfg, token, pos, caches, row_valid, bank=banks,
                      capacity_factor=capacity_factor,
                      paged={"table": table, "write_blk": wblk,
                             "write_off": woff},
                      moe_dispatch=moe_dispatch, row_capacity=row_capacity)


@functools.partial(jax.jit, static_argnames=("cfg", "capacity_factor",
                                             "moe_dispatch", "row_capacity"))
def _verify_jit(params, tokens, pos, caches, banks, row_valid, *, cfg,
                capacity_factor, moe_dispatch=None, row_capacity=None):
    return spec_verify(params, cfg, tokens, pos, caches, row_valid,
                       bank=banks, capacity_factor=capacity_factor,
                       moe_dispatch=moe_dispatch, row_capacity=row_capacity)


@functools.partial(jax.jit, static_argnames=("cfg", "capacity_factor",
                                             "moe_dispatch", "row_capacity"))
def _verify_paged_jit(params, tokens, pos, caches, banks, row_valid, table,
                      wblk, woff, *, cfg, capacity_factor,
                      moe_dispatch=None, row_capacity=None):
    return spec_verify(params, cfg, tokens, pos, caches, row_valid,
                       bank=banks, capacity_factor=capacity_factor,
                       paged={"table": table, "write_blk": wblk,
                              "write_off": woff},
                       moe_dispatch=moe_dispatch, row_capacity=row_capacity)


# ---- cache-slot snapshot / restore ---------------------------------------
# A draft/verify burst writes cache slots for positions the round may
# REJECT. In a ring cache those writes clobber still-valid old positions
# (slot = pos % C); in a DENSE full cache a row riding past its own depth
# (or its sequence cap) can wrap ``(pos + j) % C`` onto live low slots the
# same way. So every dense attention cache (full and ring alike) snapshots
# the lanes the burst will write and restores (a) ALL lanes between draft
# and verify — verify must read pre-burst contents through its per-step
# validity masks — and (b) every non-accepted lane after acceptance. Paged
# mode only needs this for sliding-window stacks: full-attention paged
# writes go to fresh private blocks (beyond-depth lanes target the trash
# block), and slots past the accepted position are masked out of every
# later read until their rightful token overwrites them.

@jax.jit
def _gather_dense_slots(blocks: Dict, slots):
    """blocks: {pos: KVCache((nsb, B, Hkv, C, hd))}; slots: (B, W) →
    snapshots (nsb, B, Hkv, W, hd) per leaf."""
    def one(a):
        nsb, B, Hkv, _, hd = a.shape
        idx = jnp.broadcast_to(slots[None, :, None, :, None],
                               (nsb, B, Hkv, slots.shape[1], hd))
        return jnp.take_along_axis(a, idx, axis=3)
    return jax.tree_util.tree_map(one, blocks)


@jax.jit
def _restore_dense_slots(blocks: Dict, snap: Dict, slots, mask):
    """Write ``snap`` back into ``slots`` where ``mask`` ((B, W) bool);
    unmasked lanes keep the cache's current value."""
    def one(a, s):
        nsb, B, Hkv, _, hd = a.shape
        W = slots.shape[1]
        idx = jnp.broadcast_to(slots[None, :, None, :, None],
                               (nsb, B, Hkv, W, hd))
        cur = jnp.take_along_axis(a, idx, axis=3)
        vals = jnp.where(mask[None, :, None, :, None], s, cur)
        x = jnp.transpose(vals, (1, 3, 0, 2, 4))        # (B, W, nsb, Hkv, hd)
        b = jnp.arange(B)[:, None]
        return a.at[:, b, :, slots].set(x)
    return jax.tree_util.tree_map(one, blocks, snap)


@jax.jit
def _gather_paged_lanes(blocks: Dict, blk, off):
    """blocks: {pos: PagedKVCache((nsb, N, Hkv, bt, hd))}; blk/off: (B, W)
    physical lanes → snapshots (B, W, nsb, Hkv, hd) per leaf."""
    return jax.tree_util.tree_map(lambda a: a[:, blk, :, off], blocks)


@jax.jit
def _restore_paged_lanes(blocks: Dict, snap: Dict, blk, off, mask):
    def one(a, s):
        cur = a[:, blk, :, off]
        vals = jnp.where(mask[:, :, None, None, None], s, cur)
        return a.at[:, blk, :, off].set(vals)
    return jax.tree_util.tree_map(one, blocks, snap)


@jax.jit
def _select_ssm(stacked: Dict, sel):
    """Per-row rollback of recurrent state: stacked leaves (S, nsb, B, ...)
    from the verify scan, ``sel`` (B,) the per-row accepted step index →
    (nsb, B, ...) leaves holding each row's state after its last accepted
    token."""
    def one(st):
        out = st[sel, :, jnp.arange(sel.shape[0])]       # (B, nsb, ...)
        return jnp.moveaxis(out, 0, 1)
    return jax.tree_util.tree_map(one, stacked)


def all_lo_banks(banks, cache: Dict):
    """Derive the draft banks: the SAME lo/hi buffers with every hi slot
    disowned, so every expert serves from the always-resident lo tier.
    ``cache`` memoizes the constant all(-1) owner arrays per MoE position
    (bank objects are mutated in place by the transition manager, so the
    derivation re-reads them every round — it is a handful of array refs)."""
    if banks is None:
        return None
    out = {}
    for k, b in banks.items():
        if isinstance(b, ExpertBankQ):
            neg = cache.get(k)
            if neg is None:
                neg = cache[k] = jnp.full_like(b.slot_owner, -1)
            out[k] = dataclasses.replace(b, slot_owner=neg)
        else:
            out[k] = b
    return out


def accept_burst(sampler: RequestSampler, drafts: np.ndarray,
                 target_logits: Optional[np.ndarray],
                 target_top: Optional[np.ndarray] = None
                 ) -> Tuple[int, List[int]]:
    """Rejection-sample one row's burst. ``drafts``: (d,) draft tokens;
    ``target_logits``: (d+1, V) f32 verify logits (``target_logits[j]`` is
    the target distribution for the token after consuming ``drafts[:j]``).
    A greedy request only needs ``target_top`` ((d+1,) device-side argmax
    of the verify logits) — the engine then never ships the full (W, B, V)
    logits to host on the greedy fast path.

    Returns ``(n_accepted, emitted)`` where ``emitted`` is the accepted
    prefix plus exactly one target-sampled token (the correction on
    rejection, the bonus on full acceptance) — so every round emits at
    least one token and the output distribution matches sampling from the
    target one token at a time. The draft proposal is greedy (a point mass
    at ``d``): accept with probability ``p(d)``; the residual is ``p`` with
    ``d`` removed, renormalized."""
    sp = sampler.sp
    d = int(drafts.shape[0])
    out: List[int] = []
    a = 0
    if sp.greedy:
        if target_top is None:
            target_top = np.argmax(target_logits, axis=-1)
        for j in range(d):
            t = int(target_top[j])
            out.append(t)
            if t != int(drafts[j]):
                return a, out                      # correction token
            a += 1
        out.append(int(target_top[d]))             # bonus token
        return a, out
    rnd = sampler.spec_round
    for j in range(d):
        p = sampling_probs(target_logits[j], sp)
        dj = int(drafts[j])
        if sampler.uniform(STREAM_ACCEPT, rnd, j) < p[dj]:
            out.append(dj)
            a += 1
            continue
        q = p.copy()
        q[dj] = 0.0
        s = q.sum()
        if s <= 0.0:                               # p was (numerically) 1_d
            masked = np.array(target_logits[j], np.float64)
            masked[dj] = -np.inf
            out.append(int(np.argmax(masked)))
        else:
            out.append(categorical(q / s,
                                   sampler.uniform(STREAM_RESIDUAL, rnd, j)))
        return a, out
    out.append(categorical(sampling_probs(target_logits[d], sp),
                           sampler.uniform(STREAM_BONUS, rnd)))
    return a, out


class SpecDecoder:
    """Per-engine speculative-decoding orchestrator (built by the engine
    when ``EngineConfig.spec_k > 0``). Owns the adaptive draft depth, the
    draft-bank derivation, and the round statistics the engine surfaces
    through ``stats()``."""

    def __init__(self, engine):
        self.eng = engine
        k_max = int(engine.ecfg.spec_k)
        if engine._attn_pos and engine.cfg.attn.sliding_window is not None:
            # A burst that wraps the ring would overwrite its own accepted
            # slots; keep the whole burst inside one window.
            k_max = min(k_max, engine._C_attn - 1)
        self.k_max = max(1, k_max)
        ladder, v = [], 1
        while v < self.k_max:
            ladder.append(v)
            v *= 2
        ladder.append(self.k_max)
        self.ladder = ladder                       # power-of-two k buckets
        self.adaptive = bool(engine.ecfg.spec_adaptive)
        self.ema_alpha = 0.25
        self.ema = 0.75                            # aggregate (telemetry)
        self._neg_owner_cache: Dict = {}
        self.rounds = 0
        self.row_rounds = 0              # (round, active row) pairs
        self.draft_total = 0
        self.accepted_total = 0
        self.verified_total = 0

    # ------------------------------------------------------------------
    def _pick_k(self, ema: float) -> int:
        """Largest ladder depth an acceptance EMA supports: the expected
        accepted run of a per-token acceptance rate r is r/(1-r) — there is
        no point drafting much deeper than the run that survives. Depth is
        chosen from each REQUEST's own EMA (``handle.spec_ema``): row-local
        adaptation keeps a request's burst boundaries — and therefore its
        sampling-PRNG stream consumption — independent of batch
        composition."""
        target = ema / max(1e-6, 1.0 - ema)
        k = 1
        for v in self.ladder:
            if v <= max(1.0, target):
                k = v
        return min(k, self.k_max)

    def stats(self) -> Dict[str, float]:
        return {
            "spec_rounds": float(self.rounds),
            "spec_row_rounds": float(self.row_rounds),
            "draft_tokens": float(self.draft_total),
            "verified_tokens": float(self.verified_total),
            "accept_rate": (self.accepted_total / self.draft_total)
            if self.draft_total else 0.0,
        }

    # ------------------------------------------------------------------
    def round(self, active, finished) -> bool:
        """Run one draft/verify round over the active rows. Returns False
        (caller falls back to the plain single-token step) when no row has
        speculation headroom — e.g. every request needs just one more
        token, or sits one position from its sequence cap."""
        eng = self.eng
        B = eng.ecfg.max_slots
        depth = np.zeros(B, np.int64)
        for i, h in active:
            rem = h.request.max_new_tokens - len(h.tokens)
            k_h = self._pick_k(h.spec_ema) if self.adaptive else self.k_max
            depth[i] = max(0, min(k_h, rem - 1,
                                  eng.ecfg.max_len - 1 - int(eng.pos[i])))
        k = int(depth.max())
        if k <= 0:
            return False
        # Round the scan length UP to the ladder so the draft/verify
        # executables only ever compile at O(log k_max) shapes — per-row
        # clamps (a request nearing its token budget) would otherwise leak
        # arbitrary k values into fresh whole-model compilations. The
        # step-validity mask neutralizes the padded steps, the same way
        # admission bucketing pads prompts.
        k = next(v for v in self.ladder if v >= k)
        W = k + 1
        # The round may cover a SUBSET of occupied rows (the scheduler's
        # spec tier): row_valid masks dispatch/counts to the rows this
        # round advances, while ``occupied`` — every slot holding a request,
        # active in this round or not — drives the snapshot/restore masks:
        # a burst lane that wraps the ring can clobber ANOTHER group's live
        # slot, so non-active occupied rows restore all their lanes.
        row_valid = np.zeros(B, bool)
        for i, _ in active:
            row_valid[i] = True
        occupied = np.asarray([h is not None for h in eng.slots], bool)
        # Step j of the burst is real for row i iff j <= depth[i]; rows past
        # their depth (and vacant rows) ride along masked out of MoE
        # dispatch and every count.
        step_valid = row_valid[None, :] & \
            (np.arange(W)[:, None] <= depth[None, :])
        t0 = time.perf_counter()
        pos0 = eng.pos.copy()

        # ---- resolve paged write lanes up front (alloc + COW) ----------
        table = wblk = woff = None
        fresh: Dict[int, List[int]] = {}
        if eng.pool is not None:
            wblk = np.zeros((W, B), np.int32)      # beyond-depth → trash
            woff = np.zeros((W, B), np.int32)
            cows: List[Tuple[int, int]] = []
            for i, h in active:
                seen: List[int] = []
                for j in range(int(depth[i]) + 1):
                    p = int(pos0[i]) + j
                    jb = (p % eng._C_pad) // eng._bt
                    was_free = int(h.lease.table[jb]) < 0
                    wblk[j, i], woff[j, i] = eng._ensure_write(
                        h.lease, p, cows)
                    if was_free and jb not in seen:
                        seen.append(jb)
                fresh[i] = seen
            eng._apply_copies(cows)
            table = eng._block_tables()

        # ---- snapshots --------------------------------------------------
        # Dense caches always snapshot (a burst lane can wrap onto a live
        # slot whenever a row rides past its own depth or sequence cap);
        # paged pools only for sliding-window rings (full-attention paged
        # lanes target fresh private blocks or the trash block).
        restore = bool(eng._attn_pos) and \
            (eng.pool is None or eng.cfg.attn.sliding_window is not None)
        snap = slots_bw = blk_bw = off_bw = None
        if restore:
            attn_now = {p: eng.caches.blocks[p] for p in eng._attn_pos}
            if eng.pool is not None:
                blk_bw = jnp.asarray(np.ascontiguousarray(wblk.T))
                off_bw = jnp.asarray(np.ascontiguousarray(woff.T))
                snap = _gather_paged_lanes(attn_now, blk_bw, off_bw)
            else:
                C = eng._C_attn
                slots_bw = jnp.asarray(
                    ((pos0[:, None] + np.arange(W)[None, :]) % C)
                    .astype(np.int32))
                snap = _gather_dense_slots(attn_now, slots_bw)
        # Mamba state snapshot is free: jax arrays are immutable, holding
        # the pre-burst references IS the snapshot.
        ssm_snap = {p: eng.caches.blocks[p] for p in eng._mamba_pos}

        # ---- draft: k chained greedy steps, all-lo banks, one dispatch --
        # The draft rides the SAME dispatch layout as the target decode:
        # under "ragged" every draft step streams only active experts' lo
        # codes through the fused kernel — no separate all-lo GEMM path.
        dbanks = all_lo_banks(eng.banks, self._neg_owner_cache)
        cf = eng.ecfg.capacity_factor
        md = eng.moe_dispatch
        rc = eng._row_cap_decode
        if eng.pool is not None:
            drafted_dev, caches = _draft_paged_jit(
                eng.params, jnp.asarray(eng.tokens), jnp.asarray(pos0),
                eng.caches, dbanks, jnp.asarray(step_valid[1:]),
                jnp.asarray(table), jnp.asarray(wblk[:k]),
                jnp.asarray(woff[:k]), cfg=eng.cfg, capacity_factor=cf,
                moe_dispatch=md, row_capacity=rc)
        else:
            drafted_dev, caches = _draft_jit(
                eng.params, jnp.asarray(eng.tokens), jnp.asarray(pos0),
                eng.caches, dbanks, jnp.asarray(step_valid[1:]),
                cfg=eng.cfg, capacity_factor=cf,
                moe_dispatch=md, row_capacity=rc)
        drafted = np.asarray(drafted_dev)          # (k, B)

        # ---- rewind the draft's side effects before verify --------------
        blocks = dict(caches.blocks)
        blocks.update(ssm_snap)                    # restore recurrent state
        caches = DecodeCaches(blocks=blocks, cross=None)
        if restore:
            all_mask = jnp.asarray(
                np.broadcast_to(occupied[:, None], (B, W)).copy())
            attn_sub = {p: caches.blocks[p] for p in eng._attn_pos}
            if eng.pool is not None:
                attn_sub = _restore_paged_lanes(attn_sub, snap, blk_bw,
                                                off_bw, all_mask)
            else:
                attn_sub = _restore_dense_slots(attn_sub, snap, slots_bw,
                                                all_mask)
            caches = DecodeCaches(blocks={**caches.blocks, **attn_sub},
                                  cross=None)

        # ---- verify: k+1 positions, target banks, one dispatch ----------
        vtoks = np.concatenate([eng.tokens[None, :], drafted], axis=0)
        if eng.pool is not None:
            logits_dev, caches, counts_dev, ssm_stack = _verify_paged_jit(
                eng.params, jnp.asarray(vtoks), jnp.asarray(pos0), caches,
                eng.banks, jnp.asarray(step_valid), jnp.asarray(table),
                jnp.asarray(wblk), jnp.asarray(woff), cfg=eng.cfg,
                capacity_factor=cf, moe_dispatch=md, row_capacity=rc)
        else:
            logits_dev, caches, counts_dev, ssm_stack = _verify_jit(
                eng.params, jnp.asarray(vtoks), jnp.asarray(pos0), caches,
                eng.banks, jnp.asarray(step_valid), cfg=eng.cfg,
                capacity_factor=cf, moe_dispatch=md, row_capacity=rc)
        logits_dev.block_until_ready()
        dt = time.perf_counter() - t0
        # Greedy fast path: only the (W, B) device-side argmax crosses to
        # host; full (W, ·, V) f32 logits ship only for the rows that
        # genuinely sample (gathered on device first, so greedy neighbors
        # in a mixed batch stay off the transfer).
        top = np.asarray(jnp.argmax(logits_dev, -1), np.int32)   # (W, B)
        samp_rows = [i for i, h in active if not h.sampler.greedy]
        samp_logits: Dict[int, np.ndarray] = {}
        if samp_rows:
            sub = np.asarray(
                logits_dev[:, jnp.asarray(samp_rows, jnp.int32)])
            samp_logits = {i: sub[:, j] for j, i in enumerate(samp_rows)}

        # ---- rejection sampling per row ---------------------------------
        # -1 for rows outside this round: the occupied-row restore mask
        # (lane j restored iff j > accepts) then covers ALL their lanes.
        accepts = np.full(B, -1, np.int32)
        emitted: Dict[int, List[int]] = {}
        n_draft = 0
        n_accept = 0
        for i, h in active:
            d = int(depth[i])
            row_logits = samp_logits.get(i)
            a, toks = accept_burst(
                h.sampler, drafted[:d, i],
                None if row_logits is None else row_logits[:d + 1],
                target_top=top[:d + 1, i])
            h.sampler.end_round()
            accepts[i] = a
            emitted[i] = toks
            n_draft += d
            n_accept += a

        # ---- hotness: verify-pass counts of ACCEPTED steps only ---------
        counts_np = {kk: np.asarray(v) for kk, v in counts_dev.items()}
        eng._note_dispatch(counts_np)          # per-verify-step gauges
        accept_mask = row_valid[None, :] & \
            (np.arange(W)[:, None] <= accepts[None, :])        # (W, B)
        obs: Dict[str, np.ndarray] = {}
        for kk, v in counts_np.items():
            if v.ndim == 4:                        # (W, nsb, B, E)
                obs[kk] = (v * accept_mask[:, None, :, None]).sum(axis=0)
            else:                                  # aggregated fallback
                obs[kk] = v.sum(axis=0)
        stall = eng.backend.observe(obs, dt, prefill=False,
                                    row_valid=row_valid)
        eng._stall_clock += stall
        if stall:
            for _, h in active:
                h.stall_exposure_s += stall
        latency = dt + stall
        eng.decode_times.append(latency)
        eng._tpot_ema = latency if eng._tpot_ema == 0.0 else \
            0.9 * eng._tpot_ema + 0.1 * latency
        eng.last_row_counts = obs
        eng.last_counts = {kk: v.sum(axis=1) if v.ndim == 3 else v
                           for kk, v in obs.items()}

        # ---- roll recurrent state back to the last accepted step --------
        if eng._mamba_pos:
            sub = _select_ssm({p: ssm_stack[p] for p in eng._mamba_pos},
                              jnp.asarray(np.maximum(accepts, 0)))
            if bool(np.any(occupied & ~row_valid)):
                # Rows outside this round rode through the verify scan
                # masked — their recurrent state must come back from the
                # pre-round snapshot, not from any scan step.
                act = jnp.asarray(row_valid)
                sub = {
                    p: jnp.where(
                        act.reshape((1, -1) + (1,) * (sub[p].ndim - 2)),
                        sub[p], ssm_snap[p])
                    for p in eng._mamba_pos}
            caches = DecodeCaches(blocks={**caches.blocks, **sub},
                                  cross=None)

        # ---- restore non-accepted lanes ---------------------------------
        if restore:
            rej = jnp.asarray(occupied[:, None] &
                              (np.arange(W)[None, :] > accepts[:, None]))
            attn_sub = {p: caches.blocks[p] for p in eng._attn_pos}
            if eng.pool is not None:
                attn_sub = _restore_paged_lanes(attn_sub, snap, blk_bw,
                                                off_bw, rej)
            else:
                attn_sub = _restore_dense_slots(attn_sub, snap, slots_bw,
                                                rej)
            caches = DecodeCaches(blocks={**caches.blocks, **attn_sub},
                                  cross=None)
        eng.caches = caches

        # ---- release blocks that only held rejected positions -----------
        if eng.pool is not None and eng.cfg.attn.sliding_window is None:
            for i, h in active:
                new_pos = int(pos0[i]) + int(accepts[i]) + 1
                for jb in fresh.get(i, ()):
                    if jb * eng._bt >= new_pos:
                        h.lease.unwind(jb)

        # ---- emit + bookkeeping -----------------------------------------
        eng._tpot_sum += latency * len(active)
        kept_total = 0
        for i, h in active:
            toks = emitted[i]
            n_before = len(h.tokens)
            h.tokens.extend(toks)
            eng.tokens[i] = toks[-1]
            eng.pos[i] += int(accepts[i]) + 1
            # _done may TRUNCATE at a mid-burst EOS: only tokens that
            # survive count toward latency amortization and spec meters.
            done = eng._done(h)
            kept = len(h.tokens) - n_before
            kept_total += kept
            # The round's latency amortizes over every token it emitted
            # for this row — step_times stays per-TOKEN.
            h.step_times.extend([latency / max(1, kept)] * kept)
            if h.expert_counts is not None:
                for kk, v in counts_np.items():
                    if v.ndim == 4 and kk in h.expert_counts:
                        h.expert_counts[kk] += (
                            v[:, :, i].astype(np.int64) *
                            accept_mask[:, i][:, None, None]).sum(axis=0)
            d = int(depth[i])
            if d:
                # Row-local acceptance EMA → row-local draft depth.
                r = int(accepts[i]) / d
                h.spec_ema = (1 - self.ema_alpha) * h.spec_ema + \
                    self.ema_alpha * r
            if done:
                eng._finish(h, finished)
        eng._tpot_tokens += kept_total
        eng.counters["steps"] += 1
        self.rounds += 1
        self.row_rounds += len(active)
        self.draft_total += n_draft
        self.accepted_total += n_accept
        self.verified_total += kept_total
        if eng.tracer is not None:
            eng.tracer.instant("spec_round", cat="engine",
                               rows=len(active), drafted=int(n_draft),
                               accepted=int(n_accept),
                               emitted=int(kept_total))
        if n_draft:
            r = n_accept / n_draft
            self.ema = (1 - self.ema_alpha) * self.ema + self.ema_alpha * r
        return True
