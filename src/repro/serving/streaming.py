"""Streaming cold start: serve before the checkpoint fully materializes.

``training.checkpoint`` stores a flat leaf list — fine for training, useless
for serving an 80B MoE whose first token only needs the router, attention,
and the (4–8× smaller) lo tier. This module defines an **expert-sharded**
layout plus the loaders the residency ladder streams from:

    <root>/manifest.json                 positions, shapes, quantizer meta
    <root>/base/leaf_*.npy               every non-expert param (checkpoint
                                         format, experts pruned)
    <root>/lo/p{pos}_l{layer}.npz        PREPACKED lo rows for one layer —
                                         keys "{name}.packed" (E, K/epb, N)
                                         u8 and "{name}.scales" f32
    <root>/hi/p{pos}_l{layer}_e{e}.npz   one expert's dense rows, f32

Quantization happens at SAVE time, so a cold start reads ``lo_bits/16`` of
the expert bytes before serving — the structural reason streaming TTFT beats
full materialization — and the staged rows are bit-identical to what
``build_bank`` would have produced from the dense weights (temp-0 token
parity with a fully materialized engine).

Cold-start sequence (driven by ``DynaExqBackend`` with ``stream=``):
router/attention load from ``base/`` at construction; the lo tier backfills
via async staged writes in hotness order (restored priors when a hotness
snapshot exists); serving opens the moment ``lo_valid`` is complete; the
hi and host tiers keep backfilling lazily — each promotion's
``ensure_hi`` pulls its shard — so under a tight envelope the dense experts
never fully materialize anywhere.
"""
from __future__ import annotations

import json
import os
import zipfile
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.fault.inject import TransferFault
from repro.quant.qtensor import quantize


def _flatten(tree: Dict, prefix: str = ""):
    for k in sorted(tree):
        v = tree[k]
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            yield from _flatten(v, key + "/")
        elif v is None:
            continue
        else:
            yield key, v


def save_expert_shards(path: str, params: Dict, moe_positions,
                       lo_bits: int = 4, group_size: int = 64) -> None:
    """Write the expert-sharded serving checkpoint. ``params`` must still
    hold dense experts (run before any backend frees them)."""
    os.makedirs(os.path.join(path, "lo"), exist_ok=True)
    os.makedirs(os.path.join(path, "hi"), exist_ok=True)
    os.makedirs(os.path.join(path, "base"), exist_ok=True)
    manifest = {"lo_bits": lo_bits, "group_size": group_size,
                "positions": [], "shapes": {}}
    base_keys = []
    for key, leaf in _flatten(params):
        if "/moe/experts/" in key:
            continue
        arr = np.asarray(leaf)
        meta = {"key": key, "dtype": str(arr.dtype)}
        if arr.dtype.kind not in "biufc":      # bf16 → f32 (lossless)
            arr = arr.astype(np.float32)
        np.save(os.path.join(path, "base",
                             f"leaf_{len(base_keys):05d}.npy"), arr)
        base_keys.append(meta)
    manifest["base"] = base_keys
    for pos in moe_positions:
        pos = str(pos)
        experts = params["blocks"][pos]["moe"]["experts"]
        if experts is None:
            raise ValueError(f"position {pos}: experts already freed")
        names = sorted(experts)
        shapes = {n: list(np.asarray(experts[n]).shape) for n in names}
        manifest["positions"].append(pos)
        manifest["shapes"][pos] = shapes
        L, E = shapes[names[0]][:2]
        packed = {n: quantize(jax.numpy.asarray(experts[n]), bits=lo_bits,
                              group_size=group_size) for n in names}
        for l in range(L):
            rows = {}
            for n in names:
                rows[f"{n}.packed"] = np.asarray(packed[n].packed[l])
                rows[f"{n}.scales"] = np.asarray(
                    packed[n].scales[l], np.float32)
            np.savez(os.path.join(path, "lo", f"p{pos}_l{l}.npz"), **rows)
            for e in range(E):
                np.savez(
                    os.path.join(path, "hi", f"p{pos}_l{l}_e{e}.npz"),
                    **{n: np.asarray(experts[n][l, e], np.float32)
                       for n in names})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def load_streaming_params(path: str) -> Dict:
    """Rebuild the params tree from ``base/`` with every MoE position's
    ``experts`` left as ``None`` — the banks stream in behind it. This is
    the ONLY synchronous read of a cold start."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    params: Dict = {}
    for i, meta in enumerate(manifest["base"]):
        arr = np.load(os.path.join(path, "base", f"leaf_{i:05d}.npy"))
        leaf = jax.numpy.asarray(arr).astype(meta["dtype"])
        node = params
        parts = meta["key"].split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    for pos in manifest["positions"]:
        params["blocks"][pos]["moe"]["experts"] = None
    return params


class ShardSource:
    """Loader half of the streaming cold start: per-layer prepacked lo rows
    and per-expert dense hi rows, with read accounting (the benchmark's
    bytes-before-first-token comes from here)."""

    def __init__(self, path: str):
        self.path = path
        with open(os.path.join(path, "manifest.json")) as f:
            self.manifest = json.load(f)
        self.lo_bits = int(self.manifest["lo_bits"])
        self.group_size = int(self.manifest["group_size"])
        self.positions: List[str] = list(self.manifest["positions"])
        self.stats = {"lo_reads": 0, "hi_reads": 0, "bytes_read": 0,
                      "fault_stall_s": 0.0}
        # Fault injection (``shard_lo``/``shard_hi`` sites): missing and
        # corrupt npz files — injected or real — surface as retryable
        # `TransferFault`s; the retry loop lives in the consuming
        # ``HostExpertStore`` loaders.
        self.injector = None

    def shapes(self, pos) -> Dict[str, tuple]:
        return {n: tuple(s)
                for n, s in self.manifest["shapes"][str(pos)].items()}

    def _fire(self, site: str, **ctx) -> None:
        if self.injector is None:
            return
        f = self.injector.fire(site, **ctx)
        if f is None:
            return
        if f.kind == "stall":
            self.stats["fault_stall_s"] += f.stall_s   # modeled slow read
            return
        raise TransferFault(site, kind=f.kind, seq=f.seq)

    def _read_npz(self, site: str, path: str) -> Dict[str, np.ndarray]:
        try:
            with np.load(path) as z:
                return {k: z[k] for k in z.files}
        except (OSError, ValueError, zipfile.BadZipFile, KeyError) as e:
            # Missing or corrupt shard on disk: same retryable surface as
            # an injected fault, so one degradation path covers both.
            raise TransferFault(site, detail=f"{path}: {e}") from e

    def lo_layer(self, pos, layer: int) -> Dict[str, np.ndarray]:
        self._fire("shard_lo", pos=str(pos), layer=layer)
        rows = self._read_npz("shard_lo", os.path.join(
            self.path, "lo", f"p{pos}_l{layer}.npz"))
        self.stats["lo_reads"] += 1
        self.stats["bytes_read"] += sum(a.nbytes for a in rows.values())
        return rows

    def hi_expert(self, pos, layer: int, expert: int
                  ) -> Dict[str, np.ndarray]:
        self._fire("shard_hi", pos=str(pos), layer=layer, expert=expert)
        rows = self._read_npz("shard_hi", os.path.join(
            self.path, "hi", f"p{pos}_l{layer}_e{expert}.npz"))
        self.stats["hi_reads"] += 1
        self.stats["bytes_read"] += sum(a.nbytes for a in rows.values())
        return rows

    def load_dense_experts(self, pos) -> Dict[str, jax.Array]:
        """Materialize one position's FULL dense experts from the hi shards
        — the no-streaming baseline path (reads every shard upfront; the
        cold-start benchmark measures exactly this against streaming)."""
        shapes = self.shapes(pos)
        names = sorted(shapes)
        L, E = shapes[names[0]][:2]
        out = {n: np.zeros(tuple(shapes[n]), np.float32) for n in names}
        for l in range(L):
            for e in range(E):
                rows = self.hi_expert(pos, l, e)
                for n in names:
                    out[n][l, e] = rows[n]
        return {n: jax.numpy.asarray(a, jax.numpy.bfloat16)
                for n, a in out.items()}


def hotness_stage_order(scores: Optional[np.ndarray], L: int,
                        E: int) -> List[tuple]:
    """Cold-start staging order for one position's (layer, expert) cells:
    hottest-first when a restored hotness snapshot exists (previous run's
    traffic), deterministic row-major otherwise."""
    if scores is None or scores.shape != (L, E) or not scores.any():
        return [(l, e) for l in range(L) for e in range(E)]
    flat = np.argsort(-scores.reshape(-1), kind="stable")
    return [(int(i) // E, int(i) % E) for i in flat]
