"""Residency hierarchy (ISSUE 8): streaming cold start vs full materialize,
and global vs per-layer allocation under a shifting hot set.

Two structural claims, measured on the shared trained bench model:

* **Streaming TTFT < full-materialize TTFT.** The full path quantizes every
  expert and fills the hi pool before the first forward; the streaming path
  builds an empty bank, backfills prepacked lo rows from the expert-sharded
  checkpoint, and serves the moment the lo tier completes (hi promotions
  come later, driven by real traffic). Both TTFTs are wall-clock from
  "checkpoint in hand" to the first emitted token, with jit compilation
  warmed beforehand so the comparison is residency work, not XLA. The
  ordering is asserted, not just reported.

* **Transfer spend under a workload shift, global vs per-layer.** When the
  hot set migrates (text → math → code prompts draw from disjoint vocab
  slices), the per-layer top-n rule re-ranks every layer against its own
  fixed quota while the global knapsack funds any swap that beats the
  margin anywhere in the model — including cross-layer moves the per-layer
  rule cannot express. Both policies' ``bytes_moved`` / promotion counts
  land side by side so the trade is machine-comparable across PRs.

Rows land in ``experiments/BENCH_hierarchy.json``. ``BENCH_SMOKE=1``
shrinks the sweep.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import time

import jax

from benchmarks.common import (BENCH_SMOKE, bench_backend, bench_config,
                               clone, trained_model)
from repro.core import ControllerConfig
from repro.models import init_params
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           load_streaming_params, make_backend, make_prompts,
                           save_expert_shards)

N_NEW = 3 if BENCH_SMOKE else 8
PROMPT = 32
TRIALS = 3
SHIFT_ROUNDS = 1 if BENCH_SMOKE else 3
JSON_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "BENCH_hierarchy.json")


def _moe_positions(cfg):
    return [p for p, _ in enumerate(cfg.superblock_or_default())
            if cfg.ffn_kind(p) == "moe"]


def _ttft(cfg, params, backend, toks):
    """Wall-clock from backend materialization to the first token."""
    t0 = time.perf_counter()
    eng = InferenceEngine(cfg, params, backend,
                          EngineConfig(max_slots=1, max_len=96))
    h = eng.submit(Request(tokens=toks[0], max_new_tokens=N_NEW))
    steps = 0
    while not h.tokens:
        eng.step()
        steps += 1
        assert steps < 10_000
    ttft = time.perf_counter() - t0
    eng.drain()
    eng.flush()
    return ttft, eng


def _bench_streaming(report):
    # A wider expert population than the shared bench model: cold-start
    # residency work scales with L×E (quantize-everything vs stage-packed-
    # rows) while the shared prefill/decode cost does not, so the structural
    # gap is measurable above CPU timing noise. Weights are untrained —
    # this figure times residency, not quality.
    cfg = dataclasses.replace(
        bench_config(), name="bench-moe-wide",
        moe=dataclasses.replace(bench_config().moe, num_experts=16))
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = make_prompts("text", cfg.vocab_size, 1, PROMPT)

    def full_backend():
        return bench_backend("dynaexq")

    shard_dir = tempfile.mkdtemp(prefix="repro_shards_")
    try:
        save_expert_shards(shard_dir, clone(params), _moe_positions(cfg),
                           lo_bits=4)

        def stream_backend():
            return make_backend("dynaexq", lo_bits=4, n_hi_per_layer=2,
                                stream=shard_dir, stream_experts_per_tick=64)

        # Warm every jit cache (quantize, row staging, prefill, decode) so
        # the timed runs compare residency work, not XLA compiles.
        for mk, p in ((full_backend, clone(params)),
                      (stream_backend, load_streaming_params(shard_dir))):
            weng = InferenceEngine(cfg, p, mk(),
                                   EngineConfig(max_slots=1, max_len=96))
            weng.generate({"tokens": toks}, 2)
            weng.flush()
            del weng

        full_s = min(_ttft(cfg, clone(params), full_backend(), toks)[0]
                     for _ in range(TRIALS))
        stream_s, seng = float("inf"), None
        for _ in range(TRIALS):
            t0 = time.perf_counter()
            sparams = load_streaming_params(shard_dir)  # part of cold start
            load_s = time.perf_counter() - t0
            s, eng = _ttft(cfg, sparams, stream_backend(), toks)
            if s + load_s < stream_s:
                stream_s, seng = s + load_s, eng
        assert seng.backend.serving_ready()
        assert stream_s < full_s, (
            f"streaming TTFT {stream_s:.3f}s must beat full-materialize "
            f"TTFT {full_s:.3f}s")
        row = {"full_ttft_s": full_s, "stream_ttft_s": stream_s,
               "num_experts": cfg.moe.num_experts,
               "ready_frac": float(seng.backend.ready_frac()),
               "lo_bytes_staged": float(sum(
                   s.stats["lo_bytes_staged"]
                   for s in seng.backend.stores.values()))}
        report("hierarchy/stream_ttft", stream_s * 1e6,
               f"full={full_s*1e3:.1f}ms stream={stream_s*1e3:.1f}ms")
        return row
    finally:
        shutil.rmtree(shard_dir, ignore_errors=True)


def _bench_allocation(cfg, params, report):
    rows = {}
    for mode in ("global", "per_layer"):
        be = make_backend(
            "dynaexq", lo_bits=4, n_hi_per_layer=2,
            global_alloc=(mode == "global"),
            controller=ControllerConfig(update_interval_s=0.0))
        eng = InferenceEngine(cfg, clone(params), be,
                              EngineConfig(max_slots=4, max_len=96))
        for _ in range(SHIFT_ROUNDS):
            for w in ("text", "math", "code"):     # the hot set migrates
                toks = make_prompts(w, cfg.vocab_size, 4, PROMPT)
                for b in range(4):
                    eng.submit(Request(tokens=toks[b],
                                       max_new_tokens=N_NEW))
                eng.drain()
        eng.flush()
        st = be.stats()
        hi = be.hi_sets()
        rows[mode] = {
            "bytes_moved": float(st["bytes_moved"]),
            "promotions": float(st["promotions"]),
            "demotions": float(st["demotions"]),
            "hi_slots": sum(len(s) for sets in hi.values() for s in sets)}
        report(f"hierarchy/shift_{mode}", st["bytes_moved"],
               f"promotions={st['promotions']:.0f}")
    return rows


def run(report) -> None:
    cfg, params, _ = trained_model()
    out = {"streaming": _bench_streaming(report),
           "allocation": _bench_allocation(cfg, params, report)}
    with open(JSON_OUT, "w") as f:
        json.dump(out, f, indent=2)
    report("hierarchy/json", 0.0, JSON_OUT)


if __name__ == "__main__":
    run(lambda n, us, d: print(f"{n},{us:.1f},{d}"))
