"""Symmetric group-wise quantization with sub-byte packing.

Layout contract (used by both the jnp reference path and the Pallas kernels):

* A weight ``w`` with shape ``(..., K, N)`` is quantized along the
  contraction axis ``K``: every ``group_size`` consecutive rows of a column
  share one scale.  ``scales`` has shape ``(..., K // group_size, N)``.
* Integer codes are symmetric, ``q in [-qmax, qmax]`` with
  ``qmax = 2**(bits-1) - 1`` (int2 uses the degenerate-but-useful
  ``[-1, 1]`` two-level-plus-zero code the paper's Int2 tier implies).
* Codes are stored biased (``u = q + 2**(bits-1)``) and packed
  little-endian along ``K``: ``8 // bits`` consecutive K-rows per uint8.
  ``packed`` has shape ``(..., K // elems_per_byte, N)`` dtype uint8.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

SUPPORTED_BITS = (2, 4, 8)


def bits_per_element(bits: int) -> int:
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"unsupported bit-width {bits}; supported: {SUPPORTED_BITS}")
    return bits


def _elems_per_byte(bits: int) -> int:
    return 8 // bits


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class QuantizedTensor:
    """Packed integer weight + per-group scales. A pytree node.

    ``shape`` is the logical (dequantized) shape; ``bits``/``group_size``
    are static metadata (part of the treedef, not traced).
    """

    packed: jax.Array          # uint8, (..., K // epb, N)
    scales: jax.Array          # float32/bf16, (..., K // group_size, N)
    bits: int
    group_size: int
    shape: tuple               # logical (..., K, N)

    def tree_flatten_with_keys(self):
        K = jax.tree_util.GetAttrKey
        return (((K("packed"), self.packed), (K("scales"), self.scales)),
                (self.bits, self.group_size, tuple(self.shape)))

    def tree_flatten(self):
        return (self.packed, self.scales), (self.bits, self.group_size, tuple(self.shape))

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scales = children
        bits, group_size, shape = aux
        return cls(packed=packed, scales=scales, bits=bits, group_size=group_size, shape=shape)

    @property
    def nbytes(self) -> int:
        return quantized_nbytes(self.shape, self.bits, self.group_size)

    def dequantize(self, dtype=jnp.bfloat16) -> jax.Array:
        return dequantize(self, dtype=dtype)


def quantized_nbytes(shape, bits: int, group_size: int, scale_bytes: int = 2) -> int:
    """Device bytes of the packed representation (packed codes + scales)."""
    n_elem = int(np.prod(shape))
    k = shape[-2]
    n_groups = n_elem // shape[-2] * (k // group_size)
    return n_elem * bits // 8 + n_groups * scale_bytes


def pack_bits(u: jax.Array, bits: int) -> jax.Array:
    """Pack biased codes ``u`` (uint8-valued, (..., K, N)) along axis -2."""
    epb = _elems_per_byte(bits)
    if bits == 8:
        return u.astype(jnp.uint8)
    *lead, k, n = u.shape
    if k % epb:
        raise ValueError(f"K={k} not divisible by elems/byte={epb}")
    u = u.astype(jnp.uint8).reshape(*lead, k // epb, epb, n)
    shifts = (jnp.arange(epb, dtype=jnp.uint8) * bits).reshape((1,) * len(lead) + (1, epb, 1))
    word = jnp.sum(
        (u.astype(jnp.uint32) << shifts.astype(jnp.uint32)), axis=-2
    ).astype(jnp.uint8)
    return word  # (..., K // epb, N)


def unpack_bits(packed: jax.Array, bits: int, k: int) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns biased codes (..., K, N) int32."""
    epb = _elems_per_byte(bits)
    if bits == 8:
        return packed.astype(jnp.int32)
    *lead, kp, n = packed.shape
    if kp * epb != k:
        raise ValueError(f"packed K={kp} * epb={epb} != K={k}")
    mask = (1 << bits) - 1
    shifts = (jnp.arange(epb, dtype=jnp.uint32) * bits).reshape((1,) * len(lead) + (1, epb, 1))
    u = (packed.astype(jnp.uint32)[..., :, None, :] >> shifts) & mask
    return u.reshape(*lead, k, n).astype(jnp.int32)


@partial(jax.jit, static_argnames=("bits", "group_size", "scale_dtype"))
def quantize(w: jax.Array, bits: int, group_size: int = 64,
             scale_dtype=jnp.bfloat16) -> QuantizedTensor:
    """Symmetric group-wise quantization of ``w`` (..., K, N) along K."""
    bits_per_element(bits)
    *lead, k, n = w.shape
    if k % group_size:
        raise ValueError(f"K={k} not divisible by group_size={group_size}")
    if group_size % _elems_per_byte(bits):
        raise ValueError(f"group_size={group_size} not divisible by elems/byte")
    qmax = 2 ** (bits - 1) - 1
    wf = w.astype(jnp.float32).reshape(*lead, k // group_size, group_size, n)
    absmax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.round(wf / scale), -qmax, qmax).astype(jnp.int32)
    u = (q + (1 << (bits - 1))).reshape(*lead, k, n)
    packed = pack_bits(u, bits)
    scales = scale.squeeze(-2).astype(scale_dtype)
    return QuantizedTensor(packed=packed, scales=scales, bits=bits,
                           group_size=group_size, shape=tuple(w.shape))


def unpack_codes_int8(packed: jax.Array, bits: int) -> jax.Array:
    """Unpack to CENTERED int8 codes (..., K, N) without widening to int32 —
    the narrow-dtype unpack used by the group-blocked quantized matmul."""
    if bits == 8:
        return (packed.astype(jnp.int16) - 128).astype(jnp.int8)
    epb = _elems_per_byte(bits)
    *lead, kp, n = packed.shape
    mask = jnp.uint8((1 << bits) - 1)
    shifts = (jnp.arange(epb, dtype=jnp.uint8) * bits).reshape(
        (1,) * len(lead) + (1, epb, 1))
    u = (packed[..., :, None, :] >> shifts) & mask
    bias = jnp.int8(1 << (bits - 1))
    return (u.astype(jnp.int8) - bias).reshape(*lead, kp * epb, n)


def dequant_arrays(packed: jax.Array, scales: jax.Array, bits: int,
                   group_size: int, dtype=jnp.bfloat16) -> jax.Array:
    """Dequantize from raw arrays (duck-typed; usable on shard-local views).

    Shapes are derived from the *arrays*, not stored metadata, so a tensor
    whose leading (layer/expert) axes were sliced by lax.scan or shard_map
    still dequantizes correctly."""
    *lead, kp, n = packed.shape
    k = kp * _elems_per_byte(bits)
    u = unpack_bits(packed, bits, k)
    q = u - (1 << (bits - 1))
    qf = q.reshape(*lead, k // group_size, group_size, n).astype(jnp.float32)
    w = qf * scales[..., :, None, :].astype(jnp.float32)
    return w.reshape(*lead, k, n).astype(dtype)


def dequantize(qt, dtype=jnp.bfloat16) -> jax.Array:
    return dequant_arrays(qt.packed, qt.scales, qt.bits, qt.group_size, dtype)
