"""Prefix-sharing KV reuse: hit rate + prefill-tokens-saved.

Shared-prefix serving traffic (every request carries the same system
prompt / few-shot header, then a unique tail) through three engines over
the same trained model:

* ``dense``       — per-slot dense KV rows (the pre-paging engine);
* ``paged``       — block-pool KV, prefix sharing off (paging cost only);
* ``paged+share`` — block pool + prefix trie (the full subsystem).

The structural claim measured here: with sharing on, the engine computes
STRICTLY fewer prefill tokens than the dense engine on the same stream
(trie hits skip the shared prefix entirely), while staying token-identical.
Rows land in ``experiments/BENCH_kv.json`` with the uniform ``stats()``
schema plus the KV gauges (``kv_blocks_in_use``, ``prefix_hit_tokens``,
``prefill_tokens``, ``hit_rate``), so the reuse trajectory is
machine-comparable across PRs. ``BENCH_SMOKE=1`` shrinks the stream.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import BENCH_SMOKE, clone, trained_model
from repro.serving import (EngineConfig, InferenceEngine, Request, STAT_KEYS,
                           make_prompts)

N_REQ = 6 if BENCH_SMOKE else 24
PREFIX_LEN = 48                 # shared system prompt (3 blocks)
TAIL_LEN = 8
N_NEW = 3 if BENCH_SMOKE else 8
JSON_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "BENCH_kv.json")

VARIANTS = {
    "dense": dict(paged=False, prefix_sharing=False),
    "paged": dict(paged=True, prefix_sharing=False),
    "paged_share": dict(paged=True, prefix_sharing=True),
}


def _requests(cfg):
    sysp = make_prompts("text", cfg.vocab_size, 1, PREFIX_LEN, seed=1234)[0]
    out = []
    for i in range(N_REQ):
        tail = make_prompts("math", cfg.vocab_size, 1, TAIL_LEN,
                            seed=10_000 + i)[0]
        out.append(np.concatenate([sysp, tail]))
    return out


def _run(cfg, params, backend_kw):
    from repro.serving import make_backend
    # capacity_factor 8 keeps MoE dispatch drop-free: a capacity-limited
    # router drops tokens as a function of the COMPUTE batch, so skipping
    # prefix tokens legitimately shifts which tokens overflow a tight
    # capacity — parity is only well-defined without drops.
    eng = InferenceEngine(
        cfg, clone(params), make_backend("fp16"),
        EngineConfig(max_slots=4, max_len=96, prefill_rows=2,
                     capacity_factor=8.0, **backend_kw))
    t0 = time.perf_counter()
    handles = [eng.submit(Request(tokens=p, max_new_tokens=N_NEW))
               for p in _requests(cfg)]
    eng.drain()
    wall = time.perf_counter() - t0
    st = eng.stats()
    st["e2e_s"] = wall + st["stall_s"]
    total_prompt = float(N_REQ * (PREFIX_LEN + TAIL_LEN))
    st["prompt_tokens_total"] = total_prompt
    st["hit_rate"] = st.get("prefix_hit_tokens", 0.0) / total_prompt
    return st, [h.tokens for h in handles]


def run(report):
    cfg, params, _task = trained_model()
    results = {"schema": list(STAT_KEYS) + [
                   "e2e_s", "prefill_tokens", "prefix_hit_tokens",
                   "hit_rate", "kv_blocks_in_use", "kv_cow_copies"],
               "smoke": BENCH_SMOKE, "n_requests": N_REQ,
               "prefix_len": PREFIX_LEN, "variants": {}}
    toks = {}
    for name, kw in VARIANTS.items():
        _run(cfg, params, kw)                       # warm-up compile
        st, toks[name] = _run(cfg, params, kw)
        results["variants"][name] = st
        report(f"kv_reuse/prefill_tokens/{name}", 0.0,
               int(st["prefill_tokens"]))
        report(f"kv_reuse/hit_rate/{name}", 0.0, round(st["hit_rate"], 3))
        report(f"kv_reuse/ttft/{name}", st["ttft_s"] * 1e6,
               round(st["ttft_s"], 4))
    if toks["dense"] != toks["paged_share"]:
        raise AssertionError("prefix sharing changed generated tokens")
    saved = (results["variants"]["dense"]["prefill_tokens"] -
             results["variants"]["paged_share"]["prefill_tokens"])
    if saved <= 0:
        raise AssertionError(
            "prefix sharing recomputed no fewer prefill tokens than the "
            f"dense engine ({saved=}) — reuse regressed")
    results["prefill_tokens_saved"] = float(saved)
    report("kv_reuse/prefill_tokens_saved", 0.0, int(saved))
    print(f"kv_reuse: {N_REQ} requests sharing a {PREFIX_LEN}-token prefix "
          f"→ {int(saved)} prefill tokens saved "
          f"(hit rate {results['variants']['paged_share']['hit_rate']:.2f}),"
          f" token-identical to dense")
    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    with open(JSON_OUT, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.normpath(JSON_OUT)}")
