"""Ragged mixed-precision FFN kernel + grouped-GEMM dispatcher parity.

These tests run the Pallas kernels in interpret mode on CPU — the kernel
code paths themselves, not just the jnp fallback (CI pins a dedicated step
on this file). Parity contracts:

* ``ops.grouped_lo_matmul``: the jnp and Pallas backends are the SAME
  group-blocked decomposition (per-group partial dot, scales after) —
  asserted bit-identical.
* ``ops.ragged_quant_ffn_op``: jnp oracle vs Pallas kernel agree to within
  float tolerance (the fused kernel keeps f32 accumulators across K tiles
  where the batched-einsum oracle rounds per call — a ≤1-ulp bf16
  difference in reduction order is expected and accepted).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.quant import quantize


def _mats(E=4, K=128, F=256, D=128, bits=4, seed=0):
    lo, dense = {}, {}
    for i, (name, kk, nn) in enumerate([("w_gate", K, F), ("w_up", K, F),
                                        ("w_down", F, D)]):
        w = jax.random.normal(jax.random.PRNGKey(seed + i), (E, kk, nn),
                              jnp.float32) * kk ** -0.5
        dense[name] = w
        lo[name] = quantize(w, bits=bits, group_size=64)
    return lo, dense


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("b,c,k,n", [(4, 16, 128, 256), (2, 8, 256, 128)])
def test_grouped_lo_matmul_backend_bit_parity(bits, b, c, k, n):
    """The satellite contract: one dispatcher, two re-expressions of the
    same math, bit-identical results."""
    xg = jax.random.normal(jax.random.PRNGKey(b + bits), (b, c, k),
                           jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (b, k, n), jnp.float32)
    qt = quantize(w, bits=bits, group_size=64)
    y_jnp = kops.grouped_lo_matmul(xg, qt.packed, qt.scales, bits, 64,
                                   backend="jnp")
    y_pl = kops.grouped_lo_matmul(xg, qt.packed, qt.scales, bits, 64,
                                  backend="pallas")
    np.testing.assert_array_equal(np.asarray(y_jnp), np.asarray(y_pl))


def test_grouped_lo_matmul_matches_dequant_reference():
    """Both dispatcher backends stay allclose to the dequantize-then-dot
    oracle (the duplicated dequant math the dispatcher replaced)."""
    xg = jax.random.normal(jax.random.PRNGKey(0), (3, 16, 256), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 256, 128), jnp.float32)
    qt = quantize(w, bits=4, group_size=64)
    want = ref.grouped_quant_matmul_ref(xg, qt.packed, qt.scales, 4, 64)
    for be in ("jnp", "pallas"):
        got = kops.grouped_lo_matmul(xg, qt.packed, qt.scales, 4, 64,
                                     backend=be)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=4e-2, atol=4e-1)


@pytest.mark.parametrize("bits", [2, 4])
def test_ragged_ffn_pallas_matches_oracle_mixed_precision(bits):
    """Fused gate∥up+SiLU·mul / down kernels vs the jnp oracle, with a mix
    of hi and lo tiles (incl. tiles of the SAME expert id appearing twice)."""
    lo, dense = _mats(bits=bits)
    n_hi = 2
    hi = {n: jnp.asarray(dense[n][:n_hi], jnp.bfloat16) for n in dense}
    bm = 8
    tile_eid = jnp.asarray([0, 0, 2, 1, 3, 3], jnp.int32)
    tile_slot = jnp.asarray([0, 0, -1, 1, -1, -1], jnp.int32)  # e0,e1 hi
    xs = jax.random.normal(jax.random.PRNGKey(9),
                           (tile_eid.shape[0] * bm, 128), jnp.bfloat16)
    y_j = kops.ragged_quant_ffn_op(xs, tile_eid, tile_slot, lo, hi,
                                   bits=bits, group=64, bm=bm, backend="jnp")
    y_p = kops.ragged_quant_ffn_op(xs, tile_eid, tile_slot, lo, hi,
                                   bits=bits, group=64, bm=bm,
                                   backend="pallas")
    np.testing.assert_allclose(np.asarray(y_j, np.float32),
                               np.asarray(y_p, np.float32),
                               rtol=2e-2, atol=2e-1)


def test_ragged_ffn_no_hi_variant():
    """n_hi == 0 compiles the kernel WITHOUT hi operands (the all-lo bank:
    static-PTQ backend / speculative draft tier) and still matches."""
    lo, _ = _mats()
    bm = 8
    tile_eid = jnp.asarray([1, 2, 2, 0], jnp.int32)
    neg = jnp.full((4,), -1, jnp.int32)
    xs = jax.random.normal(jax.random.PRNGKey(3), (4 * bm, 128), jnp.bfloat16)
    y_j = kops.ragged_quant_ffn_op(xs, tile_eid, neg, lo, None,
                                   bits=4, group=64, bm=bm, backend="jnp")
    y_p = kops.ragged_quant_ffn_op(xs, tile_eid, neg, lo, None,
                                   bits=4, group=64, bm=bm, backend="pallas")
    np.testing.assert_allclose(np.asarray(y_j, np.float32),
                               np.asarray(y_p, np.float32),
                               rtol=2e-2, atol=2e-1)


def test_ragged_ffn_matches_dense_expert_math():
    """End math check against plain dense SwiGLU with the dequantized
    weights (loose: int4 quantization error dominates)."""
    lo, dense = _mats()
    bm = 8
    tile_eid = jnp.asarray([2, 1], jnp.int32)
    neg = jnp.full((2,), -1, jnp.int32)
    xs = jax.random.normal(jax.random.PRNGKey(5), (2 * bm, 128), jnp.bfloat16)
    y = kops.ragged_quant_ffn_op(xs, tile_eid, neg, lo, None,
                                 bits=4, group=64, bm=bm, backend="pallas")
    for t, e in enumerate([2, 1]):
        xt = xs[t * bm:(t + 1) * bm].astype(jnp.float32)
        g = xt @ dense["w_gate"][e]
        u = xt @ dense["w_up"][e]
        want = (jax.nn.silu(g) * u) @ dense["w_down"][e]
        np.testing.assert_allclose(
            np.asarray(y[t * bm:(t + 1) * bm], np.float32),
            np.asarray(want), rtol=0.3, atol=0.4)


def test_hold_last_forward_fill():
    v = jnp.asarray([-1, -1, 3, -1, 5, -1, -1], jnp.int32)
    out = np.asarray(kops._hold_last(v))
    np.testing.assert_array_equal(out, [0, 0, 3, 3, 5, 5, 5])


def test_ragged_tile_map_skips_inactive_experts():
    """Zero-token experts never appear in the live tile prefix — the grid
    property that keeps their weights out of HBM traffic."""
    from repro.models.moe import ragged_tile_map
    counts = jnp.asarray([0, 9, 0, 1, 16, 0, 0, 3], jnp.int32)
    astart, tile_eid, n_tiles = ragged_tile_map(counts, 8, 32)
    live = np.asarray(tile_eid)[:int(n_tiles)]
    assert sorted(set(live.tolist())) == [1, 3, 4, 7]
    # per-expert tile multiplicity = ceil(count/bm)
    assert (live == 1).sum() == 2 and (live == 4).sum() == 2
    assert (live == 3).sum() == 1 and (live == 7).sum() == 1
    # tail tiles hold the last active expert (repeat ⇒ no fresh DMA)
    assert set(np.asarray(tile_eid)[int(n_tiles):].tolist()) == {7}
    # segments are bm-aligned and disjoint
    np.testing.assert_array_equal(np.asarray(astart)[[1, 3, 4, 7]],
                                  [0, 16, 24, 40])
