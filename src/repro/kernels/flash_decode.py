"""Pallas TPU kernel: single-token flash attention over a long KV cache.

The decode_32k / long_500k hot spot: one query row per (batch, head) against
S cached keys. Online-softmax accumulation over KV tiles keeps the working
set at O(bs·hd) VMEM regardless of S; GQA is handled in the BlockSpec index
map (q head → kv head), so kv tiles are fetched once per kv head group.

Grid: (B, H, S/bs), S innermost/sequential with running (m, l, acc) scratch.

``flash_decode_paged`` is the gather-by-block-table variant for the paged
KV pool (``repro.serving.kvpool``): K/V live as (N, Hkv, bt, hd) physical
blocks — the ``repro.models.layers.PagedKVCache`` layout — and each
sequence's (B, nb) block table rides in as a scalar-prefetch argument, so
the BlockSpec index map DMAs exactly the blocks the row owns — no
materialized logical copy of the cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _fd_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
               m_ref, l_ref, acc_ref, *, ns, scale):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (1, hd) via block
    k = k_ref[0, :, 0].astype(jnp.float32)              # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)              # (bs, hd)
    logits = (q @ k.T) * scale                          # (1, bs)
    logits = jnp.where(valid_ref[0][None, :], logits, -jnp.inf)

    m_prev = m_ref[...]                                 # (1, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    # All-masked tiles keep m at -inf; exp(-inf - -inf) is nan — guard.
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
    p = jnp.exp(logits - m_new)                         # (1, bs), 0 where -inf
    p = jnp.where(jnp.isfinite(logits), p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v         # (1, hd)
    m_ref[...] = m_new

    @pl.when(s == ns - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom)[0].astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, valid: jax.Array,
                 *, bs: int = 512, interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k/v: (B, S, Hkv, hd); valid: (B, S) bool → (B, H, hd)."""
    B, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    bs = min(bs, S)
    if S % bs:
        raise ValueError(f"S={S} not tileable by bs={bs}")
    ns = S // bs
    grid = (B, H, ns)
    return pl.pallas_call(
        functools.partial(_fd_kernel, ns=ns, scale=hd ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h // rep, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h // rep, 0)),
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, s: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[_vmem((1, 1), jnp.float32),
                        _vmem((1, 1), jnp.float32),
                        _vmem((1, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, valid)


def _fd_paged_kernel(table_ref, q_ref, k_ref, v_ref, valid_ref, o_ref,
                     m_ref, l_ref, acc_ref, *, ns, scale):
    # Online-softmax accumulation, one KV tile per physical block. The
    # block table only acts in the index maps (table_ref is the
    # scalar-prefetch operand); tiles arrive in the pool's head-major
    # (1, 1, bt, hd) layout.
    del table_ref
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (1, hd) via block
    k = k_ref[0, 0].astype(jnp.float32)                 # (bt, hd)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bt, hd)
    logits = (q @ k.T) * scale                          # (1, bt)
    logits = jnp.where(valid_ref[0][None, :], logits, -jnp.inf)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
    p = jnp.exp(logits - m_new)
    p = jnp.where(jnp.isfinite(logits), p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_new

    @pl.when(s == ns - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom)[0].astype(o_ref.dtype)


def flash_decode_paged(q: jax.Array, k: jax.Array, v: jax.Array,
                       table: jax.Array, valid: jax.Array,
                       *, interpret: bool = False) -> jax.Array:
    """Paged flash decode over the pool's own layout: q: (B, H, hd); k/v:
    (N, Hkv, bt, hd) physical block pools (exactly
    ``repro.models.layers.PagedKVCache``, one superblock slice); table:
    (B, nb) int32 physical block ids per logical block (-1 = unallocated,
    routed to block 0 — mask those slots out via ``valid``); valid:
    (B, nb·bt) bool over the logical view. Returns (B, H, hd), numerically
    identical to ``flash_decode`` over the gathered logical cache. One KV
    tile per block: the scalar-prefetched table IS the gather."""
    from jax.experimental.pallas import tpu as pltpu

    B, H, hd = q.shape
    Hkv, bt = k.shape[1], k.shape[2]
    nb = table.shape[1]
    rep = H // Hkv
    if valid.shape != (B, nb * bt):
        raise ValueError(f"valid {valid.shape} != (B, nb*bt)="
                         f"{(B, nb * bt)}")
    table = jnp.clip(table.astype(jnp.int32), 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nb),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, s, t: (b, h, 0)),
            pl.BlockSpec((1, 1, bt, hd),
                         lambda b, h, s, t: (t[b, s], h // rep, 0, 0)),
            pl.BlockSpec((1, 1, bt, hd),
                         lambda b, h, s, t: (t[b, s], h // rep, 0, 0)),
            pl.BlockSpec((1, bt), lambda b, h, s, t: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, s, t: (b, h, 0)),
        scratch_shapes=[_vmem((1, 1), jnp.float32),
                        _vmem((1, 1), jnp.float32),
                        _vmem((1, hd), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_fd_paged_kernel, ns=nb, scale=hd ** -0.5),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(table, q, k, v, valid)
