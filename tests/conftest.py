import os
import sys
import types

# Tests run single-device: the multi-device dry-run tests spawn subprocesses
# with their own XLA_FLAGS (jax locks device count at first init).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# Minimal deterministic stand-in for `hypothesis` when it is not installed
# (this container bakes in jax but not hypothesis, and installing packages is
# not an option). The property tests only use a tiny strategy surface —
# integers / floats / sampled_from / lists — so a seeded-RNG driver that runs
# each property `max_examples` times preserves the coverage. With the real
# hypothesis available (e.g. in CI) this block is inert.
# ---------------------------------------------------------------------------

def _install_hypothesis_shim():
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value=0, max_value=(1 << 32) - 1):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                rng = np.random.default_rng(0xD15EA5E)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            # NOT functools.wraps: pytest must see the wrapper's empty
            # signature, not the property's drawn parameters (which would
            # otherwise be collected as missing fixtures).
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._hypothesis_shim = True
            return wrapper
        return deco

    def settings(max_examples=20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    st_mod.lists = lists
    st_mod.booleans = booleans

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st_mod
    hyp.__is_shim__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


_install_hypothesis_shim()


# ---------------------------------------------------------------------------
# Shared serving fixtures: one reduced MoE + a canonical engine builder, so
# every serving suite exercises the SAME backend settings.
# ---------------------------------------------------------------------------

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def serving_setup():
    """(cfg, params) for the reduced granite MoE used by the serving tests."""
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("granite-moe-1b-a400m", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture()
def engine_factory(serving_setup):
    """Build an InferenceEngine over a fresh clone of the shared params with
    the canonical test backend settings (int4 lo, n_hi=2, T_u=0)."""
    from repro.core import ControllerConfig
    from repro.serving import EngineConfig, InferenceEngine, make_backend

    cfg, params = serving_setup

    def build(name, max_slots=4, max_len=64, obs=None, **kw):
        if name in ("static", "dynaexq"):
            kw.setdefault("lo_bits", 4)
        if name == "dynaexq":
            kw.setdefault("n_hi_per_layer", 2)
            kw.setdefault("controller",
                          ControllerConfig(update_interval_s=0.0))
        clone = jax.tree_util.tree_map(lambda x: x, params)
        return InferenceEngine(cfg, clone, make_backend(name, **kw),
                               EngineConfig(max_slots=max_slots,
                                            max_len=max_len), obs=obs)

    return build
