"""Seeded, counter-based fault injection for transfer/IO boundaries.

A `FaultPlan` is a seed plus an ordered list of `FaultRule`s.  Each rule
targets one injection *site* (a short string naming a transfer boundary) and
describes when it fires (probabilistically per arrival and/or on a fixed
cadence) and what it does:

========== ==================================================================
site       transfer boundary
========== ==================================================================
promo_copy ``TransitionManager._issue_copy`` — the H2D promotion copy
host_hi    ``HostExpertStore.ensure_hi`` — host-tier bf16 row load
host_lo    ``HostExpertStore._lo_rows`` — host-tier quantized row load
stage_lo   ``HostExpertStore.stage_lo[_batch]`` — host→device lo staging
shard_lo   ``ShardSource.lo_layer`` — streaming lo shard read (npz)
shard_hi   ``ShardSource.hi_expert`` — streaming hi shard read (npz)
ep_mig     ``EPCoordinator._migrate`` — expert-parallel ownership swap
host_fetch demand host fetch in ``_observe_residency`` (modeled stall path)
========== ==================================================================

========= ===================================================================
kind      effect at the site
========= ===================================================================
fail      the transfer raises `TransferFault` (retryable)
stall     the transfer succeeds but is slow: promotions stay in flight until
          the injected deadline passes; modeled-stall sites add ``stall_s``
corrupt   the payload lands but is bad — promotions are caught by the
          publish-time integrity check and cancelled; host/shard reads treat
          it as a failed checksum and retry; EP migrations abort mid-swap
========= ===================================================================

Determinism: the decision for the k-th arrival at a site is a pure Philox
counter function of ``(seed, site, k, rule)`` — no sequential RNG state, so
replays (including virtual-clock `engine.replay`) see bit-identical fault
schedules regardless of interleaving.  The harness never sleeps; stalls are
modeled seconds, compatible with the virtual clock.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Dict, Optional, Tuple

import numpy as np

SITES = ("promo_copy", "host_hi", "host_lo", "stage_lo",
         "shard_lo", "shard_hi", "ep_mig", "host_fetch")
KINDS = ("fail", "stall", "corrupt")


class TransferFault(RuntimeError):
    """A transfer failed (injected or real, e.g. a corrupt shard on disk).

    Retryable: `repro.fault.retry.retry_call` catches exactly this type."""

    def __init__(self, site: str, kind: str = "fail", seq: int = -1,
                 detail: str = ""):
        self.site = site
        self.kind = kind
        self.seq = seq
        self.detail = detail
        msg = f"transfer fault at {site} (kind={kind}, seq={seq})"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


def _counter_uniform(seed: int, stream: int, a: int, b: int = 0) -> float:
    # Mirrors serving.sampler.counter_uniform (kept local: core/ imports this
    # module, and importing repro.serving from here would be a layer cycle).
    bg = np.random.Philox(key=np.uint64(seed & (2**64 - 1)),
                          counter=[np.uint64(stream), np.uint64(a),
                                   np.uint64(b), np.uint64(0)])
    return float(np.random.Generator(bg).random())


def _site_stream(site: str) -> int:
    # Stable site → Philox stream word; offset past the sampler's streams 0-3.
    return 16 + (zlib.crc32(site.encode("utf-8")) & 0x7FFFFFFF)


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One fault schedule entry.  Fires on the ``every``-th arrivals (0 =
    disabled) and/or with probability ``prob`` per arrival, starting at
    arrival ``start``, at most ``max_fires`` times (0 = unbounded)."""
    site: str
    kind: str = "fail"
    prob: float = 0.0
    every: int = 0
    start: int = 0
    max_fires: int = 0
    stall_s: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"prob must be in [0, 1], got {self.prob}")


@dataclasses.dataclass
class Fault:
    """A fired fault, handed to the site that asked."""
    site: str
    kind: str
    seq: int            # arrival index at the site (0-based)
    stall_s: float
    rule: int           # index of the rule that fired


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seed + ordered rules.  ``parse`` accepts a JSON string or a path to a
    JSON file: ``{"seed": 7, "rules": [{"site": "host_lo", "prob": 0.1}]}``."""
    seed: int = 0
    rules: Tuple[FaultRule, ...] = ()

    @staticmethod
    def parse(text: str, seed: Optional[int] = None) -> "FaultPlan":
        if os.path.exists(text):
            with open(text, "r", encoding="utf-8") as f:
                text = f.read()
        obj = json.loads(text)
        rules = tuple(FaultRule(**r) for r in obj.get("rules", ()))
        return FaultPlan(seed=int(obj.get("seed", 0) if seed is None else seed),
                         rules=rules)

    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "rules": [dataclasses.asdict(r) for r in self.rules],
        })

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)


class FaultInjector:
    """Evaluates a `FaultPlan` at each site arrival.

    Sites call ``fire(site, **ctx)`` once per transfer attempt; a ``Fault``
    comes back when a rule fires (first matching rule wins), else ``None``.
    Holding ``injector = None`` and pointer-checking before the call keeps
    the disabled path at zero cost.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.seed = plan.seed
        self.tracer = None                      # bound by obs propagation
        self._arrivals: Dict[str, int] = {}
        self._fires: Dict[int, int] = {}        # rule index → times fired
        self.stats = {"injected": 0}

    def arrivals(self, site: str) -> int:
        return self._arrivals.get(site, 0)

    def fire(self, site: str, **ctx) -> Optional[Fault]:
        k = self._arrivals.get(site, 0)
        self._arrivals[site] = k + 1
        for ri, rule in enumerate(self.plan.rules):
            if rule.site != site or k < rule.start:
                continue
            if rule.max_fires and self._fires.get(ri, 0) >= rule.max_fires:
                continue
            hit = bool(rule.every) and (k - rule.start) % rule.every == 0
            if not hit and rule.prob > 0.0:
                hit = _counter_uniform(self.seed, _site_stream(site),
                                       k, ri) < rule.prob
            if not hit:
                continue
            self._fires[ri] = self._fires.get(ri, 0) + 1
            self.stats["injected"] += 1
            f = Fault(site=site, kind=rule.kind, seq=k,
                      stall_s=rule.stall_s, rule=ri)
            if self.tracer is not None:
                self.tracer.instant("fault_injected", cat="fault", site=site,
                                    kind=rule.kind, seq=k, **ctx)
            return f
        return None
