"""Every assigned architecture, one reduced instance each: prefill a prompt
and greedily decode a few tokens — demonstrates the single model-builder API
across dense / MoE / SSM / hybrid / audio / VLM families.

    PYTHONPATH=src python examples/multiarch_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import decode_step, init_caches, init_params, prefill
from repro.models.frontend import audio_frame_embeddings, image_patch_embeddings


def main():
    key = jax.random.PRNGKey(0)
    B, S, new = 2, 32, 4
    for arch in ARCH_IDS:
        cfg = get_config(arch, reduced=True)
        params = init_params(key, cfg)
        batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
        if cfg.family == "audio":
            batch["audio_embeds"] = audio_frame_embeddings(key, cfg, B)
        if cfg.family == "vlm":
            batch["image_embeds"] = image_patch_embeddings(key, cfg, B)
        img = cfg.num_image_tokens if cfg.family == "vlm" else 0
        caches = init_caches(cfg, B, 64 + img)
        t0 = time.perf_counter()
        logits, caches, _ = prefill(params, cfg, batch, caches)
        toks = []
        pos = S + img
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(new):
            toks.append(tok)
            logits, caches, _ = decode_step(params, cfg, tok,
                                            jnp.int32(pos + i), caches)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        dt = time.perf_counter() - t0
        print(f"{arch:24s} [{cfg.family:6s}] prefill+{new} decode ok "
              f"({dt:.1f}s)  sample={[int(t[0]) for t in toks]}")


if __name__ == "__main__":
    main()
