"""Paged KV allocator core: block refcount / free-list / COW invariants and
trie insert–match–release round-trips under randomized request
interleavings (property-style, in the spirit of test_ver_transitions)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import BudgetExceeded, BudgetTracker
from repro.serving.kvpool import KVBlockPool, KVLease, TRASH_BLOCK
from repro.serving.prefix import PrefixTrie

BB = 64      # block bytes for these tests
BT = 4       # tokens per block


def make_pool(n_blocks=16, cap_blocks=None, trie=False):
    cap = (cap_blocks if cap_blocks is not None else n_blocks) * BB
    budget = BudgetTracker(cap)
    holder = {}

    def reclaim(need):
        t = holder.get("trie")
        return t.evict(need) if t is not None else 0

    pool = KVBlockPool(n_blocks, BT, BB, budget=budget.view("kv"),
                       reclaim=reclaim)
    t = PrefixTrie(pool) if trie else None
    holder["trie"] = t
    return pool, t, budget


# ---------------------------------------------------------------------------
# Pool basics
# ---------------------------------------------------------------------------

def test_pool_accounting_and_trash():
    pool, _, budget = make_pool(8)
    assert pool.blocks_in_use == 0 and pool.n_free == 7
    assert budget.used == BB                       # trash block reserved
    assert pool.try_reserve_quota(3)
    lease = KVLease(pool, 4, 3)
    a, cow = lease.ensure(0)
    b, _ = lease.ensure(1)
    assert cow == -1 and a != b and TRASH_BLOCK not in (a, b)
    assert pool.blocks_in_use == 2 and pool.quota_blocks == 1
    assert budget.used == (2 + 1 + 1) * BB         # blocks + quota + trash
    pool.check_invariants()
    lease.close()
    assert pool.blocks_in_use == 0 and pool.quota_blocks == 0
    assert budget.used == BB
    pool.check_invariants()


def test_quota_denied_when_budget_full():
    pool, _, budget = make_pool(8, cap_blocks=3)    # trash + 2 blocks of cap
    assert pool.try_reserve_quota(2)
    assert not pool.try_reserve_quota(1)            # envelope exhausted
    assert pool.stats["quota_denied"] == 1
    pool.release_quota(2)
    assert pool.try_reserve_quota(1)
    pool.release_quota(1)
    pool.check_invariants()


def test_cow_on_shared_block():
    pool, _, _ = make_pool(8)
    assert pool.try_reserve_quota(4)
    a_lease = KVLease(pool, 2, 2)
    blk, _ = a_lease.ensure(0)
    b_lease = KVLease(pool, 2, 2)
    b_lease.adopt_prefix([blk])
    assert pool.refcount[blk] == 2
    # writer of a shared block gets a private copy + a copy obligation
    phys, cow = b_lease.ensure(0)
    assert cow == blk and phys != blk
    assert pool.refcount[blk] == 1 and pool.refcount[phys] == 1
    # the original owner is unaffected and writes in place
    phys_a, cow_a = a_lease.ensure(0)
    assert phys_a == blk and cow_a == -1
    a_lease.close()
    b_lease.close()
    pool.check_invariants()


def test_double_free_and_dead_retain_raise():
    pool, _, _ = make_pool(4)
    assert pool.try_reserve_quota(1)
    lease = KVLease(pool, 1, 1)
    blk, _ = lease.ensure(0)
    lease.close()
    with pytest.raises(RuntimeError):
        pool.release(blk)
    with pytest.raises(RuntimeError):
        pool.retain(blk)
    with pytest.raises(RuntimeError):
        pool.release(TRASH_BLOCK)
    with pytest.raises(BudgetExceeded):
        pool.budget.release(BB * 100)


def test_alloc_without_quota_raises():
    pool, _, _ = make_pool(4)
    lease = KVLease(pool, 1, 0)
    with pytest.raises(RuntimeError):
        lease.ensure(0)


# ---------------------------------------------------------------------------
# Trie round-trips
# ---------------------------------------------------------------------------

def _toks(*chunks):
    return np.concatenate([np.full(BT, c, np.int32) for c in chunks])


def test_trie_insert_match_roundtrip():
    pool, trie, _ = make_pool(16, trie=True)
    assert pool.try_reserve_quota(3)
    lease = KVLease(pool, 3, 3)
    chain = [lease.ensure(j)[0] for j in range(3)]
    toks = _toks(1, 2, 3)
    assert trie.insert(toks, chain) == 3
    assert trie.match(toks) == chain
    assert trie.match(_toks(1, 2)) == chain[:2]
    assert trie.match(_toks(1, 9, 3)) == chain[:1]   # diverges at chunk 2
    assert trie.match(_toks(7)) == []
    assert trie.match(toks, max_blocks=1) == chain[:1]
    # partial trailing tokens never match a whole chunk
    assert trie.match(np.full(BT - 1, 1, np.int32)) == []
    # trie holds its own refs: blocks survive the computing lease
    lease.close()
    assert all(pool.refcount[b] == 1 for b in chain)
    assert trie.clear() == 3
    pool.check_invariants()


def test_trie_first_writer_wins():
    pool, trie, _ = make_pool(16, trie=True)
    assert pool.try_reserve_quota(2)
    l1, l2 = KVLease(pool, 1, 1), KVLease(pool, 1, 1)
    b1, b2 = l1.ensure(0)[0], l2.ensure(0)[0]
    toks = _toks(5)
    trie.insert(toks, [b1])
    trie.insert(toks, [b2])                  # duplicate compute: no-op
    assert trie.match(toks) == [b1]
    assert pool.refcount[b2] == 1            # stays private to l2
    l1.close(); l2.close()
    trie.clear()
    pool.check_invariants()


def test_trie_eviction_lru_and_lease_pinning():
    pool, trie, _ = make_pool(6, trie=True)   # trash + 5 usable
    assert pool.try_reserve_quota(4)
    lease = KVLease(pool, 4, 4)
    blocks = [lease.ensure(j)[0] for j in range(4)]
    trie.insert(_toks(1), [blocks[0]])
    trie.insert(_toks(2), [blocks[1]])
    lease.close()                             # both chains now trie-only
    trie.match(_toks(1))                      # chain 1 is now most recent
    # exhaust the pool: eviction must reclaim the LRU chain (2) first
    assert pool.try_reserve_quota(4)
    l2 = KVLease(pool, 4, 4)
    got = [l2.ensure(j)[0] for j in range(4)]
    assert blocks[1] in got                   # evicted + recycled
    assert trie.match(_toks(2)) == []
    assert trie.match(_toks(1)) == [blocks[0]]  # survivor
    l2.close()
    trie.clear()
    pool.check_invariants()


def test_trie_eviction_leaf_first():
    """A chain evicts leaf-to-root; inner nodes with live children are
    never dropped before their descendants."""
    pool, trie, _ = make_pool(8, trie=True)
    assert pool.try_reserve_quota(3)
    lease = KVLease(pool, 3, 3)
    chain = [lease.ensure(j)[0] for j in range(3)]
    trie.insert(_toks(1, 2, 3), chain)
    lease.close()
    assert trie.evict(1) == 1
    assert trie.match(_toks(1, 2, 3)) == chain[:2]   # leaf gone, prefix OK
    assert trie.evict(10) == 2                        # rest unwinds
    assert trie.n_nodes == 0
    pool.check_invariants()


def test_trie_eviction_unwinds_to_interior_blocks():
    """A trie-exclusive block BEHIND a still-leased deeper chunk (the COWed
    ancestor of an adopted chain) is reclaimable: eviction unwinds the
    lease-shared leaf (dropping only the trie's reference) to reach it."""
    pool, trie, _ = make_pool(4, trie=True)   # trash + 3 usable
    assert pool.try_reserve_quota(2)
    l1 = KVLease(pool, 2, 2)
    chain = [l1.ensure(0)[0], l1.ensure(1)[0]]
    trie.insert(_toks(1, 2), chain)
    l1.close()
    # a second request adopts the chain, then COWs logical block 0 (ring
    # wrap): the interior trie block keeps refcount 1, the leaf stays
    # shared with the live lease
    assert pool.try_reserve_quota(1)
    l2 = KVLease(pool, 2, 1)
    l2.adopt_prefix(chain)
    phys, cow = l2.ensure(0)
    assert cow == chain[0] and pool.refcount[chain[0]] == 1
    assert pool.refcount[chain[1]] == 2       # trie + l2
    # pool now dry: trash + {chain[0] (trie-only), chain[1], phys}
    assert pool.n_free == 0
    freed = trie.evict(1)
    assert freed == 1                         # interior chain[0] reclaimed
    assert pool.refcount[chain[1]] == 1       # leaf ref dropped, lease lives
    assert trie.n_nodes == 0
    l2.close()
    pool.check_invariants()


def test_quota_reclaim_cannot_evict_pinned_hits():
    """The engine pins matched hit blocks before reserving quota; pinned
    blocks (refcount > 1) survive any reclaim the reservation triggers,
    while unpinned trie-only chains are fair game."""
    pool, trie, _ = make_pool(8, cap_blocks=5, trie=True)
    assert pool.try_reserve_quota(4)
    lease = KVLease(pool, 4, 4)
    blocks = [lease.ensure(j)[0] for j in range(4)]
    trie.insert(_toks(1), [blocks[0]])
    trie.insert(_toks(2), [blocks[1]])
    lease.close()                             # two trie-only chains
    hits = trie.match(_toks(1))
    for b in hits:
        pool.retain(b)                        # the engine's pin
    # cap 5 blocks: trash + 2 trie chains leave 2 blocks of headroom, so a
    # 4-block quota needs BOTH chains reclaimed. Only the unpinned one may
    # go: the reservation must fail rather than evict the pinned hit (the
    # pre-pin bug freed it and the later adopt crashed on a dead block).
    assert not pool.try_reserve_quota(4)
    assert pool.refcount[hits[0]] >= 1        # pinned hit survived
    assert trie.match(_toks(1)) == hits       # chain intact for adoption
    assert trie.match(_toks(2)) == []         # unpinned chain was evicted
    assert pool.try_reserve_quota(3)          # within the real headroom
    for b in hits:
        pool.release(b)
    pool.release_quota(3)
    trie.clear()
    pool.check_invariants()


# ---------------------------------------------------------------------------
# Property: random interleavings keep every invariant
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n_requests=st.integers(4, 24))
def test_random_interleaving_invariants(seed, n_requests):
    """Random admission/share/COW/finish interleavings: refcounts, free
    list, quota, budget bytes and trie consistency hold at every step, and
    everything returns to baseline after the last release."""
    rng = np.random.default_rng(seed)
    n_logical = 4
    pool, trie, budget = make_pool(1 + 6 * n_logical * 2, trie=True)
    live = []
    for _ in range(n_requests):
        op = rng.integers(3)
        if op == 0 or len(live) < 2:          # admit (maybe via trie hit)
            chunks = tuple(int(c) for c in rng.integers(0, 3, size=rng.integers(1, n_logical + 1)))
            toks = _toks(*chunks)
            hits = trie.match(toks, max_blocks=len(chunks))
            for b in hits:
                pool.retain(b)        # pin before the reclaim-capable gate
            quota = 2 * n_logical
            if not pool.try_reserve_quota(quota):
                for b in hits:
                    pool.release(b)
                continue
            lease = KVLease(pool, n_logical, quota)
            lease.adopt_prefix(hits, retained=True)
            for j in range(len(chunks)):
                lease.ensure(j)
            trie.insert(toks, [int(lease.table[j])
                               for j in range(len(chunks))])
            live.append(lease)
        elif op == 1:                         # decode-style write (COW)
            lease = live[rng.integers(len(live))]
            lease.ensure(int(rng.integers(n_logical)))
        else:                                 # finish
            lease = live.pop(rng.integers(len(live)))
            lease.close()
        pool.check_invariants()
        # every trie-visible block is alive
        assert all(pool.refcount[n.block] >= 1 for n in trie._leaves())
    for lease in live:
        lease.close()
    pool.check_invariants()
    trie.clear()
    pool.check_invariants()
    assert pool.blocks_in_use == 0 and pool.quota_blocks == 0
    assert budget.used == BB                  # only the trash block
