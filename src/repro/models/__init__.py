from repro.models.config import ArchConfig, AttnConfig, MoEConfig, SSMConfig
from repro.models.model import (
    init_params, init_caches, forward_train, prefill, decode_step,
    DecodeCaches,
)

__all__ = [
    "ArchConfig", "AttnConfig", "MoEConfig", "SSMConfig",
    "init_params", "init_caches", "forward_train", "prefill", "decode_step",
    "DecodeCaches",
]
