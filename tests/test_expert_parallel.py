"""Expert-parallel serving correctness.

Tentpole contract (ISSUE: sharded ragged dispatch with all-to-all): on a
forced 8-host-device mesh the EP pipeline — local routing, per-destination
compaction, all-to-all row exchange, shard-local mixed-precision FFN,
all-to-all return, gated combine — must be TOKEN-IDENTICAL to the
single-device path, with router counts, drop counts, aux loss and
per-request row_counts round-tripping exactly. On top: per-shard hi-slot
pools with per-shard budget isolation, and hotness-aware expert-ownership
rebalancing that provably moves an expert without perturbing the forward.

Mesh tests run in subprocesses (jax pins the device count at first init;
the rest of the suite is single-device). Host-side accounting tests
(ShardedSlotPool / per-shard TransitionManager budgets / coordinator
policy) run in-process.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (ControllerConfig, DynaExqController, build_bank,
                        expert_hi_nbytes)
from repro.core.budget import BudgetTracker
from repro.core.controller import EPCoordinator, RebalanceConfig
from repro.core.pools import ShardedSlotPool


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


# ---------------------------------------------------------------------------
# Layer-level: moe_apply under ep_context vs single device, bit-for-bit.
# ---------------------------------------------------------------------------

SCRIPT_LAYER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json
from repro.models.config import MoEConfig
from repro.models import moe as M
from repro.launch.dist import DistContext, dist_ctx, ep_context
from repro.launch.mesh import make_ep_mesh
from repro.core.ver import ExpertBankQ, build_bank
from repro.quant.qtensor import QuantizedTensor

cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=128, n_shared_experts=0,
                router_aux_coef=0.01, capacity_factor=2.0,
                norm_topk_prob=True)
d, T = 64, 64
key = jax.random.PRNGKey(0)
params = M.init_moe(key, d, cfg)

# Quantized bank with hi slots PUBLISHED ON SHARD-CORRECT SLOTS: n_hi=8 over
# 8 shards -> 1 slot per shard, expert e's shard is e (e_local=1), so expert
# 1 -> slot 1 and expert 6 -> slot 6.
ew = {k: v[None] for k, v in params["experts"].items()}
bank_full = build_bank(ew, n_hi=8, lo_bits=4, group_size=32)
lo = {k: QuantizedTensor(q.packed[0], q.scales[0], q.bits, q.group_size,
                         q.shape[1:]) for k, q in bank_full.lo.items()}
hi = {k: v[0].at[1].set(ew[k][0, 1].astype(v.dtype))
           .at[6].set(ew[k][0, 6].astype(v.dtype))
      for k, v in bank_full.hi.items()}
so = np.full(8, -1); so[1] = 1; so[6] = 6
bank = ExpertBankQ(lo=lo, hi=hi, slot_owner=jnp.asarray(so, jnp.int32),
                   slot_map=jnp.asarray(so, jnp.int32))

x = jax.random.normal(jax.random.PRNGKey(1), (T, d), jnp.bfloat16)
cap = M.moe_capacity(T, cfg)
tv = jnp.asarray(np.arange(T) % 16 != 3)
ctx = ep_context(make_ep_mesh(8))

def run(dispatch, dist, bnk, n_rows=None, row_capacity=None,
        token_valid=None):
    def f(p, b, xx, tvv):
        return M.moe_apply(p, b, xx, cfg, cap, token_valid=tvv,
                           n_rows=n_rows, row_capacity=row_capacity,
                           dispatch=dispatch, gemm="jnp")
    if dist is None:
        return jax.jit(f)(params, bnk, x, token_valid)
    with dist_ctx(dist):
        return jax.jit(f)(params, bnk, x, token_valid)

out = {}
# ragged EP parity across token_valid x row-count x row-capacity configs
for tvv, tag_tv in ((None, "all"), (tv, "tv")):
    for n_rows, rc in ((None, None), (16, None), (16, 2)):
        y0, a0 = run("ragged", None, bank, n_rows, rc, tvv)
        y1, a1 = run("ragged", ctx, bank, n_rows, rc, tvv)
        tag = f"{tag_tv}_r{n_rows}_c{rc}"
        out["bit_" + tag] = bool(jnp.all(y0 == y1))
        out["counts_" + tag] = bool(jnp.all(a0.counts == a1.counts))
        out["dropped_" + tag] = float(a1.dropped) == float(a0.dropped)
        out["aux_" + tag] = abs(float(a1.aux_loss) - float(a0.aux_loss)) < 1e-6
        if n_rows:
            out["rc_" + tag] = a1.row_counts is not None and \
                bool(jnp.all(a0.row_counts == a1.row_counts))

# padded sharded path (dp mesh) still round-trips row_counts
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
ctx2 = DistContext(mesh=mesh2, dp_axes=("data",), model_axis="model")
y0, a0 = run("padded", None, bank, 16, None, tv)
y2, a2 = run("padded", ctx2, bank, 16, None, tv)
out["padded_dp_err"] = float(jnp.max(jnp.abs(
    y0.astype(jnp.float32) - y2.astype(jnp.float32))))
out["padded_dp_rc"] = bool(jnp.all(a0.row_counts == a2.row_counts))

# dense (bf16 dict) banks: ragged == padded bit-for-bit, and under EP
dense = dict(params["experts"])
yd0, ad0 = run("padded", None, dense)
yd1, ad1 = run("ragged", None, dense)
yd2, ad2 = run("ragged", ctx, dense)
out["dense_ragged_bit"] = bool(jnp.all(yd0 == yd1))
out["dense_ep_bit"] = bool(jnp.all(yd1 == yd2))
out["dense_counts"] = bool(jnp.all(ad0.counts == ad2.counts))
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_ep_moe_apply_matches_single_device():
    out = _run(SCRIPT_LAYER)
    bad = {k: v for k, v in out.items()
           if k != "padded_dp_err" and v is not True}
    assert not bad, (bad, out)
    assert out["padded_dp_err"] == 0.0, out


# ---------------------------------------------------------------------------
# Engine-level: token parity through the full serving loop, per-shard hi
# publication, and glitch-free hotness rebalancing.
# ---------------------------------------------------------------------------

SCRIPT_ENGINE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config
from repro.core import ControllerConfig
from repro.core.controller import RebalanceConfig
from repro.models import init_params
from repro.models import moe as M
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           make_backend)
from repro.launch.dist import dist_ctx, ep_context
from repro.launch.mesh import make_ep_mesh
from repro.core.ver import ExpertBankQ
from repro.quant.qtensor import QuantizedTensor

cfg = get_config("granite-moe-1b-a400m", reduced=True)
params = init_params(jax.random.PRNGKey(0), cfg)

def deep(d):
    return {k: deep(v) for k, v in d.items()} if isinstance(d, dict) else d

# Frozen policy timers: residency transitions and rebalances fire only when
# forced, so the parity comparison cannot depend on wall-clock noise.
FROZEN = ControllerConfig(update_interval_s=1e9)
RB = RebalanceConfig(interval_s=1e9)

def run(dist, ep_shards):
    be = make_backend("dynaexq", n_hi_per_layer=4, ep_shards=ep_shards,
                      controller=FROZEN, rebalance=RB)
    ec = EngineConfig(max_slots=4, max_len=64, prefill_rows=4,
                      moe_dispatch="ragged", spec_k=0)
    eng = InferenceEngine(cfg, deep(params), be, ec, dist=dist)
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (4, 24), dtype=np.int64)
    hs = [eng.submit(Request(tokens=toks[b], max_new_tokens=8))
          for b in range(4)]
    eng.drain(); eng.flush()
    return [h.tokens for h in hs], eng

out = {}
t_ref, e_ref = run(None, 1)
t_ep, e_ep = run(ep_context(make_ep_mesh(4)), 4)
out["token_parity_ep4"] = t_ref == t_ep

# forced promotions publish on shard-correct slots under per-shard budgets
be = e_ep.backend
be.force_update(); be.flush()
ok_place = True
for ctl in be.controllers.values():
    ctl.tm.check_invariants()
    for l in range(ctl.tm.state.shape[0]):
        for e, s in enumerate(ctl.tm.slot_map_h[l]):
            if s >= 0 and ctl.tm.pools[l].shard_of(int(s)) != \
                    ctl.tm.shard_of_expert(e):
                ok_place = False
out["shard_correct_slots"] = ok_place
out["promoted_something"] = any(
    ctl.tm.stats["promoted"] > 0 for ctl in be.controllers.values())

# ---- hotness rebalance: provably moves an expert, forward-invariant ----
# e_local must be >= 2 for a swap to be able to improve balance (with one
# expert per shard a swap only relabels shards), so this runs at 2 shards.
t2, e2 = run(ep_context(make_ep_mesh(2)), 2)
out["token_parity_ep2"] = t_ref == t2
be2 = e2.backend
pos = be2.moe_positions[0]
ctl = be2.controllers[str(pos)]
moe_params = e2.params["blocks"][str(pos)]["moe"]
bank = ctl.bank
x = jax.random.normal(jax.random.PRNGKey(7), (8, cfg.d_model), jnp.bfloat16)
cap = M.moe_capacity(8, cfg.moe, e2.ecfg.capacity_factor)

def fwd():
    lo = {k: QuantizedTensor(q.packed[0], q.scales[0], q.bits, q.group_size,
                             q.shape[1:]) for k, q in bank.lo.items()}
    b0 = ExpertBankQ(lo=lo, hi={k: v[0] for k, v in bank.hi.items()},
                     slot_owner=bank.slot_owner[0], slot_map=bank.slot_map[0])
    p0 = {"router": moe_params["router"][0]}
    with dist_ctx(e2.dist):
        y, aux = jax.jit(lambda p, b, xx: M.moe_apply(
            p, b, xx, cfg.moe, cap, dispatch="ragged", gemm="jnp"))(p0, b0, x)
    return np.asarray(y.astype(jnp.float32)), np.asarray(aux.counts)

y_before, c_before = fwd()
# Moderate skew on shard 0 (experts {0, 1}): hot enough that moving ONE of
# them strictly improves the max shard load, not so hot it dominates
# wherever it lands.
ctl.hotness.counts[:, 0] += 100
ctl.hotness.counts[:, 1] += 100
placement = be2.coordinator._entries[0][2]
pl_before = placement.copy()
n = be2.coordinator.maybe_rebalance(force=True)
out["migrated"] = n > 0
out["placement_changed"] = not np.array_equal(pl_before, placement)
y_after, c_after = fwd()
out["forward_invariant"] = bool(np.array_equal(y_before, y_after))
# A relabel permutes expert POSITIONS, so per-position router counts
# permute with the placement; counts per ORIGINAL expert are invariant.
perm = np.argsort(pl_before[0])[placement[0]]
out["counts_invariant"] = bool(np.array_equal(c_after, c_before[..., perm]))
for c2 in be2.controllers.values():
    c2.tm.check_invariants()
out["invariants_after_migration"] = True
out["stats_migrations"] = be2.coordinator.stats["migrations"]
out["stats_bytes_moved_pos"] = be2.coordinator.stats["bytes_moved"] > 0
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_ep_engine_token_parity_and_rebalance():
    out = _run(SCRIPT_ENGINE)
    for k in ("token_parity_ep4", "token_parity_ep2", "shard_correct_slots",
              "promoted_something", "migrated", "placement_changed",
              "forward_invariant", "counts_invariant",
              "invariants_after_migration", "stats_bytes_moved_pos"):
        assert out[k] is True, (k, out)
    assert out["stats_migrations"] >= 1, out


# ---------------------------------------------------------------------------
# Host-side accounting (no mesh needed).
# ---------------------------------------------------------------------------

def test_sharded_slot_pool():
    p = ShardedSlotPool(8, 4)          # 2 slots per shard
    assert p.per_shard == 2 and p.n_free == 8
    s0 = p.alloc(0, shard=0)
    s1 = p.alloc(1, shard=0)
    assert {s0, s1} == {0, 1}          # shard 0 owns global slots [0, 2)
    assert p.n_free_in(0) == 0 and p.n_free_in(3) == 2
    with pytest.raises(RuntimeError):
        p.alloc(2, shard=0)            # shard-local exhaustion, not global
    s2 = p.alloc(9, shard=3)
    assert p.shard_of(s2) == 3 and s2 == 6
    p.free(s0)
    assert p.n_free_in(0) == 1
    assert p.alloc(4, shard=0) == s0   # lowest-index-first within the shard
    with pytest.raises(ValueError):
        ShardedSlotPool(6, 4)          # must divide evenly


def _make_ep_controller(L=1, E=8, n_hi=4, n_shards=4, shared_budget=False):
    import jax
    import jax.numpy as jnp
    key = jax.random.PRNGKey(0)
    w = {"w": jax.random.normal(key, (L, E, 64, 32), jnp.float32)
         .astype(jnp.bfloat16)}
    bank = build_bank(w, n_hi=n_hi, lo_bits=4)
    host = {k: np.asarray(v) for k, v in w.items()}
    hib = expert_hi_nbytes({k: v.shape for k, v in w.items()})
    per_cap = (n_hi // n_shards) * L * hib
    if shared_budget:
        parent = BudgetTracker(n_hi * L * hib)
        trackers = [parent.view(f"s{j}", cap=per_cap)
                    for j in range(n_shards)]
    else:
        trackers = [BudgetTracker(per_cap) for _ in range(n_shards)]
    ctl = DynaExqController(
        bank, host, n_hi_per_layer=n_hi, hi_bytes_per_expert=hib,
        cfg=ControllerConfig(update_interval_s=1e9),
        ep_shards=n_shards, shard_trackers=trackers)
    return ctl, trackers, hib


@pytest.mark.parametrize("shared_budget", [False, True])
def test_per_shard_budget_isolation(shared_budget):
    """A hot shard saturating its hi slots defers ITS promotions only —
    sibling shards still admit — and after a full
    promotion/demotion/migration cycle every shard tracker balances to
    exactly zero bytes."""
    ctl, trackers, hib = _make_ep_controller(shared_budget=shared_budget)
    tm = ctl.tm
    # E=8 over 4 shards -> experts {0,1} on shard 0; n_hi=4 -> 1 slot/shard.
    tm.request_promotion(0, 0)
    tm.request_promotion(0, 1)        # same shard: over its 1-slot budget
    tm.request_promotion(0, 2)        # shard 1: must admit regardless
    tm.drain()
    tm.publish_ready(wait=True)
    assert tm.hi_set(0) == {0, 2}
    assert tm.stats["deferred"] >= 1
    assert trackers[0].used == hib and trackers[1].used == hib
    assert trackers[2].used == 0 and trackers[3].used == 0
    tm.check_invariants()
    # expert 1 stays queued; freeing shard 0 admits it on a later drain
    # (two cycles: queue order may retry the promotion before the demotion
    # releases the slot)
    tm.request_demotion(0, 0)
    tm.drain()
    tm.publish_ready(wait=True)
    tm.drain()
    tm.publish_ready(wait=True)
    assert tm.hi_set(0) == {1, 2}
    tm.check_invariants()

    # migration (relabel 1 <-> 7 across shards 0/3) via the coordinator
    coord = EPCoordinator(4, RebalanceConfig(interval_s=1e9))
    import jax
    import jax.numpy as jnp
    moe_params = {"router": jax.random.normal(jax.random.PRNGKey(1),
                                              (1, 16, 8), jnp.float32)}
    coord.register(ctl, moe_params)
    r_before = np.asarray(moe_params["router"]).copy()
    lo_before = np.asarray(ctl.bank.lo["w"].packed).copy()
    assert coord._migrate(ctl, moe_params, coord._entries[0][2], 0, 1, 7)
    r_after = np.asarray(moe_params["router"])
    lo_after = np.asarray(ctl.bank.lo["w"].packed)
    np.testing.assert_array_equal(r_after[0, :, 1], r_before[0, :, 7])
    np.testing.assert_array_equal(r_after[0, :, 7], r_before[0, :, 1])
    np.testing.assert_array_equal(lo_after[0, 1], lo_before[0, 7])
    np.testing.assert_array_equal(lo_after[0, 7], lo_before[0, 1])
    # migration demoted expert 1 first (its hi slot is shard-local)
    assert tm.hi_set(0) == {2}
    tm.check_invariants()

    # full demotion: every shard account returns to zero
    for e in sorted(tm.hi_set(0)):
        tm.request_demotion(0, e)
    tm.drain()
    tm.publish_ready(wait=True)
    tm.check_invariants()
    assert all(t.used == 0 for t in trackers)


def test_rebalance_improvement_guard():
    """The coordinator only migrates when the swap strictly shrinks the max
    shard load: with one expert per shard a swap is a pure relabel and must
    be refused; with two it must fire exactly once for a moderate skew (no
    same-window ping-pong)."""
    # e_local == 1: never migrates, however large the skew
    ctl, _, _ = _make_ep_controller(E=4, n_hi=4, n_shards=4)
    coord = EPCoordinator(4, RebalanceConfig(interval_s=1e9,
                                             max_migrations_per_window=4))
    import jax
    import jax.numpy as jnp
    mp = {"router": jnp.zeros((1, 16, 4), jnp.float32)}
    coord.register(ctl, mp)
    ctl.hotness.counts[:, 0] += 1000
    assert coord.maybe_rebalance(force=True) == 0

    # e_local == 2: one improving swap, then the guard stops the window
    ctl2, _, _ = _make_ep_controller(E=8, n_hi=4, n_shards=2)
    coord2 = EPCoordinator(2, RebalanceConfig(interval_s=1e9,
                                              max_migrations_per_window=4))
    mp2 = {"router": jnp.zeros((1, 16, 8), jnp.float32)}
    coord2.register(ctl2, mp2)
    ctl2.hotness.counts[:, 0] += 100
    ctl2.hotness.counts[:, 1] += 100
    n = coord2.maybe_rebalance(force=True)
    assert n == 1, n
    placement = coord2._entries[0][2]
    assert not np.array_equal(placement, np.tile(np.arange(8), (1, 1)))


def test_backend_ep_validation():
    from repro.serving.backends import make_backend
    from repro.configs import get_config
    from repro.models import init_params
    import jax
    cfg = get_config("granite-moe-1b-a400m", reduced=True)   # E=4
    params = init_params(jax.random.PRNGKey(0), cfg)
    be = make_backend("dynaexq", ep_shards=3, n_hi_per_layer=3)
    with pytest.raises(ValueError, match="not divisible"):
        be.materialize_banks(cfg, params, kv_bytes=0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    be = make_backend("dynaexq", ep_shards=2, n_hi_per_layer=3)
    with pytest.raises(ValueError, match="n_hi_per_layer"):
        be.materialize_banks(cfg, params, kv_bytes=0)
    with pytest.raises(ValueError):
        make_backend("dynaexq", ep_shards=0)
