"""Decode hot-path kernel benchmark: padded (E, C, d) dispatch vs the
padding-free ragged dispatch + fused mixed-precision kernel, at decode
batches 1 / 8 / 32 under heavy-tailed routing.

The number that matters on a memory-bound decode step is WEIGHT BYTES READ
PER TOKEN. The padded path streams every expert's lo codes plus every
published hi slot each step regardless of routing; the ragged path streams
only the experts that actually received tokens, and for each only its
resident tier. Bytes are computed analytically from the observed routing
(counts ∩ residency) — interpret-mode wall clock on this CPU container
measures Python, not HBM, so the byte model IS the deliverable — alongside
measured ``MoEAux`` telemetry (active experts, dispatch pad ratio) and
jnp-path tokens/s for sanity.

Rows land in ``experiments/BENCH_kernels.json`` (uniform schema:
``{batch, path, bytes_per_token, pad_ratio, active_experts, tokens_per_s}``);
``BENCH_SMOKE=1`` shrinks the step count for CI. The analytic TPU roofline
for the plain quant-matmul (old deliverable) stays in ``run_roofline``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_SMOKE
from benchmarks.hw import HBM_GBPS, PEAK_TFLOPS_BF16
from repro.core.ver import build_bank, expert_hi_nbytes, expert_lo_nbytes
from repro.kernels.ops import quant_matmul_op
from repro.models.config import MoEConfig
from repro.models.moe import init_moe, moe_apply, moe_capacity
from repro.quant import quantize

E, TOP_K, D_MODEL, D_FF = 32, 2, 256, 512
N_HI, LO_BITS, GROUP = 4, 4, 64
BATCHES = (1, 8, 32)
N_STEPS = 3 if BENCH_SMOKE else 10
JSON_OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "BENCH_kernels.json")


def _setup():
    cfg = MoEConfig(num_experts=E, top_k=TOP_K, d_ff_expert=D_FF,
                    norm_topk_prob=True)
    params = init_moe(jax.random.PRNGKey(0), D_MODEL, cfg)
    # Heavy-tailed routing (the serving regime the ragged path targets):
    # bias the router so a handful of experts absorb most tokens.
    bias = jnp.linspace(2.5, -2.5, E)[None, :]
    params["router"] = params["router"] * 0.3 + bias
    w = {n: a[None] for n, a in params["experts"].items()}
    bank = build_bank(w, n_hi=N_HI, lo_bits=LO_BITS, group_size=GROUP)
    # Publish the N_HI hottest experts (lowest column index = hottest under
    # the bias above) — the mixed hi/lo residency the kernel selects over.
    for s in range(N_HI):
        bank.slot_map = bank.slot_map.at[0, s].set(s)
        bank.slot_owner = bank.slot_owner.at[0, s].set(s)
        for n in bank.hi:
            bank.hi[n] = bank.hi[n].at[0, s].set(w[n][0, s])
    sliced = jax.tree_util.tree_map(lambda a: a[0], bank)
    shapes = {n: tuple(a.shape) for n, a in w.items()}
    lo_b = expert_lo_nbytes(shapes, LO_BITS, GROUP)
    hi_b = expert_hi_nbytes(shapes, hi_bits=16, group_size=GROUP)
    return cfg, params, sliced, lo_b, hi_b


def _bytes_per_token(counts: np.ndarray, slot_map: np.ndarray, batch: int,
                     path: str, lo_b: int, hi_b: int) -> float:
    """Weight bytes one decode step reads under ``path``, / batch tokens."""
    is_hi = slot_map >= 0
    if path.startswith("padded"):
        # Padded reads EVERY expert's lo codes + EVERY published hi slot.
        total = E * lo_b + int(is_hi.sum()) * hi_b
    else:
        active = counts > 0
        total = int((active & ~is_hi).sum()) * lo_b + \
            int((active & is_hi).sum()) * hi_b
    return total / batch


def run(report):
    cfg, params, bank, lo_b, hi_b = _setup()
    slot_map = np.asarray(bank.slot_map)
    rows = []
    for batch in BATCHES:
        cap = moe_capacity(batch, cfg, 2.0)
        for path in ("padded-jnp", "ragged-jnp"):
            dispatch = path.split("-")[0]

            @jax.jit
            def step(x):
                return moe_apply(params, bank, x, cfg, cap,
                                 dispatch=dispatch)

            xs = [jax.random.normal(jax.random.PRNGKey(7 + s),
                                    (batch, D_MODEL), jnp.bfloat16)
                  for s in range(N_STEPS)]
            step(xs[0])[0].block_until_ready()          # compile
            bpt, padr, act = [], [], []
            t0 = time.perf_counter()
            for x in xs:
                y, aux = step(x)
                y.block_until_ready()
                c = np.asarray(aux.counts)
                bpt.append(_bytes_per_token(c, slot_map, batch, path,
                                            lo_b, hi_b))
                padr.append(float(aux.dispatch_pad_ratio))
                act.append(float(aux.active_experts))
            dt = (time.perf_counter() - t0) / N_STEPS
            row = {
                "batch": batch,
                "path": path,
                "bytes_per_token": float(np.mean(bpt)),
                "pad_ratio": float(np.mean(padr)),
                "active_experts": float(np.mean(act)),
                "tokens_per_s": batch / dt,
                "num_experts": E,
                "n_hi": N_HI,
                "lo_bits": LO_BITS,
            }
            rows.append(row)
            report(f"kernels/dispatch/{path}/b{batch}", dt * 1e6,
                   round(row["bytes_per_token"] / 1024, 1))
    # The structural claim the ragged path exists for: strictly fewer
    # weight bytes per token than padded at every decode batch ≤ 32.
    for batch in BATCHES:
        p = next(r for r in rows if r["batch"] == batch
                 and r["path"] == "padded-jnp")
        g = next(r for r in rows if r["batch"] == batch
                 and r["path"] == "ragged-jnp")
        assert g["bytes_per_token"] < p["bytes_per_token"], \
            (batch, g["bytes_per_token"], p["bytes_per_token"])
    out = {"schema": "bench/kernels/v1", "smoke": BENCH_SMOKE,
           "config": {"num_experts": E, "top_k": TOP_K, "d_model": D_MODEL,
                      "d_ff_expert": D_FF, "n_hi": N_HI,
                      "lo_bits": LO_BITS, "group_size": GROUP,
                      "ragged_bm": int(os.environ.get(
                          "REPRO_MOE_RAGGED_BM", "8"))},
           "rows": rows}
    os.makedirs(os.path.dirname(JSON_OUT), exist_ok=True)
    with open(JSON_OUT, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)


def run_roofline(report):
    """Analytic TPU roofline for the plain quant-GEMM (the original
    deliverable — this container has no TPU, so derived = roofline speedup
    of the int-fused path over bf16 weights for the memory-bound GEMM)."""
    m, k, n = 128, 2048, 768          # one qwen3 expert GEMM at decode
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    for bits in (8, 4, 2):
        qt = quantize(w, bits=bits, group_size=64)
        quant_matmul_op(x, qt).block_until_ready()      # compile
        t0 = time.perf_counter()
        quant_matmul_op(x, qt).block_until_ready()
        dt = time.perf_counter() - t0
        w_bytes = qt.nbytes
        t_mem = w_bytes / (HBM_GBPS * 1e9)
        t_bf16 = (k * n * 2) / (HBM_GBPS * 1e9)
        t_flops = (2 * m * k * n) / (PEAK_TFLOPS_BF16 * 1e12)
        speedup = t_bf16 / max(t_mem, t_flops)
        report(f"kernels/quant_matmul_int{bits}/interpret", dt * 1e6,
               round(speedup, 2))


def run_flash(report):
    from repro.kernels.ops import flash_decode_op
    B, H, Hkv, hd, S = 4, 8, 2, 64, 4096
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, hd), jnp.bfloat16)
    kk = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, hd), jnp.bfloat16)
    valid = jnp.ones((B, S), bool)
    flash_decode_op(q, kk, v, valid, bs=512).block_until_ready()
    t0 = time.perf_counter()
    flash_decode_op(q, kk, v, valid, bs=512).block_until_ready()
    dt = time.perf_counter() - t0
    kv_bytes = 2 * B * S * Hkv * hd * 2
    report("kernels/flash_decode/interpret", dt * 1e6,
           round(kv_bytes / (HBM_GBPS * 1e9) * 1e6, 3))  # derived: v5e µs
