"""Jitted public wrappers for the Pallas kernels + the serving-path
dispatchers that pick an execution backend per environment.

On TPU the kernels compile natively; everywhere else (this CPU container)
they execute in ``interpret=True`` mode, which runs the kernel body in
Python for correctness validation against ``ref.py``.

Two dispatch axes for the MoE decode hot path, each overridable by env:

* ``REPRO_MOE_GEMM``      ∈ {auto, jnp, pallas} — how quantized expert
  GEMMs execute. ``auto``: native Pallas on TPU, the (bit-identical) jnp
  group-blocked expression on CPU. ``pallas`` off-TPU runs the kernels in
  interpret mode (slow; used by CI to exercise the kernel code paths).
* ``REPRO_MOE_DISPATCH``  ∈ {auto, padded, ragged} — token dispatch layout.
  ``padded``: the fixed-capacity (E, C, d) scatter + grouped GEMM over ALL
  experts (the reference path). ``ragged``: sorted, bm-aligned compacted
  activations + active-expert tile maps — only experts that received
  tokens stream their weights (see ``moe._dispatch_ragged``). ``auto``:
  ragged on TPU, padded on CPU.

The dispatch layout is resolved ONCE at engine construction
(``EngineConfig.moe_dispatch``) and threaded as a static jit argument, so a
changed env var cannot disagree with an already-compiled executable. The
GEMM backend is read at trace time per compilation: changing
``REPRO_MOE_GEMM`` mid-process only affects shapes traced afterwards —
callers that need a pinned backend pass ``backend=``/``gemm=`` explicitly.
"""
from __future__ import annotations

import math
import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.quant_matmul import (grouped_quant_matmul, quant_matmul,
                                        ragged_quant_ffn)
from repro.kernels.flash_decode import flash_decode, flash_decode_paged
from repro.quant.qtensor import QuantizedTensor


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def moe_gemm_backend() -> str:
    """Resolved quantized-GEMM backend: 'jnp' or 'pallas'."""
    v = os.environ.get("REPRO_MOE_GEMM", "auto")
    if v not in ("auto", "jnp", "pallas"):
        raise ValueError(f"REPRO_MOE_GEMM={v!r}; one of auto|jnp|pallas")
    if v == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return v


def moe_dispatch_default() -> str:
    """Resolved MoE dispatch layout: 'padded' or 'ragged'."""
    v = os.environ.get("REPRO_MOE_DISPATCH", "auto")
    if v not in ("auto", "padded", "ragged"):
        raise ValueError(
            f"REPRO_MOE_DISPATCH={v!r}; one of auto|padded|ragged")
    if v == "auto":
        return "ragged" if jax.default_backend() == "tpu" else "padded"
    return v


def grouped_lo_matmul(xg: jax.Array, packed: jax.Array, scales: jax.Array,
                      bits: int, group: int, *,
                      backend: str | None = None) -> jax.Array:
    """THE grouped lo-tier GEMM of the padded MoE path: xg (B, C, K) ×
    packed (B, K//epb, N) → (B, C, N). One dispatcher over the two
    re-expressions of the same group-blocked math — ``ref``'s jnp einsum
    chain and the Pallas kernel (interpret-mode off TPU) — which a parity
    test holds bit-identical."""
    be = backend if backend is not None else moe_gemm_backend()
    if be == "jnp":
        return _ref.grouped_lo_gemm_jnp(xg, packed, scales, bits, group)
    return grouped_quant_matmul(xg, packed, scales, bits=bits, group=group,
                                interpret=_interpret_default())


def _hold_last(vals: jax.Array) -> jax.Array:
    """Forward-fill negatives with the last non-negative value (and clip
    the leading run to 0): turns a sparse index sequence into a DMA hold
    map — repeated block indices make Pallas skip the refetch."""
    filled = jax.lax.associative_scan(
        lambda a, b: jnp.where(b < 0, a, b), vals)
    return jnp.maximum(filled, 0).astype(jnp.int32)


def ragged_quant_ffn_op(xs: jax.Array, tile_eid: jax.Array,
                        tile_slot: jax.Array, lo: dict, hi,
                        *, bits: int, group: int, bm: int,
                        backend: str | None = None) -> jax.Array:
    """Ragged mixed-precision expert FFN dispatcher. ``xs``: (Tt·bm, K)
    compacted activations; ``tile_eid``/``tile_slot``: (Tt,) per-tile
    expert id and hi-pool slot (−1 ⇒ lo). ``lo``: name → arrays with
    ``.packed``/``.scales`` (QuantizedTensor or shard-local view); ``hi``:
    name → (n_hi, K, N) bf16 or None. Returns (Tt·bm, D)."""
    be = backend if backend is not None else moe_gemm_backend()
    n_hi = 0 if hi is None else hi["w_gate"].shape[0]
    if be == "jnp":
        return _ref.ragged_quant_ffn_ref(
            xs, tile_eid, tile_slot,
            lo["w_gate"].packed, lo["w_gate"].scales,
            lo["w_up"].packed, lo["w_up"].scales,
            lo["w_down"].packed, lo["w_down"].scales,
            None if n_hi == 0 else hi["w_gate"],
            None if n_hi == 0 else hi["w_up"],
            None if n_hi == 0 else hi["w_down"],
            bits=bits, group=group, bm=bm)
    is_hi = (tile_slot >= 0) & (n_hi > 0)
    # DMA hold maps: the tier a tile does NOT compute with re-addresses the
    # previous tile's block, so only the resident tier streams per tile.
    tile_lo = _hold_last(jnp.where(is_hi, -1, tile_eid))
    tile_hi = _hold_last(jnp.where(is_hi, tile_slot, -1))
    return ragged_quant_ffn(
        xs, tile_lo, tile_hi, is_hi.astype(jnp.int32),
        lo["w_gate"].packed, lo["w_gate"].scales,
        lo["w_up"].packed, lo["w_up"].scales,
        lo["w_down"].packed, lo["w_down"].scales,
        None if n_hi == 0 else hi["w_gate"],
        None if n_hi == 0 else hi["w_up"],
        None if n_hi == 0 else hi["w_down"],
        bits=bits, group=group, bm=bm,
        interpret=_interpret_default())


def ragged_dense_ffn_op(xs: jax.Array, tile_eid: jax.Array, bank: dict,
                        *, bm: int, backend: str | None = None) -> jax.Array:
    """Ragged DENSE expert FFN dispatcher (fp16/offload banks — no
    quantized tier to fall back on, so inactive experts are skipped by the
    tile map alone). ``bank``: {'w_gate','w_up','w_down'} → (E, K, N).
    The Pallas backend reuses the fused mixed-precision kernel in all-hi
    mode — every tile reads its expert's dense weights through the hi-pool
    operand while a placeholder lo tier holds one zero expert and is never
    streamed (the per-tile DMA hold maps pin it to block 0). Falls back to
    the jnp oracle when the kernel's tiling constraints reject the shapes.
    Returns (Tt·bm, D)."""
    be = backend if backend is not None else moe_gemm_backend()
    w_gate, w_up, w_down = bank["w_gate"], bank["w_up"], bank["w_down"]
    if be == "pallas":
        K, F = w_gate.shape[1], w_gate.shape[2]
        group = math.gcd(math.gcd(K, F), 64)
        zero_lo = lambda k, n: (jnp.zeros((1, k, n), jnp.uint8),
                                jnp.zeros((1, k // group, n), w_gate.dtype))
        gp, gs = zero_lo(K, F)
        dp_, ds = zero_lo(F, K)
        ones = jnp.ones_like(tile_eid)
        try:
            return ragged_quant_ffn(
                xs, jnp.zeros_like(tile_eid), tile_eid, ones,
                gp, gs, gp, gs, dp_, ds,
                w_gate, w_up, w_down,
                bits=8, group=group, bm=bm, interpret=_interpret_default())
        except ValueError:   # tiling constraints — oracle is always valid
            pass
    return _ref.ragged_dense_ffn_ref(xs, tile_eid, w_gate, w_up, w_down,
                                     bm=bm)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul_op(x: jax.Array, qt: QuantizedTensor, bm: int = 128,
                    bn: int = 128, bk: int = 256,
                    interpret: bool | None = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    return quant_matmul(x, qt.packed, qt.scales, bits=qt.bits,
                        group=qt.group_size, bm=bm, bn=bn, bk=bk,
                        interpret=interpret)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def grouped_quant_matmul_op(xg: jax.Array, qt: QuantizedTensor, bm: int = 128,
                            bn: int = 128, bk: int = 256,
                            interpret: bool | None = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    return grouped_quant_matmul(xg, qt.packed, qt.scales, bits=qt.bits,
                                group=qt.group_size, bm=bm, bn=bn, bk=bk,
                                interpret=interpret)


@partial(jax.jit, static_argnames=("bs", "interpret"))
def flash_decode_op(q: jax.Array, k: jax.Array, v: jax.Array,
                    valid: jax.Array, bs: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    return flash_decode(q, k, v, valid, bs=bs, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged_op(q: jax.Array, k: jax.Array, v: jax.Array,
                          table: jax.Array, valid: jax.Array,
                          interpret: bool | None = None) -> jax.Array:
    """Block-table flash decode over the paged KV pool (see
    ``flash_decode_paged``); k/v are (N, Hkv, bt, hd) physical blocks —
    the ``PagedKVCache`` layout, one superblock slice."""
    interpret = _interpret_default() if interpret is None else interpret
    return flash_decode_paged(q, k, v, table, valid, interpret=interpret)
