"""DynaExq control loop (paper Fig. 4): glue between the hotness estimator,
the budget-feasible policy, and the transition pipeline.

The worker (serving engine) calls ``observe(counts)`` after every step with
the router-trace counts the MoE layers emit; ``maybe_update(now)`` runs the
policy at the ``T_u`` cadence. All of this is host-side and O(L·E) — far off
the token critical path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.core.budget import BudgetTracker, plan_budget
from repro.core.hotness import HotnessEstimator
from repro.core.policy import PolicyConfig, select_hi_set
from repro.core.transitions import TransitionManager
from repro.core.ver import ExpertBankQ, build_bank, expert_hi_nbytes


@dataclasses.dataclass
class ControllerConfig:
    update_interval_s: float = 1.0      # T_u
    alpha: float = 0.8                  # EMA
    margin: float = 0.0                 # hysteresis
    migration_bytes_per_window: int = 0
    max_transitions_per_layer: int = 0


class DynaExqController:
    def __init__(self, bank: ExpertBankQ, host_hi: Dict[str, np.ndarray],
                 n_hi_per_layer: int, hi_bytes_per_expert: int,
                 cfg: Optional[ControllerConfig] = None, tracker=None):
        """``tracker``: optional byte-reservation ledger (e.g. an
        account-scoped ``BudgetView`` of a serving engine's shared HBM
        envelope, so promotions contend with KV-cache admission); defaults
        to a private tracker capped at the hi pool's own size."""
        # A dataclass default instance would be shared (and mutated) across
        # every controller; each controller gets its own config.
        cfg = cfg if cfg is not None else ControllerConfig()
        L, E = bank.slot_map.shape
        self.cfg = cfg
        self.hotness = HotnessEstimator(L, E, alpha=cfg.alpha)
        self.policy = PolicyConfig(
            n_hi=n_hi_per_layer, margin=cfg.margin,
            max_transitions_per_layer=cfg.max_transitions_per_layer)
        self.tracker = tracker if tracker is not None else \
            BudgetTracker(n_hi_per_layer * L * hi_bytes_per_expert)
        self.tm = TransitionManager(
            bank, host_hi, self.tracker, hi_bytes_per_expert,
            migration_bytes_per_window=cfg.migration_bytes_per_window)
        self._last_update = time.monotonic()

    @property
    def bank(self) -> ExpertBankQ:
        return self.tm.bank

    def observe(self, counts) -> None:
        self.hotness.observe(counts)

    def maybe_update(self, now: Optional[float] = None, force: bool = False) -> bool:
        now = now if now is not None else time.monotonic()
        if not force and now - self._last_update < self.cfg.update_interval_s:
            # Still publish any copies that completed since last step.
            self.tm.publish_ready()
            return False
        self._last_update = now
        self.update()
        return True

    def update(self) -> None:
        """One policy window: fold EMA → per-layer top-n w/ hysteresis →
        enqueue transitions → drain → publish completed."""
        scores = self.hotness.fold()
        L = scores.shape[0]
        for l in range(L):
            current = self.tm.hi_set(l) | self.tm.pending_experts(l)
            _, promos, demos = select_hi_set(scores[l], current, self.policy)
            for e in demos:
                self.tm.request_demotion(l, int(e))
            for e in promos:
                self.tm.request_promotion(l, int(e))
        self.tm.drain()
        self.tm.publish_ready()

    def flush(self) -> None:
        """Block on all in-flight transitions and publish (tests/shutdown)."""
        self.tm.drain()
        self.tm.publish_ready(wait=True)
        # Anything still deferred (budget) is retried once after publish.
        self.tm.drain()
        self.tm.publish_ready(wait=True)
