"""Production meshes. Functions (not module constants) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: one pod = 16×16 = 256 chips (data × model); two pods add a
    leading 'pod' axis used for data parallelism across the DCN/ICI link."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small host-device mesh for in-repo tests (run in a subprocess with
    XLA_FLAGS=--xla_force_host_platform_device_count set)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_ep_mesh(n_shards: int, n_data: int = 1):
    """Expert-parallel serving mesh: ``n_shards`` devices on the model axis
    each own E/n_shards experts (and, under ``DistContext.tokens_ep_sharded``,
    a token slice); an optional data axis replicates the expert layout."""
    return jax.make_mesh((n_data, n_shards), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
