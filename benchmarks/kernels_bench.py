"""Kernel microbenchmarks: interpret-mode correctness-path timing plus the
ANALYTIC TPU roofline for the quant-GEMM (the number that matters — this
container has no TPU). derived = arithmetic-intensity/roofline speedup of the
int4 fused path over bf16 weights for the memory-bound decode GEMM."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.hw import HBM_GBPS, PEAK_TFLOPS_BF16
from repro.kernels.ops import quant_matmul_op
from repro.kernels import ref
from repro.quant import quantize


def run(report):
    m, k, n = 128, 2048, 768          # one qwen3 expert GEMM at decode
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    for bits in (8, 4, 2):
        qt = quantize(w, bits=bits, group_size=64)
        quant_matmul_op(x, qt).block_until_ready()      # compile
        t0 = time.perf_counter()
        quant_matmul_op(x, qt).block_until_ready()
        dt = time.perf_counter() - t0
        # analytic v5e roofline: memory-bound decode GEMM time = bytes/bw
        w_bytes = qt.nbytes
        t_mem = w_bytes / (HBM_GBPS * 1e9)
        t_bf16 = (k * n * 2) / (HBM_GBPS * 1e9)
        t_flops = (2 * m * k * n) / (PEAK_TFLOPS_BF16 * 1e12)
        speedup = t_bf16 / max(t_mem, t_flops)
        report(f"kernels/quant_matmul_int{bits}/interpret", dt * 1e6,
               round(speedup, 2))


def run_flash(report):
    from repro.kernels.ops import flash_decode_op
    B, H, Hkv, hd, S = 4, 8, 2, 64, 4096
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, hd), jnp.bfloat16)
    kk = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, hd), jnp.bfloat16)
    valid = jnp.ones((B, S), bool)
    flash_decode_op(q, kk, v, valid, bs=512).block_until_ready()
    t0 = time.perf_counter()
    flash_decode_op(q, kk, v, valid, bs=512).block_until_ready()
    dt = time.perf_counter() - t0
    kv_bytes = 2 * B * S * Hkv * hd * 2
    report("kernels/flash_decode/interpret", dt * 1e6,
           round(kv_bytes / (HBM_GBPS * 1e9) * 1e6, 3))  # derived: v5e µs
