"""QoS scheduler policy tests: tiered queue ordering + weighted aging,
submit-time validation, workload→QoS mapping, shed policies, deadline
expiry, and virtual-clock accounting determinism."""
import types

import numpy as np
import pytest

from repro.serving import (EngineConfig, InferenceEngine, Request,
                           RequestState, RequestStream, SchedulerConfig,
                           TieredQueue, WORKLOAD_QOS, make_backend,
                           make_prompts, resolve_qos)
from repro.serving.scheduler import Scheduler


def _h(qos, enqueue_s=0.0, preempts=0, max_new=8, done=0):
    return types.SimpleNamespace(
        qos=qos, exec_qos=qos, enqueue_s=enqueue_s, preempts=preempts,
        request=types.SimpleNamespace(max_new_tokens=max_new),
        tokens=[0] * done)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# TieredQueue
# ---------------------------------------------------------------------------

def test_tiered_queue_class_order_and_fifo():
    clk = FakeClock()
    q = TieredQueue(clk, aging_s=5.0)
    b1, b2 = _h("batch"), _h("batch")
    s1, p1 = _h("standard"), _h("premium")
    for h in (b1, s1, b2, p1):
        q.append(h)
    assert len(q) == 4 and bool(q)
    # Premium first, then standard, then batch in FIFO order.
    assert q.peek() is p1
    assert [q.popleft() for _ in range(4)] == [p1, s1, b1, b2]
    assert not q
    with pytest.raises(IndexError):
        q.popleft()


def test_tiered_queue_weighted_aging_no_starvation():
    clk = FakeClock()
    q = TieredQueue(clk, aging_s=5.0)
    old_batch = _h("batch", enqueue_s=0.0)
    q.append(old_batch)
    clk.t = 11.0                      # age 11s → priority 0 + 11/5 = 2.2
    fresh_premium = _h("premium", enqueue_s=11.0)   # priority 2.0
    q.append(fresh_premium)
    assert q.popleft() is old_batch   # aged batch outranks fresh premium
    assert q.popleft() is fresh_premium


def test_tiered_queue_ties_break_to_higher_class():
    clk = FakeClock()
    q = TieredQueue(clk, aging_s=5.0)
    s = _h("standard", enqueue_s=0.0)     # priority 1.0 at t=0
    p = _h("premium", enqueue_s=0.0)      # priority 2.0 at t=0
    q.append(s)
    q.append(p)
    assert q.popleft() is p


def test_tiered_queue_requeue_keeps_age():
    clk = FakeClock()
    q = TieredQueue(clk, aging_s=1.0)
    old = _h("batch", enqueue_s=0.0)
    clk.t = 10.0
    q.append(old)                       # age survives append
    q.appendleft(q.popleft())           # requeue must not reset the age
    q.append(_h("premium", enqueue_s=10.0))
    assert q.popleft() is old           # 10s/1s aging beats premium's 2.0


def test_tiered_queue_prune():
    clk = FakeClock()
    q = TieredQueue(clk, aging_s=5.0)
    hs = [_h("batch", max_new=i) for i in range(4)]
    for h in hs:
        q.append(h)
    dropped = q.prune(lambda h: h.request.max_new_tokens % 2 == 0)
    assert sorted(h.request.max_new_tokens for h in dropped) == [0, 2]
    assert len(q) == 2


# ---------------------------------------------------------------------------
# Pure policy: resolution, shedding, victim selection
# ---------------------------------------------------------------------------

def test_resolve_qos_loud():
    assert resolve_qos(None, "standard") == "standard"
    assert resolve_qos("premium", "standard") == "premium"
    with pytest.raises(ValueError, match="unknown QoS"):
        resolve_qos("gold", "standard")
    with pytest.raises(ValueError):
        SchedulerConfig(qos_default="gold").validate()
    with pytest.raises(ValueError):
        SchedulerConfig(shed_policy="maybe").validate()
    with pytest.raises(ValueError):
        SchedulerConfig(aging_s=0.0).validate()


def test_admit_action_policies():
    calm = {"queue_depth": 0.0, "est_wait_s": 0.0}
    hot = {"queue_depth": 99.0, "est_wait_s": 99.0}
    none_ = Scheduler(SchedulerConfig(shed_policy="none"))
    rej = Scheduler(SchedulerConfig(shed_policy="reject"))
    down = Scheduler(SchedulerConfig(shed_policy="downgrade"))
    for qos in ("batch", "standard", "premium"):
        assert none_.admit_action(qos, hot) == "admit"
        assert rej.admit_action(qos, calm) == "admit"
    assert rej.admit_action("batch", hot) == "shed"
    assert rej.admit_action("standard", hot) == "downgrade"
    assert rej.admit_action("premium", hot) == "admit"   # never touched
    assert down.admit_action("batch", hot) == "downgrade"
    assert down.admit_action("premium", hot) == "admit"


def test_pick_victim_rules():
    sched = Scheduler(SchedulerConfig(max_preempts=2))
    b_near = (0, _h("batch", max_new=8, done=7))
    b_far = (1, _h("batch", max_new=8, done=1))
    s = (2, _h("standard", max_new=8))
    # Strictly lower class only; most remaining work first.
    assert sched.pick_victim([b_near, b_far, s], "premium") == b_far
    assert sched.pick_victim([s], "standard") is None
    # Batch before standard even with less remaining work.
    assert sched.pick_victim([b_near, s], "premium") == b_near
    # Eviction cap protects liveness.
    capped = (3, _h("batch", preempts=2))
    assert sched.pick_victim([capped], "premium") is None
    assert Scheduler(SchedulerConfig(preemption=False)).pick_victim(
        [b_far], "premium") is None


def test_decode_groups_partition():
    sched = Scheduler(SchedulerConfig())
    rows = [(0, _h("premium")), (1, _h("standard")), (2, _h("batch"))]
    groups = sched.decode_groups(rows, spec_on=True)
    assert [k for k, _ in groups] == ["spec", "mixed", "lo"] or \
        [k for k, _ in groups] == ["spec", "lo"]
    # spec off: premium+standard share the mixed group.
    groups = sched.decode_groups(rows, spec_on=False)
    assert [k for k, _ in groups] == ["mixed", "lo"]
    assert len(groups[0][1]) == 2
    # Uniform default traffic is ONE group — the untiered engine.
    uni = [(i, _h("standard")) for i in range(3)]
    assert len(sched.decode_groups(uni, spec_on=False)) == 1


# ---------------------------------------------------------------------------
# Request / RequestStream plumbing
# ---------------------------------------------------------------------------

def test_request_stream_workload_qos_and_jitter():
    stream = RequestStream(
        vocab_size=512, phases=[("text", 2), ("math", 2), ("code", 2)],
        prompt_len=8, arrival_rate_rps=100.0, arrival_jitter_s=0.01,
        seed=3, qos="workload")
    reqs = list(stream)
    assert [r.qos for r in reqs] == [WORKLOAD_QOS[r.workload] for r in reqs]
    arrivals = [r.arrival_s for r in reqs]
    assert arrivals == sorted(arrivals)          # jitter stays monotone
    # Jitter actually perturbs the bare Poisson process.
    bare = [r.arrival_s for r in RequestStream(
        vocab_size=512, phases=[("text", 2), ("math", 2), ("code", 2)],
        prompt_len=8, arrival_rate_rps=100.0, seed=3)]
    assert arrivals != bare
    with pytest.raises(ValueError, match="unknown QoS"):
        RequestStream(vocab_size=512, phases=[("text", 1)], qos="gold")
    # No class on the stream → requests carry none (engine default applies).
    assert all(r.qos is None for r in RequestStream(
        vocab_size=512, phases=[("text", 2)], prompt_len=8))


# ---------------------------------------------------------------------------
# Engine integration (reduced MoE)
# ---------------------------------------------------------------------------

def _prompt(cfg, ln, seed):
    return make_prompts("text", cfg.vocab_size, 1, ln, seed=seed)[0]


def test_submit_validation_loud(engine_factory, serving_setup):
    cfg, _ = serving_setup
    eng = engine_factory("fp16")
    with pytest.raises(ValueError, match="unknown QoS"):
        eng.submit(Request(tokens=_prompt(cfg, 8, 0), qos="gold"))
    with pytest.raises(ValueError, match="deadline_ms"):
        eng.submit(Request(tokens=_prompt(cfg, 8, 0), deadline_ms=0.0))
    h = eng.submit(Request(tokens=_prompt(cfg, 8, 0), max_new_tokens=2,
                           qos="premium", deadline_ms=5000.0))
    assert h.qos == "premium"
    eng.drain()
    assert len(h.tokens) == 2


def test_shed_reject_policy(serving_setup):
    from repro.configs import get_config  # noqa: F401  (fixture provides cfg)
    import jax
    cfg, params = serving_setup
    clone = jax.tree_util.tree_map(lambda x: x, params)
    eng = InferenceEngine(
        cfg, clone, make_backend("fp16"),
        EngineConfig(max_slots=2, max_len=64,
                     scheduler=SchedulerConfig(shed_policy="reject",
                                               shed_queue_depth=1)))
    # Overload the queue without stepping: depth climbs past the knob.
    keep = [eng.submit(Request(tokens=_prompt(cfg, 8, i), max_new_tokens=2,
                               qos="standard")) for i in range(3)]
    shed = eng.submit(Request(tokens=_prompt(cfg, 8, 9), max_new_tokens=2,
                              qos="batch"))
    assert shed.state is RequestState.SHED
    late_std = eng.submit(Request(tokens=_prompt(cfg, 8, 10),
                                  max_new_tokens=2, qos="standard"))
    assert late_std.exec_qos == "batch"          # downgraded, not dropped
    prem = eng.submit(Request(tokens=_prompt(cfg, 8, 11), max_new_tokens=2,
                              qos="premium"))
    assert prem.exec_qos == "premium"            # premium never touched
    eng.drain()
    st = eng.stats()
    assert st["shed_requests"] >= 1 and st["downgraded"] >= 1
    assert all(len(h.tokens) == 2 for h in keep + [late_std, prem])
    assert shed.tokens == []                     # never served


def test_overloaded_keys_on_budget_headroom():
    """Byte pressure alone (shared-envelope headroom below the knob) is an
    overload signal, independent of queue depth / wait estimates."""
    sched = Scheduler(SchedulerConfig(shed_policy="downgrade"))
    idle = {"queue_depth": 0.0, "est_wait_s": 0.0}
    assert not sched.overloaded({**idle, "budget_headroom_frac": 0.5})
    assert sched.overloaded({**idle, "budget_headroom_frac": 0.01})
    assert sched.admit_action(
        "batch", {**idle, "budget_headroom_frac": 0.01}) == "downgrade"
    # No envelope configured → signal absent → full headroom, no shed.
    assert not sched.overloaded(idle)
    with pytest.raises(ValueError, match="shed_headroom_frac"):
        SchedulerConfig(shed_headroom_frac=1.0).validate()
    with pytest.raises(ValueError, match="shed_headroom_frac"):
        SchedulerConfig(shed_headroom_frac=-0.1).validate()


def test_shed_under_byte_pressure_empty_queue(serving_setup):
    """Regression: a nearly-exhausted HBM envelope must shed/downgrade at
    submit time even with an EMPTY queue (the next admission would stall on
    reclaim), and admission must recover when the pressure releases."""
    import jax
    cfg, params = serving_setup
    clone = jax.tree_util.tree_map(lambda x: x, params)
    eng = InferenceEngine(
        cfg, clone, make_backend("fp16"),
        EngineConfig(max_slots=2, max_len=64, hbm_budget_bytes=1 << 30,
                     scheduler=SchedulerConfig(shed_policy="reject")))
    # Starve the envelope directly (stand-in for KV blocks + hi-tier
    # promotions filling HBM) — queue stays empty throughout.
    grab = int(eng.budget.free - 0.01 * eng.budget.cap)
    assert eng.budget.try_reserve(grab, account="pressure")
    snap = eng.load_snapshot()
    assert snap["queue_depth"] == 0.0
    assert snap["budget_headroom_frac"] < 0.05
    shed = eng.submit(Request(tokens=_prompt(cfg, 8, 0), max_new_tokens=2,
                              qos="batch"))
    assert shed.state is RequestState.SHED
    down = eng.submit(Request(tokens=_prompt(cfg, 8, 1), max_new_tokens=2,
                              qos="standard"))
    assert down.exec_qos == "batch"              # downgraded, not dropped
    prem = eng.submit(Request(tokens=_prompt(cfg, 8, 2), max_new_tokens=2,
                              qos="premium"))
    assert prem.exec_qos == "premium"            # premium never touched
    eng.budget.release(grab, account="pressure")
    ok = eng.submit(Request(tokens=_prompt(cfg, 8, 3), max_new_tokens=2,
                            qos="batch"))
    assert ok.state is not RequestState.SHED
    eng.drain()
    assert eng.stats()["shed_requests"] >= 1
    assert all(len(h.tokens) == 2 for h in (down, prem, ok))
    assert shed.tokens == []


def test_expired_batch_deadline_dropped(serving_setup):
    import jax
    cfg, params = serving_setup
    clone = jax.tree_util.tree_map(lambda x: x, params)
    eng = InferenceEngine(cfg, clone, make_backend("fp16"),
                          EngineConfig(max_slots=1, max_len=64))
    first = eng.submit(Request(tokens=_prompt(cfg, 8, 0), max_new_tokens=4))
    # Queued behind `first` with an already-hopeless deadline.
    doomed = eng.submit(Request(tokens=_prompt(cfg, 8, 1), max_new_tokens=4,
                                qos="batch", deadline_ms=1e-6))
    eng.drain()
    assert first.state is RequestState.FINISHED
    assert doomed.state is RequestState.SHED
    assert eng.stats()["shed_requests"] == 1.0


def test_virtual_replay_accounting_deterministic(serving_setup):
    import jax
    cfg, params = serving_setup

    def run():
        clone = jax.tree_util.tree_map(lambda x: x, params)
        eng = InferenceEngine(cfg, clone, make_backend("fp16"),
                              EngineConfig(max_slots=2, max_len=64))
        stream = RequestStream(
            vocab_size=cfg.vocab_size, phases=[("text", 4), ("code", 2)],
            prompt_len=8, max_new_tokens=4, arrival_rate_rps=200.0,
            arrival_jitter_s=0.002, seed=7, qos="workload")
        handles = eng.replay(stream, realtime=False)
        assert eng._clock is None                # clock uninstalled on exit
        return handles

    a, b = run(), run()
    assert [h.tokens for h in a] == [h.tokens for h in b]
    # Virtual-clock accounting is bit-deterministic across runs and
    # submit-inclusive (first token can never precede submit).
    assert [h.ttft_s for h in a] == [h.ttft_s for h in b]
    assert [h.finish_s for h in a] == [h.finish_s for h in b]
    for h in a:
        assert h.first_token_s >= h.submit_s
        assert h.finish_s >= h.first_token_s
        assert np.isfinite(h.ttft_s) and h.ttft_s >= 0.0


def test_generate_qos_kwarg(engine_factory, serving_setup):
    cfg, _ = serving_setup
    eng = engine_factory("fp16", max_slots=2)
    toks = np.stack([_prompt(cfg, 8, i) for i in range(2)], 0)
    out, ttft, _ = eng.generate({"tokens": toks}, 3, qos="premium",
                                deadline_ms=10_000.0)
    assert out.shape == (2, 3) and ttft >= 0.0
