"""Paged KV-cache subsystem, end to end: the paged engine is token-identical
to the dense-slot engine on mixed-length request streams (full-attention,
sliding-window ring and mamba stacks, with and without prefix sharing),
prefix reuse measurably skips prefill compute, KV block reservations and
expert hi-tier promotions draw from ONE BudgetTracker (promotion
backpressure under KV pressure), and the paged flash-decode kernel matches
the gathered dense reference."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ControllerConfig
from repro.models import init_params
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           RequestStream, make_backend, make_prompts)
from repro.serving.engine import _prefill_paged_jit


def _engine(cfg, params, backend_name="fp16", paged=True, sharing=True,
            max_slots=2, max_len=64, prefill_rows=None, hbm=None, **bkw):
    clone = jax.tree_util.tree_map(lambda x: x, params)
    if backend_name == "dynaexq":
        bkw.setdefault("lo_bits", 4)
        bkw.setdefault("n_hi_per_layer", 2)
        bkw.setdefault("controller", ControllerConfig(update_interval_s=0.0))
    return InferenceEngine(
        cfg, clone, make_backend(backend_name, **bkw),
        EngineConfig(max_slots=max_slots, max_len=max_len, paged=paged,
                     prefix_sharing=sharing, prefill_rows=prefill_rows,
                     hbm_budget_bytes=hbm))


def _serve(eng, prompts, news, flush_each_step=False):
    """``flush_each_step``: barrier the backend's async transitions at
    every window boundary. DynaExq publishes a promotion whenever its
    device copy happens to report ready relative to the host loop, so two
    engines (or two runs) legitimately serve a given step at different
    expert precisions; flushing pins publication to the issuing window,
    making token streams comparable across engines."""
    handles = [eng.submit(Request(tokens=p, max_new_tokens=n))
               for p, n in zip(prompts, news)]
    if flush_each_step:
        while eng.queue or any(s is not None for s in eng.slots):
            eng.step()
            eng.backend.flush()
    else:
        eng.drain()
    return [h.tokens for h in handles]


# ---------------------------------------------------------------------------
# Parity: paged == dense, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend_name", ["fp16", "dynaexq"])
def test_paged_matches_dense_mixed_lengths(serving_setup, backend_name):
    cfg, params = serving_setup
    lens, news = (9, 13, 30, 7, 21), (3, 6, 5, 8, 4)
    prompts = [make_prompts("text", cfg.vocab_size, 1, ln, seed=ln)[0]
               for ln in lens]
    sync = backend_name == "dynaexq"         # pin async publish timing
    dense = _serve(_engine(cfg, params, backend_name, paged=False),
                   prompts, news, flush_each_step=sync)
    eng = _engine(cfg, params, backend_name, paged=True)
    paged = _serve(eng, prompts, news, flush_each_step=sync)
    assert dense == paged
    if sync:
        assert eng.stats()["promotions"] > 0  # parity WITH promotions live
    eng.pool.check_invariants()
    st = eng.stats()
    assert st["kv_blocks_in_use"] >= 0 and "prefix_hit_tokens" in st


def test_paged_matches_dense_sliding_window(serving_setup):
    """Ring (sliding-window) caches: wrap during prefill AND decode, with
    prefix hits whose shared blocks get copy-on-written when the ring wraps
    back over them."""
    cfg, params0 = serving_setup
    cfg = dataclasses.replace(
        cfg, name="granite-sw32",
        attn=dataclasses.replace(cfg.attn, sliding_window=32))
    params = init_params(jax.random.PRNGKey(0), cfg)
    sysp = make_prompts("text", cfg.vocab_size, 1, 16, seed=5)[0]
    prompts = [np.concatenate(
        [sysp, make_prompts("code", cfg.vocab_size, 1, 4, seed=i)[0]])
        for i in range(3)]
    prompts.append(make_prompts("math", cfg.vocab_size, 1, 40, seed=9)[0])
    news = (20, 20, 20, 6)
    dense = _serve(_engine(cfg, params, paged=False, max_slots=3,
                           prefill_rows=1), prompts, news)
    eng = _engine(cfg, params, paged=True, max_slots=3, prefill_rows=1)
    paged = _serve(eng, prompts, news)
    assert dense == paged
    st = eng.stats()
    # decode wrapped past the window onto trie-shared blocks → COW fired
    assert st["kv_cow_copies"] > 0 and st["prefix_hit_tokens"] > 0
    eng.pool.check_invariants()


def test_paged_matches_dense_mamba_and_mixed_stack(serving_setup):
    """Pure-SSM stacks have no KV to page (engine falls back to dense rows
    even under paged=True); mixed mamba+attn stacks page their attention
    caches with the trie auto-disabled (recurrent state cannot be leased)."""
    cfgm = dataclasses.replace(get_config("mamba2-130m", reduced=True),
                               n_layers=2)
    pm = init_params(jax.random.PRNGKey(0), cfgm)
    prompts = [make_prompts("text", cfgm.vocab_size, 1, ln, seed=ln)[0]
               for ln in (5, 19, 40)]
    dense = _serve(_engine(cfgm, pm, paged=False), prompts, (2, 2, 2))
    eng = _engine(cfgm, pm, paged=True)
    assert eng.pool is None                       # nothing to page
    assert dense == _serve(eng, prompts, (2, 2, 2))

    cfgj = get_config("jamba-v0_1-52b", reduced=True)
    pj = init_params(jax.random.PRNGKey(0), cfgj)
    prompts = [make_prompts("text", cfgj.vocab_size, 1, ln, seed=ln)[0]
               for ln in (5, 19, 33)]
    dense = _serve(_engine(cfgj, pj, paged=False), prompts, (3, 3, 3))
    eng = _engine(cfgj, pj, paged=True)
    assert eng.pool is not None and eng.trie is None
    assert dense == _serve(eng, prompts, (3, 3, 3))
    eng.pool.check_invariants()


# ---------------------------------------------------------------------------
# Prefix sharing: measurable reuse
# ---------------------------------------------------------------------------

def test_prefix_sharing_skips_prefill_compute(serving_setup):
    """Shared-prefix workload: token-identical to dense, strictly fewer
    prompt tokens computed, hits reported per request and in stats()."""
    cfg, params = serving_setup
    sysp = make_prompts("text", cfg.vocab_size, 1, 32, seed=999)[0]
    prompts = [np.concatenate(
        [sysp, make_prompts("math", cfg.vocab_size, 1, 8, seed=i)[0]])
        for i in range(4)]
    news = (4,) * 4
    dense_eng = _engine(cfg, params, paged=False)
    dense = _serve(dense_eng, prompts, news)
    eng = _engine(cfg, params, paged=True, sharing=True)
    shared = _serve(eng, prompts, news)
    assert dense == shared
    st, st_d = eng.stats(), dense_eng.stats()
    assert st["prefill_tokens"] < st_d["prefill_tokens"]
    assert st["prefix_hit_tokens"] > 0
    assert st["kv_blocks_in_use"] > 0             # trie keeps prefixes warm
    # every prompt token was either computed or served from the trie
    assert sum(st[k] for k in ("prefill_tokens", "prefix_hit_tokens")) == \
        sum(len(p) for p in prompts)
    eng.pool.check_invariants()


def test_prefix_sharing_compile_count(serving_setup):
    """Bucketed admission survives paging: a mixed-length stream compiles
    at most #buckets paged-prefill executables per has_prefix variant."""
    cfg, params = serving_setup
    eng = _engine(cfg, params, paged=True, max_slots=4, prefill_rows=4)
    before = _prefill_paged_jit._cache_size()
    lens = (4, 7, 9, 13, 18, 23, 29, 33, 41, 55)
    prompts = [make_prompts("text", cfg.vocab_size, 1, ln, seed=ln)[0]
               for ln in lens]
    _serve(eng, prompts, (2,) * len(lens))
    n_buckets = len(eng.buckets)
    assert len(eng.prefill_shapes) <= n_buckets
    assert _prefill_paged_jit._cache_size() - before <= 2 * n_buckets
    assert eng.counters["prefills"] < len(lens)


# ---------------------------------------------------------------------------
# One budget: KV admission vs expert promotions
# ---------------------------------------------------------------------------

def test_kv_and_promotions_share_one_budget(serving_setup):
    """KV block reservations and hi-tier promotions draw from the same
    BudgetTracker: under KV pressure promotions defer (backpressure), and
    finished requests' freed KV bytes are exactly what lets the deferred
    promotions proceed. check_invariants stays green on the account-scoped
    views throughout."""
    cfg, params = serving_setup
    probe = _engine(cfg, params, "dynaexq", paged=True, max_slots=8)
    hi_b = next(iter(probe.backend.controllers.values())).tm.hi_bytes
    bb = probe.pool.block_bytes
    # 8 in-flight requests × 4 blocks (+ trash) of live KV, an envelope
    # with headroom for exactly ONE hi expert on top: while the requests
    # run, at most one promotion fits; their release frees > hi_b.
    kv_live = (1 + 8 * 4) * bb
    assert kv_live - bb > hi_b, "config drifted: KV must outweigh one expert"
    eng = _engine(cfg, params, "dynaexq", paged=True, sharing=False,
                  max_slots=8, hbm=kv_live + hi_b)
    prompts = [make_prompts("text", cfg.vocab_size, 1, 56, seed=i)[0]
               for i in range(8)]
    handles = [eng.submit(Request(tokens=p, max_new_tokens=16))
               for p in prompts]
    eng.step()                                # admit all 8 → KV fully live
    eng.step()
    ctls = list(eng.backend.controllers.values())
    assert eng.pool.bytes_in_use == kv_live
    deferred_mid = sum(c.tm.stats["deferred"] for c in ctls)
    promoted_mid = sum(c.tm.stats["promoted"] for c in ctls)
    assert deferred_mid > 0                   # KV pressure deferred hi work
    assert promoted_mid <= 1                  # only the headroom expert fit
    for c in ctls:
        c.tm.check_invariants()               # per-account books stay exact
    eng.drain()
    eng.flush()
    assert all(len(h.tokens) > 0 for h in handles)
    # requests done → KV bytes returned to the shared envelope → the same
    # promotions now fit (drive a few policy windows on the warm hotness)
    assert eng.pool.quota_blocks == 0 and eng.pool.blocks_in_use == 0
    for _ in range(3):
        eng.backend.force_update()
    eng.flush()
    assert sum(c.tm.stats["promoted"] for c in ctls) > promoted_mid
    for c in ctls:
        c.tm.check_invariants()
    eng.pool.check_invariants()


def test_kv_admission_waits_for_budget(serving_setup):
    """A request whose KV quota cannot be reserved waits QUEUED (no crash,
    no partial admission) and is admitted once earlier requests finish."""
    cfg, params = serving_setup
    probe = _engine(cfg, params, paged=True, max_slots=2)
    one_req_blocks = probe._quota_blocks(24, 0, 4)
    block_bytes = probe.pool.block_bytes
    # envelope fits the trash block + exactly one in-flight request
    eng = _engine(cfg, params, paged=True, sharing=False, max_slots=2,
                  hbm=(1 + one_req_blocks) * block_bytes)
    prompts = [make_prompts("text", cfg.vocab_size, 1, 24, seed=i)[0]
               for i in range(3)]
    handles = [eng.submit(Request(tokens=p, max_new_tokens=4))
               for p in prompts]
    eng.step()
    running = [h for h in handles if h.slot is not None]
    assert len(running) == 1                  # budget admits exactly one
    eng.drain()
    assert all(len(h.tokens) == 4 for h in handles)
    assert eng.pool.stats["quota_denied"] > 0
    eng.pool.check_invariants()


def test_undersized_pool_defers_instead_of_crashing(serving_setup):
    """An explicitly undersized pool (kv_blocks < max_slots·nb + 1)
    serializes admissions against the physical block supply; a pool too
    small for even ONE sequence is rejected at engine construction."""
    cfg, params = serving_setup
    clone = jax.tree_util.tree_map(lambda x: x, params)
    with pytest.raises(ValueError, match="kv_blocks"):
        InferenceEngine(cfg, clone, make_backend("fp16"),
                        EngineConfig(max_slots=2, max_len=64, kv_blocks=3))
    # room for exactly one sequence (4 blocks) + trash: 3 requests on 2
    # slots must run one at a time, never exhausting the pool
    eng = InferenceEngine(
        cfg, jax.tree_util.tree_map(lambda x: x, params),
        make_backend("fp16"),
        EngineConfig(max_slots=2, max_len=64, kv_blocks=5,
                     prefix_sharing=False))
    prompts = [make_prompts("text", cfg.vocab_size, 1, 30, seed=i)[0]
               for i in range(3)]
    handles = [eng.submit(Request(tokens=p, max_new_tokens=4))
               for p in prompts]
    eng.step()
    assert sum(h.slot is not None for h in handles) == 1
    eng.drain()
    assert all(len(h.tokens) == 4 for h in handles)
    eng.pool.check_invariants()


def test_submit_rejects_never_satisfiable_request(serving_setup):
    """A request whose worst-case KV quota can never fit the envelope is
    rejected loudly at submit() instead of blocking the queue forever."""
    cfg, params = serving_setup
    probe = _engine(cfg, params, paged=True, max_slots=2)
    bb = probe.pool.block_bytes
    eng = _engine(cfg, params, paged=True, sharing=False, max_slots=2,
                  hbm=2 * bb)                 # trash + ONE block, ever
    with pytest.raises(ValueError, match="envelope"):
        eng.submit(Request(tokens=make_prompts(
            "text", cfg.vocab_size, 1, 40, seed=0)[0], max_new_tokens=8))
    # a request that fits still serves
    h = eng.submit(Request(tokens=make_prompts(
        "text", cfg.vocab_size, 1, 8, seed=1)[0], max_new_tokens=4))
    eng.drain()
    assert len(h.tokens) == 4
    eng.pool.check_invariants()


# ---------------------------------------------------------------------------
# Virtual-clock replay (deterministic CI streams)
# ---------------------------------------------------------------------------

def test_replay_virtual_clock_deterministic(serving_setup):
    """replay(realtime=False) decouples arrival pacing from the machine: two
    replays of the same stream produce identical admission interleavings
    and token streams, and arrival order is preserved."""
    cfg, params = serving_setup

    def run():
        eng = _engine(cfg, params, paged=True, max_slots=2)
        stream = RequestStream(cfg.vocab_size,
                               phases=[("text", 3), ("math", 3)],
                               prompt_len=10, prompt_len_jitter=3,
                               max_new_tokens=3, arrival_rate_rps=200.0,
                               seed=3)
        handles = eng.replay(stream, realtime=False, virtual_step_s=2e-3)
        order = [h.id for h in handles]
        return order, [h.tokens for h in handles], eng.counters.copy()

    o1, t1, c1 = run()
    o2, t2, c2 = run()
    assert o1 == o2 and t1 == t2
    # the full engine schedule (admission groups, step count) repeats too
    for k in ("steps", "prefills", "admitted", "finished"):
        assert c1[k] == c2[k], (k, c1, c2)


def test_replay_realtime_still_default(serving_setup):
    cfg, params = serving_setup
    eng = _engine(cfg, params, paged=True, max_slots=2)
    stream = RequestStream(cfg.vocab_size, phases=[("text", 3)],
                           prompt_len=8, max_new_tokens=2,
                           arrival_rate_rps=500.0, seed=1)
    handles = eng.replay(stream)              # wall-clock path unchanged
    assert all(len(h.tokens) == 2 for h in handles)
