"""Pallas TPU kernels: fused dequant + matmul for int4/int2/int8 weights.

The DynaExq lo-tier GEMMs. The packed codes stream HBM→VMEM at ``bits``/8
bytes per element — the entire memory-footprint benefit of the lo tier —
and are expanded *in VMEM* right before feeding the MXU, so no dequantized
copy ever exists in HBM.

Three kernels:

* ``quant_matmul``          — plain (M, K) × q(K, N), dequant-tile-then-dot.
* ``grouped_quant_matmul``  — batched-over-experts (E, C, K) × q(E, K, N),
  the PADDED MoE path. Uses the group-blocked formulation (per-group partial
  dot, scales applied after) so its arithmetic matches the jnp expression
  ``ref.grouped_lo_gemm_jnp`` bit for bit — the two are collapsed behind one
  dispatcher (``ops.grouped_lo_matmul``) with a parity test.
* ``ragged_quant_ffn``      — the decode hot path: ONE fused mixed-precision
  SwiGLU FFN over a bm-aligned ragged layout. Tokens arrive compacted
  (sorted by expert, segments padded to the row-tile bm — no (E, C, d)
  zero-padded buffer), scalar-prefetched tile→expert maps drive the weight
  BlockSpecs, and each tile streams ONLY its expert's resident tier: hi
  (bf16 slot) or lo (packed int codes dequantized in VMEM). Inactive
  (zero-token) experts never appear in the tile maps, so their weights are
  never read; tail tiles past the ragged extent repeat the previous tile's
  weight block index, which Pallas recognizes as "no new DMA". w_gate and
  w_up fuse into one grid sweep with the SiLU·mul epilogue in VMEM; the
  grouped w_down GEMM rides the same tile maps.

Tiling: grid (tiles, N/bn, K/bk); K is the innermost (sequential) axis with
f32 VMEM accumulators. bk is a multiple of the quantization group so each
K-tile sees whole scale groups.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_tile(wp: jax.Array, bits: int) -> jax.Array:
    """wp: (rows, bn) uint8 packed → (rows · 8//bits, bn) centered int32."""
    if bits == 8:
        return wp.astype(jnp.int32) - 128
    epb = 8 // bits
    bkp, bn = wp.shape
    shifts = (jnp.arange(epb, dtype=jnp.uint32) * bits)[None, :, None]
    u = (wp.astype(jnp.uint32)[:, None, :] >> shifts) & ((1 << bits) - 1)
    return u.reshape(bkp * epb, bn).astype(jnp.int32) - (1 << (bits - 1))


def _dequant_tile(wp: jax.Array, s: jax.Array, bits: int, group: int) -> jax.Array:
    """wp: (bk//epb, bn) uint8; s: (bk//g, bn) → (bk, bn) f32 (in VMEM)."""
    q = _unpack_tile(wp, bits)
    scale = jnp.repeat(s.astype(jnp.float32), group, axis=0)  # (bk, bn)
    return q.astype(jnp.float32) * scale


def _group_blocked_matmul(x: jax.Array, wp: jax.Array, s: jax.Array,
                          bits: int, group: int) -> jax.Array:
    """x: (bm, bk) × packed (bk//epb, bn) / scales (bk//g, bn) → (bm, bn)
    f32, computed as Σ_g scale_g · (x_g @ q_g): per-group partial dots in
    the input dtype with f32 accumulation, scales applied AFTER — the exact
    decomposition of ``ref.grouped_lo_gemm_jnp`` (bit-parity by
    construction on a given backend)."""
    epb = 8 // bits
    bk = wp.shape[0] * epb
    rpg = group // epb                     # packed rows per scale group
    acc = jnp.zeros((x.shape[0], wp.shape[1]), jnp.float32)
    for g in range(bk // group):
        q = _unpack_tile(wp[g * rpg:(g + 1) * rpg], bits)     # (group, bn)
        part = jnp.dot(x[:, g * group:(g + 1) * group],
                       q.astype(x.dtype),
                       preferred_element_type=jnp.float32)
        acc = acc + part * s[g][None, :].astype(jnp.float32)
    return acc


def _qmm_kernel(x_ref, wp_ref, s_ref, o_ref, acc_ref, *, bits, group, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _dequant_tile(wp_ref[...], s_ref[...], bits, group)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quant_matmul(x: jax.Array, packed: jax.Array, scales: jax.Array, *,
                 bits: int, group: int, bm: int = 128, bn: int = 128,
                 bk: int = 256, interpret: bool = False) -> jax.Array:
    """x: (M, K) bf16 × packed (K//epb, N) uint8 / scales (K//g, N) → (M, N)."""
    M, K = x.shape
    epb = 8 // bits
    N = packed.shape[1]
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    bk = max(group, bk // group * group)
    if M % bm or N % bn or K % bk:
        raise ValueError(f"shape ({M},{K})x({K},{N}) not tileable by "
                         f"({bm},{bn},{bk})")
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, bits=bits, group=group, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // epb, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pl.ArrayRef((bm, bn), jnp.float32)]
        if hasattr(pl, "ArrayRef") else
        [_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, packed, scales)


def _vmem_scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _gqmm_kernel(x_ref, wp_ref, s_ref, o_ref, acc_ref, *, bits, group, nk):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _group_blocked_matmul(x_ref[0], wp_ref[0], s_ref[0],
                                          bits, group)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_quant_matmul(xg: jax.Array, packed: jax.Array, scales: jax.Array,
                         *, bits: int, group: int, bm: int = 128,
                         bn: int = 128, bk: int = 256,
                         interpret: bool = False) -> jax.Array:
    """xg: (E, C, K) × packed (E, K//epb, N) → (E, C, N)."""
    E, C, K = xg.shape
    epb = 8 // bits
    N = packed.shape[2]
    bm, bn, bk = min(bm, C), min(bn, N), min(bk, K)
    bk = max(group, bk // group * group)
    if C % bm or N % bn or K % bk:
        raise ValueError(f"({E},{C},{K})x({K},{N}) not tileable by "
                         f"({bm},{bn},{bk})")
    nk = K // bk
    grid = (E, C // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_gqmm_kernel, bits=bits, group=group, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk // epb, bn), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, bk // group, bn), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, N), xg.dtype),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xg, packed, scales)


# ---------------------------------------------------------------------------
# Ragged mixed-precision expert FFN — the decode megakernel
# ---------------------------------------------------------------------------

def _fit_tile(n: int, pref: int) -> int:
    """Largest of ``pref`` / whole-dim that tiles ``n`` exactly."""
    return pref if n % pref == 0 else n


def _ragged_gateup_kernel(lo_ref, hi_ref, ih_ref, x_ref,
                          gp_ref, gs_ref, up_ref, us_ref, hg_ref, hu_ref,
                          h_ref, accg_ref, accu_ref,
                          *, bits, group, nk, has_hi):
    """Fused w_gate∥w_up GEMM + SiLU·mul epilogue for one (tile, n, k) grid
    step. Scalar-prefetched maps: ``lo_ref``/``hi_ref`` are the DMA hold
    maps (which lo expert / hi slot this tile's weight blocks come from —
    repeated indices on the unused tier and on tail tiles suppress
    refetches), ``ih_ref`` selects which tier actually computes."""
    t = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    x = x_ref[...]
    if has_hi:
        is_hi = ih_ref[t] > 0

        @pl.when(jnp.logical_not(is_hi))
        def _lo():
            accg_ref[...] += _group_blocked_matmul(x, gp_ref[0], gs_ref[0],
                                                   bits, group)
            accu_ref[...] += _group_blocked_matmul(x, up_ref[0], us_ref[0],
                                                   bits, group)

        @pl.when(is_hi)
        def _hi():
            accg_ref[...] += jnp.dot(x, hg_ref[0],
                                     preferred_element_type=jnp.float32)
            accu_ref[...] += jnp.dot(x, hu_ref[0],
                                     preferred_element_type=jnp.float32)
    else:
        accg_ref[...] += _group_blocked_matmul(x, gp_ref[0], gs_ref[0],
                                               bits, group)
        accu_ref[...] += _group_blocked_matmul(x, up_ref[0], us_ref[0],
                                               bits, group)

    @pl.when(k == nk - 1)
    def _done():
        # Epilogue in VMEM, matching the jnp contract of the padded path:
        # both accumulators round to the activation dtype, SiLU evaluates
        # in f32, and the product rounds once more.
        g16 = accg_ref[...].astype(h_ref.dtype)
        u16 = accu_ref[...].astype(h_ref.dtype)
        h_ref[...] = (jax.nn.silu(g16.astype(jnp.float32))
                      .astype(h_ref.dtype) * u16)


def _ragged_down_kernel(lo_ref, hi_ref, ih_ref, h_ref,
                        dp_ref, ds_ref, hd_ref,
                        y_ref, acc_ref, *, bits, group, nk, has_hi):
    t = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[...]
    if has_hi:
        is_hi = ih_ref[t] > 0

        @pl.when(jnp.logical_not(is_hi))
        def _lo():
            acc_ref[...] += _group_blocked_matmul(h, dp_ref[0], ds_ref[0],
                                                  bits, group)

        @pl.when(is_hi)
        def _hi():
            acc_ref[...] += jnp.dot(h, hd_ref[0],
                                    preferred_element_type=jnp.float32)
    else:
        acc_ref[...] += _group_blocked_matmul(h, dp_ref[0], ds_ref[0],
                                              bits, group)

    @pl.when(k == nk - 1)
    def _done():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def _prefetch_grid_spec(num_scalar_prefetch, grid, in_specs, out_specs,
                        scratch_shapes):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar_prefetch, grid=grid,
        in_specs=in_specs, out_specs=out_specs,
        scratch_shapes=scratch_shapes)


def ragged_quant_ffn(xs: jax.Array, tile_lo: jax.Array, tile_hi: jax.Array,
                     tile_is_hi: jax.Array,
                     gate_packed, gate_scales, up_packed, up_scales,
                     down_packed, down_scales,
                     hi_gate=None, hi_up=None, hi_down=None, *,
                     bits: int, group: int, bm: int,
                     bn: int = 128, bk: int = 256,
                     interpret: bool = False) -> jax.Array:
    """One fused mixed-precision SwiGLU FFN over the ragged token layout.

    ``xs``: (R = Tt·bm, K) compacted activations — tokens sorted by expert,
    per-expert segments padded up to the row tile ``bm`` (the ONLY padding
    in the ragged path). ``tile_lo``/``tile_hi``: (Tt,) int32 DMA hold maps
    (lo expert id / hi slot id to stream for each row tile; the unused
    tier's index repeats the previous tile so no fresh block is fetched).
    ``tile_is_hi``: (Tt,) int32 — 1 where the tile computes with its hi
    slot. Lo weights: packed (E, K//epb, F) / scales (E, K//g, F) per
    matrix; hi weights: (n_hi, K, F) bf16 (``None`` ⇒ an all-lo bank, e.g.
    the static-PTQ backend or the speculative draft tier — the kernel then
    compiles without hi operands at all).

    Returns y (R, D). Rows of tail/padding tiles hold garbage — callers
    gather only real assignment rows back out (``moe._dispatch_ragged``)."""
    R, K = xs.shape
    Tt = tile_lo.shape[0]
    if R != Tt * bm:
        raise ValueError(f"xs rows {R} != tiles {Tt} × bm {bm}")
    epb = 8 // bits
    F = gate_packed.shape[-1]
    D = down_packed.shape[-1]
    has_hi = hi_gate is not None and hi_gate.shape[0] > 0
    bn_f = _fit_tile(F, bn)
    bn_d = _fit_tile(D, bn)
    bk_k = _fit_tile(K, max(group, min(bk, K) // group * group))
    bk_f = _fit_tile(F, max(group, min(bk, F) // group * group))
    if K % bk_k or K % group or F % bn_f or F % bk_f or F % group or D % bn_d:
        raise ValueError(f"(K={K}, F={F}, D={D}) not tileable by "
                         f"(bk={bk_k}/{bk_f}, bn={bn_f}/{bn_d}, g={group})")
    nk1 = K // bk_k
    nk2 = F // bk_f
    if not has_hi:
        # Zero-size placeholders keep one call signature; the kernel is
        # compiled without hi refs (static ``has_hi``), so nothing streams.
        hi_gate = jnp.zeros((1, K, F), xs.dtype)
        hi_up = jnp.zeros((1, K, F), xs.dtype)
        hi_down = jnp.zeros((1, F, D), xs.dtype)

    gu_specs = [
        pl.BlockSpec((bm, bk_k), lambda t, j, k, lo, hi, ih: (t, k)),
        pl.BlockSpec((1, bk_k // epb, bn_f),
                     lambda t, j, k, lo, hi, ih: (lo[t], k, j)),
        pl.BlockSpec((1, bk_k // group, bn_f),
                     lambda t, j, k, lo, hi, ih: (lo[t], k, j)),
        pl.BlockSpec((1, bk_k // epb, bn_f),
                     lambda t, j, k, lo, hi, ih: (lo[t], k, j)),
        pl.BlockSpec((1, bk_k // group, bn_f),
                     lambda t, j, k, lo, hi, ih: (lo[t], k, j)),
        pl.BlockSpec((1, bk_k, bn_f),
                     lambda t, j, k, lo, hi, ih: (hi[t], k, j)),
        pl.BlockSpec((1, bk_k, bn_f),
                     lambda t, j, k, lo, hi, ih: (hi[t], k, j)),
    ]
    h = pl.pallas_call(
        functools.partial(_ragged_gateup_kernel, bits=bits, group=group,
                          nk=nk1, has_hi=has_hi),
        grid_spec=_prefetch_grid_spec(
            3, (Tt, F // bn_f, nk1), gu_specs,
            pl.BlockSpec((bm, bn_f), lambda t, j, k, lo, hi, ih: (t, j)),
            [_vmem_scratch((bm, bn_f), jnp.float32),
             _vmem_scratch((bm, bn_f), jnp.float32)]),
        out_shape=jax.ShapeDtypeStruct((R, F), xs.dtype),
        interpret=interpret,
    )(tile_lo, tile_hi, tile_is_hi, xs, gate_packed, gate_scales,
      up_packed, up_scales, hi_gate, hi_up)

    dn_specs = [
        pl.BlockSpec((bm, bk_f), lambda t, j, k, lo, hi, ih: (t, k)),
        pl.BlockSpec((1, bk_f // epb, bn_d),
                     lambda t, j, k, lo, hi, ih: (lo[t], k, j)),
        pl.BlockSpec((1, bk_f // group, bn_d),
                     lambda t, j, k, lo, hi, ih: (lo[t], k, j)),
        pl.BlockSpec((1, bk_f, bn_d),
                     lambda t, j, k, lo, hi, ih: (hi[t], k, j)),
    ]
    return pl.pallas_call(
        functools.partial(_ragged_down_kernel, bits=bits, group=group,
                          nk=nk2, has_hi=has_hi),
        grid_spec=_prefetch_grid_spec(
            3, (Tt, D // bn_d, nk2), dn_specs,
            pl.BlockSpec((bm, bn_d), lambda t, j, k, lo, hi, ih: (t, j)),
            [_vmem_scratch((bm, bn_d), jnp.float32)]),
        out_shape=jax.ShapeDtypeStruct((R, D), xs.dtype),
        interpret=interpret,
    )(tile_lo, tile_hi, tile_is_hi, h, down_packed, down_scales, hi_down)
